//! Standalone-layer scaling study (paper Figs 2-3): run every layer artifact,
//! print the measured CPU series next to the analytic A6000 model, and flag
//! the linear-vs-quadratic scaling slopes + the FlashAttention crossover.
//!
//!     cargo run --release --example layer_bench [-- fwd|bwd]

use anyhow::Result;
use repro::bench::{report as rpt, SweepRunner};
use repro::runtime::Engine;

fn slope_loglog(points: &[(usize, f64)]) -> f64 {
    // least-squares slope of log t vs log N
    let logs: Vec<(f64, f64)> = points
        .iter()
        .map(|&(n, t)| ((n as f64).ln(), t.ln()))
        .collect();
    let n = logs.len() as f64;
    let sx: f64 = logs.iter().map(|p| p.0).sum();
    let sy: f64 = logs.iter().map(|p| p.1).sum();
    let sxx: f64 = logs.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = logs.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

fn main() -> Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "fwd".into());
    let kind = match which.as_str() {
        "bwd" => "layer_fwdbwd",
        _ => "layer_fwd",
    };

    let engine = Engine::discover()?;
    let runner = SweepRunner::new(&engine);

    let impls = ["ours", "ours_scan", "gated", "quadratic", "specdec", "flash", "softmax"];
    let mut all = Vec::new();
    for imp in impls {
        eprintln!("sweeping {kind}/{imp} …");
        let pts = runner.run_series(kind, imp)?;
        if pts.is_empty() {
            continue;
        }
        // N-scaling slope at fixed D=128 (paper's top panels)
        let series: Vec<(usize, f64)> = pts
            .iter()
            .filter(|p| p.d == 128)
            .map(|p| (p.n, p.cpu_s.p50))
            .collect();
        if series.len() >= 3 {
            println!(
                "{imp:10} N-scaling slope (log-log): {:.2}  ({} points)",
                slope_loglog(&series),
                series.len()
            );
        }
        all.extend(pts);
    }

    println!("\n{}", rpt::sweep_markdown(&format!("{kind} sweep"), &all));

    // crossover vs FlashAttention (paper §5.1: ours wins for N > ~3000)
    let ours: Vec<_> = all
        .iter()
        .filter(|p| p.impl_name == "ours" && p.d == 128)
        .collect();
    let flash: Vec<_> = all
        .iter()
        .filter(|p| p.impl_name == "flash" && p.d == 128)
        .collect();
    for o in &ours {
        if let Some(f) = flash.iter().find(|f| f.n == o.n) {
            println!(
                "N={:6}  ours {}  flash {}  → {}",
                o.n,
                rpt::fmt_time(o.cpu_s.p50),
                rpt::fmt_time(f.cpu_s.p50),
                if o.cpu_s.p50 < f.cpu_s.p50 { "ours wins" } else { "flash wins" }
            );
        }
    }
    Ok(())
}
