//! Table-2 analog: train a small LM briefly with several attention variants
//! and score each on the synthetic reasoning suite (associative recall,
//! induction, copy, reverse, modular arithmetic).
//!
//!     cargo run --release --example recall_tasks -- [--steps 40] [--count 32]
//!
//! The claim under test is *relative*: our LA should score in the same band
//! as softmax attention (paper Table 2), not that either is good in absolute
//! terms at this scale.

use anyhow::Result;
use repro::coordinator::config::{DataSection, OutputSection, TrainSection};
use repro::coordinator::{Checkpoint, RunConfig, Trainer};
use repro::runtime::Engine;
use repro::tasks::{score_task, TaskKind};
use repro::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 40)?;
    let count = args.get_usize("count", 32)?;
    let preset = args.get_or("preset", "tiny").to_string();

    let engine = Engine::discover()?;
    let attns = ["ours", "softmax", "gated"];

    // train each variant, keep task accuracies
    let mut scored: Vec<(String, Vec<f64>)> = Vec::new();
    for attn in attns {
        let cfg = RunConfig {
            train: TrainSection {
                preset: preset.clone(),
                attn: attn.to_string(),
                steps,
                eval_every: 0,
                ckpt_every: 0,
                seed: 0,
            },
            data: DataSection::default(),
            output: OutputSection { dir: "runs/tasks".into() },
        };
        let trainer = Trainer::new(&engine, cfg.clone())?;
        eprintln!("training {attn} for {steps} steps …");
        let outcome = trainer.run()?;
        eprintln!("  final loss {:.4}", outcome.final_loss);

        let ckpt = Checkpoint::load(outcome.run_dir.join("final.ckpt"))?;
        let logits = format!("{}_logits", cfg.artifact_tag());
        let mut accs = Vec::new();
        for kind in TaskKind::all() {
            let s = score_task(&engine, &logits, &ckpt.state, kind, count, 0)?;
            accs.push(s.accuracy());
        }
        scored.push((attn.to_string(), accs));
    }

    println!("| task | {} |", attns.join(" | "));
    println!(
        "|---|{}|",
        attns.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
    for (ti, kind) in TaskKind::all().iter().enumerate() {
        let row: Vec<String> = scored
            .iter()
            .map(|(_, accs)| format!("{:.1}%", accs[ti] * 100.0))
            .collect();
        println!("| {} | {} |", kind.name(), row.join(" | "));
    }
    Ok(())
}
