//! Quickstart: load the linear-attention kernel, run a forward and a
//! forward+backward pass, and verify against the quadratic oracle artifact —
//! the whole stack in ~60 lines. Runs hermetically on the native backend:
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use repro::bench::report::fmt_time;
use repro::runtime::{Engine, Tensor};

fn main() -> Result<()> {
    let engine = Engine::discover()?;
    println!("platform: {}", engine.platform());

    // quickstart artifacts are fixed at BH=4, N=256, D=64 (see aot.py)
    let fwd = engine.load("quickstart_la_fwd")?;
    let bwd = engine.load("quickstart_la_bwd")?;
    let oracle = engine.load("quickstart_la_ref")?;

    let shape = fwd.meta.inputs[0].shape.clone();
    let mut q = Tensor::randn(shape.clone(), 1);
    let mut k = Tensor::randn(shape.clone(), 2);
    let v = Tensor::randn(shape.clone(), 3);
    q.normalize_rows(); // paper §3.3
    k.normalize_rows();

    // --- forward: chunkwise kernel vs direct Eq. 4 oracle ------------------
    let o_kernel = &fwd.run(&[q.clone(), k.clone(), v.clone()])?[0];
    let o_ref = &oracle.run(&[q.clone(), k.clone(), v.clone()])?[0];
    let max_err = o_kernel
        .as_f32()?
        .iter()
        .zip(o_ref.as_f32()?)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("forward  max |kernel − oracle| = {max_err:.2e}");
    assert!(max_err < 1e-4, "kernel disagrees with oracle");

    // --- backward: analytical gradients (Eq. 16-21) ------------------------
    let grad_o = Tensor::randn(shape.clone(), 4);
    let grads = bwd.run(&[q.clone(), k.clone(), v.clone(), grad_o])?;
    println!(
        "backward outputs: dQ {:?}, dK {:?}, dV {:?}",
        grads[0].shape(),
        grads[1].shape(),
        grads[2].shape()
    );
    for (name, g) in ["dQ", "dK", "dV"].iter().zip(&grads) {
        let finite = g.as_f32()?.iter().all(|x| x.is_finite());
        assert!(finite, "{name} has non-finite entries");
    }

    // --- quick timing -------------------------------------------------------
    let stats = repro::bench::measure(2, 10, || Ok(fwd.run_timed(&[&q, &k, &v])?.1))?;
    println!(
        "forward kernel (BH=4, N=256, D=64): p50 {} (p95 {})",
        fmt_time(stats.p50),
        fmt_time(stats.p95)
    );
    println!("quickstart OK");
    Ok(())
}
