//! End-to-end driver (Fig 5): train the LM with our linear attention, the
//! gated-LA baseline, and regular softmax attention on the synthetic corpus,
//! logging all three loss curves — the full three-layer stack exercised on a
//! real training workload.
//!
//!     cargo run --release --example train_lm -- \
//!         [--preset tiny] [--steps 60] [--attns ours,gated,softmax]
//!
//! Metrics land in runs/<tag>/metrics.{jsonl,csv}; compare with
//! `repro report --runs runs`.

use anyhow::Result;
use repro::coordinator::config::{DataSection, OutputSection, TrainSection};
use repro::coordinator::{RunConfig, Trainer};
use repro::runtime::Engine;
use repro::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let preset = args.get_or("preset", "tiny").to_string();
    let steps = args.get_usize("steps", 60)?;
    let attns: Vec<String> = args
        .get_or("attns", "ours,gated,softmax")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let out = args.get_or("out", "runs").to_string();

    let engine = Engine::discover()?;
    println!("| attn | steps | final loss | tok/s | wall (s) |");
    println!("|---|---|---|---|---|");
    for attn in &attns {
        let cfg = RunConfig {
            train: TrainSection {
                preset: preset.clone(),
                attn: attn.clone(),
                steps,
                eval_every: (steps / 4).max(1),
                ckpt_every: 0,
                seed: 0,
            },
            data: DataSection::default(),
            output: OutputSection { dir: out.clone() },
        };
        let trainer = Trainer::new(&engine, cfg)?;
        eprintln!(
            "training attn={attn} (vocab {}, batch {}, ctx {})",
            trainer.vocab_size(),
            trainer.batch_size(),
            trainer.seq_len()
        );
        let o = trainer.run()?;
        println!(
            "| {attn} | {} | {:.4} | {:.0} | {:.1} |",
            o.steps, o.final_loss, o.tokens_per_s, o.wall_s
        );
    }
    println!("\nloss curves: runs/lm_<preset>_<attn>/metrics.csv (step,wall_s,loss,…)");
    Ok(())
}
