"""Backward-pass correctness: analytical gradients (Eq. 16-21) vs autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.linear_attention import (
    LAParams, default_chunk, la_bwd, la_fwd_with_denom, linear_attention)
from compile.kernels.ref import ref_la, ref_la_grads

from .conftest import make_qkv

ATOL = 5e-5
RTOL = 5e-5


def _grads_kernel(q, k, v, grad_o, params=LAParams(), chunk=None):
    o, g = la_fwd_with_denom(q, k, v, params, chunk)
    return la_bwd(q, k, v, o, g, grad_o, params, chunk)


@pytest.mark.parametrize("bh,n,d,chunk", [
    (1, 8, 4, 4),
    (2, 32, 8, 8),
    (3, 64, 16, 16),
    (2, 128, 32, 32),
    (1, 64, 16, 64),   # single chunk
])
def test_bwd_matches_autodiff(rng, bh, n, d, chunk):
    key = jax.random.fold_in(rng, n * d)
    q, k, v = make_qkv(key, bh, n, d)
    grad_o = jax.random.normal(jax.random.fold_in(key, 1), (bh, n, d))
    dq, dk, dv = _grads_kernel(q, k, v, grad_o, chunk=chunk)
    rq, rk, rv = ref_la_grads(q, k, v, grad_o)
    np.testing.assert_allclose(dq, rq, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(dk, rk, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(dv, rv, atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("a,b", [(1.0, 1.0), (0.5, 2.0), (2.0, 0.25)])
def test_bwd_kernel_coefficients(rng, a, b):
    key = jax.random.fold_in(rng, 11)
    q, k, v = make_qkv(key, 2, 64, 16)
    grad_o = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 16))
    dq, dk, dv = _grads_kernel(q, k, v, grad_o, LAParams(a, b), chunk=16)
    rq, rk, rv = ref_la_grads(q, k, v, grad_o, a, b)
    np.testing.assert_allclose(dq, rq, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(dk, rk, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(dv, rv, atol=ATOL, rtol=RTOL)


def test_bwd_chunk_invariance(rng):
    key = jax.random.fold_in(rng, 13)
    q, k, v = make_qkv(key, 2, 128, 16)
    grad_o = jax.random.normal(jax.random.fold_in(key, 3), (2, 128, 16))
    ref = _grads_kernel(q, k, v, grad_o, chunk=8)
    for c in (16, 32, 64, 128):
        got = _grads_kernel(q, k, v, grad_o, chunk=c)
        for g1, g2 in zip(got, ref):
            np.testing.assert_allclose(g1, g2, atol=ATOL, rtol=RTOL)


def test_custom_vjp_grad_path(rng):
    """jax.grad through linear_attention must hit the analytical kernels and
    agree with jax.grad through the direct oracle."""
    key = jax.random.fold_in(rng, 17)
    q, k, v = make_qkv(key, 2, 64, 16)
    w = jax.random.normal(jax.random.fold_in(key, 4), (2, 64, 16))

    loss_kernel = lambda q_, k_, v_: jnp.sum(
        linear_attention(q_, k_, v_, LAParams(), 16) * w)
    loss_ref = lambda q_, k_, v_: jnp.sum(ref_la(q_, k_, v_) * w)
    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a_, b_ in zip(gk, gr):
        np.testing.assert_allclose(a_, b_, atol=ATOL, rtol=RTOL)


def test_bwd_value_and_grad_jit(rng):
    """The custom-vjp composes under jit (the L2 train step relies on this)."""
    key = jax.random.fold_in(rng, 19)
    q, k, v = make_qkv(key, 1, 32, 8)

    @jax.jit
    def f(q_, k_, v_):
        return jnp.sum(linear_attention(q_, k_, v_, LAParams(), 8) ** 2)

    val, grads = jax.value_and_grad(f, argnums=(0, 1, 2))(q, k, v)
    assert jnp.isfinite(val)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))


def test_bwd_zero_upstream_gives_zero(rng):
    q, k, v = make_qkv(jax.random.fold_in(rng, 23), 1, 32, 8)
    dq, dk, dv = _grads_kernel(q, k, v, jnp.zeros((1, 32, 8)), chunk=8)
    for g in (dq, dk, dv):
        np.testing.assert_allclose(g, jnp.zeros_like(g), atol=1e-7)


def test_bwd_dv_rows_are_convex_weights(rng):
    """∇V row p = Σ_{i≥p} a_ip Ω̂ ... with Ω = 1 upstream and one output row j,
    the v-gradient must be non-negative (attention weights are positive for
    normalized inputs)."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 29), 1, 32, 8)
    grad_o = jnp.ones((1, 32, 8))
    _, _, dv = _grads_kernel(q, k, v, grad_o, chunk=8)
    assert float(jnp.min(dv)) > -1e-6


def test_bwd_causality(rng):
    """∇V for token p only depends on tokens i ≥ p: perturbing the *past*
    upstream gradient rows must not change later-v grads' dependence...
    concretely, zeroing Ω rows < p zeroes nothing of dv[p:] contributions from
    those rows beyond what Eq. 18 allows."""
    key = jax.random.fold_in(rng, 31)
    q, k, v = make_qkv(key, 1, 64, 16)
    grad_o = jax.random.normal(jax.random.fold_in(key, 5), (1, 64, 16))
    # dk,dv at position p are sums over i >= p; changing grad_o[:p] must leave
    # the i >= p terms intact only if we also keep rows >= p — check via oracle
    dq1, dk1, dv1 = _grads_kernel(q, k, v, grad_o, chunk=16)
    grad_o2 = grad_o.at[:, :32].set(0.0)
    dq2, dk2, dv2 = _grads_kernel(q, k, v, grad_o2, chunk=16)
    # dv for p >= 32 depends only on Ω rows i >= p >= 32 → unchanged
    np.testing.assert_allclose(dv1[:, 32:], dv2[:, 32:], atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(dk1[:, 32:], dk2[:, 32:], atol=ATOL, rtol=RTOL)
    # dq for i < 32 has Ω̂_i = 0 → exactly zero
    np.testing.assert_allclose(dq2[:, :32], jnp.zeros_like(dq2[:, :32]),
                               atol=1e-7)


@settings(max_examples=12, deadline=None)
@given(
    bh=st.integers(1, 2),
    logn=st.integers(3, 6),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_hypothesis_shape_sweep(bh, logn, d, seed):
    n = 2 ** logn
    key = jax.random.PRNGKey(seed)
    q, k, v = make_qkv(key, bh, n, d)
    grad_o = jax.random.normal(jax.random.fold_in(key, 1), (bh, n, d))
    chunk = default_chunk(n, preferred=min(16, n))
    got = _grads_kernel(q, k, v, grad_o, chunk=chunk)
    want = ref_la_grads(q, k, v, grad_o)
    for g1, g2 in zip(got, want):
        np.testing.assert_allclose(g1, g2, atol=1e-4, rtol=1e-4)
