"""Correctness of the comparator implementations (paper §5 baselines)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.baselines import (
    flash_softmax, gated_la_chunkwise, gated_la_recurrent, quadratic_la,
    softmax_attention, spec_dec_la)
from compile.kernels.ref import ref_la, ref_softmax

from .conftest import make_qkv


def test_quadratic_la_is_oracle(rng):
    q, k, v = make_qkv(rng, 2, 64, 16)
    np.testing.assert_allclose(quadratic_la(q, k, v), ref_la(q, k, v),
                               atol=1e-6, rtol=1e-6)


def test_softmax_attention_is_oracle(rng):
    q, k, v = make_qkv(jax.random.fold_in(rng, 1), 2, 64, 16,
                       normalized=False)
    np.testing.assert_allclose(softmax_attention(q, k, v),
                               ref_softmax(q, k, v), atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("chunk", [16, 32, 64, 96])
def test_flash_softmax_matches_direct(rng, chunk):
    """Online-softmax streaming must be exact (up to fp) for any chunking."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 2), 2, 96, 16,
                       normalized=False)
    np.testing.assert_allclose(flash_softmax(q, k, v, chunk=chunk),
                               ref_softmax(q, k, v), atol=2e-5, rtol=2e-5)


def test_flash_softmax_first_row(rng):
    """Row 0 attends only to itself → output is exactly v_0."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 3), 1, 64, 8,
                       normalized=False)
    o = flash_softmax(q, k, v, chunk=16)
    np.testing.assert_allclose(o[:, 0], v[:, 0], atol=1e-5)


def test_spec_dec_la_linear_kernel(rng):
    """f(x)=b·x: equals the a=0 direct form where the denominator is safe."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 4), 2, 64, 16)
    got = spec_dec_la(q, k, v)
    scores = jnp.einsum("bnd,bmd->bnm", q, k) * jnp.tril(
        jnp.ones((64, 64), jnp.float32))
    g = jnp.sum(scores, axis=-1, keepdims=True)
    safe = jnp.abs(g[..., 0]) >= 1e-6
    want = jnp.einsum("bnm,bmd->bnd", scores, v) / g
    np.testing.assert_allclose(got[safe], want[safe], atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_gla_chunkwise_matches_recurrent(rng, chunk):
    q, k, v = make_qkv(jax.random.fold_in(rng, 5), 2, 64, 16)
    np.testing.assert_allclose(gated_la_chunkwise(q, k, v, chunk=chunk),
                               gated_la_recurrent(q, k, v),
                               atol=5e-4, rtol=5e-4)


def test_gla_gamma_one_is_unnormalized_la(rng):
    """With γ = 1 the gate never forgets → plain (unnormalized) linear attn."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 6), 1, 32, 8)
    gamma = jnp.ones((8,), jnp.float32)
    got = gated_la_chunkwise(q, k, v, gamma=gamma, chunk=8)
    scores = jnp.einsum("bnd,bmd->bnm", q, k) * jnp.tril(
        jnp.ones((32, 32), jnp.float32))
    want = jnp.einsum("bnm,bmd->bnd", scores, v)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_gla_decay_forgets(rng):
    """With strong decay, early tokens must stop influencing late outputs."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 7), 1, 128, 8)
    gamma = jnp.full((8,), 0.5, jnp.float32)
    o1 = gated_la_recurrent(q, k, v, gamma=gamma)
    v2 = v.at[:, 0].set(v[:, 0] + 100.0)
    o2 = gated_la_recurrent(q, k, v2, gamma=gamma)
    # influence of token 0 on token 127 decayed by 0.5^127 ≈ 0
    assert float(jnp.max(jnp.abs(o1[:, -1] - o2[:, -1]))) < 1e-3
    assert float(jnp.max(jnp.abs(o1[:, 1] - o2[:, 1]))) > 1.0


def test_all_baselines_causal(rng):
    """No baseline may leak future tokens into past outputs."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 8), 1, 64, 16)
    half = 32
    impls = [
        lambda q_, k_, v_: quadratic_la(q_, k_, v_),
        lambda q_, k_, v_: softmax_attention(q_, k_, v_),
        lambda q_, k_, v_: flash_softmax(q_, k_, v_, chunk=16),
        lambda q_, k_, v_: gated_la_chunkwise(q_, k_, v_, chunk=16),
        lambda q_, k_, v_: spec_dec_la(q_, k_, v_),
    ]
    v2 = v.at[:, half:].set(v[:, half:] * -2.0 + 1.0)
    for impl in impls:
        o1 = impl(q, k, v)
        o2 = impl(q, k, v2)
        np.testing.assert_allclose(o1[:, :half], o2[:, :half],
                                   atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(3, 7), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_flash_hypothesis(logn, d, seed):
    n = 2 ** logn
    q, k, v = make_qkv(jax.random.PRNGKey(seed), 1, n, d, normalized=False)
    np.testing.assert_allclose(flash_softmax(q, k, v, chunk=min(32, n)),
                               ref_softmax(q, k, v), atol=5e-5, rtol=5e-5)
