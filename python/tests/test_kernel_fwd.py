"""Forward-pass correctness: Pallas kernel vs the direct Eq. 4 oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.linear_attention import (
    LAParams, default_chunk, la_fwd, la_fwd_with_denom, normalize_qk)
from compile.kernels.ref import ref_la, ref_la_with_denom

from .conftest import make_qkv

ATOL = 2e-5
RTOL = 2e-5


@pytest.mark.parametrize("bh,n,d,chunk", [
    (1, 8, 4, 4),
    (2, 32, 8, 8),
    (3, 64, 16, 16),
    (4, 128, 32, 64),
    (1, 128, 64, 128),   # single chunk == full sequence
    (2, 96, 16, 32),     # non-power-of-two N
])
def test_fwd_matches_oracle(rng, bh, n, d, chunk):
    q, k, v = make_qkv(rng, bh, n, d)
    o, g = la_fwd_with_denom(q, k, v, LAParams(), chunk=chunk)
    o_ref, g_ref = ref_la_with_denom(q, k, v)
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(g, g_ref, atol=1e-3, rtol=RTOL)


@pytest.mark.parametrize("a,b", [(1.0, 1.0), (0.5, 2.0), (2.0, 0.25), (1.0, 0.0)])
def test_fwd_kernel_coefficients(rng, a, b):
    """f(x) = a + b·x for several (a, b) — incl. b=0 (pure averaging)."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 7), 2, 64, 16)
    o = la_fwd(q, k, v, LAParams(a, b), chunk=16)
    o_ref = ref_la(q, k, v, a, b)
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)


def test_fwd_chunk_invariance(rng):
    """The chunk length is an implementation detail — output must not move."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 1), 2, 128, 16)
    outs = [la_fwd(q, k, v, chunk=c) for c in (8, 16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=ATOL, rtol=RTOL)


def test_fwd_first_token_is_v0(rng):
    """Causality base case: o_0 = f(q_0·k_0)v_0 / f(q_0·k_0) = v_0."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 2), 2, 32, 8)
    o = la_fwd(q, k, v, chunk=8)
    np.testing.assert_allclose(o[:, 0], v[:, 0], atol=ATOL, rtol=RTOL)


def test_fwd_causality(rng):
    """Perturbing future tokens must not change past outputs."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 3), 1, 64, 16)
    o1 = la_fwd(q, k, v, chunk=16)
    k2 = k.at[:, 40:].set(-k[:, 40:])
    v2 = v.at[:, 40:].set(v[:, 40:] * 3.0 + 1.0)
    o2 = la_fwd(q, k2, v2, chunk=16)
    np.testing.assert_allclose(o1[:, :40], o2[:, :40], atol=ATOL, rtol=RTOL)
    assert float(jnp.max(jnp.abs(o1[:, 40:] - o2[:, 40:]))) > 1e-3


def test_fwd_constant_value_passthrough(rng):
    """If every v_n = c, the convex combination returns exactly c."""
    q, k, _ = make_qkv(jax.random.fold_in(rng, 4), 2, 64, 16)
    v = jnp.ones((2, 64, 16), jnp.float32) * 2.5
    o = la_fwd(q, k, v, chunk=16)
    np.testing.assert_allclose(o, v, atol=ATOL, rtol=RTOL)


def test_fwd_batch_independence(rng):
    """Rows of the flattened batch·head axis must not interact — the scratch
    reset at chunk 0 is what guarantees this."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 5), 4, 64, 16)
    o_full = la_fwd(q, k, v, chunk=16)
    o_single = la_fwd(q[2:3], k[2:3], v[2:3], chunk=16)
    np.testing.assert_allclose(o_full[2:3], o_single, atol=ATOL, rtol=RTOL)


def test_fwd_denominator_positive_when_normalized(rng):
    """§3.3: with row-normalized q,k and f(x)=1+x, g_i ≥ 0 and grows with i."""
    q, k, v = make_qkv(jax.random.fold_in(rng, 6), 2, 128, 32)
    _, g = la_fwd_with_denom(q, k, v, chunk=32)
    assert float(jnp.min(g)) > 0.0
    # g_i ≈ i + Σ q·k; must grow roughly linearly
    assert float(jnp.min(g[:, -1] - g[:, 0])) > 0.0


def test_default_chunk_divides():
    for n in (8, 96, 100, 1000, 4096, 3 * 7 * 11):
        c = default_chunk(n)
        assert n % c == 0 and 1 <= c <= 128


def test_normalize_qk_unit_rows(rng):
    q = jax.random.normal(rng, (2, 32, 16), jnp.float32) * 10.0
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 16)) * 0.1
    qn, kn = normalize_qk(q, k)
    np.testing.assert_allclose(jnp.linalg.norm(qn, axis=-1),
                               jnp.ones((2, 32)), atol=1e-4)
    np.testing.assert_allclose(jnp.linalg.norm(kn, axis=-1),
                               jnp.ones((2, 32)), atol=1e-3)


def test_fwd_rejects_bad_chunk(rng):
    q, k, v = make_qkv(rng, 1, 64, 8)
    with pytest.raises(ValueError):
        la_fwd(q, k, v, chunk=48)


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 3),
    logn=st.integers(3, 7),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwd_hypothesis_shape_sweep(bh, logn, d, seed):
    """Property sweep over (BH, N, D, chunk): kernel == oracle everywhere."""
    n = 2 ** logn
    q, k, v = make_qkv(jax.random.PRNGKey(seed), bh, n, d)
    chunk = default_chunk(n, preferred=min(32, n))
    o = la_fwd(q, k, v, chunk=chunk)
    o_ref = ref_la(q, k, v)
    np.testing.assert_allclose(o, o_ref, atol=5e-5, rtol=5e-5)


def test_scan_form_matches_kernel(rng):
    """la_fwd_scan (ablation: same algorithm as lax.scan) == pallas kernel."""
    from compile.kernels.linear_attention import la_fwd_scan
    q, k, v = make_qkv(jax.random.fold_in(rng, 77), 2, 128, 16)
    a = la_fwd(q, k, v, chunk=32)
    b = la_fwd_scan(q, k, v, chunk=32)
    np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)
    # and chunk-invariant like the kernel
    c = la_fwd_scan(q, k, v, chunk=64)
    np.testing.assert_allclose(b, c, atol=ATOL, rtol=RTOL)
