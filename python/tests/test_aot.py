"""AOT builder: manifest correctness, caching, HLO round-trip via jax runtime."""

import json
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs
from compile.aot import (Artifact, _artifact_hash, _count_entry_params,
                         _source_hash, build, to_hlo_text)


def small_inventory():
    s = jax.ShapeDtypeStruct((2, 3), jnp.float32)
    return [Artifact("t_add", lambda a, b: (a + b,), [s, s],
                     {"kind": "layer_fwd", "impl": "ours", "bh": 1, "n": 2,
                      "d": 3, "chunk": 1})]


def test_build_writes_manifest_and_hlo(tmp_path, monkeypatch):
    monkeypatch.setattr(aot, "inventory", lambda preset: small_inventory())
    m = build(tmp_path, "min", verbose=False)
    assert (tmp_path / "t_add.hlo.txt").exists()
    mj = json.loads((tmp_path / "manifest.json").read_text())
    art = mj["artifacts"]["t_add"]
    assert art["inputs"][0]["shape"] == [2, 3]
    assert art["outputs"][0]["dtype"] == "f32"
    assert art["kind"] == "layer_fwd"


def test_cache_skips_rebuild(tmp_path, monkeypatch):
    monkeypatch.setattr(aot, "inventory", lambda preset: small_inventory())
    build(tmp_path, "min", verbose=False)
    t0 = (tmp_path / "t_add.hlo.txt").stat().st_mtime_ns
    build(tmp_path, "min", verbose=False)
    assert (tmp_path / "t_add.hlo.txt").stat().st_mtime_ns == t0


def test_artifact_hash_changes_with_meta():
    s = jax.ShapeDtypeStruct((2,), jnp.float32)
    a1 = Artifact("x", lambda a: (a,), [s], {"kind": "k", "n": 1})
    a2 = Artifact("x", lambda a: (a,), [s], {"kind": "k", "n": 2})
    src = _source_hash()
    assert _artifact_hash(src, a1) != _artifact_hash(src, a2)


def test_entry_param_counter():
    s = jax.ShapeDtypeStruct((4,), jnp.float32)
    lowered = jax.jit(lambda a, b: (a * b,)).lower(s, s)
    text = to_hlo_text(lowered)
    assert _count_entry_params(text) == 2


def test_default_inventory_covers_every_kind():
    arts = aot.inventory("default")
    kinds = {a.meta["kind"] for a in arts}
    assert {"layer_fwd", "layer_fwdbwd", "lm_init", "lm_train_step",
            "lm_eval", "lm_logits"} <= kinds
    names = [a.name for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    # quadratic-memory impls must respect the N cap
    for a in arts:
        if a.meta.get("impl") in ("quadratic", "specdec", "softmax"):
            assert a.meta["n"] <= configs.QUAD_N_CAP


def test_layer_artifact_inventory_shapes():
    arts = [a for a in aot.layer_artifacts() if a.meta["kind"] == "layer_fwd"]
    for a in arts:
        bh, n, d = a.meta["bh"], a.meta["n"], a.meta["d"]
        assert [list(x.shape) for x in a.args] == [[bh, n, d]] * 3


def test_lowered_artifact_reexecutes_correctly():
    """Round-trip sanity inside the jax runtime: lowering the quickstart LA
    artifact and comparing against direct kernel execution."""
    from compile.kernels.linear_attention import la_fwd, LAParams, normalize_qk
    bh, n, d = 2, 64, 16
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(kk, (bh, n, d), jnp.float32)
               for kk in jax.random.split(key, 3))
    q, k = normalize_qk(q, k)
    fn = lambda q_, k_, v_: (la_fwd(q_, k_, v_, LAParams(), 16),)
    compiled = jax.jit(fn).lower(q, k, v).compile()
    out = compiled(q, k, v)[0]
    ref = fn(q, k, v)[0]
    np.testing.assert_allclose(out, ref, atol=1e-6)
