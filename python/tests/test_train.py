"""Train-step semantics: AdamW updates, schedule, loss descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, param_specs
from compile.train import (TrainConfig, eval_loss, init_state, lr_at_step,
                           state_specs, train_step)

CFG = ModelConfig(vocab_size=64, d_model=32, n_heads=2, n_layers=1,
                  n_ctx=32, chunk=8)
TC = TrainConfig(warmup_steps=5, total_steps=50)


def batch(key, b=2):
    return jax.random.randint(key, (b, CFG.n_ctx + 1), 0, CFG.vocab_size)


def test_state_specs_structure():
    ps = param_specs(CFG)
    ss = state_specs(CFG)
    assert len(ss) == 3 * len(ps)
    assert ss[len(ps)][0] == "m." + ps[0][0]
    assert ss[2 * len(ps)][0] == "v." + ps[0][0]


def test_init_state_moments_zero():
    state = init_state(CFG, 0)
    n = len(param_specs(CFG))
    for m in state[n:]:
        assert float(jnp.max(jnp.abs(m))) == 0.0


def test_lr_schedule_matches_rust_mirror():
    """Spot-check values the Rust CosineSchedule tests also pin down."""
    tc = TrainConfig(lr_max=1e-3, lr_min=5e-5, warmup_steps=10,
                     total_steps=100)
    assert float(lr_at_step(tc, 0)) == 0.0
    np.testing.assert_allclose(float(lr_at_step(tc, 5)), 5e-4, rtol=1e-6)
    np.testing.assert_allclose(float(lr_at_step(tc, 10)), 1e-3, rtol=1e-6)
    np.testing.assert_allclose(float(lr_at_step(tc, 100)), 5e-5, rtol=1e-5)
    # monotone decay after warmup
    lrs = [float(lr_at_step(tc, s)) for s in range(10, 101, 5)]
    assert all(a >= b - 1e-12 for a, b in zip(lrs, lrs[1:]))


def test_single_step_reduces_loss_on_same_batch(rng):
    state = init_state(CFG, 0)
    b = batch(rng)
    step = jax.jit(lambda s, t, i: train_step(CFG, TC, s, t, i))
    loss0, state = step(state, b, 0)
    # a few steps on the same batch must overfit it
    for i in range(1, 6):
        loss, state = step(state, b, i)
    assert float(loss) < float(loss0)


def test_warmup_step0_freezes_params(rng):
    """lr(0) = 0 during warmup — step 0 must update moments, not params."""
    state = init_state(CFG, 0)
    n = len(param_specs(CFG))
    _, new_state = jax.jit(
        lambda s, t: train_step(CFG, TC, s, t, 0))(state, batch(rng))
    assert float(jnp.max(jnp.abs(new_state[0] - state[0]))) == 0.0
    assert float(jnp.max(new_state[2 * n])) > 0  # v moment accumulated


def test_update_changes_params_but_not_shapes(rng):
    state = init_state(CFG, 0)
    shapes = [tuple(s.shape) for s in state]
    loss, new_state = jax.jit(
        lambda s, t: train_step(CFG, TC, s, t, 3))(state, batch(rng))
    assert [tuple(s.shape) for s in new_state] == shapes
    n = len(param_specs(CFG))
    # params moved
    assert float(jnp.max(jnp.abs(new_state[0] - state[0]))) > 0
    # second moment became positive somewhere
    assert float(jnp.max(new_state[2 * n])) > 0


def test_grad_clip_bounds_update(rng):
    """With a tiny clip, the parameter step is bounded by ~lr·(1+wd·|p|)."""
    tc = TrainConfig(warmup_steps=0, total_steps=10, grad_clip=1e-3)
    state = init_state(CFG, 0)
    _, new_state = jax.jit(
        lambda s, t: train_step(CFG, tc, s, t, 5))(state, batch(rng))
    lr = float(lr_at_step(tc, 5))
    delta = float(jnp.max(jnp.abs(new_state[0] - state[0])))
    assert delta <= lr * 1.5, (delta, lr)


def test_eval_loss_matches_loss_fn(rng):
    state = init_state(CFG, 3)
    n = len(param_specs(CFG))
    b = batch(rng)
    from compile.model import loss_fn
    np.testing.assert_allclose(
        float(eval_loss(CFG, state[:n], b)),
        float(loss_fn(CFG, state[:n], b)), rtol=1e-6)


@pytest.mark.parametrize("attn", ["ours", "softmax"])
def test_short_training_descends(rng, attn):
    cfg = ModelConfig(**{**CFG.__dict__, "attn": attn})
    # random tokens carry no structure: the model must memorize the 3 batches,
    # which needs a hotter LR than the paper schedule at 15 steps
    tc = TrainConfig(warmup_steps=2, total_steps=20, lr_max=3e-3)
    state = init_state(cfg, 0)
    step = jax.jit(lambda s, t, i: train_step(cfg, tc, s, t, i))
    losses = []
    for i in range(15):
        b = batch(jax.random.fold_in(rng, i % 3))
        loss, state = step(state, b, i)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses
