"""Shared fixtures for the kernel / model test suite."""

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_qkv(key, bh, n, d, normalized=True):
    """Random (q, k, v) triple; q, k row-normalized by default (paper §3.3)."""
    import jax.numpy as jnp
    from compile.kernels.linear_attention import normalize_qk

    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (bh, n, d), jnp.float32)
    k = jax.random.normal(kk, (bh, n, d), jnp.float32)
    v = jax.random.normal(kv, (bh, n, d), jnp.float32)
    if normalized:
        q, k = normalize_qk(q, k)
    return q, k, v
