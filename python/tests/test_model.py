"""L2 model correctness: shapes, causality, attention-impl parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, forward, init_params, loss_fn, param_specs

TINY = ModelConfig(vocab_size=64, d_model=32, n_heads=2, n_layers=2,
                   n_ctx=32, chunk=8)


def toks(key, cfg, batch=2, n=None):
    return jax.random.randint(key, (batch, n or cfg.n_ctx), 0, cfg.vocab_size)


@pytest.mark.parametrize("attn", ["ours", "gated", "softmax", "flash", "quadratic"])
def test_forward_shapes_all_impls(rng, attn):
    cfg = ModelConfig(**{**TINY.__dict__, "attn": attn})
    params = init_params(cfg, 0)
    logits = forward(cfg, params, toks(rng, cfg))
    assert logits.shape == (2, cfg.n_ctx, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_specs_count_and_order():
    specs = param_specs(TINY)
    assert specs[0][0] == "embed"
    assert specs[-1][0] == "ln_f.bias"
    # embed + 12/layer + 2 final
    assert len(specs) == 1 + 12 * TINY.n_layers + 2
    assert TINY.n_params == sum(int(np.prod(s)) for _, s in specs)


def test_init_deterministic_and_seed_sensitive():
    a = init_params(TINY, 7)
    b = init_params(TINY, 7)
    c = init_params(TINY, 8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(float(jnp.max(jnp.abs(x - y))) > 0 for x, y in zip(a, c))


def test_model_is_causal(rng):
    """Changing future tokens must not change past logits."""
    cfg = TINY
    params = init_params(cfg, 0)
    t1 = toks(rng, cfg, batch=1)
    t2 = t1.at[:, 20:].set((t1[:, 20:] + 7) % cfg.vocab_size)
    l1 = forward(cfg, params, t1)
    l2 = forward(cfg, params, t2)
    np.testing.assert_allclose(l1[:, :20], l2[:, :20], atol=2e-5, rtol=2e-4)
    assert float(jnp.max(jnp.abs(l1[:, 20:] - l2[:, 20:]))) > 1e-4


def test_loss_near_uniform_at_init(rng):
    """Fresh model ≈ uniform predictor: loss ≈ ln(V)."""
    cfg = TINY
    params = init_params(cfg, 0)
    batch = jax.random.randint(rng, (4, cfg.n_ctx + 1), 0, cfg.vocab_size)
    loss = float(loss_fn(cfg, params, batch))
    assert abs(loss - np.log(cfg.vocab_size)) < 0.5, loss


def test_loss_differentiable_all_impls(rng):
    for attn in ["ours", "gated", "softmax"]:
        cfg = ModelConfig(**{**TINY.__dict__, "attn": attn})
        params = init_params(cfg, 0)
        batch = jax.random.randint(rng, (2, cfg.n_ctx + 1), 0, cfg.vocab_size)
        grads = jax.grad(lambda ps: loss_fn(cfg, ps, batch))(params)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in grads), attn
        # embed grad must be nonzero
        assert float(jnp.max(jnp.abs(grads[0]))) > 0


def test_config_validation():
    with pytest.raises(ValueError):
        ModelConfig(d_model=30, n_heads=4)
    with pytest.raises(ValueError):
        ModelConfig(attn="mamba")
