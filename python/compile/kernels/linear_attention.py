"""Factorized causal linear attention — the paper's core contribution (§3, §4).

Forward (Eq. 5-9) and analytical backward (Eq. 16-21) of linear attention with
kernel ``f(x) = a + b·x`` and causal mask, in ``O(N·D²)`` time and ``O(N·D)``
memory, implemented as Pallas kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
implementation keeps the running prefix state ``x⁽²⁾ ∈ R^{D×D}`` in per-thread
registers and streams ``q_i, k_i`` through shared memory.  On TPU the same
insight — keep the O(D²) state on-chip, touch each sequence element once —
maps to a VMEM scratch accumulator carried across a *sequential* grid over
sequence chunks, with BlockSpec pipelining the HBM→VMEM chunk transfers.
Intra-chunk terms use a causal-masked (C,C) matmul (MXU work); inter-chunk
terms use the carried state.  This is the chunkwise-parallel form of the
paper's recurrences: mathematically identical, one pass over the sequence.

State carried by the forward scan (per batch·head):
    S ∈ R^{D×D} = Σ_{n≤i} k_n v_nᵀ        (paper's x⁽²⁾ / b)
    z ∈ R^{D}   = Σ_{n≤i} k_n             (paper's y⁽²⁾ / b)
    t ∈ R^{D}   = Σ_{n≤i} v_n             (paper's x⁽¹⁾ / a)
    n ∈ R       = i                       (paper's y⁽¹⁾ / a)
so that  o_i = (a·t + b·S ᵀq_i) / (a·n + b·z·q_i)   (Eq. 8).

Backward (derived from Eq. 16-18, see DESIGN.md):
    Ω̂_i  = Ω_i / g_i                                        (Eq. 20)
    ∇q_i = b·[ S_iᵀ Ω̂_i − z_i · (o_i·Ω̂_i) ]                 forward scan
    ∇k_p = b·[ A_p v_p − c_p ]                                reverse scan
    ∇v_p = a·u_p + b·A_pᵀ k_p                                 reverse scan
with reverse-cumulative states A_p = Σ_{i≥p} q_i Ω̂_iᵀ, c_p = Σ_{i≥p} q_i (o_i·Ω̂_i),
u_p = Σ_{i≥p} Ω̂_i.  Only Q, K, V, O, g are stored between passes → O(N·D).

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); they lower to plain HLO and compose into the AOT artifacts.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "LAParams",
    "normalize_qk",
    "la_fwd",
    "la_fwd_with_denom",
    "la_fwd_scan",
    "la_bwd",
    "linear_attention",
    "default_chunk",
]

_NEG_SLOPE = None  # no leaky parameters; attention kernel is f(x) = a + b x


class LAParams(NamedTuple):
    """Static coefficients of the attention kernel ``f(x) = a + b·x``.

    The paper uses ``a = b = 1`` (§4: "We employ attention kernel of
    f(x) = 1 + x"); they may also be set from a Taylor expansion of exp.
    """

    a: float = 1.0
    b: float = 1.0


def default_chunk(n: int, preferred: int = 128) -> int:
    """Largest chunk length ≤ ``preferred`` that divides ``n``.

    The sequential grid requires N % C == 0; TPU tiling prefers multiples of 8
    (sublane) — all our Ns are powers of two so this returns a power of two.
    """
    c = min(preferred, n)
    while n % c != 0:
        c -= 1
    return max(c, 1)


def normalize_qk(q: jax.Array, k: jax.Array, eps: float = 1e-6):
    """Row-wise L2 normalization of queries and keys (paper §3.3, Eq. 22).

    Keeps q·k ∈ [−1, 1] so f(x) = 1 + x ≥ 0 and the denominator g_i ≥ Σ eps
    stays well-conditioned — the paper's recommended guard against vanishing /
    exploding gradients in sub-quadratic attention.
    """
    qn = q * jax.lax.rsqrt(jnp.sum(q * q, axis=-1, keepdims=True) + eps)
    kn = k * jax.lax.rsqrt(jnp.sum(k * k, axis=-1, keepdims=True) + eps)
    return qn, kn


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, g_ref, s_ref, z_ref, t_ref, n_ref,
                *, a: float, b: float, chunk: int):
    """One (batch·head, chunk) grid step of the forward pass.

    Refs (VMEM blocks):
      q/k/v_ref : (C, D) current sequence chunk
      o_ref     : (C, D) output chunk
      g_ref     : (C,)  per-row denominator (saved for the backward pass)
      s_ref     : (D, D) scratch — running Σ k vᵀ         (persists across grid)
      z_ref     : (1, D) scratch — running Σ k
      t_ref     : (1, D) scratch — running Σ v
      n_ref     : (1, 1) scratch — running token count
    """
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _reset():  # new batch·head row: zero the carried state
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]

    # --- intra-chunk (causal within the chunk, diagonal included) ----------
    scores = a + b * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (cols <= rows).astype(scores.dtype)
    scores = scores * mask
    f_intra = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    g_intra = jnp.sum(scores, axis=1, keepdims=True)

    # --- inter-chunk (carried prefix state) ---------------------------------
    s = s_ref[...]
    z = z_ref[...]
    t = t_ref[...]
    n = n_ref[...]
    f_inter = a * t + b * jnp.dot(q, s, preferred_element_type=jnp.float32)
    g_inter = a * n + b * jnp.dot(q, z.T, preferred_element_type=jnp.float32)

    g = g_intra + g_inter
    o_ref[...] = (f_intra + f_inter) / g
    g_ref[...] = g[:, 0]

    # --- advance the carried state ------------------------------------------
    s_ref[...] = s + jnp.dot(k.T, v, preferred_element_type=jnp.float32)
    z_ref[...] = z + jnp.sum(k, axis=0, keepdims=True)
    t_ref[...] = t + jnp.sum(v, axis=0, keepdims=True)
    n_ref[...] = n + jnp.float32(chunk)


def la_fwd_with_denom(q: jax.Array, k: jax.Array, v: jax.Array,
                      params: LAParams = LAParams(),
                      chunk: int | None = None):
    """Forward pass returning ``(O, g)`` where g is the row denominator.

    Args:
      q, k, v: float32 arrays of shape (BH, N, D) — batch·heads flattened.
      params: attention-kernel coefficients (a, b).
      chunk: sequence chunk length C (must divide N); default ≤128 divisor.

    Returns:
      o: (BH, N, D) attention output, g: (BH, N) denominators.
    """
    bh, n, d = q.shape
    c = chunk or default_chunk(n)
    if n % c:
        raise ValueError(f"chunk {c} must divide sequence length {n}")
    nc = n // c

    grid = (bh, nc)
    blk = lambda: pl.BlockSpec((1, c, d), lambda i, j: (i, j, 0))
    gblk = pl.BlockSpec((1, c), lambda i, j: (i, j))

    kern = functools.partial(_fwd_kernel, a=params.a, b=params.b, chunk=c)

    def _squeeze(kernel):
        # pallas blocks come in with the leading grid dim of size 1; present
        # (C, D) views to the kernel body.
        def wrapped(q_ref, k_ref, v_ref, o_ref, g_ref, *scratch):
            kernel(q_ref.at[0], k_ref.at[0], v_ref.at[0],
                   o_ref.at[0], g_ref.at[0], *scratch)
        return wrapped

    o, g = pl.pallas_call(
        _squeeze(kern),
        grid=grid,
        in_specs=[blk(), blk(), blk()],
        out_specs=[blk(), gblk],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, n), jnp.float32),
        ],
        scratch_shapes=[
            pl.MemorySpace.ANY((d, d), jnp.float32),
            pl.MemorySpace.ANY((1, d), jnp.float32),
            pl.MemorySpace.ANY((1, d), jnp.float32),
            pl.MemorySpace.ANY((1, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return o, g


def la_fwd(q, k, v, params: LAParams = LAParams(), chunk: int | None = None):
    """Forward pass returning only the attention output O (BH, N, D)."""
    return la_fwd_with_denom(q, k, v, params, chunk)[0]


def la_fwd_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                params: LAParams = LAParams(), chunk: int | None = None):
    """The same chunkwise algorithm as `la_fwd`, expressed as a lax.scan.

    Ablation implementation (DESIGN.md): identical math and O(N·D²) work, but
    compiled as a plain XLA while-loop instead of an interpret-mode Pallas
    grid.  On CPU this is the production-speed form; on TPU the Pallas kernel
    controls the HBM↔VMEM schedule that this form leaves to the compiler.
    """
    bh, n, d = q.shape
    a, b = params.a, params.b
    c = chunk or default_chunk(n)
    if n % c:
        raise ValueError(f"chunk {c} must divide sequence length {n}")
    nc = n // c

    rows = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = (cols <= rows).astype(jnp.float32)
    offs = jnp.arange(1, c + 1, dtype=jnp.float32)  # token count inside chunk

    qc = jnp.moveaxis(q.reshape(bh, nc, c, d), 1, 0)
    kc = jnp.moveaxis(k.reshape(bh, nc, c, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(bh, nc, c, d), 1, 0)

    def step(carry, inputs):
        s, z, t, cnt = carry  # (BH,D,D), (BH,D), (BH,D), (BH,)
        qi, ki, vi = inputs
        scores = (a + b * jnp.einsum("bcd,bed->bce", qi, ki)) * mask
        f_intra = jnp.einsum("bce,bed->bcd", scores, vi)
        g_intra = jnp.sum(scores, axis=-1)
        f_inter = a * t[:, None, :] + b * jnp.einsum("bcd,bde->bce", qi, s)
        g_inter = a * cnt[:, None] + b * jnp.einsum("bcd,bd->bc", qi, z)
        # NOTE: g_intra already contains a·(local count); offs only covers the
        # intra part, cnt the carried part — see the kernel version.
        g = g_intra + g_inter
        o = (f_intra + f_inter) / g[..., None]
        s = s + jnp.einsum("bcd,bce->bde", ki, vi)
        z = z + jnp.sum(ki, axis=1)
        t = t + jnp.sum(vi, axis=1)
        cnt = cnt + jnp.float32(c)
        return (s, z, t, cnt), o

    del offs
    carry0 = (
        jnp.zeros((bh, d, d), jnp.float32),
        jnp.zeros((bh, d), jnp.float32),
        jnp.zeros((bh, d), jnp.float32),
        jnp.zeros((bh,), jnp.float32),
    )
    _, o = jax.lax.scan(step, carry0, (qc, kc, vc))
    return jnp.moveaxis(o, 0, 1).reshape(bh, n, d)


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, o_ref, om_ref, dq_ref,
                   s_ref, z_ref, *, a: float, b: float, chunk: int):
    """∇Q — forward scan (Eq. 16).  om_ref holds Ω̂ = Ω/g.

    ∇q_i = b·[ S_iᵀ Ω̂_i − z_i (o_i·Ω̂_i) ]  where S_i, z_i include rows ≤ i.
    Intra-chunk part via causal-masked matmuls; inter-chunk via carried S, z.
    """
    del a  # ∇Q has no a-term: d/dq of the constant term is zero
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    o = o_ref[...]
    om = om_ref[...]  # Ω̂, (C, D)
    w = jnp.sum(o * om, axis=-1, keepdims=True)  # (C,1): o_i·Ω̂_i

    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (cols <= rows).astype(jnp.float32)

    # intra: Σ_{l≤i} k_l (v_l·Ω̂_i) = (M ⊙ (Ω̂ Vᵀ)) K ;  Σ_{l≤i} k_l = M K
    ov = jnp.dot(om, v.T, preferred_element_type=jnp.float32) * mask
    dq_intra = jnp.dot(ov, k, preferred_element_type=jnp.float32)
    ksum_intra = jnp.dot(mask, k, preferred_element_type=jnp.float32)

    s = s_ref[...]
    z = z_ref[...]
    dq_inter = jnp.dot(om, s.T, preferred_element_type=jnp.float32)
    dq_ref[...] = b * (dq_intra + dq_inter - (ksum_intra + z) * w)

    s_ref[...] = s + jnp.dot(k.T, v, preferred_element_type=jnp.float32)
    z_ref[...] = z + jnp.sum(k, axis=0, keepdims=True)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, o_ref, om_ref, dk_ref, dv_ref,
                    a_ref, c_ref, u_ref, *, a: float, b: float, chunk: int):
    """∇K, ∇V — reverse scan (Eq. 17-18).

    Grid walks chunks back-to-front (index_map reverses).  Carried state is
    *strictly-future* (rows > this chunk):
      A = Σ_{i>chunk} q_i Ω̂_iᵀ, c = Σ_{i>chunk} q_i (o_i·Ω̂_i), u = Σ_{i>chunk} Ω̂_i.
    """
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        a_ref[...] = jnp.zeros_like(a_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    o = o_ref[...]
    om = om_ref[...]
    w = jnp.sum(o * om, axis=-1, keepdims=True)  # (C,1)

    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask[p, i] = 1 where i ≥ p (future-inclusive, transposed causal)
    maskT = (cols >= rows).astype(jnp.float32)

    A = a_ref[...]  # (D, D): Σ q Ω̂ᵀ  (rows: q-dim r, cols: Ω̂-dim j)
    cc = c_ref[...]  # (1, D)
    u = u_ref[...]  # (1, D)

    # ∇k_p = b [ A_p v_p − c_p ]; split A_p into intra (i in chunk, i ≥ p) + carried.
    # intra: Σ_{i≥p} q_i (v_p·Ω̂_i) = (Mᵀ ⊙ (V Ω̂ᵀ)) Q
    vo = jnp.dot(v, om.T, preferred_element_type=jnp.float32) * maskT
    dk_intra = jnp.dot(vo, q, preferred_element_type=jnp.float32)
    dk_inter = jnp.dot(v, A.T, preferred_element_type=jnp.float32)
    cw_intra = jnp.dot(maskT, q * w, preferred_element_type=jnp.float32)
    dk_ref[...] = b * (dk_intra + dk_inter - cw_intra - cc)

    # ∇v_p = a u_p + b A_pᵀ k_p; intra A-part: Σ_{i≥p} (q_i·k_p) Ω̂_ij = (Mᵀ ⊙ (K Qᵀ)) Ω̂
    kq = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * maskT
    dv_intra = b * jnp.dot(kq, om, preferred_element_type=jnp.float32)
    dv_inter = b * jnp.dot(k, A, preferred_element_type=jnp.float32)
    u_intra = jnp.dot(maskT, om, preferred_element_type=jnp.float32)
    dv_ref[...] = a * (u_intra + u) + dv_intra + dv_inter

    a_ref[...] = A + jnp.dot(q.T, om, preferred_element_type=jnp.float32)
    c_ref[...] = cc + jnp.sum(q * w, axis=0, keepdims=True)
    u_ref[...] = u + jnp.sum(om, axis=0, keepdims=True)


def la_bwd(q, k, v, o, g, grad_o,
           params: LAParams = LAParams(), chunk: int | None = None):
    """Analytical backward pass (Eq. 16-21): returns (∇Q, ∇K, ∇V).

    Only Q, K, V, O, g are consumed — the O(N·D²) intermediates of the forward
    recurrence are *recomputed on the fly* inside the scans, which is the
    paper's memory-reduction result (§3.2): O(N·D) residency.
    """
    bh, n, d = q.shape
    c = chunk or default_chunk(n)
    if n % c:
        raise ValueError(f"chunk {c} must divide sequence length {n}")
    nc = n // c

    om = grad_o / g[..., None]  # Ω̂ (Eq. 20)

    blk_f = lambda: pl.BlockSpec((1, c, d), lambda i, j: (i, j, 0))
    # reverse scan: grid step j processes chunk nc-1-j
    blk_r = lambda: pl.BlockSpec((1, c, d), lambda i, j: (i, nc - 1 - j, 0))

    def _squeeze(kernel, nin, nout):
        def wrapped(*refs):
            ins = [r.at[0] for r in refs[:nin]]
            outs = [r.at[0] for r in refs[nin:nin + nout]]
            kernel(*ins, *outs, *refs[nin + nout:])
        return wrapped

    dq = pl.pallas_call(
        _squeeze(functools.partial(_bwd_dq_kernel, a=params.a, b=params.b,
                                   chunk=c), 5, 1),
        grid=(bh, nc),
        in_specs=[blk_f() for _ in range(5)],
        out_specs=blk_f(),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
        scratch_shapes=[
            pl.MemorySpace.ANY((d, d), jnp.float32),
            pl.MemorySpace.ANY((1, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, o, om)

    dk, dv = pl.pallas_call(
        _squeeze(functools.partial(_bwd_dkv_kernel, a=params.a, b=params.b,
                                   chunk=c), 5, 2),
        grid=(bh, nc),
        in_specs=[blk_r() for _ in range(5)],
        out_specs=[blk_r(), blk_r()],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
        ],
        scratch_shapes=[
            pl.MemorySpace.ANY((d, d), jnp.float32),
            pl.MemorySpace.ANY((1, d), jnp.float32),
            pl.MemorySpace.ANY((1, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, o, om)

    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wiring — the public differentiable entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def linear_attention(q, k, v, params: LAParams = LAParams(),
                     chunk: int | None = None):
    """Causal linear attention with kernel f(x) = a + b·x (differentiable).

    Shapes: q, k, v (BH, N, D) float32 → (BH, N, D).  Uses the Pallas forward
    kernel and, under ``jax.grad``, the analytical backward kernels (never
    autodiff through the recurrence — that is the paper's O(N·D²)-memory trap).
    """
    return la_fwd(q, k, v, params, chunk)


def _la_vjp_fwd(q, k, v, params, chunk):
    o, g = la_fwd_with_denom(q, k, v, params, chunk)
    return o, (q, k, v, o, g)


def _la_vjp_bwd(params, chunk, res, grad_o):
    q, k, v, o, g = res
    return la_bwd(q, k, v, o, g, grad_o, params, chunk)


linear_attention.defvjp(_la_vjp_fwd, _la_vjp_bwd)
