"""Pure-jnp correctness oracles for the linear-attention kernels.

These implement Eq. 4 of the paper *directly* — materializing the full N×N
attention matrix — so they are O(N²·D) time / O(N²) memory and only usable at
test scale.  They are the ground truth every kernel is validated against;
gradients come from ``jax.grad`` through this direct form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .masks import causal_mask_bool, causal_mask_f32

__all__ = ["ref_la", "ref_la_with_denom", "ref_la_grads", "ref_softmax"]


def ref_la_with_denom(q, k, v, a: float = 1.0, b: float = 1.0,
                      causal: bool = True):
    """Direct evaluation of Eq. 4: o_ij = Σ f(q_i·k_n) v_nj / Σ f(q_i·k_n).

    Returns (o, g) with o: (BH, N, D), g: (BH, N).
    """
    scores = a + b * jnp.einsum("bnd,bmd->bnm", q, k)
    if causal:
        n = q.shape[1]
        mask = causal_mask_f32(n)
        scores = scores * mask
    g = jnp.sum(scores, axis=-1)
    o = jnp.einsum("bnm,bmd->bnd", scores, v) / g[..., None]
    return o, g


def ref_la(q, k, v, a: float = 1.0, b: float = 1.0, causal: bool = True):
    """Direct Eq. 4 forward, output only."""
    return ref_la_with_denom(q, k, v, a, b, causal)[0]


def ref_la_grads(q, k, v, grad_o, a: float = 1.0, b: float = 1.0,
                 causal: bool = True):
    """(∇Q, ∇K, ∇V) through the direct form via jax.vjp — the autodiff ground
    truth for the paper's hand-derived Eq. 16-18."""
    _, vjp = jax.vjp(lambda q_, k_, v_: ref_la(q_, k_, v_, a, b, causal),
                     q, k, v)
    return vjp(grad_o)


def ref_softmax(q, k, v, causal: bool = True):
    """Regular attention (Eq. 2-3): softmax kernel f(x) = exp(x/√D)."""
    d = q.shape[-1]
    scores = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        n = q.shape[1]
        mask = causal_mask_bool(n)
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bnm,bmd->bnd", w, v)
