"""Comparator attention implementations from the paper's evaluation (§5).

Each baseline reproduces the *algorithm* (and hence its complexity and
off-chip-traffic class) of a system the paper compares against:

  ``quadratic_la``      — "baseline PyTorch LA": Eq. 4 evaluated directly,
                          materializing the N×N attention matrix (O(N²D) time,
                          O(N²) memory; autodiff backward → O(N·D²) residency).
  ``spec_dec_la``       — Speculative-Decoding LA (You et al. 2024): f(x)=b·x
                          transformer-based LA, quadratic materialization with
                          causal mask (their causal backward stores O(N·D²)).
  ``softmax_attention`` — Regular Attention (Vaswani et al.), direct.
  ``flash_softmax``     — FlashAttention-2 analog: blocked *online-softmax*
                          streaming over key chunks, O(N²D) time / O(N·D) mem.
  ``gated_la_recurrent``/``gated_la_chunkwise`` — Gated LA (Yang et al. 2023)
                          analog: per-dimension decay gate, token-recurrent
                          oracle + the chunkwise hardware-efficient form GLA
                          actually ships.

All take (BH, N, D) float32 and return (BH, N, D); all are causal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .masks import causal_mask_bool, causal_mask_f32

__all__ = [
    "quadratic_la",
    "spec_dec_la",
    "softmax_attention",
    "flash_softmax",
    "gated_la_recurrent",
    "gated_la_chunkwise",
]


def quadratic_la(q, k, v, a: float = 1.0, b: float = 1.0):
    """Baseline LA: direct Eq. 4 with causal mask, full N×N materialization."""
    scores = a + b * jnp.einsum("bnd,bmd->bnm", q, k)
    n = q.shape[1]
    mask = causal_mask_f32(n)
    scores = scores * mask
    g = jnp.sum(scores, axis=-1, keepdims=True)
    return jnp.einsum("bnm,bmd->bnd", scores, v) / g


def spec_dec_la(q, k, v, b: float = 1.0, eps: float = 1e-6):
    """Speculative-Decoding LA analog: kernel f(x) = b·x (no constant term).

    Follows You et al.'s transformer-based formulation; the denominator can
    approach zero for raw inputs, so an eps guard is applied (their models use
    feature maps that keep it positive — with row-normalized q, k and the eps
    the behaviour matches at bench scale).
    """
    scores = b * jnp.einsum("bnd,bmd->bnm", q, k)
    n = q.shape[1]
    mask = causal_mask_f32(n)
    scores = scores * mask
    g = jnp.sum(scores, axis=-1, keepdims=True)
    g = jnp.where(jnp.abs(g) < eps, eps, g)
    return jnp.einsum("bnm,bmd->bnd", scores, v) / g


def softmax_attention(q, k, v):
    """Regular Attention: softmax(QKᵀ/√D) with causal mask, direct O(N²)."""
    d = q.shape[-1]
    scores = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(d))
    n = q.shape[1]
    mask = causal_mask_bool(n)
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bnm,bmd->bnd", w, v)


def flash_softmax(q, k, v, chunk: int = 128):
    """FlashAttention-2 analog: streaming blocked softmax.

    Scans key/value chunks carrying the online-softmax state (running max m,
    running sum l, unnormalized accumulator acc) for *all* queries at once.
    Never materializes the N×N matrix → O(N·D) memory, still O(N²·D) time.
    """
    bh, n, d = q.shape
    c = min(chunk, n)
    while n % c:
        c -= 1
    nc = n // c
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    kc = k.reshape(bh, nc, c, d)
    vc = v.reshape(bh, nc, c, d)
    row_ids = jnp.arange(n)[None, :, None]  # (1, N, 1)

    def step(carry, inputs):
        m, l, acc = carry
        j, kj, vj = inputs  # kj, vj: (BH, C, D)
        s = jnp.einsum("bnd,bcd->bnc", q, kj) * scale  # (BH, N, C)
        col_ids = j * c + jnp.arange(c)[None, None, :]
        s = jnp.where(col_ids <= row_ids, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf) against NaN from exp(-inf+inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        p = jnp.exp(s - m_safe[..., None])
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[..., None] * acc + jnp.einsum("bnc,bcd->bnd", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((bh, n), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bh, n), jnp.float32)
    acc0 = jnp.zeros((bh, n, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (jnp.arange(nc), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    return acc / l[..., None]


# ---------------------------------------------------------------------------
# Gated LA (Yang et al. 2023 analog)
# ---------------------------------------------------------------------------


def gated_la_recurrent(q, k, v, gamma=None):
    """Token-by-token GLA recurrence (oracle): S_t = Diag(γ)·S_{t-1} + k_t v_tᵀ,
    o_t = S_tᵀ q_t.  γ ∈ (0,1)^D is a per-key-dimension decay gate.

    This is the RNN form the paper contrasts with (Appendix B, Table 3) —
    inherently sequential over tokens.
    """
    bh, n, d = q.shape
    if gamma is None:
        gamma = _default_gamma(d)

    def step(s, inputs):
        qt, kt, vt = inputs  # (BH, D) each
        s = gamma[:, None] * s + jnp.einsum("bm,bj->bmj", kt, vt)
        ot = jnp.einsum("bm,bmj->bj", qt, s)
        return s, ot

    s0 = jnp.zeros((bh, d, d), jnp.float32)
    _, o = jax.lax.scan(step, s0,
                        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
                         jnp.moveaxis(v, 1, 0)))
    return jnp.moveaxis(o, 0, 1)


def gated_la_chunkwise(q, k, v, gamma=None, chunk: int = 64):
    """Chunkwise-parallel GLA — the hardware-efficient form Yang et al. ship.

    Within a chunk of length C, with Λ_i = γ^i:
      o_i = (q_i ⊙ Λ_i)·S_prev + Σ_{l≤i} [(q_i⊙Λ_i)·(k_l⊘Λ_l)] v_l
      S_new = Λ_C ⊙ S_prev + Σ_l (k_l ⊙ Λ_{C-l}) v_lᵀ
    Chunk state crosses chunks via lax.scan (the "carry over" of GLA §4).
    """
    import numpy as np

    bh, n, d = q.shape
    if gamma is None:
        gamma = np.asarray(_default_gamma_tuple(d), np.float32)
    c = min(chunk, n)
    while n % c:
        c -= 1
    nc = n // c

    # Decay tables are computed in numpy so they lower as literal constants.
    # (jax ≥0.8 re-materializes jnp-level constants as in-graph iota+power
    # chains, which the pinned xla_extension 0.5.1 CPU backend miscompiles
    # to NaN — see DESIGN.md §Substitutions / known-issues.)
    gamma_np = np.asarray(gamma, np.float32)
    i1 = np.arange(1, c + 1, dtype=np.float32)[:, None]  # (C, 1)
    lam = jnp.asarray(gamma_np[None, :] ** i1)            # Λ_i = γ^i, (C, D)
    lam_inv = jnp.asarray(gamma_np[None, :] ** (-i1))     # γ^{-l}
    lam_rem = jnp.asarray(gamma_np[None, :] ** (c - i1))  # γ^{C-l}
    lam_c = jnp.asarray(gamma_np ** c)                    # γ^C, (D,)
    mask = causal_mask_f32(c)

    qc = jnp.moveaxis(q.reshape(bh, nc, c, d), 1, 0)
    kc = jnp.moveaxis(k.reshape(bh, nc, c, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(bh, nc, c, d), 1, 0)

    def step(s, inputs):
        qi, ki, vi = inputs  # (BH, C, D)
        qt = qi * lam
        kt = ki * lam_inv
        scores = jnp.einsum("bcd,bed->bce", qt, kt) * mask
        o_intra = jnp.einsum("bce,bed->bcd", scores, vi)
        o_inter = jnp.einsum("bcm,bmj->bcj", qt, s)
        s_new = lam_c[None, :, None] * s + jnp.einsum(
            "bcm,bcj->bmj", ki * lam_rem, vi)
        return s_new, o_intra + o_inter

    s0 = jnp.zeros((bh, d, d), jnp.float32)
    _, o = jax.lax.scan(step, s0, (qc, kc, vc))
    return jnp.moveaxis(o, 0, 1).reshape(bh, n, d)


@functools.lru_cache(maxsize=None)
def _default_gamma_tuple(d: int):
    # log-spaced decays in [0.95, 0.999], the range GLA-family models learn
    import numpy as np
    g = np.exp(np.linspace(np.log(0.95), np.log(0.999), d)).astype("float32")
    return tuple(float(x) for x in g)


def _default_gamma(d: int) -> jax.Array:
    return jnp.asarray(_default_gamma_tuple(d), jnp.float32)
