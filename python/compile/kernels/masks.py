"""Causal-mask helpers that lower to iota+compare, never to an N×N literal.

``jnp.tril(jnp.ones((n, n)))`` embeds an N² constant into the HLO text — at
N = 32768 that is a gigabyte of literal. These helpers emit
``broadcasted_iota`` comparisons instead, which XLA fuses for free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["causal_mask_f32", "causal_mask_bool"]


def causal_mask_f32(n: int, m: int | None = None) -> jax.Array:
    """(n, m) float32 mask: 1 where col ≤ row (causal, diagonal kept)."""
    m = n if m is None else m
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    return (cols <= rows).astype(jnp.float32)


def causal_mask_bool(n: int, m: int | None = None) -> jax.Array:
    """(n, m) bool mask: True where col ≤ row."""
    m = n if m is None else m
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    return cols <= rows
