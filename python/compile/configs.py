"""Model / bench presets shared by aot.py and the test suite.

The paper's end-to-end run is Pythia-1.4B @ N=8192 on 8×A6000; this testbed
is one CPU core running interpret-mode Pallas, so the *recorded* runs use the
scaled presets below (DESIGN.md §Substitutions).  `lm-pythia1b4` exists to
document the paper-faithful shape; it is lowerable but not part of the default
artifact set.
"""

from __future__ import annotations

from .model import ModelConfig

__all__ = ["MODEL_PRESETS", "BENCH_N_SWEEP", "BENCH_D_SWEEP", "BENCH_BH",
           "QUAD_N_CAP", "FLASH_N_CAP", "model_preset"]

MODEL_PRESETS: dict[str, ModelConfig] = {
    # ~0.86 M params — unit tests, smoke runs
    "lm-tiny": ModelConfig(vocab_size=256, d_model=128, n_heads=4,
                           n_layers=2, n_ctx=128, chunk=32),
    # ~4.4 M params — the recorded Fig-5/Table-2 runs.  chunk=128 after the
    # §Perf ablation: interpret-mode cost is per-grid-step, so fewer, larger
    # chunks win on CPU (−38 % step time vs chunk=64; EXPERIMENTS.md §Perf).
    "lm-small": ModelConfig(vocab_size=512, d_model=256, n_heads=8,
                            n_layers=4, n_ctx=256, chunk=128),
    # ~28 M params — overnight-scale config
    "lm-base": ModelConfig(vocab_size=1024, d_model=512, n_heads=8,
                           n_layers=8, n_ctx=512, chunk=64),
    # ~86 M params — the "~100M transformer" config
    "lm-100m": ModelConfig(vocab_size=2048, d_model=768, n_heads=12,
                           n_layers=12, n_ctx=512, chunk=64),
    # paper-faithful Pythia-1.4B shape (documentation / lowering check only)
    "lm-pythia1b4": ModelConfig(vocab_size=50304, d_model=2048, n_heads=16,
                                n_layers=24, n_ctx=8192, chunk=128),
}


def model_preset(name: str, attn: str | None = None) -> ModelConfig:
    cfg = MODEL_PRESETS[name]
    if attn is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn=attn)
    return cfg


# Layer-bench sweeps (Figs 2-4, Table 1). The paper sweeps N ∈ [1e3, 3e5] and
# D ∈ [32, 256] at B=4, H=16; we keep D and the N *range shape* but flatten
# BH to 4 and cap the quadratic-memory implementations so a 35 GB host
# survives (documented in EXPERIMENTS.md).
BENCH_BH = 4
BENCH_N_SWEEP = [1024, 2048, 4096, 8192, 16384, 32768]
BENCH_D_SWEEP = [32, 64, 128, 256]
BENCH_D_DEFAULT = 128
BENCH_N_DEFAULT = 4096
QUAD_N_CAP = 4096    # softmax / quadratic LA / spec-dec: N² buffers
FLASH_N_CAP = 16384  # flash: O(N·D) memory but O(N²·D) single-core time
