"""L2 — training step (AdamW + cosine warmup/decay) lowered into the HLO.

The optimizer lives *inside* the artifact so the Rust coordinator only shuttles
opaque state buffers: state = params ++ adam_m ++ adam_v (flat, in
param_specs order).  One `train_step(state..., tokens, step)` call returns
`(loss, state'...)`; Rust donates the old state and keeps the new one.

The schedule mirrors the paper's LLM setup (§5.2): cosine warmup + decay
between lr_min and lr_max.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .model import ModelConfig, init_params, loss_fn, param_specs

__all__ = ["TrainConfig", "init_state", "train_step", "eval_loss",
           "lr_at_step", "state_specs"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule hyper-parameters (baked into the artifact)."""

    lr_max: float = 1e-3       # paper §5.2
    lr_min: float = 5e-5       # paper §5.2
    warmup_steps: int = 50
    total_steps: int = 500
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at_step(tc: TrainConfig, step):
    """Cosine warmup→decay. `step` may be a traced i32 scalar."""
    s = jnp.asarray(step, jnp.float32)
    warm = tc.lr_max * s / max(tc.warmup_steps, 1)
    span = max(tc.total_steps - tc.warmup_steps, 1)
    frac = jnp.clip((s - tc.warmup_steps) / span, 0.0, 1.0)
    cos = tc.lr_min + 0.5 * (tc.lr_max - tc.lr_min) * (
        1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < tc.warmup_steps, warm, cos)


def state_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """(name, shape) for the full training state: params, then m, then v."""
    ps = param_specs(cfg)
    return (ps + [("m." + n, s) for n, s in ps]
            + [("v." + n, s) for n, s in ps])


def init_state(cfg: ModelConfig, seed) -> list[jax.Array]:
    """Fresh params + zeroed Adam moments (flat, state_specs order)."""
    params = init_params(cfg, seed)
    zeros = [jnp.zeros_like(p) for p in params]
    return params + zeros + [jnp.zeros_like(p) for p in params]


def _split_state(cfg: ModelConfig, state: list[jax.Array]):
    n = len(param_specs(cfg))
    return state[:n], state[n:2 * n], state[2 * n:]


_NO_DECAY_SUFFIXES = (".scale", ".bias", ".b1", ".b2")


def train_step(cfg: ModelConfig, tc: TrainConfig, state: list[jax.Array],
               tokens: jax.Array, step: jax.Array):
    """One AdamW step.  Returns (loss, new_state).

    tokens: i32 (B, N+1); step: i32 scalar (0-based).
    Gradient-norm clipping at tc.grad_clip; decoupled weight decay applied to
    matrix weights only (standard GPT practice).
    """
    params, m, v = _split_state(cfg, state)
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens))(params)

    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-12))
    grads = [g * scale for g in grads]

    t = jnp.asarray(step, jnp.float32) + 1.0
    lr = lr_at_step(tc, step)
    bc1 = 1.0 - tc.beta1 ** t
    bc2 = 1.0 - tc.beta2 ** t

    names = [n for n, _ in param_specs(cfg)]
    new_p, new_m, new_v = [], [], []
    for name, p, g, mi, vi in zip(names, params, grads, m, v):
        mi = tc.beta1 * mi + (1.0 - tc.beta1) * g
        vi = tc.beta2 * vi + (1.0 - tc.beta2) * g * g
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + tc.eps)
        if not name.endswith(_NO_DECAY_SUFFIXES):
            upd = upd + tc.weight_decay * p
        new_p.append(p - lr * upd)
        new_m.append(mi)
        new_v.append(vi)

    return loss, new_p + new_m + new_v


def eval_loss(cfg: ModelConfig, params: list[jax.Array],
              tokens: jax.Array) -> jax.Array:
    """Held-out cross-entropy (no optimizer)."""
    return loss_fn(cfg, params, tokens)
