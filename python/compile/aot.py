"""AOT artifact builder: lower every jax computation to HLO text, once.

This is the *only* place Python runs in the whole system — `make artifacts`
invokes it, and the Rust coordinator then works exclusively from
``artifacts/*.hlo.txt`` + ``artifacts/manifest.json``.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts are content-hash cached: an artifact is re-lowered only when the
Python sources, jax version, or its spec change.

Usage:
    python -m compile.aot --out ../artifacts [--preset default|bench|lm|min]
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs
from .kernels import baselines
from .kernels.linear_attention import LAParams, default_chunk, la_fwd, \
    la_fwd_scan, linear_attention
from .model import param_specs
from .train import TrainConfig, eval_loss, init_state, train_step

F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True: the Rust
    side unwraps with to_tuple*).

    `as_hlo_text(True)` prints large constants in full — the default elides
    them as ``{...}``, which the Rust-side HLO text parser silently
    zero-fills (observed: GLA decay tables became zeros → NaN outputs).
    A belt-and-braces check in `build()` rejects any ``{...}`` leftover.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(True)  # print_large_constants=True


def _count_entry_params(hlo_text: str) -> int:
    """Number of parameters of the ENTRY computation in HLO text."""
    import re
    entry = hlo_text.split("ENTRY ", 1)[1]
    ids = {int(m) for m in re.findall(r"parameter\((\d+)\)", entry)}
    return len(ids)


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(dt)]


def _io_spec(avals) -> list[dict]:
    return [{"index": i, "dtype": _dtype_tag(a.dtype), "shape": list(a.shape)}
            for i, a in enumerate(avals)]


# ---------------------------------------------------------------------------
# Artifact inventory
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Artifact:
    name: str
    fn: "callable"
    args: list  # ShapeDtypeStructs
    meta: dict


def _qkv(bh: int, n: int, d: int):
    s = jax.ShapeDtypeStruct((bh, n, d), F32)
    return [s, s, s]


_LAYER_IMPLS = {
    # impl name -> forward callable (q, k, v, chunk) -> o
    "ours": lambda q, k, v, chunk: la_fwd(q, k, v, LAParams(), chunk),
    # ablation: identical chunkwise algorithm as a plain lax.scan (no Pallas
    # interpret overhead) — the production-CPU form of "ours"
    "ours_scan": lambda q, k, v, chunk: la_fwd_scan(q, k, v, LAParams(),
                                                    chunk),
    "gated": lambda q, k, v, chunk: baselines.gated_la_chunkwise(
        q, k, v, chunk=chunk),
    "quadratic": lambda q, k, v, chunk: baselines.quadratic_la(q, k, v),
    "specdec": lambda q, k, v, chunk: baselines.spec_dec_la(q, k, v),
    "flash": lambda q, k, v, chunk: baselines.flash_softmax(q, k, v,
                                                            chunk=chunk),
    "softmax": lambda q, k, v, chunk: baselines.softmax_attention(q, k, v),
}

# gradient path: custom-vjp (analytical kernels) for ours; autodiff otherwise
_LAYER_GRAD_IMPLS = dict(_LAYER_IMPLS)
_LAYER_GRAD_IMPLS["ours"] = lambda q, k, v, chunk: linear_attention(
    q, k, v, LAParams(), chunk)


def _n_cap(impl: str) -> int:
    if impl in ("quadratic", "specdec", "softmax"):
        return configs.QUAD_N_CAP
    if impl == "flash":
        return configs.FLASH_N_CAP
    return 1 << 30


def layer_artifacts() -> list[Artifact]:
    """Figs 2-4 / Table 1: per-(impl, N, D) forward and fwd+bwd modules."""
    out: list[Artifact] = []
    bh = configs.BENCH_BH
    points: list[tuple[int, int]] = [
        (n, configs.BENCH_D_DEFAULT) for n in configs.BENCH_N_SWEEP]
    points += [(configs.BENCH_N_DEFAULT, d) for d in configs.BENCH_D_SWEEP
               if d != configs.BENCH_D_DEFAULT]

    for impl, fwd in _LAYER_IMPLS.items():
        for n, d in points:
            if n > _n_cap(impl):
                continue
            chunk = default_chunk(n)
            out.append(Artifact(
                f"layer_{impl}_fwd_n{n}_d{d}",
                (lambda f, c: lambda q, k, v: (f(q, k, v, c),))(fwd, chunk),
                _qkv(bh, n, d),
                {"kind": "layer_fwd", "impl": impl, "bh": bh, "n": n,
                 "d": d, "chunk": chunk}))

    for impl, fwd in _LAYER_GRAD_IMPLS.items():
        for n, d in points:
            if n > _n_cap(impl):
                continue
            chunk = default_chunk(n)

            def make(f, c):
                def fwdbwd(q, k, v, go):
                    _, vjp = jax.vjp(
                        lambda a_, b_, c_: f(a_, b_, c_, c), q, k, v)
                    return vjp(go)
                return fwdbwd

            out.append(Artifact(
                f"layer_{impl}_bwd_n{n}_d{d}", make(fwd, chunk),
                _qkv(bh, n, d) + [jax.ShapeDtypeStruct((bh, n, d), F32)],
                {"kind": "layer_fwdbwd", "impl": impl, "bh": bh, "n": n,
                 "d": d, "chunk": chunk}))
    return out


def ablation_artifacts() -> list[Artifact]:
    """§Perf chunk ablation: the same (N, D) point at several chunk lengths,
    for both the Pallas kernel and the scan form."""
    out: list[Artifact] = []
    bh, n, d = configs.BENCH_BH, 8192, configs.BENCH_D_DEFAULT
    for chunk in (64, 128, 256, 512):
        out.append(Artifact(
            f"ablate_ours_fwd_n{n}_c{chunk}",
            (lambda c: lambda q, k, v: (la_fwd(q, k, v, LAParams(), c),))(chunk),
            _qkv(bh, n, d),
            {"kind": "ablation_fwd", "impl": "ours", "bh": bh, "n": n,
             "d": d, "chunk": chunk}))
        out.append(Artifact(
            f"ablate_ours_scan_fwd_n{n}_c{chunk}",
            (lambda c: lambda q, k, v: (la_fwd_scan(q, k, v, LAParams(),
                                                    c),))(chunk),
            _qkv(bh, n, d),
            {"kind": "ablation_fwd", "impl": "ours_scan", "bh": bh, "n": n,
             "d": d, "chunk": chunk}))
    return out


def quickstart_artifacts() -> list[Artifact]:
    """Small fixed-shape modules for examples/quickstart.rs."""
    bh, n, d = 4, 256, 64
    chunk = 64
    arts = [Artifact(
        "quickstart_la_fwd",
        lambda q, k, v: (la_fwd(q, k, v, LAParams(), chunk),),
        _qkv(bh, n, d),
        {"kind": "layer_fwd", "impl": "ours", "bh": bh, "n": n, "d": d,
         "chunk": chunk})]

    def fwdbwd(q, k, v, go):
        _, vjp = jax.vjp(
            lambda a_, b_, c_: linear_attention(a_, b_, c_, LAParams(),
                                                chunk), q, k, v)
        return vjp(go)

    arts.append(Artifact(
        "quickstart_la_bwd", fwdbwd,
        _qkv(bh, n, d) + [jax.ShapeDtypeStruct((bh, n, d), F32)],
        {"kind": "layer_fwdbwd", "impl": "ours", "bh": bh, "n": n, "d": d,
         "chunk": chunk}))
    arts.append(Artifact(
        "quickstart_la_ref",
        lambda q, k, v: (baselines.quadratic_la(q, k, v),),
        _qkv(bh, n, d),
        {"kind": "layer_fwd", "impl": "quadratic", "bh": bh, "n": n, "d": d,
         "chunk": chunk}))
    return arts


LM_ATTNS = ("ours", "gated", "softmax")


def lm_artifacts(preset: str, attns=LM_ATTNS, batch: int = 4,
                 train_cfg: TrainConfig | None = None) -> list[Artifact]:
    """End-to-end LM modules (Fig 5 / Table 2): init, train_step, eval, logits.

    The training state (params ++ adam_m ++ adam_v, flat) crosses the FFI as
    individual buffers in param_specs order — the manifest records the names.
    """
    tc = train_cfg or TrainConfig()
    out: list[Artifact] = []
    for attn in attns:
        cfg = configs.model_preset(preset, attn)
        specs = param_specs(cfg)
        nparam = len(specs)
        base_meta = {
            "preset": preset, "attn": attn,
            "model": dataclasses.asdict(cfg),
            "train": dataclasses.asdict(tc),
            "n_params": cfg.n_params,
            "n_param_arrays": nparam,
            "param_names": [n for n, _ in specs],
            "batch": batch,
        }
        tag = f"lm_{preset.replace('lm-', '')}_{attn}"

        state_shapes = [jax.ShapeDtypeStruct(s, F32) for _, s in specs] * 3
        tokens = jax.ShapeDtypeStruct((batch, cfg.n_ctx + 1), I32)
        tokens_fwd = jax.ShapeDtypeStruct((batch, cfg.n_ctx), I32)
        seed = jax.ShapeDtypeStruct((), I32)
        step = jax.ShapeDtypeStruct((), I32)

        out.append(Artifact(
            tag + "_init",
            lambda s, cfg=cfg: tuple(init_state(cfg, s)),
            [seed], {**base_meta, "kind": "lm_init"}))

        def mk_step(cfg=cfg, tc=tc, nstate=3 * nparam):
            def f(*args):
                state = list(args[:nstate])
                loss, new_state = train_step(cfg, tc, state, args[nstate],
                                             args[nstate + 1])
                return (loss, *new_state)
            return f

        out.append(Artifact(
            tag + "_train_step", mk_step(),
            state_shapes + [tokens, step],
            {**base_meta, "kind": "lm_train_step"}))

        def mk_eval(cfg=cfg, nparam=nparam):
            def f(*args):
                return (eval_loss(cfg, list(args[:nparam]), args[nparam]),)
            return f

        out.append(Artifact(
            tag + "_eval", mk_eval(),
            state_shapes[:nparam] + [tokens],
            {**base_meta, "kind": "lm_eval"}))

        def mk_logits(cfg=cfg, nparam=nparam):
            from .model import forward

            def f(*args):
                return (forward(cfg, list(args[:nparam]), args[nparam]),)
            return f

        out.append(Artifact(
            tag + "_logits", mk_logits(),
            state_shapes[:nparam] + [tokens_fwd],
            {**base_meta, "kind": "lm_logits"}))
    return out


def inventory(preset: str) -> list[Artifact]:
    arts = quickstart_artifacts()
    if preset in ("default", "bench"):
        arts += layer_artifacts()
        arts += ablation_artifacts()
    if preset in ("default", "lm"):
        arts += lm_artifacts("lm-tiny", batch=2,
                             train_cfg=TrainConfig(warmup_steps=10,
                                                   total_steps=100))
        arts += lm_artifacts("lm-small", batch=4)
    return arts


# ---------------------------------------------------------------------------
# Build driver with content-hash cache
# ---------------------------------------------------------------------------


def _source_hash() -> str:
    h = hashlib.sha256()
    root = pathlib.Path(__file__).parent
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    h.update(jax.__version__.encode())
    return h.hexdigest()[:16]


def _artifact_hash(src_hash: str, art: Artifact) -> str:
    h = hashlib.sha256()
    h.update(src_hash.encode())
    h.update(art.name.encode())
    h.update(json.dumps(art.meta, sort_keys=True, default=str).encode())
    h.update(json.dumps(_io_spec(art.args), sort_keys=True).encode())
    return h.hexdigest()[:16]


def build(out_dir: pathlib.Path, preset: str, only: str | None = None,
          verbose: bool = True) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    old: dict = {}
    if manifest_path.exists():
        try:
            old = json.loads(manifest_path.read_text()).get("artifacts", {})
        except json.JSONDecodeError:
            old = {}

    src_hash = _source_hash()
    arts = inventory(preset)
    if only:
        arts = [a for a in arts if only in a.name]

    manifest = {"version": 1, "jax": jax.__version__, "preset": preset,
                "source_hash": src_hash, "artifacts": {}}
    n_built = n_cached = 0
    for art in arts:
        ahash = _artifact_hash(src_hash, art)
        fpath = out_dir / f"{art.name}.hlo.txt"
        prev = old.get(art.name)
        if prev and prev.get("hash") == ahash and fpath.exists():
            manifest["artifacts"][art.name] = prev
            n_cached += 1
            continue
        t0 = time.time()
        lowered = jax.jit(art.fn).lower(*art.args)
        text = to_hlo_text(lowered)
        # Contract check: the ENTRY computation must take exactly the declared
        # inputs.  jax hoists long-lived closure Arrays into extra leading
        # parameters, which would silently break the Rust runtime.
        if "{...}" in text:
            raise RuntimeError(
                f"{art.name}: HLO text contains an elided constant ({{...}})"
                " — the Rust parser would zero-fill it")
        n_entry_params = _count_entry_params(text)
        if n_entry_params != len(art.args):
            raise RuntimeError(
                f"{art.name}: HLO entry has {n_entry_params} parameters but "
                f"{len(art.args)} inputs declared — a closure constant was "
                "hoisted; compute it in-graph instead")
        fpath.write_text(text)
        out_avals = jax.eval_shape(art.fn, *art.args)
        manifest["artifacts"][art.name] = {
            "file": fpath.name,
            "hash": ahash,
            **art.meta,
            "inputs": _io_spec(art.args),
            "outputs": _io_spec(jax.tree_util.tree_leaves(out_avals)),
        }
        n_built += 1
        if verbose:
            print(f"  built {art.name}  ({len(text) / 1e6:.2f} MB, "
                  f"{time.time() - t0:.1f}s)", flush=True)

    manifest_path.write_text(json.dumps(manifest, indent=1))
    if verbose:
        print(f"artifacts: {n_built} built, {n_cached} cached → {out_dir}")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="default",
                    choices=["default", "bench", "lm", "min"])
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args(argv)
    build(pathlib.Path(args.out), args.preset, args.only)
    return 0


if __name__ == "__main__":
    sys.exit(main())
