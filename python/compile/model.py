"""L2 — JAX transformer language model whose attention calls the L1 kernels.

A Pythia-style decoder-only LM (pre-LN, rotary position embedding, GELU MLP,
tied embeddings) with a pluggable attention implementation:

  "ours"      — the paper's factorized linear attention (Pallas kernels,
                analytical backward via jax.custom_vjp), q/k normalized §3.3
  "gated"     — Gated-LA chunkwise analog (Yang et al. 2023)
  "softmax"   — Regular Attention (direct)
  "flash"     — FlashAttention-2 analog (blocked online softmax)
  "quadratic" — baseline LA (direct Eq. 4, autodiff backward)

Everything here is build-time Python: `aot.py` lowers init / train-step /
eval / logits functions to HLO text once; the Rust coordinator loads and runs
the artifacts and never imports Python.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import baselines
from .kernels.linear_attention import LAParams, linear_attention, normalize_qk

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn", "param_specs"]

ATTN_IMPLS = ("ours", "gated", "softmax", "flash", "quadratic")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyper-parameters (baked into the HLO artifact)."""

    vocab_size: int = 512
    d_model: int = 256
    n_heads: int = 8
    n_layers: int = 4
    n_ctx: int = 256
    attn: str = "ours"
    chunk: int = 64          # sequence chunk for chunked attention impls
    mlp_ratio: int = 4
    rope_base: float = 10000.0
    eps: float = 1e-5

    def __post_init__(self):
        if self.d_model % self.n_heads:
            raise ValueError("d_model must be divisible by n_heads")
        if self.attn not in ATTN_IMPLS:
            raise ValueError(f"attn must be one of {ATTN_IMPLS}")

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Total trainable parameter count."""
        return sum(math.prod(s) for _, s in param_specs(self))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) list — the contract with the Rust side.

    The manifest emitted by aot.py serializes exactly this ordering; the Rust
    checkpoint format stores buffers in the same order.
    """
    c, m = cfg.d_model, cfg.mlp_ratio
    specs: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab_size, c))]
    for i in range(cfg.n_layers):
        p = f"block{i}."
        specs += [
            (p + "ln1.scale", (c,)), (p + "ln1.bias", (c,)),
            (p + "attn.wq", (c, c)), (p + "attn.wk", (c, c)),
            (p + "attn.wv", (c, c)), (p + "attn.wo", (c, c)),
            (p + "ln2.scale", (c,)), (p + "ln2.bias", (c,)),
            (p + "mlp.w1", (c, m * c)), (p + "mlp.b1", (m * c,)),
            (p + "mlp.w2", (m * c, c)), (p + "mlp.b2", (c,)),
        ]
    specs += [("ln_f.scale", (c,)), ("ln_f.bias", (c,))]
    return specs


def init_params(cfg: ModelConfig, seed) -> list[jax.Array]:
    """GPT-2-style init: N(0, 0.02), residual-output projections scaled by
    1/√(2L), LN scales 1, biases 0.  `seed` may be a python int or a traced
    i32 scalar (AOT init artifact).  Returns the flat param_specs list."""
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)

    out: list[jax.Array] = []
    resid_scale = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    for i, (name, shape) in enumerate(param_specs(cfg)):
        sub = jax.random.fold_in(key, i)
        if name.endswith(".scale"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".bias", ".b1", ".b2")):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith((".wo", ".w2")):
            out.append(jax.random.normal(sub, shape, jnp.float32) *
                       resid_scale)
        else:
            out.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return out


def _tree(cfg: ModelConfig, flat: list[jax.Array]) -> dict[str, Any]:
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _layernorm(x, scale, bias, eps):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _rope_tables(tokens: jax.Array, d_head: int, base: float):
    """cos/sin tables computed *in-graph* from the traced token batch.

    Deriving positions from `tokens` (rather than a cached concrete array)
    keeps the tables inside the lowered HLO — jax hoists long-lived closure
    Arrays into extra entry parameters, which would break the fixed
    input contract with the Rust runtime (aot.py asserts this).
    """
    half = d_head // 2
    freqs = (1.0 / base) ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.cumsum(jnp.ones_like(tokens[0], jnp.float32)) - 1.0  # (N,)
    angles = pos[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def _rope(x, cos, sin):
    """Rotary position embedding (half-split form, Su et al. 2024).
    x: (BH, N, D); cos/sin: (N, D/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def _attention(cfg: ModelConfig, q, k, v):
    """Dispatch on the configured implementation. q,k,v: (BH, N, Dh)."""
    if cfg.attn == "ours":
        q, k = normalize_qk(q, k)
        return linear_attention(q, k, v, LAParams(1.0, 1.0),
                                min(cfg.chunk, q.shape[1]))
    if cfg.attn == "gated":
        q, k = normalize_qk(q, k)
        return baselines.gated_la_chunkwise(q, k, v, chunk=cfg.chunk)
    if cfg.attn == "softmax":
        return baselines.softmax_attention(q, k, v)
    if cfg.attn == "flash":
        return baselines.flash_softmax(q, k, v, chunk=cfg.chunk)
    if cfg.attn == "quadratic":
        q, k = normalize_qk(q, k)
        return baselines.quadratic_la(q, k, v)
    raise ValueError(cfg.attn)


def _block(cfg: ModelConfig, p: dict, prefix: str, x, cos, sin):
    b, n, c = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    g = lambda s: p[prefix + s]

    y = _layernorm(x, g("ln1.scale"), g("ln1.bias"), cfg.eps)
    q = (y @ g("attn.wq")).reshape(b, n, h, dh)
    k = (y @ g("attn.wk")).reshape(b, n, h, dh)
    v = (y @ g("attn.wv")).reshape(b, n, h, dh)
    # flatten batch·head for the kernels: (B*H, N, Dh)
    to_bh = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, n, dh)
    q, k, v = to_bh(q), to_bh(k), to_bh(v)
    q = _rope(q, cos, sin)
    k = _rope(k, cos, sin)
    o = _attention(cfg, q, k, v)
    o = o.reshape(b, h, n, dh).transpose(0, 2, 1, 3).reshape(b, n, c)
    x = x + o @ g("attn.wo")

    y = _layernorm(x, g("ln2.scale"), g("ln2.bias"), cfg.eps)
    y = jax.nn.gelu(y @ g("mlp.w1") + g("mlp.b1")) @ g("mlp.w2") + g("mlp.b2")
    return x + y


def forward(cfg: ModelConfig, flat_params: list[jax.Array],
            tokens: jax.Array) -> jax.Array:
    """Logits for a token batch. tokens: i32 (B, N) → f32 (B, N, V).

    Embeddings are tied: the unembedding matrix is embedᵀ.
    """
    p = _tree(cfg, flat_params)
    x = p["embed"][tokens]
    cos, sin = _rope_tables(tokens, cfg.d_head, cfg.rope_base)
    for i in range(cfg.n_layers):
        x = _block(cfg, p, f"block{i}.", x, cos, sin)
    x = _layernorm(x, p["ln_f.scale"], p["ln_f.bias"], cfg.eps)
    return x @ p["embed"].T


def loss_fn(cfg: ModelConfig, flat_params: list[jax.Array],
            tokens: jax.Array) -> jax.Array:
    """Next-token cross-entropy. tokens: i32 (B, N+1); predicts [1:] from [:-1]."""
    x, y = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat_params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
