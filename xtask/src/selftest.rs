//! `lint --self-test`: run the full engine over embedded fixtures — every
//! rule must fire on its seeded violation and stay quiet on the clean
//! twin, mirroring `tests/pool_model.rs`'s broken-twin pattern. A final
//! coverage pass asserts every registered rule is exercised by at least
//! one fixture, so a rule can never ship twin-less.

use crate::parse::SourceFile;
use crate::rules::{run_all, Violation, RULES};
use std::process::ExitCode;

pub struct Fixture {
    pub name: &'static str,
    /// Files as `(rel-path-under-rust/src, source)` — multi-file fixtures
    /// exercise cross-file call-graph edges.
    pub files: &'static [(&'static str, &'static str)],
    /// Rules that MUST fire (empty = must be clean).
    pub expect: &'static [&'static str],
}

pub const FIXTURES: &[Fixture] = &[
    Fixture {
        name: "clean native file with commented unsafe",
        files: &[(
            "native/good.rs",
            r#"
/// Doc. The string "unsafe { }" and the comment below must not trip rules.
// this line mentions partial_cmp but is a comment
fn safe_fn(p: *const f32) -> bool {
    // SAFETY: p is non-null and valid for reads by the caller contract.
    let y = unsafe { *p };
    y.total_cmp(&0.0).is_gt()
}
"#,
        )],
        expect: &[],
    },
    Fixture {
        name: "seeded: uncommented unsafe block",
        files: &[(
            "native/bad_safety.rs",
            r#"
fn oops(p: *const f32) -> f32 {
    unsafe { *p }
}
"#,
        )],
        expect: &["safety-comment"],
    },
    Fixture {
        name: "seeded: unsafe outside native/",
        files: &[(
            "bench/bad_place.rs",
            r#"
// SAFETY: a comment does not make the location legal.
fn oops(p: *const f32) -> f32 {
    unsafe { *p }
}
"#,
        )],
        expect: &["unsafe-location"],
    },
    Fixture {
        name: "seeded: partial_cmp in model code",
        files: &[(
            "native/bad_float.rs",
            r#"
fn pick(a: f32, b: f32) -> bool {
    a.partial_cmp(&b) == Some(core::cmp::Ordering::Greater)
}
"#,
        )],
        expect: &["float-ordering"],
    },
    Fixture {
        name: "seeded: allocation in a deny_alloc function",
        files: &[(
            "native/bad_alloc.rs",
            r#"
// deny_alloc
#[inline]
fn hot(n: usize) -> f32 {
    let tmp = vec![0.0f32; n];
    tmp.iter().sum()
}
"#,
        )],
        expect: &["deny-alloc"],
    },
    Fixture {
        name: "deny_alloc function that is actually clean",
        files: &[(
            "native/good_alloc.rs",
            r#"
// deny_alloc
fn hot(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o += 1.0;
    }
}
"#,
        )],
        expect: &[],
    },
    Fixture {
        name: "seeded: allocation hidden behind a helper, one file away",
        files: &[
            (
                "native/twin_chain_root.rs",
                r#"
// deny_alloc
pub fn hot(out: &mut [f32]) {
    helper_fill(out);
}
"#,
            ),
            (
                "native/twin_chain_helper.rs",
                r#"
pub fn helper_fill(out: &mut [f32]) {
    let tmp = vec![0.0f32; out.len()];
    for (o, t) in out.iter_mut().zip(tmp.iter()) {
        *o = *t;
    }
}
"#,
            ),
        ],
        expect: &["deny-alloc"],
    },
    Fixture {
        name: "deny_alloc chain whose helper carries the contract too",
        files: &[
            (
                "native/twin_chain_root.rs",
                r#"
// deny_alloc
pub fn hot(out: &mut [f32]) {
    helper_fill(out);
}
"#,
            ),
            (
                "native/twin_chain_helper.rs",
                r#"
// deny_alloc
pub fn helper_fill(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o += 1.0;
    }
}
"#,
            ),
        ],
        expect: &[],
    },
    Fixture {
        name: "seeded: panic two calls deep on a no_panic path",
        files: &[(
            "infer/twin_panic.rs",
            r#"
// no_panic
pub fn serve_one(xs: &[f32]) -> f32 {
    mid(xs)
}
fn mid(xs: &[f32]) -> f32 {
    leaf(xs)
}
fn leaf(xs: &[f32]) -> f32 {
    *xs.first().unwrap()
}
"#,
        )],
        expect: &["no-panic"],
    },
    Fixture {
        name: "no_panic chain with guarded, annotated indexing",
        files: &[(
            "infer/twin_panic_clean.rs",
            r#"
// no_panic
pub fn serve_one(xs: &[f32]) -> f32 {
    mid(xs)
}
fn mid(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    // in_bounds: emptiness is checked directly above
    xs[0]
}
"#,
        )],
        expect: &[],
    },
    Fixture {
        name: "seeded: atomic access without an ordering justification",
        files: &[(
            "util/alloc_gate.rs",
            r#"
use std::sync::atomic::{AtomicUsize, Ordering};
pub static HITS: AtomicUsize = AtomicUsize::new(0);
pub fn bump() -> usize {
    HITS.fetch_add(1, Ordering::Relaxed)
}
"#,
        )],
        expect: &["atomic-ordering"],
    },
    Fixture {
        name: "atomic access with a written ordering justification",
        files: &[(
            "util/alloc_gate.rs",
            r#"
use std::sync::atomic::{AtomicUsize, Ordering};
pub static HITS: AtomicUsize = AtomicUsize::new(0);
pub fn bump() -> usize {
    // ordering: Relaxed — a monotone statistic; nothing is published
    HITS.fetch_add(1, Ordering::Relaxed)
}
"#,
        )],
        expect: &[],
    },
];

/// Run one fixture through the real engine and return the fired rules
/// (sorted, deduped).
pub fn fired_rules(fixture: &Fixture) -> (Vec<&'static str>, Vec<Violation>) {
    let files: Vec<SourceFile> = fixture
        .files
        .iter()
        .map(|(rel, src)| SourceFile::new("rust/src", rel, src))
        .collect();
    let (vs, _) = run_all(&files);
    let mut fired: Vec<&'static str> = vs.iter().map(|v| v.rule).collect();
    fired.sort_unstable();
    fired.dedup();
    (fired, vs)
}

pub fn fixture_ok(fixture: &Fixture, fired: &[&str]) -> bool {
    fixture.expect.iter().all(|r| fired.contains(r))
        && fired.iter().all(|r| fixture.expect.contains(r))
}

/// Exit non-zero if any seeded violation goes undetected, a clean twin
/// trips, or some registered rule has no fixture exercising it.
pub fn run_self_test() -> ExitCode {
    let mut failed = false;
    for f in FIXTURES {
        let (fired, vs) = fired_rules(f);
        if fixture_ok(f, &fired) {
            println!("self-test ok: {} → {:?}", f.name, fired);
        } else {
            failed = true;
            eprintln!(
                "self-test FAILED: {} — expected rules {:?}, got {:?}",
                f.name, f.expect, fired
            );
            for v in &vs {
                eprintln!("  {v}");
            }
        }
    }
    // coverage: no registered rule may be twin-less
    let mut uncovered = Vec::new();
    for rule in RULES {
        if !FIXTURES.iter().any(|f| f.expect.contains(rule)) {
            uncovered.push(*rule);
        }
    }
    if !uncovered.is_empty() {
        failed = true;
        eprintln!("self-test FAILED: rules with no seeded fixture: {uncovered:?}");
    }
    if failed {
        eprintln!("xtask lint --self-test: the checker missed a seeded violation");
        ExitCode::FAILURE
    } else {
        println!(
            "xtask lint --self-test: all {} fixtures behaved; every rule of {:?} is exercised",
            FIXTURES.len(),
            RULES
        );
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_behave_exactly_as_the_self_test_demands() {
        for f in FIXTURES {
            let (fired, vs) = fired_rules(f);
            assert!(
                fixture_ok(f, &fired),
                "{}: expected {:?}, got {:?}\n{}",
                f.name,
                f.expect,
                fired,
                vs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
            );
        }
    }

    #[test]
    fn every_registered_rule_has_a_seeding_fixture() {
        for rule in RULES {
            assert!(
                FIXTURES.iter().any(|f| f.expect.contains(rule)),
                "rule {rule} has no fixture that seeds it"
            );
        }
    }

    #[test]
    fn chain_violations_name_the_full_path() {
        let fixture = FIXTURES
            .iter()
            .find(|f| f.name.contains("panic two calls deep"))
            .expect("fixture present");
        let (_, vs) = fired_rules(fixture);
        let v = vs.iter().find(|v| v.rule == "no-panic").expect("violation");
        assert!(v.msg.contains("serve_one"), "{}", v.msg);
        assert!(v.msg.contains("mid"), "{}", v.msg);
        assert!(v.msg.contains("leaf"), "{}", v.msg);
    }
}
