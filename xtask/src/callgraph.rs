//! Workspace-wide call graph over the parsed items, with the conservative
//! resolution policy the transitive contracts run on.
//!
//! Resolution is deliberately heuristic (no type inference):
//!
//! - `Type::name(…)` — exact `(impl type, name)` match, falling back to
//!   free fns of that name; `Self::name(…)` maps `Self` to the caller's
//!   impl type first.
//! - `recv.name(…)` — candidates are fns named `name` **with a `self`
//!   receiver**. A `self.…` receiver prefers the caller's own impl; a
//!   plain-ident receiver must share a substring (≥ 3 chars, case- and
//!   underscore-insensitive) with the impl type name, else the call is
//!   treated as external (std/core) and drops no edge; a complex receiver
//!   (`xs[i].push(…)`, `foo().bar(…)`) keeps every candidate — over- rather
//!   than under-approximating the contract closure.
//! - `name(…)` — free fns of that name.

use crate::parse::{Call, CallKind, FnItem, SourceFile};
use std::collections::{HashMap, HashSet};

/// Index of every non-test fn across the scanned files, addressed as
/// `(file index, fn index)`.
pub struct Graph {
    /// Flattened (file idx, fn idx) pairs; graph node ids index this.
    pub fns: Vec<(usize, usize)>,
    by_method: HashMap<String, Vec<usize>>,
    by_free: HashMap<String, Vec<usize>>,
    by_qual: HashMap<(String, String), Vec<usize>>,
}

impl Graph {
    pub fn new(files: &[SourceFile]) -> Self {
        let mut fns = Vec::new();
        for (fi, sf) in files.iter().enumerate() {
            for (gi, f) in sf.fns.iter().enumerate() {
                if !f.is_test {
                    fns.push((fi, gi));
                }
            }
        }
        let mut by_method: HashMap<String, Vec<usize>> = HashMap::new();
        let mut by_free: HashMap<String, Vec<usize>> = HashMap::new();
        let mut by_qual: HashMap<(String, String), Vec<usize>> = HashMap::new();
        for (idx, &(fi, gi)) in fns.iter().enumerate() {
            let f = &files[fi].fns[gi];
            match &f.impl_ty {
                Some(ty) => {
                    if f.has_self {
                        by_method.entry(f.name.clone()).or_default().push(idx);
                    }
                    by_qual.entry((ty.clone(), f.name.clone())).or_default().push(idx);
                }
                None => by_free.entry(f.name.clone()).or_default().push(idx),
            }
        }
        Graph { fns, by_method, by_free, by_qual }
    }

    pub fn item<'a>(&self, files: &'a [SourceFile], idx: usize) -> (&'a SourceFile, &'a FnItem) {
        let (fi, gi) = self.fns[idx];
        (&files[fi], &files[fi].fns[gi])
    }

    /// Does `impl_ty` define a method/assoc fn named `name`? (Used by the
    /// no-panic rule to tell a workspace `self.expect(…)` call from std's.)
    pub fn impl_defines(&self, impl_ty: &str, name: &str) -> bool {
        self.by_qual.contains_key(&(impl_ty.to_string(), name.to_string()))
    }

    /// Candidate callees for one call site.
    pub fn resolve(
        &self,
        files: &[SourceFile],
        call: &Call,
        caller_impl: Option<&str>,
    ) -> Vec<usize> {
        match call.kind {
            CallKind::Qual => {
                let qual = match (call.recv.as_deref(), caller_impl) {
                    (Some("Self"), Some(ci)) => ci,
                    (Some(q), _) => q,
                    (None, _) => "",
                };
                if let Some(hits) = self.by_qual.get(&(qual.to_string(), call.name.clone())) {
                    return hits.clone();
                }
                self.by_free.get(&call.name).cloned().unwrap_or_default()
            }
            CallKind::Method => {
                let cands = match self.by_method.get(&call.name) {
                    Some(c) => c,
                    None => return Vec::new(),
                };
                let recv = call.recv.as_deref().unwrap_or("<complex>");
                if recv == "<complex>" {
                    return cands.clone();
                }
                let rl: String =
                    recv.trim_matches('_').to_lowercase();
                if rl == "self" {
                    if let Some(ci) = caller_impl {
                        let own: Vec<usize> = cands
                            .iter()
                            .copied()
                            .filter(|&c| self.item(files, c).1.impl_ty.as_deref() == Some(ci))
                            .collect();
                        if !own.is_empty() {
                            return own;
                        }
                    }
                    return cands.clone();
                }
                cands
                    .iter()
                    .copied()
                    .filter(|&c| {
                        let ty = self
                            .item(files, c)
                            .1
                            .impl_ty
                            .as_deref()
                            .unwrap_or("")
                            .to_lowercase();
                        !ty.is_empty() && rl.len() >= 3 && (ty.contains(&rl) || rl.contains(&ty))
                    })
                    .collect()
            }
            CallKind::Free => self.by_free.get(&call.name).cloned().unwrap_or_default(),
        }
    }
}

/// One transitive-contract violation: the offending site plus the call
/// chain that reaches it from the marked root.
pub struct ChainHit {
    /// Node id of the fn the offending token sits in.
    pub node: usize,
    /// 0-based line of the token.
    pub line: usize,
    /// Display form of what was found (`` `vec!` ``, ``indexing `[i]` ``).
    pub what: String,
    /// `key (path:line)` entries from the root down to the offending fn.
    pub chain: Vec<String>,
}

/// DFS from `root`, cutting at callees that carry the contract themselves
/// (they are checked at their own root) or sit on the audited allowlist.
/// Every node on the walk is scanned; hits carry the full call chain.
pub fn transitive_check(
    files: &[SourceFile],
    graph: &Graph,
    root: usize,
    scan: &dyn Fn(&SourceFile, &FnItem) -> Vec<(usize, String)>,
    allowlist: &[(Option<&str>, &str)],
    marked: &dyn Fn(&FnItem) -> bool,
) -> Vec<ChainHit> {
    let mut out = Vec::new();
    let mut seen: HashSet<usize> = HashSet::new();
    let mut stack: Vec<(usize, Vec<String>)> = vec![(root, Vec::new())];
    while let Some((idx, chain)) = stack.pop() {
        if !seen.insert(idx) {
            continue;
        }
        let (sf, f) = graph.item(files, idx);
        let mut here = chain;
        here.push(format!("{} ({}:{})", f.key(), sf.path(), f.line + 1));
        for (ln, what) in scan(sf, f) {
            out.push(ChainHit { node: idx, line: ln, what, chain: here.clone() });
        }
        for call in &f.calls {
            for tgt in graph.resolve(files, call, f.impl_ty.as_deref()) {
                if seen.contains(&tgt) {
                    continue;
                }
                let (_, tf) = graph.item(files, tgt);
                let allowed = allowlist.iter().any(|&(ty, nm)| {
                    nm == tf.name && (ty.is_none() || ty == tf.impl_ty.as_deref())
                });
                if allowed || marked(tf) {
                    continue; // audited primitive / checked at its own root
                }
                stack.push((tgt, here.clone()));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parse::SourceFile;

    fn graph_of(srcs: &[(&str, &str)]) -> (Vec<SourceFile>, Graph) {
        let files: Vec<SourceFile> =
            srcs.iter().map(|(rel, src)| SourceFile::new("rust/src", rel, src)).collect();
        let g = Graph::new(&files);
        (files, g)
    }

    fn resolved_keys(files: &[SourceFile], g: &Graph, caller: &str) -> Vec<String> {
        let idx = (0..g.fns.len())
            .find(|&i| g.item(files, i).1.name == caller)
            .expect("caller fn present");
        let f = g.item(files, idx).1;
        let caller_impl = f.impl_ty.clone();
        let mut out = Vec::new();
        for c in &f.calls {
            for t in g.resolve(files, c, caller_impl.as_deref()) {
                out.push(g.item(files, t).1.key());
            }
        }
        out.sort();
        out.dedup();
        out
    }

    const TWO_IMPLS: &str = "struct ThreadPool;\nstruct DecodeStream;\nimpl ThreadPool {\n    pub fn push(&self, n: usize) -> usize { n }\n}\nimpl DecodeStream {\n    pub fn push(&self, n: usize) -> usize { n + 1 }\n}\n";

    #[test]
    fn ident_receiver_resolves_by_type_substring() {
        let src = format!("{TWO_IMPLS}fn caller(pool: &ThreadPool) {{ pool.push(1); }}\n");
        let (files, g) = graph_of(&[("a.rs", &src)]);
        assert_eq!(resolved_keys(&files, &g, "caller"), vec!["ThreadPool::push"]);
    }

    #[test]
    fn unmatched_ident_receiver_is_treated_as_external() {
        let src = format!("{TWO_IMPLS}fn caller(cdf: &Cdf) {{ cdf.push(1); }}\n");
        let (files, g) = graph_of(&[("a.rs", &src)]);
        assert!(resolved_keys(&files, &g, "caller").is_empty());
    }

    #[test]
    fn short_receivers_never_substring_match() {
        let src = format!("{TWO_IMPLS}fn caller(d: &DecodeStream) {{ d.push(1); }}\n");
        let (files, g) = graph_of(&[("a.rs", &src)]);
        // "d" is too short to claim DecodeStream — external, no edge
        assert!(resolved_keys(&files, &g, "caller").is_empty());
    }

    #[test]
    fn complex_receiver_keeps_every_candidate() {
        let src = format!("{TWO_IMPLS}fn caller(v: &[DecodeStream]) {{ v[0].push(1); }}\n");
        let (files, g) = graph_of(&[("a.rs", &src)]);
        assert_eq!(
            resolved_keys(&files, &g, "caller"),
            vec!["DecodeStream::push", "ThreadPool::push"]
        );
    }

    #[test]
    fn self_receiver_prefers_the_callers_impl() {
        let src = format!(
            "{TWO_IMPLS}impl ThreadPool {{\n    fn caller(&self) {{ self.push(1); }}\n}}\n"
        );
        let (files, g) = graph_of(&[("a.rs", &src)]);
        assert_eq!(resolved_keys(&files, &g, "caller"), vec!["ThreadPool::push"]);
    }

    #[test]
    fn self_qualifier_maps_to_the_callers_impl() {
        let src = "struct A;\nstruct B;\nimpl A {\n    fn mk() -> usize { 1 }\n    fn caller(&self) -> usize { Self::mk() }\n}\nimpl B {\n    fn mk() -> usize { 2 }\n}\n";
        let (files, g) = graph_of(&[("a.rs", src)]);
        assert_eq!(resolved_keys(&files, &g, "caller"), vec!["A::mk"]);
    }

    #[test]
    fn associated_fns_are_not_method_candidates() {
        // Args::parse has no self receiver — `s.parse()` must not edge to it
        let src = "struct Args;\nimpl Args {\n    fn parse(v: usize) -> usize { v }\n}\nfn caller(s: &str) {\n    s.parse::<u32>().ok();\n}\n";
        let (files, g) = graph_of(&[("a.rs", src)]);
        assert!(resolved_keys(&files, &g, "caller").is_empty());
    }

    #[test]
    fn qual_falls_back_to_free_fns_and_crosses_files() {
        let (files, g) = graph_of(&[
            ("a.rs", "fn caller() { other::helper(); }\n"),
            ("b.rs", "pub fn helper() {}\n"),
        ]);
        assert_eq!(resolved_keys(&files, &g, "caller"), vec!["helper"]);
    }

    #[test]
    fn transitive_walk_reports_the_full_chain() {
        let (files, g) = graph_of(&[(
            "a.rs",
            "fn root() { mid(); }\nfn mid() { leaf(); }\nfn leaf() { tok(); }\n",
        )]);
        let root = (0..g.fns.len()).find(|&i| g.item(&files, i).1.name == "root").unwrap();
        let scan = |_sf: &SourceFile, f: &FnItem| -> Vec<(usize, String)> {
            if f.name == "leaf" { vec![(f.line, "`tok`".to_string())] } else { Vec::new() }
        };
        let hits = transitive_check(&files, &g, root, &scan, &[], &|_| false);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].chain.len(), 3);
        assert!(hits[0].chain[0].starts_with("root "));
        assert!(hits[0].chain[2].starts_with("leaf "));
    }

    #[test]
    fn allowlisted_and_marked_callees_cut_the_walk() {
        let (files, g) = graph_of(&[(
            "a.rs",
            "fn root() { audited(); checked(); }\nfn audited() { tok(); }\n// deny_alloc\nfn checked() { tok(); }\n",
        )]);
        let root = (0..g.fns.len()).find(|&i| g.item(&files, i).1.name == "root").unwrap();
        let scan = |_sf: &SourceFile, f: &FnItem| -> Vec<(usize, String)> {
            if f.name != "root" { vec![(f.line, "`tok`".to_string())] } else { Vec::new() }
        };
        let hits =
            transitive_check(&files, &g, root, &scan, &[(None, "audited")], &|f| f.deny_alloc);
        assert!(hits.is_empty(), "both callees must be cut");
    }
}
