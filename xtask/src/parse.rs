//! Lightweight Rust item parser: functions, impl blocks, methods, and the
//! contract markers above them. Built on the masking lexer — a deliberate
//! non-goal is full Rust syntax (no `syn`; the build image is hermetic).
//! Closures are not items of their own: calls inside a closure body are
//! attributed to the enclosing `fn`, which is exactly what the transitive
//! contracts need.

use crate::lexer::{comment_text, mask, token_positions};

/// How a call site names its target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    /// `helper(x)` — a plain path call.
    Free,
    /// `recv.name(x)` — a method call; `recv` holds the receiver ident (or
    /// `<complex>` when the receiver is an expression).
    Method,
    /// `Type::name(x)` — a qualified call; `recv` holds the qualifier.
    Qual,
}

/// One call site extracted from a function body.
#[derive(Debug, Clone)]
pub struct Call {
    pub kind: CallKind,
    /// Receiver ident (Method), qualifier (Qual), or None (Free).
    pub recv: Option<String>,
    pub name: String,
}

/// One `fn` item (free function, inherent/trait-impl method, or nested fn).
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Enclosing `impl` type name, if any.
    pub impl_ty: Option<String>,
    /// 0-based line of the `fn` keyword.
    pub line: usize,
    /// Inclusive 0-based line span of the body (opening `{` .. closing `}`).
    pub body: (usize, usize),
    /// Inside a `#[cfg(test)] mod` region.
    pub is_test: bool,
    pub deny_alloc: bool,
    pub no_panic: bool,
    /// `// bounds:` fn-level audit: indexing in this fn is argued safe as a
    /// whole (used for microkernels where per-line annotations would drown
    /// the code).
    pub bounds_audit: bool,
    /// Declared with a `self` receiver (method rather than associated fn).
    pub has_self: bool,
    pub calls: Vec<Call>,
}

impl FnItem {
    /// Display key: `Type::name` or `name`.
    pub fn key(&self) -> String {
        match &self.impl_ty {
            Some(t) => format!("{t}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// A parsed source file: aligned code/comment views plus the items found.
pub struct SourceFile {
    /// Scan root the file came from (`rust/src` or `xtask/src`).
    pub root: String,
    /// Path relative to the root, with `/` separators.
    pub rel: String,
    pub code_lines: Vec<String>,
    pub com_lines: Vec<String>,
    /// Per line: inside a `#[cfg(test)] mod` block.
    pub test_lines: Vec<bool>,
    pub fns: Vec<FnItem>,
    /// 0-based comment lines consumed as a contract marker by some fn —
    /// any marker line NOT in this set is dangling.
    pub claimed_markers: Vec<usize>,
}

impl SourceFile {
    pub fn new(root: &str, rel: &str, src: &str) -> Self {
        let (code, com) = mask(src);
        let code_lines: Vec<String> = code.split('\n').map(|s| s.to_string()).collect();
        let com_lines: Vec<String> = com.split('\n').map(|s| s.to_string()).collect();
        let test_lines = compute_test_regions(&code_lines);
        let mut sf = SourceFile {
            root: root.to_string(),
            rel: rel.to_string(),
            code_lines,
            com_lines,
            test_lines,
            fns: Vec::new(),
            claimed_markers: Vec::new(),
        };
        parse_fns(&mut sf);
        sf
    }

    /// Display path: `root/rel`.
    pub fn path(&self) -> String {
        format!("{}/{}", self.root, self.rel)
    }
}

/// Per-line flags: inside a `#[cfg(test)]` (or `#[cfg(all(test, …))]`) mod.
fn compute_test_regions(code_lines: &[String]) -> Vec<bool> {
    let n = code_lines.len();
    let mut in_test = vec![false; n];
    let mut pending_attr = false;
    let mut i = 0;
    while i < n {
        let line = &code_lines[i];
        let stripped = line.trim();
        if stripped.starts_with("#[")
            && stripped.contains("cfg")
            && !token_positions(line, "test").is_empty()
        {
            pending_attr = true;
            i += 1;
            continue;
        }
        if pending_attr {
            if stripped.starts_with("#[") || stripped.is_empty() {
                i += 1;
                continue;
            }
            if !token_positions(line, "mod").is_empty() {
                // brace-match the mod block from here
                let mut depth = 0i64;
                let mut opened = false;
                let mut j = i;
                while j < n {
                    for ch in code_lines[j].chars() {
                        if ch == '{' {
                            depth += 1;
                            opened = true;
                        } else if ch == '}' {
                            depth -= 1;
                        }
                    }
                    in_test[j] = true;
                    if opened && depth <= 0 {
                        break;
                    }
                    j += 1;
                }
                i = j + 1;
                pending_attr = false;
                continue;
            }
            pending_attr = false;
        }
        i += 1;
    }
    in_test
}

/// A contract marker found at the start of a comment line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Marker {
    DenyAlloc,
    NoPanic,
    BoundsAudit,
}

/// The marker this comment line carries, if any. The marker token must
/// START the comment text (after `//`/`///`/`//!`), so prose that merely
/// mentions a contract never registers.
pub fn marker_of(com_line: &str) -> Option<Marker> {
    let t = comment_text(com_line);
    if starts_with_ident_token(t, "deny_alloc") {
        Some(Marker::DenyAlloc)
    } else if starts_with_ident_token(t, "no_panic") {
        Some(Marker::NoPanic)
    } else if t.starts_with("bounds:") {
        Some(Marker::BoundsAudit)
    } else {
        None
    }
}

fn starts_with_ident_token(t: &str, tok: &str) -> bool {
    if !t.starts_with(tok) {
        return false;
    }
    match t[tok.len()..].chars().next() {
        Some(c) => !(c.is_alphanumeric() || c == '_'),
        None => true,
    }
}

const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "else", "let", "mut", "ref",
    "dyn", "impl", "pub", "use", "where", "async", "await", "break", "continue", "crate",
    "super", "struct", "enum", "union", "trait", "type", "mod", "static", "const", "extern",
    "move", "unsafe", "fn", "self", "Self", "true", "false",
];

/// Marker lines may sit this many comment/attr/blank lines above the `fn`.
const MARK_LOOKBACK: usize = 16;

fn parse_fns(sf: &mut SourceFile) {
    let n = sf.code_lines.len();
    // impl region stack: (type name, inclusive end line)
    let mut impl_stack: Vec<(String, usize)> = Vec::new();
    let mut fns = Vec::new();
    let mut claimed = Vec::new();
    let mut i = 0;
    while i < n {
        while impl_stack.last().is_some_and(|top| i > top.1) {
            impl_stack.pop();
        }
        let line = &sf.code_lines[i];
        let trimmed = line.trim();
        if !token_positions(line, "impl").is_empty()
            && (trimmed.starts_with("impl") || trimmed.starts_with("unsafe impl"))
        {
            if let Some(ty) = impl_type_name(&sf.code_lines, i) {
                let end = brace_span_end(&sf.code_lines, i);
                impl_stack.push((ty, end));
            }
        }
        if !token_positions(line, "fn").is_empty() {
            let impl_ty = impl_stack.last().map(|t| t.0.clone());
            if let Some(f) = parse_one_fn(sf, i, impl_ty, &mut claimed) {
                fns.push(f);
                // body lines are NOT skipped: nested fns are parsed too
            }
        }
        i += 1;
    }
    sf.fns = fns;
    sf.claimed_markers = claimed;
}

/// The `Self` type an `impl` header names: the last path segment of the
/// type after `for` (trait impls) or after the generics (inherent impls).
fn impl_type_name(code_lines: &[String], i: usize) -> Option<String> {
    // gather the header until `{` or `;`
    let mut buf = String::new();
    let mut j = i;
    while j < code_lines.len() && !buf.contains('{') && !buf.contains(';') {
        buf.push_str(&code_lines[j]);
        buf.push(' ');
        j += 1;
    }
    let header = match buf.find('{') {
        Some(p) => &buf[..p],
        None => &buf[..],
    };
    let tail: String = if let Some(fp) = token_positions(header, "for").first() {
        header.chars().skip(fp + 3).collect()
    } else {
        // strip `unsafe`, `impl`, and one `<…>` generics group
        let chars: Vec<char> = header.chars().collect();
        let mut k = 0;
        let skip_ws = |k: &mut usize, chars: &[char]| {
            while *k < chars.len() && chars[*k].is_whitespace() {
                *k += 1;
            }
        };
        skip_ws(&mut k, &chars);
        for kw in ["unsafe", "impl"] {
            let kwc: Vec<char> = kw.chars().collect();
            if chars.len() >= k + kwc.len() && chars[k..k + kwc.len()] == kwc[..] {
                k += kwc.len();
                skip_ws(&mut k, &chars);
            }
        }
        if k < chars.len() && chars[k] == '<' {
            while k < chars.len() && chars[k] != '>' {
                k += 1;
            }
            if k < chars.len() {
                k += 1;
            }
            skip_ws(&mut k, &chars);
        }
        chars[k.min(chars.len())..].iter().collect()
    };
    // leading path: `(ident::)* ident` with no spaces around `::`
    let tc: Vec<char> = tail.trim().chars().collect();
    let mut pos = 0;
    let mut last: Option<String> = None;
    loop {
        let start = pos;
        if pos < tc.len() && (tc[pos].is_alphabetic() || tc[pos] == '_') {
            while pos < tc.len() && (tc[pos].is_alphanumeric() || tc[pos] == '_') {
                pos += 1;
            }
            last = Some(tc[start..pos].iter().collect());
        } else {
            break;
        }
        if pos + 1 < tc.len() && tc[pos] == ':' && tc[pos + 1] == ':' {
            pos += 2;
        } else {
            break;
        }
    }
    last
}

/// Inclusive end line of the brace block opening at/after `start`.
fn brace_span_end(code_lines: &[String], start: usize) -> usize {
    let mut depth = 0i64;
    let mut opened = false;
    for (j, line) in code_lines.iter().enumerate().skip(start) {
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            return j;
        }
    }
    code_lines.len().saturating_sub(1)
}

fn parse_one_fn(
    sf: &SourceFile,
    i: usize,
    impl_ty: Option<String>,
    claimed: &mut Vec<usize>,
) -> Option<FnItem> {
    let first: Vec<char> = sf.code_lines[i].chars().collect();
    let p = *token_positions(&sf.code_lines[i], "fn").first()?;
    // the name is the first ident after `fn`
    let mut q = p + 2;
    while q < first.len() && first[q].is_whitespace() {
        q += 1;
    }
    let name_start = q;
    if q >= first.len() || !(first[q].is_alphabetic() || first[q] == '_') {
        return None;
    }
    while q < first.len() && (first[q].is_alphanumeric() || first[q] == '_') {
        q += 1;
    }
    let name: String = first[name_start..q].iter().collect();

    // scan forward for the body span; `;` at paren depth 0 before any `{`
    // means a trait declaration without a body — not an item we track
    let mut paren = 0i64;
    let mut brace = 0i64;
    let mut opened = false;
    let mut start_line = 0usize;
    let mut sig = String::new();
    let mut sig_done = false;
    let mut j = i;
    let mut body: Option<(usize, usize)> = None;
    'outer: while j < sf.code_lines.len() {
        let text: Vec<char> = sf.code_lines[j].chars().collect();
        let mut k = if j == i { p } else { 0 };
        while k < text.len() {
            let ch = text[k];
            if paren > 0 && !sig_done && !opened {
                sig.push(ch);
            }
            if ch == '(' {
                paren += 1;
            } else if ch == ')' {
                paren -= 1;
                if paren == 0 && !sig_done {
                    sig_done = true;
                }
            } else if ch == ';' && paren == 0 && !opened {
                return None;
            } else if ch == '{' {
                if paren == 0 && !opened {
                    start_line = j;
                }
                if paren == 0 || opened {
                    brace += 1;
                    opened = true;
                }
            } else if ch == '}' && opened {
                brace -= 1;
                if brace == 0 {
                    body = Some((start_line, j));
                    break 'outer;
                }
            }
            k += 1;
        }
        j += 1;
    }
    let body = match body {
        Some(b) => b,
        None if opened => (start_line, sf.code_lines.len().saturating_sub(1)),
        None => return None,
    };

    let mut f = FnItem {
        name,
        impl_ty,
        line: i,
        body,
        is_test: sf.test_lines[i],
        deny_alloc: false,
        no_panic: false,
        bounds_audit: false,
        has_self: !token_positions(&sig, "self").is_empty(),
        calls: Vec::new(),
    };

    // contract markers: walk upward over comment/attr/blank lines
    let mut up = i;
    let mut steps = 0;
    while up > 0 && steps < MARK_LOOKBACK {
        up -= 1;
        steps += 1;
        let code = sf.code_lines[up].trim();
        if !code.is_empty() && !code.starts_with('#') {
            break; // real code intervenes
        }
        match marker_of(&sf.com_lines[up]) {
            Some(Marker::DenyAlloc) => {
                f.deny_alloc = true;
                claimed.push(up);
            }
            Some(Marker::NoPanic) => {
                f.no_panic = true;
                claimed.push(up);
            }
            Some(Marker::BoundsAudit) => {
                f.bounds_audit = true;
                claimed.push(up);
            }
            None => {}
        }
    }

    // call sites, line by line over the body span
    for text in sf.code_lines.iter().take(body.1 + 1).skip(body.0) {
        let chars: Vec<char> = text.chars().collect();
        extract_calls(&chars, &mut f.calls);
    }
    Some(f)
}

/// Extract call sites from one (masked) code line. Mirrors the shape
/// `[Qual ::] name [::<…>] (` with macro (`name!(…)`) and keyword
/// filtering; a `.` before the name makes it a method call and captures
/// the receiver ident when there is one.
fn extract_calls(chars: &[char], out: &mut Vec<Call>) {
    let n = chars.len();
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut i = 0;
    while i < n {
        if !is_ident(chars[i]) {
            i += 1;
            continue;
        }
        // an ident run starts here (scanning left-to-right guarantees the
        // previous char is a non-ident)
        let start = i;
        let mut e = i;
        while e < n && is_ident(chars[e]) {
            e += 1;
        }
        i = e;
        if chars[start].is_ascii_digit() {
            continue; // numeric literal, not an ident
        }
        // after the ident: optional spaces, then `(` or a turbofish `::<…>(`
        let mut j = e;
        while j < n && chars[j] == ' ' {
            j += 1;
        }
        let mut is_call = false;
        if j < n && chars[j] == '(' {
            is_call = true;
        } else if j + 1 < n && chars[j] == ':' && chars[j + 1] == ':' {
            let mut k = j + 2;
            while k < n && chars[k] == ' ' {
                k += 1;
            }
            if k < n && chars[k] == '<' {
                // turbofish call: next `(` must be directly preceded
                // (modulo spaces) by the closing `>`
                let mut m = k;
                while m < n && chars[m] != '(' {
                    m += 1;
                }
                if m < n {
                    let mut back = m;
                    while back > k && chars[back - 1] == ' ' {
                        back -= 1;
                    }
                    if back > k && chars[back - 1] == '>' {
                        is_call = true;
                    }
                }
            }
            // plain `Qual::name` — the name is scanned on a later iteration
        }
        if !is_call {
            continue;
        }
        let name: String = chars[start..e].iter().collect();
        if KEYWORDS.contains(&name.as_str()) {
            continue;
        }
        // what precedes the name decides the call kind
        let mut b = start;
        while b > 0 && chars[b - 1] == ' ' {
            b -= 1;
        }
        if b >= 2 && chars[b - 1] == ':' && chars[b - 2] == ':' {
            // qualified: walk back over `Qual ::`
            let mut qe = b - 2;
            while qe > 0 && chars[qe - 1] == ' ' {
                qe -= 1;
            }
            let mut qs = qe;
            while qs > 0 && is_ident(chars[qs - 1]) {
                qs -= 1;
            }
            if qs < qe && !chars[qs].is_ascii_digit() {
                let qual: String = chars[qs..qe].iter().collect();
                out.push(Call { kind: CallKind::Qual, recv: Some(qual), name });
                continue;
            }
            // `>::name(` / `]::name(` — no single qualifying ident; treat
            // as a free call so it can still resolve to a free fn by name
            out.push(Call { kind: CallKind::Free, recv: None, name });
            continue;
        }
        if b >= 1 && chars[b - 1] == '.' {
            // method: receiver ident directly before the dot, if any
            let re = b - 1;
            let mut rs = re;
            while rs > 0 && is_ident(chars[rs - 1]) {
                rs -= 1;
            }
            let recv: String = if rs < re {
                chars[rs..re].iter().collect()
            } else {
                "<complex>".to_string()
            };
            out.push(Call { kind: CallKind::Method, recv: Some(recv), name });
            continue;
        }
        // `fn name(` is a definition, not a call
        let pre: String = chars[..start].iter().collect();
        if token_positions(pre.trim_end(), "fn")
            .last()
            .is_some_and(|&p| p + 2 == pre.trim_end().chars().count())
        {
            continue;
        }
        out.push(Call { kind: CallKind::Free, recv: None, name });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SourceFile {
        SourceFile::new("rust/src", "native/t.rs", src)
    }

    fn find<'a>(sf: &'a SourceFile, name: &str) -> &'a FnItem {
        sf.fns
            .iter()
            .find(|f| f.name == name)
            .unwrap_or_else(|| panic!("fn {name} not parsed; got {:?}", names(sf)))
    }

    fn names(sf: &SourceFile) -> Vec<String> {
        sf.fns.iter().map(|f| f.key()).collect()
    }

    #[test]
    fn generic_fns_and_where_clauses_parse() {
        let sf = parse(
            "fn gen<T: Into<String>, const N: usize>(x: [T; N]) -> usize\nwhere\n    T: Clone,\n{\n    x.len()\n}\n",
        );
        let f = find(&sf, "gen");
        assert!(!f.has_self);
        assert_eq!(f.body.0, 3);
    }

    #[test]
    fn impl_methods_get_the_type_and_self_flag() {
        let sf = parse(
            "struct Pool;\nimpl Pool {\n    pub fn run(&self, n: usize) -> usize { n }\n    pub fn make() -> Pool { Pool }\n}\n",
        );
        let run = find(&sf, "run");
        assert_eq!(run.impl_ty.as_deref(), Some("Pool"));
        assert!(run.has_self);
        let make = find(&sf, "make");
        assert_eq!(make.impl_ty.as_deref(), Some("Pool"));
        assert!(!make.has_self);
    }

    #[test]
    fn trait_impl_for_clause_names_the_self_type() {
        let sf = parse(
            "impl<'a> core::fmt::Display for Violation {\n    fn fmt(&self) -> usize { 0 }\n}\n",
        );
        let f = find(&sf, "fmt");
        assert_eq!(f.impl_ty.as_deref(), Some("Violation"));
    }

    #[test]
    fn trait_declarations_without_bodies_are_skipped() {
        let sf = parse("trait T {\n    fn sig_only(&self);\n    fn with_default(&self) -> usize { 1 }\n}\n");
        assert!(sf.fns.iter().all(|f| f.name != "sig_only"));
        assert!(sf.fns.iter().any(|f| f.name == "with_default"));
    }

    #[test]
    fn same_name_methods_on_different_impls_both_parse() {
        let sf = parse(
            "struct A;\nstruct B;\nimpl A {\n    fn go(&self) -> usize { 1 }\n}\nimpl B {\n    fn go(&self) -> usize { 2 }\n}\n",
        );
        let tys: Vec<_> = sf
            .fns
            .iter()
            .filter(|f| f.name == "go")
            .map(|f| f.impl_ty.clone())
            .collect();
        assert_eq!(tys.len(), 2, "{:?}", names(&sf));
        assert!(tys.contains(&Some("A".to_string())));
        assert!(tys.contains(&Some("B".to_string())));
    }

    #[test]
    fn nested_closures_attribute_calls_to_the_enclosing_fn() {
        let sf = parse(
            "fn outer(xs: &[f32]) -> f32 {\n    let f = |x: f32| helper(x) + inner_helper(x);\n    xs.iter().map(|&x| f(x)).sum()\n}\n",
        );
        let f = find(&sf, "outer");
        let called: Vec<_> = f.calls.iter().map(|c| c.name.as_str()).collect();
        assert!(called.contains(&"helper"), "{called:?}");
        assert!(called.contains(&"inner_helper"), "{called:?}");
    }

    #[test]
    fn macro_invocations_are_not_calls_but_their_args_are_scanned() {
        let sf = parse("fn f(n: usize) {\n    println!(\"{}\", compute(n));\n    assert_eq!(compute(n), 1);\n}\n");
        let f = find(&sf, "f");
        assert!(f.calls.iter().all(|c| c.name != "println"));
        assert!(f.calls.iter().all(|c| c.name != "assert_eq"));
        assert!(f.calls.iter().any(|c| c.name == "compute"));
    }

    #[test]
    fn receivers_and_qualifiers_are_captured() {
        let sf = parse(
            "fn f(pool: &Pool, xs: Vec<f32>) {\n    pool.run(1);\n    Pool::make();\n    Self::assoc();\n    xs[0].clamp(0.0, 1.0);\n    helper();\n}\n",
        );
        let f = find(&sf, "f");
        let get = |nm: &str| {
            f.calls.iter().find(|c| c.name == nm).map(|c| (c.kind, c.recv.clone()))
        };
        assert_eq!(get("run"), Some((CallKind::Method, Some("pool".to_string()))));
        assert_eq!(get("make"), Some((CallKind::Qual, Some("Pool".to_string()))));
        assert_eq!(get("assoc"), Some((CallKind::Qual, Some("Self".to_string()))));
        assert_eq!(get("clamp"), Some((CallKind::Method, Some("<complex>".to_string()))));
        assert_eq!(get("helper"), Some((CallKind::Free, None)));
    }

    #[test]
    fn turbofish_calls_are_calls_and_fn_pointer_types_are_not() {
        let sf = parse(
            "fn f(xs: &[f32], g: fn(usize) -> usize) -> Vec<f32> {\n    let v = xs.iter().copied().collect::<Vec<f32>>();\n    parse::<u32>(\"1\");\n    v\n}\n",
        );
        let f = find(&sf, "f");
        assert!(f.calls.iter().any(|c| c.name == "collect"));
        assert!(f.calls.iter().any(|c| c.name == "parse"));
    }

    #[test]
    fn markers_are_claimed_through_attributes() {
        let sf = parse("// deny_alloc\n// no_panic\n#[inline]\nfn hot(x: &mut [f32]) { x.fill(0.0); }\n");
        let f = find(&sf, "hot");
        assert!(f.deny_alloc && f.no_panic);
        assert_eq!(sf.claimed_markers.len(), 2);
    }

    #[test]
    fn marker_prose_mentions_do_not_register() {
        assert_eq!(marker_of("// the deny_alloc contract is documented here"), None);
        assert_eq!(marker_of("// `deny_alloc` in backticks"), None);
        assert_eq!(marker_of("// deny_allocator"), None);
        assert_eq!(marker_of("// deny_alloc"), Some(Marker::DenyAlloc));
        assert_eq!(marker_of("/// no_panic — reason"), Some(Marker::NoPanic));
        assert_eq!(marker_of("// bounds: argued below"), Some(Marker::BoundsAudit));
        assert_eq!(marker_of("// in_bounds: line-level, not a fn marker"), None);
    }

    #[test]
    fn cfg_test_mod_regions_are_flagged() {
        let sf = parse(
            "fn prod() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { helper(); }\n}\n",
        );
        assert!(!find(&sf, "prod").is_test);
        assert!(find(&sf, "t").is_test);
    }
}
