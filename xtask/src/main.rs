//! `cargo run -p xtask -- lint`: repo-invariant checks clippy can't express.
//!
//! Scans `rust/src` and enforces:
//!
//! 1. **`safety-comment`** — every `unsafe` keyword (block, fn, impl) is
//!    preceded (within 8 lines, comments only) by a written `SAFETY:`
//!    justification (`# Safety` doc headers count).
//! 2. **`unsafe-location`** — `unsafe` appears only under `native/` and in
//!    `util/alloc_gate.rs` (the counting global allocator); everywhere else
//!    is forbidden (and additionally `#![forbid(unsafe_code)]`-pinned).
//! 3. **`float-ordering`** — no `partial_cmp` outside `util/`: float
//!    comparisons in kernel/model/bench code must use `total_cmp`, which
//!    cannot silently drop NaN rows the way `partial_cmp().unwrap_or(...)`
//!    patterns do.
//! 4. **`deny-alloc`** — a function whose preceding comment line contains
//!    `deny_alloc` must not allocate: no `vec!`, `Vec::new`,
//!    `Vec::with_capacity`, `Box::new`, `format!`, `.collect()`,
//!    `.to_vec()`, `.to_string()`, `.to_owned()`, `String::…`, `Arc::new`,
//!    `Rc::new` anywhere in its body. This pins the GEMM microkernels and
//!    the decode `block_step` hot path.
//!
//! The rule engine is a small hand-rolled lexer (line/block comments,
//! strings, raw strings, char-vs-lifetime) producing two aligned views of
//! each file — code-only and comments-only — so rules never fire on
//! commented-out code or string contents. Deliberately dependency-free (no
//! `syn`): the build image is hermetic.
//!
//! `cargo run -p xtask -- lint --self-test` proves the checker has teeth:
//! every rule must fire on an embedded seeded violation (an uncommented
//! `unsafe` block, a stray `partial_cmp`, an allocating `deny_alloc` fn)
//! and stay quiet on the good twin. The same fixtures run under
//! `cargo test -p xtask`.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root: Option<PathBuf> = None;
    let mut self_test = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "lint" => cmd = Some("lint"),
            "--self-test" => self_test = true,
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => return usage("--root needs a path"),
                }
            }
            other => return usage(&format!("unknown argument {other:?}")),
        }
        i += 1;
    }
    match cmd {
        Some("lint") if self_test => run_self_test(),
        Some("lint") => run_lint(root),
        _ => usage("expected a command: lint [--self-test] [--root PATH]"),
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("xtask: {msg}");
    eprintln!("usage: cargo run -p xtask -- lint [--self-test] [--root PATH]");
    ExitCode::from(2)
}

/// Repo root: `--root`, or the workspace directory this crate lives in.
fn repo_root(cli: Option<PathBuf>) -> PathBuf {
    cli.unwrap_or_else(|| {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent dir").to_path_buf()
    })
}

fn run_lint(root: Option<PathBuf>) -> ExitCode {
    let root = repo_root(root);
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        eprintln!("xtask lint: {} is not a directory", src.display());
        return ExitCode::from(2);
    }
    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&src, &mut files) {
        eprintln!("xtask lint: walking {}: {e}", src.display());
        return ExitCode::from(2);
    }
    files.sort();
    let mut violations = Vec::new();
    let mut checked = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&src)
            .expect("collected under src")
            .to_string_lossy()
            .replace('\\', "/");
        match std::fs::read_to_string(path) {
            Ok(text) => {
                checked += 1;
                check_source(&rel, &text, &mut violations);
            }
            Err(e) => {
                eprintln!("xtask lint: reading {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        println!("xtask lint: {checked} files clean (safety-comment, unsafe-location, float-ordering, deny-alloc)");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s) in {checked} files", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rust/src/{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// --- lexer ---------------------------------------------------------------

/// Split `src` into two equal-length, line-aligned views: `code` (comments
/// and string/char contents blanked) and `comments` (everything but comment
/// text blanked). Newlines survive in both so indices map to source lines.
fn mask(src: &str) -> (String, String) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut code = String::with_capacity(src.len());
    let mut com = String::with_capacity(src.len());
    let keep_nl = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0;
    while i < n {
        let c = b[i];
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                code.push(' ');
                com.push(b[i]);
                i += 1;
            }
            continue;
        }
        // block comment (nesting, as in Rust)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    code.push(' ');
                    com.push('/');
                    code.push(' ');
                    com.push('*');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    code.push(' ');
                    com.push('*');
                    code.push(' ');
                    com.push('/');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    code.push(keep_nl(b[i]));
                    com.push(b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // raw string r"…" / r#"…"# (with optional b prefix)
        let raw_at = if c == 'r' && !prev_is_ident(&b, i) {
            Some(i + 1)
        } else if c == 'b' && !prev_is_ident(&b, i) && i + 1 < n && b[i + 1] == 'r' {
            Some(i + 2)
        } else {
            None
        };
        if let Some(mut j) = raw_at {
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                // emit the prefix + opening quote as code, then blank until
                // the matching `"###…` terminator
                while i <= j {
                    code.push(b[i]);
                    com.push(' ');
                    i += 1;
                }
                'scan: while i < n {
                    if b[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                code.push(b[i]);
                                com.push(' ');
                                i += 1;
                            }
                            break 'scan;
                        }
                    }
                    code.push(keep_nl(b[i]));
                    com.push(keep_nl(b[i]));
                    i += 1;
                }
                continue;
            }
            // `r` / `br` not followed by a string — fall through as code
        }
        // ordinary string (also covers b"…")
        if c == '"' {
            code.push('"');
            com.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    code.push(' ');
                    com.push(' ');
                    code.push(keep_nl(b[i + 1]));
                    com.push(keep_nl(b[i + 1]));
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    code.push('"');
                    com.push(' ');
                    i += 1;
                    break;
                }
                code.push(keep_nl(b[i]));
                com.push(keep_nl(b[i]));
                i += 1;
            }
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char literal: '…' with a backslash
                code.push(' ');
                com.push(' ');
                i += 1;
                while i < n && b[i] != '\'' {
                    code.push(keep_nl(b[i]));
                    com.push(keep_nl(b[i]));
                    i += 1;
                }
                if i < n {
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // plain 'x' char literal
                // all three chars (quotes + payload) are blanked in both views
                for _ in 0..3 {
                    code.push(keep_nl(b[i]));
                    com.push(' ');
                    i += 1;
                }
                continue;
            }
            // lifetime ('a) or lone quote — plain code
            code.push('\'');
            com.push(' ');
            i += 1;
            continue;
        }
        code.push(c);
        com.push(keep_nl(c));
        i += 1;
    }
    (code, com)
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Positions (0-based char index) where `token` occurs in `hay` with
/// identifier boundaries on both sides.
fn token_positions(hay: &str, token: &str) -> Vec<usize> {
    let h: Vec<char> = hay.chars().collect();
    let t: Vec<char> = token.chars().collect();
    let mut out = Vec::new();
    if t.is_empty() || h.len() < t.len() {
        return out;
    }
    let boundary_needed = t[0].is_alphanumeric() || t[0] == '_';
    for s in 0..=h.len() - t.len() {
        if h[s..s + t.len()] != t[..] {
            continue;
        }
        if boundary_needed && s > 0 && (h[s - 1].is_alphanumeric() || h[s - 1] == '_') {
            continue;
        }
        let e = s + t.len();
        let last = t[t.len() - 1];
        if (last.is_alphanumeric() || last == '_')
            && e < h.len()
            && (h[e].is_alphanumeric() || h[e] == '_')
        {
            continue;
        }
        out.push(s);
    }
    out
}

// --- rules ---------------------------------------------------------------

/// Files allowed to contain `unsafe`: the native executor and the counting
/// global allocator (a `GlobalAlloc` impl is unsafe by definition).
fn unsafe_allowed(rel: &str) -> bool {
    rel.starts_with("native/") || rel == "util/alloc_gate.rs"
}

/// Files exempt from the `partial_cmp` ban (the util layer may build
/// ordering helpers).
fn float_ordering_exempt(rel: &str) -> bool {
    rel.starts_with("util/")
}

/// How many comment lines above an `unsafe` keyword may hold the SAFETY
/// justification.
const SAFETY_LOOKBACK: usize = 8;

const DENY_ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "Vec::new",
    "Vec::with_capacity",
    "Box::new",
    "String::new",
    "String::from",
    "String::with_capacity",
    "Arc::new",
    "Rc::new",
    "format!",
    ".collect()",
    ".to_vec()",
    ".to_string()",
    ".to_owned()",
];

fn check_source(rel: &str, src: &str, out: &mut Vec<Violation>) {
    let (code, com) = mask(src);
    let code_lines: Vec<&str> = code.lines().collect();
    let com_lines: Vec<&str> = com.lines().collect();

    // rules 1 + 2: unsafe placement and SAFETY comments
    for (ln, line) in code_lines.iter().enumerate() {
        if token_positions(line, "unsafe").is_empty() {
            continue;
        }
        if !unsafe_allowed(rel) {
            out.push(Violation {
                file: rel.to_string(),
                line: ln + 1,
                rule: "unsafe-location",
                msg: "`unsafe` outside native/ (and util/alloc_gate.rs) — move the unsafe code \
                      or express it safely"
                    .to_string(),
            });
            continue;
        }
        let lo = ln.saturating_sub(SAFETY_LOOKBACK);
        let justified = com_lines[lo..=ln]
            .iter()
            .any(|c| c.contains("SAFETY") || c.contains("# Safety") || c.contains("Safety:"));
        if !justified {
            out.push(Violation {
                file: rel.to_string(),
                line: ln + 1,
                rule: "safety-comment",
                msg: format!(
                    "`unsafe` without a `// SAFETY:` comment within {SAFETY_LOOKBACK} lines"
                ),
            });
        }
    }

    // rule 3: float ordering
    if !float_ordering_exempt(rel) {
        for (ln, line) in code_lines.iter().enumerate() {
            if !token_positions(line, "partial_cmp").is_empty() {
                out.push(Violation {
                    file: rel.to_string(),
                    line: ln + 1,
                    rule: "float-ordering",
                    msg: "`partial_cmp` outside util/ — use `f32::total_cmp` so NaN cannot \
                          silently reorder"
                        .to_string(),
                });
            }
        }
    }

    // rule 4: deny_alloc-marked functions
    for (ln, cline) in com_lines.iter().enumerate() {
        if !cline.contains("deny_alloc") {
            continue;
        }
        if let Some((fn_line, body)) = function_body_after(&code_lines, ln + 1) {
            for tok in DENY_ALLOC_TOKENS {
                for (bl, bline) in body.iter().enumerate() {
                    if bline.contains(tok) {
                        out.push(Violation {
                            file: rel.to_string(),
                            line: fn_line + bl + 1,
                            rule: "deny-alloc",
                            msg: format!(
                                "`{tok}` inside a `// deny_alloc` function — use a caller-held \
                                 scratch buffer"
                            ),
                        });
                    }
                }
            }
        } else {
            out.push(Violation {
                file: rel.to_string(),
                line: ln + 1,
                rule: "deny-alloc",
                msg: "`deny_alloc` marker with no function following it".to_string(),
            });
        }
    }
}

/// Starting at code line `start`, skip attributes/blank lines to the next
/// `fn`, then return `(fn_first_line_0based, body_lines)` — the lines from
/// the function's opening `{` through its matching close (code view, so
/// braces in strings/comments are already blanked).
fn function_body_after<'a>(code_lines: &[&'a str], start: usize) -> Option<(usize, Vec<&'a str>)> {
    let mut i = start;
    // allow attributes, cfgs, and blanks between the marker and the fn
    while i < code_lines.len() {
        let t = code_lines[i].trim();
        if t.is_empty() || t.starts_with('#') {
            i += 1;
            continue;
        }
        if token_positions(code_lines[i], "fn").is_empty() {
            return None; // something else intervened — marker is dangling
        }
        break;
    }
    if i >= code_lines.len() {
        return None;
    }
    let fn_line = i;
    let mut depth = 0usize;
    let mut opened = false;
    let mut body = Vec::new();
    for line in code_lines.iter().skip(fn_line) {
        if opened || line.contains('{') {
            body.push(*line);
        }
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if opened && depth == 0 {
                        return Some((fn_line, body));
                    }
                }
                _ => {}
            }
        }
    }
    if opened {
        Some((fn_line, body)) // unbalanced (shouldn't happen on rustc-valid code)
    } else {
        None
    }
}

// --- self-test -----------------------------------------------------------

struct Fixture {
    name: &'static str,
    file: &'static str,
    src: &'static str,
    /// Rules that MUST fire (empty = must be clean).
    expect: &'static [&'static str],
}

const FIXTURES: &[Fixture] = &[
    Fixture {
        name: "clean native file with commented unsafe",
        file: "native/good.rs",
        src: r#"
/// Doc. The string "unsafe { }" and the comment below must not trip rules.
// this line mentions partial_cmp but is a comment
fn safe_fn(p: *const f32) -> bool {
    // SAFETY: p is non-null and valid for reads by the caller contract.
    let y = unsafe { *p };
    y.total_cmp(&0.0).is_gt()
}
"#,
        expect: &[],
    },
    Fixture {
        name: "seeded: uncommented unsafe block",
        file: "native/bad_safety.rs",
        src: r#"
fn oops(p: *const f32) -> f32 {
    unsafe { *p }
}
"#,
        expect: &["safety-comment"],
    },
    Fixture {
        name: "seeded: unsafe outside native/",
        file: "bench/bad_place.rs",
        src: r#"
// SAFETY: a comment does not make the location legal.
fn oops(p: *const f32) -> f32 {
    unsafe { *p }
}
"#,
        expect: &["unsafe-location"],
    },
    Fixture {
        name: "seeded: partial_cmp in model code",
        file: "native/bad_float.rs",
        src: r#"
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i].partial_cmp(&xs[best]) == Some(core::cmp::Ordering::Greater) {
            best = i;
        }
    }
    best
}
"#,
        expect: &["float-ordering"],
    },
    Fixture {
        name: "seeded: allocation in a deny_alloc function",
        file: "native/bad_alloc.rs",
        src: r#"
// deny_alloc
#[inline]
fn hot(n: usize) -> f32 {
    let tmp = vec![0.0f32; n];
    tmp.iter().sum()
}
"#,
        expect: &["deny-alloc"],
    },
    Fixture {
        name: "deny_alloc function that is actually clean",
        file: "native/good_alloc.rs",
        src: r#"
// deny_alloc
fn hot(out: &mut [f32]) {
    for o in out.iter_mut() {
        *o += 1.0;
    }
}
"#,
        expect: &[],
    },
];

/// Run every fixture through the real rule engine; exit non-zero if any
/// seeded violation goes undetected (or a clean fixture trips).
fn run_self_test() -> ExitCode {
    let mut failed = false;
    for f in FIXTURES {
        let mut vs = Vec::new();
        check_source(f.file, f.src, &mut vs);
        let fired: Vec<&str> = vs.iter().map(|v| v.rule).collect();
        let ok = f.expect.iter().all(|r| fired.contains(r))
            && fired.iter().all(|r| f.expect.contains(r));
        if ok {
            println!("self-test ok: {} → {:?}", f.name, fired);
        } else {
            failed = true;
            eprintln!("self-test FAILED: {} — expected rules {:?}, got {:?}", f.name, f.expect, fired);
            for v in &vs {
                eprintln!("  {v}");
            }
        }
    }
    if failed {
        eprintln!("xtask lint --self-test: the checker missed a seeded violation");
        ExitCode::FAILURE
    } else {
        println!("xtask lint --self-test: all {} fixtures behaved", FIXTURES.len());
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_for(file: &str, src: &str) -> Vec<&'static str> {
        let mut vs = Vec::new();
        check_source(file, src, &mut vs);
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn fixtures_behave_exactly_as_the_self_test_demands() {
        for f in FIXTURES {
            let fired = rules_for(f.file, f.src);
            assert!(
                f.expect.iter().all(|r| fired.contains(r))
                    && fired.iter().all(|r| f.expect.contains(r)),
                "{}: expected {:?}, got {:?}",
                f.name,
                f.expect,
                fired
            );
        }
    }

    #[test]
    fn masking_blanks_strings_and_keeps_code() {
        let (code, com) = mask("let s = \"unsafe\"; // unsafe here\nlet t = 'a';\n");
        assert!(!code.contains("unsafe"), "string/comment leaked into code: {code:?}");
        assert!(com.contains("unsafe here"), "comment text lost: {com:?}");
        assert!(code.contains("let t ="));
    }

    #[test]
    fn masking_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"vec! unsafe\"#; let c = '\\n'; let q = 'x'; }";
        let (code, _) = mask(src);
        assert!(!code.contains("unsafe"), "{code:?}");
        assert!(!code.contains("vec!"), "{code:?}");
        assert!(code.contains("<'a>"), "lifetime mangled: {code:?}");
    }

    #[test]
    fn token_positions_respect_identifier_boundaries() {
        assert!(token_positions("let unsafer = 1;", "unsafe").is_empty());
        assert_eq!(token_positions("unsafe { }", "unsafe").len(), 1);
        assert!(!token_positions("x.partial_cmp(&y)", "partial_cmp").is_empty());
    }

    #[test]
    fn safety_lookback_window_is_bounded() {
        // a SAFETY comment 10 lines up must NOT satisfy the rule
        let mut src = String::from("// SAFETY: too far away.\n");
        for _ in 0..10 {
            src.push_str("fn pad() {}\n");
        }
        src.push_str("fn f(p: *const f32) -> f32 { unsafe { *p } }\n");
        assert!(rules_for("native/far.rs", &src).contains(&"safety-comment"));
    }

    #[test]
    fn deny_alloc_sees_through_attributes_and_reports_none_on_clean() {
        let src = "// deny_alloc\n#[allow(clippy::too_many_arguments)]\n#[inline]\nfn f(x: &mut [f32]) { x[0] = 1.0; }\n";
        assert!(rules_for("native/a.rs", src).is_empty());
        let bad = "// deny_alloc\nfn f() -> Vec<f32> { Vec::with_capacity(4) }\n";
        assert_eq!(rules_for("native/b.rs", bad), vec!["deny-alloc"]);
    }
}
