//! `cargo run -p xtask -- lint`: repo-invariant checks clippy can't express.
//!
//! The engine builds a workspace-wide call graph from a dependency-free
//! item parser (`parse.rs` on top of the masking lexer in `lexer.rs`,
//! `callgraph.rs` for resolution) and runs the rules in `rules/`:
//!
//! 1. **`safety-comment`** — every `unsafe` keyword is preceded (within 8
//!    lines, comments only) by a written `SAFETY:` justification.
//! 2. **`unsafe-location`** — `unsafe` appears only under `native/` and in
//!    `util/alloc_gate.rs` (the counting global allocator).
//! 3. **`float-ordering`** — no `partial_cmp` outside `util/`: kernel and
//!    model code must use `total_cmp`, which cannot silently drop NaN rows.
//! 4. **`deny-alloc`** — a `// deny_alloc` fn must not allocate, in its own
//!    body or through anything it transitively calls; violations print the
//!    full call chain from the marked root.
//! 5. **`no-panic`** — a `// no_panic` fn (the serve/decode hot path) must
//!    not reach `unwrap`/`expect`/`panic!`-family tokens or un-annotated
//!    slice indexing, transitively. `// in_bounds:` / `// guarded:` /
//!    `// bounds:` annotations are the audited escape hatches.
//! 6. **`atomic-ordering`** — every `Ordering::*` in `native/pool.rs` and
//!    `util/alloc_gate.rs` must carry an adjacent `// ordering:`
//!    justification; the justified set is printed as a reviewable table.
//!
//! `lint` scans `rust/src` and self-hosts over `xtask/src`. Deliberately
//! dependency-free (no `syn`): the build image is hermetic.
//!
//! `lint --self-test` proves the checker has teeth: every rule must fire
//! on an embedded seeded violation (allocation hidden behind a helper one
//! file away, a panic two calls deep, an unjustified atomic ordering) and
//! stay quiet on the clean twin; a coverage pass asserts no registered
//! rule is fixture-less. The same fixtures run under `cargo test -p xtask`.
//!
//! `bench-check [--file PATH]` validates a `BENCH_native.json` against the
//! `bench_native/v7` schema emitted by `rust/src/bench/report.rs`.

#![forbid(unsafe_code)]

mod benchcheck;
mod callgraph;
mod lexer;
mod parse;
mod rules;
mod selftest;

use parse::SourceFile;
use rules::run_all;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Clone, Copy, PartialEq, Eq)]
enum Format {
    Text,
    Json,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root: Option<PathBuf> = None;
    let mut file: Option<PathBuf> = None;
    let mut self_test = false;
    let mut format = Format::Text;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "lint" => cmd = Some("lint"),
            "bench-check" => cmd = Some("bench-check"),
            "--self-test" => self_test = true,
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => return usage("--root needs a path"),
                }
            }
            "--file" => {
                i += 1;
                match args.get(i) {
                    Some(p) => file = Some(PathBuf::from(p)),
                    None => return usage("--file needs a path"),
                }
            }
            "--format" => {
                i += 1;
                match args.get(i).map(|s| s.as_str()) {
                    Some("text") => format = Format::Text,
                    Some("json") => format = Format::Json,
                    _ => return usage("--format needs `text` or `json`"),
                }
            }
            other => return usage(&format!("unknown argument {other:?}")),
        }
        i += 1;
    }
    match cmd {
        Some("lint") if self_test => selftest::run_self_test(),
        Some("lint") => run_lint(root, format),
        Some("bench-check") => run_bench_check(root, file),
        _ => usage("expected a command: lint or bench-check"),
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("xtask: {msg}");
    eprintln!("usage: cargo run -p xtask -- lint [--self-test] [--root PATH] [--format text|json]");
    eprintln!("       cargo run -p xtask -- bench-check [--root PATH] [--file PATH]");
    ExitCode::from(2)
}

/// Repo root: `--root`, or the workspace directory this crate lives in.
fn repo_root(cli: Option<PathBuf>) -> PathBuf {
    cli.unwrap_or_else(|| {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent dir").to_path_buf()
    })
}

/// Load every `.rs` file under `root/<tree>` as a `SourceFile` rooted at
/// `tree` (so paths in diagnostics read `rust/src/...` / `xtask/src/...`).
fn load_tree(
    root: &Path,
    tree: &str,
    required: bool,
    out: &mut Vec<SourceFile>,
) -> Result<(), ExitCode> {
    let dir = root.join(tree);
    if !dir.is_dir() {
        if required {
            eprintln!("xtask lint: {} is not a directory", dir.display());
            return Err(ExitCode::from(2));
        }
        return Ok(());
    }
    let mut paths = Vec::new();
    if let Err(e) = collect_rs_files(&dir, &mut paths) {
        eprintln!("xtask lint: walking {}: {e}", dir.display());
        return Err(ExitCode::from(2));
    }
    paths.sort();
    for path in &paths {
        let rel = path
            .strip_prefix(&dir)
            .expect("collected under tree")
            .to_string_lossy()
            .replace('\\', "/");
        match std::fs::read_to_string(path) {
            Ok(text) => out.push(SourceFile::new(tree, &rel, &text)),
            Err(e) => {
                eprintln!("xtask lint: reading {}: {e}", path.display());
                return Err(ExitCode::from(2));
            }
        }
    }
    Ok(())
}

fn run_lint(root: Option<PathBuf>, format: Format) -> ExitCode {
    let root = repo_root(root);
    let mut files = Vec::new();
    // rust/src is the product tree; xtask/src is self-hosted so the linter
    // obeys its own contracts.
    if let Err(code) = load_tree(&root, "rust/src", true, &mut files) {
        return code;
    }
    if let Err(code) = load_tree(&root, "xtask/src", false, &mut files) {
        return code;
    }
    let (violations, atomics) = run_all(&files);
    match format {
        Format::Json => {
            for v in &violations {
                println!("{}", v.to_json_line());
            }
        }
        Format::Text => {
            for v in &violations {
                eprintln!("{v}");
            }
            if !atomics.is_empty() {
                println!("audited atomics ({} justified):", atomics.len());
                for row in &atomics {
                    println!(
                        "  {}:{}  {:<8} {}",
                        row.path, row.line, row.ordering, row.note
                    );
                }
            }
        }
    }
    if violations.is_empty() {
        if format == Format::Text {
            println!(
                "xtask lint: {} files clean ({})",
                files.len(),
                rules::RULES.join(", ")
            );
        }
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s) in {} files", violations.len(), files.len());
        ExitCode::FAILURE
    }
}

fn run_bench_check(root: Option<PathBuf>, file: Option<PathBuf>) -> ExitCode {
    let path = file.unwrap_or_else(|| repo_root(root).join("BENCH_native.json"));
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("xtask bench-check: reading {}: {e}", path.display());
            return ExitCode::from(2);
        }
    };
    let doc = match benchcheck::parse_json(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("xtask bench-check: {}: invalid JSON: {e}", path.display());
            return ExitCode::FAILURE;
        }
    };
    let errors = benchcheck::validate_v7(&doc);
    if errors.is_empty() {
        println!("xtask bench-check: {} conforms to bench_native/v7", path.display());
        ExitCode::SUCCESS
    } else {
        for e in &errors {
            eprintln!("xtask bench-check: {}: {e}", path.display());
        }
        eprintln!("xtask bench-check: {} schema error(s)", errors.len());
        ExitCode::FAILURE
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
