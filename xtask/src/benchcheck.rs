//! `xtask bench-check`: validate `BENCH_native.json` against the
//! `bench_native/v7` shape — section presence, per-row field types, and
//! the decode/prefill fidelity-gate fields non-null whenever those arrays
//! carry rows. Extra fields are tolerated (the committed placeholder adds
//! a `note`), `lm[].grad_norm_last` is nullable by design (the emitter
//! writes `null` for a non-finite norm), and empty section arrays are
//! valid: the committed artifact is a placeholder CI overwrites.
//!
//! Ships its own ~100-line JSON reader instead of depending on the `repro`
//! crate: the lint lane must not rebuild the model to validate a file.

use std::collections::HashMap;

/// Minimal JSON value (objects keep a map; duplicate keys keep the last).
pub enum JsonVal {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonVal>),
    Obj(HashMap<String, JsonVal>),
}

impl JsonVal {
    fn type_name(&self) -> &'static str {
        match self {
            JsonVal::Null => "null",
            JsonVal::Bool(_) => "bool",
            JsonVal::Num(_) => "number",
            JsonVal::Str(_) => "string",
            JsonVal::Arr(_) => "array",
            JsonVal::Obj(_) => "object",
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn lit(&mut self, s: &str, v: JsonVal) -> Result<JsonVal, String> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("byte {}: expected `{s}`", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonVal, String> {
        self.skip_ws();
        match self.peek() {
            None => Err("unexpected end of input".to_string()),
            Some(b'n') => self.lit("null", JsonVal::Null),
            Some(b't') => self.lit("true", JsonVal::Bool(true)),
            Some(b'f') => self.lit("false", JsonVal::Bool(false)),
            Some(b'"') => self.string().map(JsonVal::Str),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(JsonVal::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(JsonVal::Arr(items));
                        }
                        _ => return Err(format!("byte {}: expected `,` or `]`", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = HashMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(JsonVal::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    if self.peek() != Some(b':') {
                        return Err(format!("byte {}: expected `:`", self.pos));
                    }
                    self.pos += 1;
                    map.insert(key, self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(JsonVal::Obj(map));
                        }
                        _ => return Err(format!("byte {}: expected `,` or `}}`", self.pos)),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.peek() != Some(b'"') {
            return Err(format!("byte {}: expected a string", self.pos));
        }
        self.pos += 1;
        let mut out = Vec::new();
        while let Some(c) = self.peek() {
            self.pos += 1;
            match c {
                b'"' => {
                    return String::from_utf8(out).map_err(|_| "invalid UTF-8".to_string());
                }
                b'\\' => {
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' | b'\\' | b'/' => out.push(esc),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(8),
                        b'f' => out.push(12),
                        b'u' => {
                            // \uXXXX — decode the code unit (no surrogate
                            // pairing: the bench artifact is ASCII anyway)
                            if self.pos + 4 > self.b.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            let ch = char::from_u32(cp).unwrap_or('\u{fffd}');
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                other => out.push(other),
            }
        }
        Err("unterminated string".to_string())
    }

    fn number(&mut self) -> Result<JsonVal, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| "bad number".to_string())?;
        s.parse::<f64>().map(JsonVal::Num).map_err(|_| format!("byte {start}: bad number `{s}`"))
    }
}

pub fn parse_json(text: &str) -> Result<JsonVal, String> {
    let mut r = Reader { b: text.as_bytes(), pos: 0 };
    let v = r.value()?;
    r.skip_ws();
    if r.pos != r.b.len() {
        return Err(format!("byte {}: trailing data after the document", r.pos));
    }
    Ok(v)
}

/// Field requirement for one row of a section.
enum Field {
    Str(&'static str),
    Num(&'static str),
    /// A fidelity-gate field: must be present and a non-null number.
    Gate(&'static str),
    /// Present-if-any type check only (nullable or optional by design).
    OptNum(&'static str),
}

fn section_spec(name: &str) -> &'static [Field] {
    use Field::*;
    match name {
        "artifacts" => &[
            Str("name"),
            Str("impl"),
            Str("kind"),
            Num("bh"),
            Num("n"),
            Num("d"),
            Num("chunk"),
            Num("median_ns"),
            Num("p10_ns"),
            Num("p90_ns"),
            OptNum("scalar_median_ns"),
            OptNum("speedup_vs_scalar"),
        ],
        "lm" => &[
            Str("preset"),
            Str("attn"),
            Num("n_layer"),
            Num("n_head"),
            Num("d_model"),
            Num("n_params"),
            Num("steps"),
            Num("tokens_per_step"),
            Num("step_s_p50"),
            Num("step_s_p50_rebuild"),
            Num("speedup_inplace"),
            Num("weight_decay"),
            Num("clip_norm"),
            OptNum("grad_norm_last"),
            Num("tokens_per_s"),
            Num("loss_first"),
            Num("loss_last"),
        ],
        "opt" => &[
            Str("preset"),
            Num("n_params"),
            Num("n_param_arrays"),
            Num("inplace_s_p50"),
            Num("rebuild_s_p50"),
            Num("speedup_inplace"),
        ],
        "decode" => &[
            Str("preset"),
            Str("attn"),
            Str("precision"),
            Num("n_params"),
            Num("param_bytes"),
            Num("tokens"),
            Num("recurrent_tok_s"),
            Num("recompute_tok_s"),
            Num("speedup_recurrent"),
            Num("step_s_p50_first_half"),
            Num("step_s_p50_second_half"),
            Num("state_bytes_first"),
            Num("state_bytes_last"),
            Num("state_growth"),
            Gate("logit_maxabs_vs_f32"),
            Gate("nll_delta_vs_f32"),
        ],
        "prefill" => &[
            Str("preset"),
            Str("attn"),
            Str("precision"),
            Num("prompt_tokens"),
            Num("chunk"),
            Num("ttft_ms"),
            Num("prefill_tok_s"),
            Num("serial_tok_s"),
            Num("speedup_vs_serial"),
            Gate("logit_maxabs_vs_serial"),
            Gate("nll_delta_vs_f32"),
        ],
        "serve" => &[
            Str("preset"),
            Str("attn"),
            Str("precision"),
            Num("slots"),
            Num("requests"),
            Num("rejected"),
            Num("occupancy_mean"),
            Num("occupancy_max"),
            Num("ttft_ms_p50"),
            Num("ttft_ms_p95"),
            Num("ttft_ms_p99"),
            Num("latency_ms_p50"),
            Num("latency_ms_p95"),
            Num("latency_ms_p99"),
            Num("decode_tok_s_p50"),
            Num("fit_overhead_ms"),
            Num("fit_bytes_per_s"),
            Num("fit_rms_residual_ms"),
            Num("fit_samples"),
        ],
        _ => &[],
    }
}

const SECTIONS: &[&str] = &["artifacts", "lm", "opt", "decode", "prefill", "serve"];

/// Validate one parsed document. Returns human-readable problems (empty =
/// the document conforms).
pub fn validate_v7(doc: &JsonVal) -> Vec<String> {
    let mut errs = Vec::new();
    let top = match doc {
        JsonVal::Obj(m) => m,
        other => {
            return vec![format!("top level must be an object, got {}", other.type_name())];
        }
    };
    match top.get("schema") {
        Some(JsonVal::Str(s)) if s == "bench_native/v7" => {}
        Some(JsonVal::Str(s)) => errs.push(format!("schema is {s:?}, want \"bench_native/v7\"")),
        Some(other) => errs.push(format!("schema must be a string, got {}", other.type_name())),
        None => errs.push("missing top-level \"schema\"".to_string()),
    }
    for key in ["threads", "chunk"] {
        match top.get(key) {
            Some(JsonVal::Num(_)) => {}
            Some(other) => {
                errs.push(format!("\"{key}\" must be a number, got {}", other.type_name()));
            }
            None => errs.push(format!("missing top-level \"{key}\"")),
        }
    }
    for &sec in SECTIONS {
        let rows = match top.get(sec) {
            Some(JsonVal::Arr(rows)) => rows,
            Some(other) => {
                errs.push(format!("\"{sec}\" must be an array, got {}", other.type_name()));
                continue;
            }
            None => {
                errs.push(format!("missing section \"{sec}\""));
                continue;
            }
        };
        for (ri, row) in rows.iter().enumerate() {
            let obj = match row {
                JsonVal::Obj(m) => m,
                other => {
                    errs.push(format!(
                        "{sec}[{ri}] must be an object, got {}",
                        other.type_name()
                    ));
                    continue;
                }
            };
            for field in section_spec(sec) {
                let (key, want, required, null_ok) = match field {
                    Field::Str(k) => (*k, "string", true, false),
                    Field::Num(k) => (*k, "number", true, false),
                    Field::Gate(k) => (*k, "number", true, false),
                    Field::OptNum(k) => (*k, "number", false, true),
                };
                match obj.get(key) {
                    Some(JsonVal::Str(_)) if want == "string" => {}
                    Some(JsonVal::Num(_)) if want == "number" => {}
                    Some(JsonVal::Null) if null_ok => {}
                    Some(JsonVal::Null) => {
                        let gate = matches!(field, Field::Gate(_));
                        errs.push(format!(
                            "{sec}[{ri}].{key} is null{}",
                            if gate { " — fidelity gate must carry a value" } else { "" }
                        ));
                    }
                    Some(other) => errs.push(format!(
                        "{sec}[{ri}].{key} must be a {want}, got {}",
                        other.type_name()
                    )),
                    None if required => errs.push(format!("{sec}[{ri}] missing \"{key}\"")),
                    None => {}
                }
            }
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_valid() -> String {
        concat!(
            "{\"schema\":\"bench_native/v7\",\"note\":\"extra fields tolerated\",",
            "\"threads\":0,\"chunk\":128,",
            "\"artifacts\":[],\"lm\":[],\"opt\":[],\"decode\":[],\"prefill\":[],",
            "\"serve\":[]}"
        )
        .to_string()
    }

    fn prefill_row(gate: &str) -> String {
        format!(
            concat!(
                "{{\"preset\":\"tiny\",\"attn\":\"ours\",\"precision\":\"f32\",",
                "\"prompt_tokens\":512,\"chunk\":128,\"ttft_ms\":1.0,",
                "\"prefill_tok_s\":100.0,\"serial_tok_s\":50.0,\"speedup_vs_serial\":2.0,",
                "\"logit_maxabs_vs_serial\":{gate},\"nll_delta_vs_f32\":0.0}}"
            ),
            gate = gate
        )
    }

    fn errs_of(doc: &str) -> Vec<String> {
        validate_v7(&parse_json(doc).expect("parse"))
    }

    #[test]
    fn the_empty_placeholder_shape_passes() {
        assert_eq!(errs_of(&minimal_valid()), Vec::<String>::new());
    }

    #[test]
    fn a_missing_section_and_a_bad_type_fail() {
        let doc = minimal_valid().replace(",\"prefill\":[]", "");
        assert!(errs_of(&doc).iter().any(|e| e.contains("missing section \"prefill\"")));
        let doc = minimal_valid().replace("\"threads\":0", "\"threads\":\"zero\"");
        assert!(errs_of(&doc).iter().any(|e| e.contains("\"threads\" must be a number")));
    }

    #[test]
    fn populated_rows_are_field_checked_and_gates_must_be_non_null() {
        let with_row = |gate: &str| {
            let rows = format!("\"prefill\":[{}]", prefill_row(gate));
            minimal_valid().replace("\"prefill\":[]", &rows)
        };
        let good = with_row("0.001");
        assert_eq!(errs_of(&good), Vec::<String>::new());
        let nulled = with_row("null");
        let errs = errs_of(&nulled);
        assert!(errs.iter().any(|e| e.contains("fidelity gate")), "{errs:?}");
        let missing = good.replace("\"ttft_ms\":1.0,", "");
        assert!(errs_of(&missing).iter().any(|e| e.contains("missing \"ttft_ms\"")));
    }

    #[test]
    fn serve_rows_are_field_checked() {
        let row = concat!(
            "{\"preset\":\"tiny\",\"attn\":\"ours\",\"precision\":\"f32\",",
            "\"slots\":4,\"requests\":8,\"rejected\":0,",
            "\"occupancy_mean\":2.5,\"occupancy_max\":4,",
            "\"ttft_ms_p50\":10.0,\"ttft_ms_p95\":20.0,\"ttft_ms_p99\":25.0,",
            "\"latency_ms_p50\":50.0,\"latency_ms_p95\":90.0,\"latency_ms_p99\":99.0,",
            "\"decode_tok_s_p50\":1000.0,\"fit_overhead_ms\":0.2,",
            "\"fit_bytes_per_s\":1e9,\"fit_rms_residual_ms\":0.05,\"fit_samples\":64}"
        );
        let good = minimal_valid().replace("\"serve\":[]", &format!("\"serve\":[{row}]"));
        assert_eq!(errs_of(&good), Vec::<String>::new());
        let missing = good.replace("\"occupancy_mean\":2.5,", "");
        assert!(
            errs_of(&missing).iter().any(|e| e.contains("missing \"occupancy_mean\"")),
            "{:?}",
            errs_of(&missing)
        );
        let bad = good.replace("\"fit_samples\":64", "\"fit_samples\":\"many\"");
        assert!(errs_of(&bad).iter().any(|e| e.contains("fit_samples") && e.contains("number")));
        let doc = minimal_valid().replace(",\"serve\":[]", "");
        assert!(errs_of(&doc).iter().any(|e| e.contains("missing section \"serve\"")));
    }

    #[test]
    fn nullable_grad_norm_is_tolerated_in_lm_rows() {
        let row = concat!(
            "{\"preset\":\"tiny\",\"attn\":\"ours\",\"n_layer\":2,\"n_head\":2,",
            "\"d_model\":32,\"n_params\":1000,\"steps\":2,\"tokens_per_step\":512,",
            "\"step_s_p50\":0.1,\"step_s_p50_rebuild\":0.2,\"speedup_inplace\":2.0,",
            "\"weight_decay\":0.1,\"clip_norm\":1.0,\"grad_norm_last\":null,",
            "\"tokens_per_s\":5120.0,\"loss_first\":5.0,\"loss_last\":4.0}"
        );
        let doc = minimal_valid().replace("\"lm\":[]", &format!("\"lm\":[{row}]"));
        assert_eq!(errs_of(&doc), Vec::<String>::new());
    }

    #[test]
    fn the_parser_rejects_malformed_documents() {
        assert!(parse_json("{\"a\":1,}").is_err());
        assert!(parse_json("{\"a\" 1}").is_err());
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{} trailing").is_err());
        assert!(parse_json("{\"a\": nul}").is_err());
    }
}
