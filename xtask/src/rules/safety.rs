//! `safety-comment` / `unsafe-location`: every `unsafe` keyword carries a
//! written SAFETY justification within a bounded comment window, and
//! `unsafe` may only appear under `rust/src/native/` and in the counting
//! allocator. `xtask/src` is `#![forbid(unsafe_code)]` and additionally
//! lint-banned here, so the checker cannot grow an unsafe surface of its
//! own.

use crate::lexer::token_positions;
use crate::parse::SourceFile;
use crate::rules::Violation;

/// How many comment lines above an `unsafe` keyword may hold the SAFETY
/// justification.
const SAFETY_LOOKBACK: usize = 8;

fn unsafe_allowed(sf: &SourceFile) -> bool {
    sf.root == "rust/src" && (sf.rel.starts_with("native/") || sf.rel == "util/alloc_gate.rs")
}

pub fn check(sf: &SourceFile, out: &mut Vec<Violation>) {
    for (ln, line) in sf.code_lines.iter().enumerate() {
        if token_positions(line, "unsafe").is_empty() {
            continue;
        }
        if !unsafe_allowed(sf) {
            out.push(Violation {
                path: sf.path(),
                line: ln + 1,
                rule: "unsafe-location",
                msg: "`unsafe` outside native/ (and util/alloc_gate.rs) — move the unsafe code \
                      or express it safely"
                    .to_string(),
            });
            continue;
        }
        let lo = ln.saturating_sub(SAFETY_LOOKBACK);
        let justified = sf.com_lines[lo..=ln]
            .iter()
            .any(|c| c.contains("SAFETY") || c.contains("# Safety") || c.contains("Safety:"));
        if !justified {
            out.push(Violation {
                path: sf.path(),
                line: ln + 1,
                rule: "safety-comment",
                msg: format!(
                    "`unsafe` without a `// SAFETY:` comment within {SAFETY_LOOKBACK} lines"
                ),
            });
        }
    }
}
