//! `atomic-ordering`: every memory-ordering constant in the thread pool
//! and the counting allocator must sit within a few lines of a
//! `// ordering: <why>` justification. Justified sites are collected into
//! a reviewable table (printed by `lint` in text mode) so an ordering
//! audit is one read, not a grep.

use crate::lexer::token_positions;
use crate::parse::SourceFile;
use crate::rules::{AtomicRow, Violation};

/// Files under the audit: the only two modules that touch atomics.
const AUDITED: &[&str] = &["rust/src/native/pool.rs", "rust/src/util/alloc_gate.rs"];

const ATOMIC_TOKENS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

/// How many lines above an atomic site the `// ordering:` comment may sit.
const ORDERING_LOOKBACK: usize = 8;

pub fn check(files: &[SourceFile], out: &mut Vec<Violation>) -> Vec<AtomicRow> {
    let mut rows = Vec::new();
    for sf in files {
        if !AUDITED.contains(&sf.path().as_str()) {
            continue;
        }
        for (ln, line) in sf.code_lines.iter().enumerate() {
            if sf.test_lines[ln] {
                continue;
            }
            for &tok in ATOMIC_TOKENS {
                for _ in token_positions(line, tok) {
                    let lo = ln.saturating_sub(ORDERING_LOOKBACK);
                    let just = (lo..=ln)
                        .rev()
                        .map(|cl| &sf.com_lines[cl])
                        .find(|c| c.contains("ordering:"));
                    match just {
                        Some(note) => rows.push(AtomicRow {
                            path: sf.path(),
                            line: ln + 1,
                            ordering: tok.split("::").last().unwrap_or(tok).to_string(),
                            note: note.trim().to_string(),
                        }),
                        None => out.push(Violation {
                            path: sf.path(),
                            line: ln + 1,
                            rule: "atomic-ordering",
                            msg: format!(
                                "`{tok}` without a `// ordering:` justification within \
                                 {ORDERING_LOOKBACK} lines"
                            ),
                        }),
                    }
                }
            }
        }
    }
    rows
}
