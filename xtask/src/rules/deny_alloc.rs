//! `deny-alloc`: a fn marked `// deny_alloc` may not allocate — not in its
//! own body, and not through anything it (transitively) calls. The walk
//! cuts at callees that are themselves marked (checked at their own root)
//! and at the audited allowlist below; everything else reached from a
//! marked root is scanned for allocating tokens, and a hit is reported
//! with the full call chain from the root.

use crate::callgraph::{transitive_check, Graph};
use crate::parse::{marker_of, Marker, SourceFile};
use crate::rules::Violation;

const DENY_ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "Vec::new",
    "Vec::with_capacity",
    "Box::new",
    "String::new",
    "String::from",
    "String::with_capacity",
    "Arc::new",
    "Rc::new",
    "format!",
    ".collect()",
    ".to_vec()",
    ".to_string()",
    ".to_owned()",
];

/// Audited non-allocating-by-contract primitives the walk may rely on
/// without descending into them:
/// - `ThreadPool::run*` — task dispatch reuses the pool's slot storage;
///   steady-state allocation freedom is asserted by `tests/alloc_gate.rs`.
/// - `QuantBuf::append_rows` — itself `// deny_alloc`-marked and
///   amortized-growth audited.
/// - `la_chunk_fwd_carry` — per-chunk scratch is budget-bounded by design
///   (`CHUNK_STATE_FLOATS_BUDGET`); the alloc-gate prefill budget pins it.
const ALLOC_ALLOWLIST: &[(Option<&str>, &str)] = &[
    (Some("ThreadPool"), "run"),
    (Some("ThreadPool"), "run_chunks"),
    (Some("ThreadPool"), "run_chunks3"),
    (Some("ThreadPool"), "run_stripes"),
    (Some("QuantBuf"), "append_rows"),
    (None, "la_chunk_fwd_carry"),
];

pub fn check(files: &[SourceFile], graph: &Graph, out: &mut Vec<Violation>) {
    let scan = |sf: &SourceFile, f: &crate::parse::FnItem| -> Vec<(usize, String)> {
        let mut hits = Vec::new();
        for (ln, line) in
            sf.code_lines.iter().enumerate().take(f.body.1 + 1).skip(f.body.0)
        {
            for tok in DENY_ALLOC_TOKENS {
                if line.contains(tok) {
                    hits.push((ln, format!("`{tok}`")));
                }
            }
        }
        hits
    };
    for root in 0..graph.fns.len() {
        let (_, f) = graph.item(files, root);
        if !f.deny_alloc {
            continue;
        }
        for hit in transitive_check(files, graph, root, &scan, ALLOC_ALLOWLIST, &|tf| {
            tf.deny_alloc
        }) {
            let (hsf, _) = graph.item(files, hit.node);
            let msg = if hit.chain.len() == 1 {
                format!(
                    "{} in `// deny_alloc` fn {} — use a caller-held scratch buffer",
                    hit.what, hit.chain[0]
                )
            } else {
                format!(
                    "{} reachable from `// deny_alloc` root via {}",
                    hit.what,
                    hit.chain.join(" -> ")
                )
            };
            out.push(Violation {
                path: hsf.path(),
                line: hit.line + 1,
                rule: "deny-alloc",
                msg,
            });
        }
    }
    // dangling markers: a marker comment no fn claimed protects nothing
    for sf in files {
        for (ln, com) in sf.com_lines.iter().enumerate() {
            if marker_of(com) == Some(Marker::DenyAlloc) && !sf.claimed_markers.contains(&ln) {
                out.push(Violation {
                    path: sf.path(),
                    line: ln + 1,
                    rule: "deny-alloc",
                    msg: "`deny_alloc` marker with no function following it".to_string(),
                });
            }
        }
    }
}
