//! `float-ordering`: no `partial_cmp` outside `rust/src/util/` — float
//! comparisons in kernel/model/bench code must use `total_cmp`, which
//! cannot silently drop NaN rows the way `partial_cmp().unwrap_or(…)`
//! patterns do. The util layer may build ordering helpers; `xtask` itself
//! gets no exemption.

use crate::lexer::token_positions;
use crate::parse::SourceFile;
use crate::rules::Violation;

fn exempt(sf: &SourceFile) -> bool {
    sf.root == "rust/src" && sf.rel.starts_with("util/")
}

pub fn check(sf: &SourceFile, out: &mut Vec<Violation>) {
    if exempt(sf) {
        return;
    }
    for (ln, line) in sf.code_lines.iter().enumerate() {
        if !token_positions(line, "partial_cmp").is_empty() {
            out.push(Violation {
                path: sf.path(),
                line: ln + 1,
                rule: "float-ordering",
                msg: "`partial_cmp` outside util/ — use `f32::total_cmp` so NaN cannot \
                      silently reorder"
                    .to_string(),
            });
        }
    }
}
