//! Rule registry and the single entry point that runs every rule over a
//! set of parsed files. Per-file rules (safety, float ordering) run first;
//! the call-graph rules (transitive contracts, atomics audit) run over the
//! whole workspace at once.

pub mod atomics;
pub mod deny_alloc;
pub mod float;
pub mod no_panic;
pub mod safety;

use crate::callgraph::Graph;
use crate::parse::SourceFile;
use std::fmt;

/// Every rule id the engine can emit. `--self-test` asserts each one is
/// exercised by at least one seeded fixture — no rule ships twin-less.
pub const RULES: &[&str] = &[
    "safety-comment",
    "unsafe-location",
    "float-ordering",
    "deny-alloc",
    "no-panic",
    "atomic-ordering",
];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Display path: `rust/src/…` or `xtask/src/…`.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

impl Violation {
    /// One-line JSON object for `--format json` (consumed by the CI
    /// problem matcher; keys are emitted in a fixed order).
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"msg\":\"{}\"}}",
            json_escape(&self.path),
            self.line,
            self.rule,
            json_escape(&self.msg)
        )
    }
}

pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One justified atomic site, for the reviewable `ordering:` table.
pub struct AtomicRow {
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// `Relaxed` / `Acquire` / `Release` / `AcqRel` / `SeqCst`.
    pub ordering: String,
    /// The justification comment, trimmed.
    pub note: String,
}

/// Run every rule over `files`. Returns the sorted violation list and the
/// audited-atomics table.
pub fn run_all(files: &[SourceFile]) -> (Vec<Violation>, Vec<AtomicRow>) {
    let mut out = Vec::new();
    for sf in files {
        safety::check(sf, &mut out);
        float::check(sf, &mut out);
    }
    let graph = Graph::new(files);
    deny_alloc::check(files, &graph, &mut out);
    no_panic::check(files, &graph, &mut out);
    let rows = atomics::check(files, &mut out);
    out.sort_by(|a, b| {
        (&a.path, a.line, a.rule, &a.msg).cmp(&(&b.path, b.line, b.rule, &b.msg))
    });
    out.dedup();
    (out, rows)
}
