//! `no-panic`: a fn marked `// no_panic` (the serve/decode hot path) may
//! not reach a panic site — `unwrap`/`expect`/`panic!`/`todo!`/
//! `unimplemented!`/`unreachable!` — or un-annotated slice indexing,
//! transitively through everything it calls.
//!
//! Escape hatches, each carrying a written argument:
//! - line-level `// in_bounds: <why>` — the indexing on this line (or the
//!   line below a comment block) is proven in range;
//! - line-level `// guarded: <why>` — the panic token cannot fire (e.g. a
//!   re-check of an already-validated prefix);
//! - fn-level `// bounds: <why>` — every index in this fn is argued safe
//!   as a whole (microkernel tile loops, where the enclosing dispatch
//!   asserts the spans).
//!
//! `.expect(…)` on `self` is treated as a call edge rather than a panic
//! site when the caller's own impl defines an `expect` method (the JSON
//! parser's `Parser::expect` returns `Result`).

use crate::callgraph::{transitive_check, Graph};
use crate::parse::{marker_of, FnItem, Marker, SourceFile};
use crate::rules::Violation;

const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!", "todo!", "unimplemented!", "unreachable!"];

/// `ThreadPool::run*` re-raises task panics by design (the submitting
/// thread must observe a worker's panic, not deadlock on it); the closures
/// submitted INTO the pool are still walked at their own call sites.
const NO_PANIC_ALLOWLIST: &[(Option<&str>, &str)] = &[
    (Some("ThreadPool"), "run"),
    (Some("ThreadPool"), "run_chunks"),
    (Some("ThreadPool"), "run_chunks3"),
    (Some("ThreadPool"), "run_stripes"),
];

/// The same-line or directly-above contiguous comment block that may hold
/// a line-level annotation for line `ln`.
fn annotation_scope(sf: &SourceFile, ln: usize) -> String {
    let mut anno = sf.com_lines[ln].clone();
    let mut j = ln;
    while j > 0 {
        j -= 1;
        if sf.com_lines[j].trim().is_empty() || !sf.code_lines[j].trim().is_empty() {
            break;
        }
        anno.push(' ');
        anno.push_str(&sf.com_lines[j]);
    }
    anno
}

/// Indexing sites on a (masked) code line: a `[` directly glued to an
/// ident/`]`/`)` — space-separated `[` is a slice TYPE (`&mut [f32]`),
/// not an index. Full-range `[..]` re-slices are not indexing.
fn find_indexing(chars: &[char]) -> Vec<String> {
    let n = chars.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        if chars[i] == '[' {
            let prev = if i > 0 { chars[i - 1] } else { ' ' };
            if prev.is_alphanumeric() || prev == '_' || prev == ']' || prev == ')' {
                let mut depth = 0i64;
                let mut k = i;
                while k < n {
                    if chars[k] == '[' {
                        depth += 1;
                    } else if chars[k] == ']' {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                let inner: String = if k < n {
                    chars[i + 1..k].iter().collect()
                } else {
                    chars[i + 1..].iter().collect()
                };
                let t = inner.trim();
                if !t.is_empty() && t != ".." {
                    out.push(t.chars().take(24).collect());
                }
            }
        }
        i += 1;
    }
    out
}

pub fn check(files: &[SourceFile], graph: &Graph, out: &mut Vec<Violation>) {
    let scan = |sf: &SourceFile, f: &FnItem| -> Vec<(usize, String)> {
        let mut hits = Vec::new();
        for (ln, line) in
            sf.code_lines.iter().enumerate().take(f.body.1 + 1).skip(f.body.0)
        {
            let anno = annotation_scope(sf, ln);
            let guarded = anno.contains("guarded:");
            for tok in PANIC_TOKENS {
                if !line.contains(tok) {
                    continue;
                }
                if guarded {
                    continue;
                }
                if *tok == ".expect(" {
                    if let Some(ty) = f.impl_ty.as_deref() {
                        let squeezed: String = line.chars().filter(|c| *c != ' ').collect();
                        if graph.impl_defines(ty, "expect") && squeezed.contains("self.expect(")
                        {
                            continue; // workspace Result-returning expect
                        }
                    }
                }
                hits.push((ln, format!("`{tok}`")));
            }
            if !f.bounds_audit {
                let chars: Vec<char> = line.chars().collect();
                for inner in find_indexing(&chars) {
                    if anno.contains("in_bounds:") {
                        continue;
                    }
                    hits.push((ln, format!("un-annotated indexing `[{inner}]`")));
                }
            }
        }
        hits
    };
    for root in 0..graph.fns.len() {
        let (_, f) = graph.item(files, root);
        if !f.no_panic {
            continue;
        }
        for hit in
            transitive_check(files, graph, root, &scan, NO_PANIC_ALLOWLIST, &|tf| tf.no_panic)
        {
            let (hsf, _) = graph.item(files, hit.node);
            let msg = if hit.chain.len() == 1 {
                format!("{} in `// no_panic` fn {}", hit.what, hit.chain[0])
            } else {
                format!(
                    "{} reachable from `// no_panic` root via {}",
                    hit.what,
                    hit.chain.join(" -> ")
                )
            };
            out.push(Violation { path: hsf.path(), line: hit.line + 1, rule: "no-panic", msg });
        }
    }
    // dangling markers protect nothing
    for sf in files {
        for (ln, com) in sf.com_lines.iter().enumerate() {
            let m = marker_of(com);
            if (m == Some(Marker::NoPanic) || m == Some(Marker::BoundsAudit))
                && !sf.claimed_markers.contains(&ln)
            {
                let which = if m == Some(Marker::NoPanic) { "no_panic" } else { "bounds:" };
                out.push(Violation {
                    path: sf.path(),
                    line: ln + 1,
                    rule: "no-panic",
                    msg: format!("`{which}` marker with no function following it"),
                });
            }
        }
    }
}
