//! Masking lexer: split Rust source into two aligned, line-preserving
//! views — code-only and comments-only — so downstream rules never fire
//! on commented-out code or string contents.
//!
//! Handles line comments, nested block comments, ordinary and byte
//! strings, raw strings (`r"…"`, `r#"…"#`, `br"…"`), char literals
//! (escaped and plain), and char-vs-lifetime disambiguation. Newlines
//! survive in both views so indices map 1:1 to source lines.

/// Split `src` into `(code, comments)` views of equal length.
pub fn mask(src: &str) -> (String, String) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut code = String::with_capacity(src.len());
    let mut com = String::with_capacity(src.len());
    let keep_nl = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0;
    while i < n {
        let c = b[i];
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                code.push(' ');
                com.push(b[i]);
                i += 1;
            }
            continue;
        }
        // block comment (nesting, as in Rust)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    code.push(' ');
                    com.push('/');
                    code.push(' ');
                    com.push('*');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    code.push(' ');
                    com.push('*');
                    code.push(' ');
                    com.push('/');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    code.push(keep_nl(b[i]));
                    com.push(b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // raw string r"…" / r#"…"# (with optional b prefix)
        let raw_at = if c == 'r' && !prev_is_ident(&b, i) {
            Some(i + 1)
        } else if c == 'b' && !prev_is_ident(&b, i) && i + 1 < n && b[i + 1] == 'r' {
            Some(i + 2)
        } else {
            None
        };
        if let Some(mut j) = raw_at {
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                // emit the prefix + opening quote as code, then blank until
                // the matching `"###…` terminator
                while i <= j {
                    code.push(b[i]);
                    com.push(' ');
                    i += 1;
                }
                'scan: while i < n {
                    if b[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                code.push(b[i]);
                                com.push(' ');
                                i += 1;
                            }
                            break 'scan;
                        }
                    }
                    code.push(keep_nl(b[i]));
                    com.push(keep_nl(b[i]));
                    i += 1;
                }
                continue;
            }
            // `r` / `br` not followed by a string — fall through as code
        }
        // ordinary string (also covers b"…")
        if c == '"' {
            code.push('"');
            com.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    code.push(' ');
                    com.push(' ');
                    code.push(keep_nl(b[i + 1]));
                    com.push(keep_nl(b[i + 1]));
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    code.push('"');
                    com.push(' ');
                    i += 1;
                    break;
                }
                code.push(keep_nl(b[i]));
                com.push(keep_nl(b[i]));
                i += 1;
            }
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char literal: '…' with a backslash
                code.push(' ');
                com.push(' ');
                i += 1;
                while i < n && b[i] != '\'' {
                    code.push(keep_nl(b[i]));
                    com.push(keep_nl(b[i]));
                    i += 1;
                }
                if i < n {
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // plain 'x' char literal: all three chars blanked in both views
                for _ in 0..3 {
                    code.push(keep_nl(b[i]));
                    com.push(' ');
                    i += 1;
                }
                continue;
            }
            // lifetime ('a) or lone quote — plain code
            code.push('\'');
            com.push(' ');
            i += 1;
            continue;
        }
        code.push(c);
        com.push(keep_nl(c));
        i += 1;
    }
    (code, com)
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Positions (0-based char index) where `token` occurs in `hay` with
/// identifier boundaries on both sides.
pub fn token_positions(hay: &str, token: &str) -> Vec<usize> {
    let h: Vec<char> = hay.chars().collect();
    let t: Vec<char> = token.chars().collect();
    let mut out = Vec::new();
    if t.is_empty() || h.len() < t.len() {
        return out;
    }
    let boundary_needed = t[0].is_alphanumeric() || t[0] == '_';
    for s in 0..=h.len() - t.len() {
        if h[s..s + t.len()] != t[..] {
            continue;
        }
        if boundary_needed && s > 0 && (h[s - 1].is_alphanumeric() || h[s - 1] == '_') {
            continue;
        }
        let e = s + t.len();
        let last = t[t.len() - 1];
        if (last.is_alphanumeric() || last == '_')
            && e < h.len()
            && (h[e].is_alphanumeric() || h[e] == '_')
        {
            continue;
        }
        out.push(s);
    }
    out
}

/// The human text of a comment line: strip leading `/` and `!` markers and
/// surrounding whitespace (`// x`, `/// x`, `//! x` all yield `x …`).
pub fn comment_text(line: &str) -> &str {
    let mut t = line.trim();
    loop {
        if let Some(rest) = t.strip_prefix('/') {
            t = rest;
        } else if let Some(rest) = t.strip_prefix('!') {
            t = rest;
        } else {
            break;
        }
    }
    t.trim()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_blanks_strings_and_keeps_code() {
        let (code, com) = mask("let s = \"unsafe\"; // unsafe here\nlet t = 'a';\n");
        assert!(!code.contains("unsafe"), "string/comment leaked into code: {code:?}");
        assert!(com.contains("unsafe here"), "comment text lost: {com:?}");
        assert!(code.contains("let t ="));
    }

    #[test]
    fn masking_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"vec! unsafe\"#; let c = '\\n'; let q = 'x'; }";
        let (code, _) = mask(src);
        assert!(!code.contains("unsafe"), "{code:?}");
        assert!(!code.contains("vec!"), "{code:?}");
        assert!(code.contains("<'a>"), "lifetime mangled: {code:?}");
    }

    #[test]
    fn masking_is_line_aligned() {
        let src = "a\n/* b\nc */\nd \"e\nf\" g\n";
        let (code, com) = mask(src);
        assert_eq!(code.matches('\n').count(), src.matches('\n').count());
        assert_eq!(com.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn token_positions_respect_identifier_boundaries() {
        assert!(token_positions("let unsafer = 1;", "unsafe").is_empty());
        assert_eq!(token_positions("unsafe { }", "unsafe").len(), 1);
        assert!(!token_positions("x.partial_cmp(&y)", "partial_cmp").is_empty());
    }

    #[test]
    fn comment_text_strips_doc_markers() {
        assert_eq!(comment_text("  /// hello"), "hello");
        assert_eq!(comment_text("//! inner"), "inner");
        assert_eq!(comment_text("// ordering: x"), "ordering: x");
    }
}
