"""Repo-root pytest shim: make `pytest python/tests/` work from the root by
putting `python/` (the build-path package tree) on sys.path."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
