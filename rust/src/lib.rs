//! Reproduction of *"Transformer Based Linear Attention with Optimized GPU
//! Kernel Implementation"* (Gerami & Duraiswami, 2025).
//!
//! Three-layer architecture (see `DESIGN.md`):
//! - **L1/L2** (build-time Python): Pallas linear-attention kernels and a JAX
//!   transformer LM, AOT-lowered to HLO text under `artifacts/`.
//! - **L3** (this crate): the coordinator — PJRT runtime, config system, data
//!   pipeline, training loop, synthetic-task evaluation, GPU-traffic
//!   simulator, and the benchmark harness that regenerates every table and
//!   figure of the paper's evaluation section.
//!
//! Python never runs on the request path: the `repro` binary is self-contained
//! once `make artifacts` has produced the HLO modules.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod simulator;
pub mod tasks;
pub mod util;
