#![cfg_attr(feature = "simd", feature(portable_simd))]
// Verification layer (see rust/README.md "Verification"): every unsafe
// operation inside an `unsafe fn` still needs its own `unsafe { }` block with
// a written SAFETY argument, and `cargo run -p xtask -- lint` enforces that
// unsafe code appears only under `native/` (and `util/alloc_gate.rs`).
#![deny(unsafe_op_in_unsafe_fn)]
//! Reproduction of *"Transformer Based Linear Attention with Optimized GPU
//! Kernel Implementation"* (Gerami & Duraiswami, 2025).
//!
//! Multi-backend architecture (see `rust/README.md` for the backend matrix):
//! - **runtime** — the backend abstraction ([`runtime::Backend`] /
//!   [`runtime::Executor`]) plus the [`runtime::Engine`] cache; callers are
//!   backend-agnostic.
//! - **native** (default) — dependency-free pure-Rust CPU implementations of
//!   the paper's causal linear-attention kernels (state scan, chunkwise,
//!   quadratic baselines) and a tiny trainable LM, parallel across batch×heads
//!   on a scoped `std::thread` pool (`RUST_PALLAS_THREADS`) and tiled through
//!   cache-blocked GEMM microkernels (`--features simd` adds nightly
//!   `core::simd` paths). Hermetic: builds and runs with `anyhow` as the only
//!   dependency.
//! - **pjrt** (cargo feature `pjrt`, off by default) — the original AOT path:
//!   Pallas/JAX kernels lowered to HLO text by `python/compile/aot.py` and
//!   executed through a CPU PJRT client.
//!
//! On top of the runtime sit the coordinator (config, training loop,
//! checkpoints, metrics), the data pipeline, the inference subsystem
//! (O(1)-state recurrent decoding, batched generation, and the warm `serve`
//! mode), the synthetic-task evaluation suite, the GPU-traffic simulator,
//! and the benchmark harness that regenerates the paper's tables and
//! figures.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod infer;
pub mod native;
pub mod runtime;
pub mod simulator;
pub mod tasks;
pub mod util;
