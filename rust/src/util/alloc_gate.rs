//! A counting `#[global_allocator]` wrapper: the allocation gate.
//!
//! PR 4's in-place optimizer and this PR's decode scratch reuse both claim
//! "zero steady-state allocations". Prose claims rot; this module turns them
//! into failing tests. Built with `--features alloc-gate`, the crate installs
//! [`CountingAlloc`] as the global allocator, which delegates every call to
//! [`System`] and bumps two sets of counters:
//!
//! - **thread-local** (`const`-initialized `Cell`s, so reading them never
//!   allocates or takes a lock) — what [`measure`] and the gate macros use.
//!   Counting per thread keeps the numbers deterministic: a gated region run
//!   with a 1-thread [`Pool`](crate::native::pool::Pool) executes entirely on
//!   the calling thread, so background noise from other test threads can't
//!   flake the assertion.
//! - **global** (`AtomicU64`, Relaxed — they are statistics, not
//!   synchronization) — for coarse whole-process reporting.
//!
//! The gate macros [`assert_no_alloc!`](crate::assert_no_alloc) and
//! [`alloc_budget!`](crate::alloc_budget) wrap a block and assert on the
//! thread-local delta. Without the `alloc-gate` feature the macros still
//! *run* the block (so gated call sites cost nothing in production builds)
//! but skip the assertion, because no counting allocator is installed and
//! the delta would be a meaningless zero. The real proof lives in
//! `tests/alloc_gate.rs`, which is compiled only under the feature:
//!
//! ```text
//! cargo test --features alloc-gate --test alloc_gate
//! ```
//!
//! This module necessarily contains `unsafe` (implementing [`GlobalAlloc`])
//! and is, with `native/`, one of the two places the `xtask lint`
//! unsafe-location invariant allows it.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Whether the counting allocator is installed as `#[global_allocator]`.
/// The gate macros skip their assertions when this is false.
pub const fn is_active() -> bool {
    cfg!(feature = "alloc-gate")
}

// Global (whole-process) tallies. Relaxed: these are monotone statistics
// read for reporting only; no memory is published through them.
static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);
static TOTAL_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    // `const` initializers: reading/writing these never triggers lazy
    // initialization, and `Cell<u64>` has no destructor to register — so the
    // counting paths themselves perform no allocation and cannot recurse.
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static TL_BYTES_ALLOC: Cell<u64> = const { Cell::new(0) };
    static TL_BYTES_DEALLOC: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn record_alloc(bytes: usize) {
    // ordering: Relaxed — monotone statistics read for reporting only; no
    // memory is published through these counters
    TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
    TOTAL_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    // `try_with`: the allocator can be called during thread teardown after
    // TLS destruction; an allocator must never panic, so drop the sample.
    let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
    let _ = TL_BYTES_ALLOC.try_with(|c| c.set(c.get() + bytes as u64));
}

#[inline]
fn record_dealloc(bytes: usize) {
    let _ = TL_BYTES_DEALLOC.try_with(|c| c.set(c.get() + bytes as u64));
}

/// A [`GlobalAlloc`] that counts and then delegates to [`System`].
pub struct CountingAlloc;

use std::alloc::{GlobalAlloc, Layout, System};

// SAFETY: every method delegates directly to `System`, which upholds the
// `GlobalAlloc` contract; the counting side effects touch only `Cell`s and
// relaxed atomics — no allocation, no panics (`try_with`), no reentrancy.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record_alloc(layout.size());
        // SAFETY: `layout` is forwarded unchanged; the caller upholds the
        // `alloc` preconditions (non-zero size).
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record_alloc(layout.size());
        // SAFETY: as in `alloc`; same layout, same caller contract.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        record_dealloc(layout.size());
        // SAFETY: the caller guarantees `ptr` was allocated by this
        // allocator with `layout`; we allocated it via `System`.
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A realloc is one allocation event plus a size transfer: count the
        // new block as allocated and the old one as freed, so `net_bytes`
        // stays truthful for grow-in-place as well.
        record_alloc(new_size);
        record_dealloc(layout.size());
        // SAFETY: the caller guarantees `ptr`/`layout` describe a live block
        // from this allocator and `new_size > 0`; delegated unchanged.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[cfg(feature = "alloc-gate")]
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Point-in-time reading of the *current thread's* counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocSnapshot {
    allocs: u64,
    bytes_alloc: u64,
    bytes_dealloc: u64,
}

/// What happened (on this thread) between two snapshots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocDelta {
    /// Allocation events (`alloc`, `alloc_zeroed`, and `realloc` each count
    /// as one).
    pub allocs: u64,
    /// Bytes requested by those events.
    pub bytes_allocated: u64,
    /// Bytes released (`dealloc` plus the old block of each `realloc`).
    pub bytes_deallocated: u64,
}

impl AllocDelta {
    /// Bytes retained by the region: allocated minus deallocated. Zero for a
    /// region that churns temporaries but keeps nothing; the number the
    /// "net-zero retained" train-step gate pins.
    pub fn net_bytes(&self) -> i64 {
        self.bytes_allocated as i64 - self.bytes_deallocated as i64
    }
}

/// Read the current thread's counters. Always available (returns zeros when
/// the feature — and hence the counting allocator — is off).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: TL_ALLOCS.with(|c| c.get()),
        bytes_alloc: TL_BYTES_ALLOC.with(|c| c.get()),
        bytes_dealloc: TL_BYTES_DEALLOC.with(|c| c.get()),
    }
}

/// Whole-process totals `(allocation_events, bytes)` since start.
pub fn global_totals() -> (u64, u64) {
    // ordering: Relaxed — a statistics snapshot; the two loads need not be
    // mutually consistent and publish nothing
    (TOTAL_ALLOCS.load(Ordering::Relaxed), TOTAL_BYTES.load(Ordering::Relaxed))
}

/// Run `f` and return its result together with the thread-local
/// [`AllocDelta`] it incurred. Only counts allocations made by the calling
/// thread — run gated regions with a 1-thread `Pool` so all work stays here.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, AllocDelta) {
    let before = snapshot();
    let r = f();
    let after = snapshot();
    (
        r,
        AllocDelta {
            allocs: after.allocs - before.allocs,
            bytes_allocated: after.bytes_alloc - before.bytes_alloc,
            bytes_deallocated: after.bytes_dealloc - before.bytes_dealloc,
        },
    )
}

/// Assert a block performs **zero** allocation events on this thread.
///
/// Evaluates to the block's value. Without the `alloc-gate` feature the
/// block still runs but the assertion is skipped (no counting allocator is
/// installed, so the delta would be vacuously zero anyway).
#[macro_export]
macro_rules! assert_no_alloc {
    ($label:expr, $body:expr) => {{
        let (__gate_r, __gate_d) = $crate::util::alloc_gate::measure(|| $body);
        if $crate::util::alloc_gate::is_active() {
            assert!(
                __gate_d.allocs == 0,
                "{}: expected zero allocations, got {} events / {} bytes",
                $label,
                __gate_d.allocs,
                __gate_d.bytes_allocated
            );
        }
        __gate_r
    }};
}

/// Assert a block stays within an allocation-event budget on this thread.
///
/// `alloc_budget!("label", max_allocs = N, { ... })` evaluates to the
/// block's value; assertion skipped without the `alloc-gate` feature.
#[macro_export]
macro_rules! alloc_budget {
    ($label:expr, max_allocs = $max:expr, $body:expr) => {{
        let (__gate_r, __gate_d) = $crate::util::alloc_gate::measure(|| $body);
        if $crate::util::alloc_gate::is_active() {
            assert!(
                __gate_d.allocs <= $max,
                "{}: allocation budget exceeded: {} events > {} allowed ({} bytes)",
                $label,
                __gate_d.allocs,
                $max,
                __gate_d.bytes_allocated
            );
        }
        __gate_r
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_a_vec_when_counting() {
        let (v, d) = measure(|| vec![0u8; 4096]);
        assert_eq!(v.len(), 4096);
        if is_active() {
            assert!(d.allocs >= 1, "a fresh Vec must be counted: {d:?}");
            assert!(d.bytes_allocated >= 4096, "bytes under-counted: {d:?}");
        } else {
            assert_eq!(d.allocs, 0, "no counting allocator installed");
        }
    }

    #[test]
    fn net_bytes_is_zero_for_a_dropped_temporary() {
        let ((), d) = measure(|| {
            let tmp = vec![0u8; 1024];
            drop(tmp);
        });
        if is_active() {
            assert_eq!(d.net_bytes(), 0, "allocate-then-drop must net out: {d:?}");
        }
    }

    #[test]
    fn gate_macros_pass_through_values() {
        // With the feature off this checks pass-through; with it on, it also
        // checks that pure arithmetic really does not allocate.
        let x = assert_no_alloc!("arith", { 21 * 2 });
        assert_eq!(x, 42);
        let y = alloc_budget!("one vec", max_allocs = 8, { vec![1u8, 2, 3].len() });
        assert_eq!(y, 3);
    }
}
