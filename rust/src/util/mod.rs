//! Small in-tree utilities replacing external crates (the build is offline
//! and hermetic: `anyhow` is the only dependency — see Cargo.toml).

pub mod cli;
pub mod json;
pub mod tomlmini;
