//! Small in-tree utilities replacing external crates (the build is offline:
//! only `xla` + `anyhow` are available — see Cargo.toml).

pub mod cli;
pub mod json;
pub mod tomlmini;
