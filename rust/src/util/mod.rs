//! Small in-tree utilities replacing external crates (the build is offline
//! and hermetic: `anyhow` is the only dependency — see Cargo.toml).

pub mod alloc_gate;
pub mod cli;
pub mod json;
pub mod modelcheck;
pub mod tomlmini;
