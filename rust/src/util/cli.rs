//! Tiny CLI argument helper: `prog <subcommand> [--flag value] [--switch]`.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--steps", "100", "--attn=ours", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("attn"), Some("ours"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--x", "1"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["go", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }
}
