#![forbid(unsafe_code)]
//! A tiny bounded model checker: exhaustively explore every interleaving of
//! a set of modeled threads over a cloneable shared state.
//!
//! This is the dependency-free, always-on companion to the loom lane. The
//! thread-pool's job protocol (`native::pool`) is re-stated in
//! `tests/pool_model.rs` as a handful of *atomic steps* per thread (claim an
//! index, run a task, decrement the countdown, …) and [`explore`] walks the
//! full interleaving graph on every `cargo test` run, checking:
//!
//! - a user **invariant** at every reachable state (e.g. "no task executed
//!   twice");
//! - a **terminal** condition at every state where all threads finished
//!   (e.g. "every task executed exactly once and the panic was delivered");
//! - **deadlock-freedom**: a reachable state where some thread is unfinished
//!   but none can step is reported as a deadlock.
//!
//! Scope, honestly stated: steps interleave under *sequential consistency*
//! (each step is one indivisible action and every thread sees its effects
//! immediately), and blocking is modeled as "not runnable until a predicate
//! holds". That exhaustively covers protocol-logic bugs — lost tasks,
//! double-claims, early completion, deadlocks, dropped panic payloads — but
//! not weak-memory reorderings or lost condvar wakeups; those belong to the
//! loom models (`tests/loom_pool.rs`) and the TSan CI lane.
//!
//! States are deduplicated by `Hash`/`Eq`, so models whose state space is
//! finite terminate even when the raw interleaving count is astronomical.
//! [`explore`] refuses to run past `max_states` distinct states rather than
//! silently truncating coverage.

use std::collections::HashSet;
use std::hash::Hash;

/// One modeled thread: three pure functions over the shared state. The
/// thread's own program counter and locals live *inside* `S` (keyed by the
/// thread id passed to each function) so that state deduplication sees them.
pub struct ThreadSpec<S> {
    /// Name used in diagnostics.
    pub name: &'static str,
    /// True once the thread has terminated (it will never step again).
    pub done: fn(&S, usize) -> bool,
    /// True when the thread can take a step *now*. A thread that is neither
    /// `done` nor `runnable` is blocked (waiting on a predicate); if every
    /// thread is blocked or done while one is still blocked, that state is a
    /// deadlock.
    pub runnable: fn(&S, usize) -> bool,
    /// Perform exactly one atomic step.
    pub step: fn(&mut S, usize),
}

/// What a successful exploration covered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Coverage {
    /// Distinct states reached (after dedup).
    pub states: usize,
    /// Distinct terminal states (all threads done) checked.
    pub terminals: usize,
    /// Total steps taken across all explored edges.
    pub steps: usize,
}

/// Exhaustively explore every interleaving of `threads` from `init`.
///
/// Returns coverage stats, or a description of the first violation found:
/// an invariant failure, a terminal-condition failure, a deadlock, or the
/// `max_states` budget being exceeded (which means *inconclusive*, never
/// "passed").
pub fn explore<S>(
    init: S,
    threads: &[ThreadSpec<S>],
    invariant: impl Fn(&S) -> Result<(), String>,
    terminal: impl Fn(&S) -> Result<(), String>,
    max_states: usize,
) -> Result<Coverage, String>
where
    S: Clone + Eq + Hash,
{
    let mut seen: HashSet<S> = HashSet::new();
    let mut stack: Vec<S> = Vec::new();
    let mut terminals = 0usize;
    let mut steps = 0usize;

    invariant(&init).map_err(|e| format!("invariant violated in the initial state: {e}"))?;
    seen.insert(init.clone());
    stack.push(init);

    while let Some(state) = stack.pop() {
        let mut any_runnable = false;
        let mut all_done = true;
        for (tid, th) in threads.iter().enumerate() {
            if (th.done)(&state, tid) {
                continue;
            }
            all_done = false;
            if !(th.runnable)(&state, tid) {
                continue;
            }
            any_runnable = true;
            let mut next = state.clone();
            (threads[tid].step)(&mut next, tid);
            steps += 1;
            invariant(&next).map_err(|e| {
                format!("invariant violated after a step of thread {:?}: {e}", threads[tid].name)
            })?;
            if seen.insert(next.clone()) {
                if seen.len() > max_states {
                    return Err(format!(
                        "state budget exceeded: more than {max_states} distinct states \
                         (inconclusive — raise the budget or shrink the model)"
                    ));
                }
                stack.push(next);
            }
        }
        if all_done {
            terminals += 1;
            terminal(&state).map_err(|e| format!("terminal condition violated: {e}"))?;
        } else if !any_runnable {
            let blocked: Vec<&str> = threads
                .iter()
                .enumerate()
                .filter(|(tid, th)| !(th.done)(&state, *tid))
                .map(|(_, th)| th.name)
                .collect();
            return Err(format!("deadlock: threads {blocked:?} are blocked forever"));
        }
    }

    Ok(Coverage { states: seen.len(), terminals, steps })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads each do read → increment-local → write-back on a shared
    /// counter. The non-atomic version must be caught losing an update; the
    /// atomic version must pass. This is the checker's own smoke test: it
    /// proves `explore` actually visits the interleavings that matter.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct Counter {
        value: u8,
        /// Per-thread program counter: 0 = about to read, 1 = about to
        /// write, 2 = done.
        pc: [u8; 2],
        /// Per-thread register holding the read snapshot.
        reg: [u8; 2],
    }

    fn counter_done(s: &Counter, tid: usize) -> bool {
        s.pc[tid] == 2
    }

    fn counter_runnable(_: &Counter, _: usize) -> bool {
        true
    }

    fn racy_step(s: &mut Counter, tid: usize) {
        match s.pc[tid] {
            0 => {
                s.reg[tid] = s.value;
                s.pc[tid] = 1;
            }
            _ => {
                s.value = s.reg[tid] + 1;
                s.pc[tid] = 2;
            }
        }
    }

    fn atomic_step(s: &mut Counter, tid: usize) {
        // read-modify-write as ONE step — the atomic fetch_add model
        s.value += 1;
        s.pc[tid] = 2;
    }

    fn threads(step: fn(&mut Counter, usize)) -> Vec<ThreadSpec<Counter>> {
        vec![
            ThreadSpec { name: "t0", done: counter_done, runnable: counter_runnable, step },
            ThreadSpec { name: "t1", done: counter_done, runnable: counter_runnable, step },
        ]
    }

    fn init() -> Counter {
        Counter { value: 0, pc: [0, 0], reg: [0, 0] }
    }

    #[test]
    fn finds_the_lost_update_in_a_racy_counter() {
        let err = explore(
            init(),
            &threads(racy_step),
            |_| Ok(()),
            |s| {
                if s.value == 2 {
                    Ok(())
                } else {
                    Err(format!("lost update: counter ended at {}", s.value))
                }
            },
            10_000,
        )
        .expect_err("the racy interleaving must be found");
        assert!(err.contains("lost update"), "unexpected error: {err}");
    }

    #[test]
    fn passes_the_atomic_counter() {
        let cov = explore(
            init(),
            &threads(atomic_step),
            |_| Ok(()),
            |s| {
                if s.value == 2 {
                    Ok(())
                } else {
                    Err(format!("counter ended at {}", s.value))
                }
            },
            10_000,
        )
        .expect("the atomic protocol has no bad interleaving");
        assert!(cov.terminals >= 1);
        assert!(cov.states >= 3, "must have explored both orders, got {}", cov.states);
    }

    /// A thread blocked on a predicate nobody ever satisfies is a deadlock,
    /// and `explore` must say so instead of hanging or passing.
    #[test]
    fn reports_deadlock() {
        #[derive(Clone, PartialEq, Eq, Hash)]
        struct Stuck {
            flag: bool,
            done: bool,
        }
        let spec = [ThreadSpec::<Stuck> {
            name: "waiter",
            done: |s, _| s.done,
            // waits for a flag no thread sets
            runnable: |s, _| s.flag,
            step: |s, _| s.done = true,
        }];
        let err = explore(Stuck { flag: false, done: false }, &spec, |_| Ok(()), |_| Ok(()), 100)
            .expect_err("must report the deadlock");
        assert!(err.contains("deadlock"), "unexpected error: {err}");
    }

    #[test]
    fn refuses_to_pass_on_a_blown_state_budget() {
        #[derive(Clone, PartialEq, Eq, Hash)]
        struct Big {
            n: u32,
        }
        let spec = [ThreadSpec::<Big> {
            name: "grower",
            done: |s, _| s.n >= 1000,
            runnable: |_, _| true,
            step: |s, _| s.n += 1,
        }];
        let err = explore(Big { n: 0 }, &spec, |_| Ok(()), |_| Ok(()), 10)
            .expect_err("must refuse, not truncate silently");
        assert!(err.contains("budget"), "unexpected error: {err}");
    }

    #[test]
    fn invariant_violations_name_the_stepping_thread() {
        let err = explore(
            init(),
            &threads(atomic_step),
            |s| {
                if s.value < 2 {
                    Ok(())
                } else {
                    Err("value hit 2".to_string())
                }
            },
            |_| Ok(()),
            10_000,
        )
        .expect_err("the invariant must trip");
        assert!(err.contains("invariant violated"), "unexpected error: {err}");
    }
}
