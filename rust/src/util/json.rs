//! Minimal JSON parser + writer — enough for the artifact manifest and the
//! metrics JSONL files.  Full RFC 8259 value grammar, UTF-8 strings with the
//! standard escapes, f64 numbers.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // no_panic
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- accessors -----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.field` chained lookup returning Result with a useful message.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON field {key:?}"))
    }

    // -- writer ---------------------------------------------------------------

    // no_panic
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    // no_panic
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- builders -------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    // no_panic
    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        // in_bounds: pos ≤ bytes.len() — peek() returned Some to get here
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    // in_bounds: pos < bytes.len() — peek() returned Some
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| anyhow!("invalid UTF-8 in string"))?;
                    // guarded: rest is non-empty and from_utf8-validated, so
                    // a first char exists
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        // in_bounds: start ≤ pos ≤ bytes.len() — pos only advances past
        // peeked bytes
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|_| anyhow!("bad number {text:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": [1, 2.5, -3], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"x": {"y": [{"z": 42}]}}"#).unwrap();
        let z = v.get("x").unwrap().get("y").unwrap().as_arr().unwrap()[0]
            .get("z")
            .unwrap()
            .as_usize();
        assert_eq!(z, Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ↯""#).unwrap();
        assert_eq!(v.as_str(), Some("café ↯"));
        let s = Json::str("tab\ttab").to_string();
        assert_eq!(s, "\"tab\\ttab\"");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn scientific_numbers() {
        let v = Json::parse("[1e-3, 2.5E2]").unwrap();
        let a = v.as_arr().unwrap();
        assert!((a[0].as_f64().unwrap() - 1e-3).abs() < 1e-12);
        assert!((a[1].as_f64().unwrap() - 250.0).abs() < 1e-9);
    }
}
