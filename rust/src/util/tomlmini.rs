//! Minimal TOML subset parser for run configs: `[section]` headers and
//! `key = value` pairs with string / integer / float / boolean values and
//! `#` comments.  No arrays-of-tables, no multi-line strings — the run
//! config doesn't need them.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// section name → key → value; top-level keys live under "".
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse the TOML subset.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let Some(name) = name.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = k.trim().to_string();
        let value = parse_value(v.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if let Some(s) = v.strip_prefix('"') {
        let Some(s) = s.strip_suffix('"') else {
            bail!("unterminated string {v:?}");
        };
        return Ok(TomlValue::Str(s.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {v:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_types() {
        let doc = parse(
            r#"
            top = 1
            [train]
            preset = "small"   # comment
            steps = 2_000
            lr = 1e-3
            resume = false
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Int(1));
        assert_eq!(doc["train"]["preset"].as_str(), Some("small"));
        assert_eq!(doc["train"]["steps"].as_usize(), Some(2000));
        assert!((doc["train"]["lr"].as_f64().unwrap() - 1e-3).abs() < 1e-12);
        assert_eq!(doc["train"]["resume"], TomlValue::Bool(false));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse(r#"k = "a#b""#).unwrap();
        assert_eq!(doc[""]["k"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[open").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = what").is_err());
    }
}
