//! Score a trained LM on the synthetic suite via the `lm_*_logits` artifact.

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Engine, Tensor};

use super::suite::{Task, TaskKind};

/// Accuracy summary for one task.
#[derive(Debug, Clone)]
pub struct TaskScore {
    pub task: &'static str,
    pub examples: usize,
    pub positions: usize,
    pub correct: usize,
}

impl TaskScore {
    pub fn accuracy(&self) -> f64 {
        if self.positions == 0 {
            0.0
        } else {
            self.correct as f64 / self.positions as f64
        }
    }
}

/// Run `examples` through the logits artifact in batches and count argmax
/// hits at the answer positions.
///
/// `params` are the first `n_param_arrays` tensors of a training state (or a
/// checkpoint restored by the trainer).
pub fn score_task(
    engine: &Engine,
    logits_artifact: &str,
    params: &[Tensor],
    kind: TaskKind,
    count: usize,
    seed: u64,
) -> Result<TaskScore> {
    let exe = engine.load(logits_artifact)?;
    let meta = &exe.meta;
    let nparam = meta
        .n_param_arrays
        .ok_or_else(|| anyhow!("logits artifact missing n_param_arrays"))?;
    if params.len() < nparam {
        bail!("expected ≥{nparam} param literals, got {}", params.len());
    }
    let batch = meta.batch.ok_or_else(|| anyhow!("missing batch"))?;
    let n_ctx = meta
        .model_field_usize("n_ctx")
        .ok_or_else(|| anyhow!("missing n_ctx"))?;
    let vocab = meta.model_field_usize("vocab_size").unwrap_or(256);

    let task = Task::new(kind, n_ctx)?;
    let examples = task.generate(count, seed);

    let mut score = TaskScore {
        task: kind.name(),
        examples: 0,
        positions: 0,
        correct: 0,
    };
    for chunk in examples.chunks(batch) {
        if chunk.len() < batch {
            break; // static shapes: drop the ragged tail
        }
        let mut data = Vec::with_capacity(batch * n_ctx);
        for ex in chunk {
            data.extend_from_slice(&ex.tokens);
        }
        let tokens = Tensor::i32(vec![batch, n_ctx], data)?;
        let mut args: Vec<&Tensor> = params[..nparam].iter().collect();
        args.push(&tokens);
        let out = exe.run_refs(&args)?;
        let logits = out[0].as_f32()?;
        // logits: (batch, n_ctx, vocab); prediction for pos p reads row p-1
        for (bi, ex) in chunk.iter().enumerate() {
            score.examples += 1;
            for &p in &ex.answer_pos {
                let row = &logits[(bi * n_ctx + (p - 1)) * vocab..][..vocab];
                // total_cmp never panics on NaN; a diverged model (non-finite
                // winner) predicts -1 and simply scores the position wrong
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .filter(|(_, v)| v.is_finite())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(-1);
                score.positions += 1;
                if argmax == ex.tokens[p] {
                    score.correct += 1;
                }
            }
        }
    }
    Ok(score)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_math() {
        let s = TaskScore { task: "copy", examples: 4, positions: 10, correct: 7 };
        assert!((s.accuracy() - 0.7).abs() < 1e-12);
        let z = TaskScore { task: "copy", examples: 0, positions: 0, correct: 0 };
        assert_eq!(z.accuracy(), 0.0);
    }
}
