//! Task generators: token sequences with designated answer positions.

use anyhow::{bail, Result};

use crate::data::rng::SplitMix64;

/// The five synthetic reasoning tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// `k₁ v₁ k₂ v₂ … query=kᵢ → vᵢ` — in-context key/value lookup.
    AssociativeRecall,
    /// `… x y … x → y` — induction-head completion of a repeated bigram.
    Induction,
    /// `seq # seq` — verbatim copy after a separator.
    Copy,
    /// `seq # reverse(seq)` — reversal after a separator.
    Reverse,
    /// `a + b = c (mod 10)` digit sequences.
    ModArithmetic,
}

impl TaskKind {
    pub fn all() -> [TaskKind; 5] {
        [
            TaskKind::AssociativeRecall,
            TaskKind::Induction,
            TaskKind::Copy,
            TaskKind::Reverse,
            TaskKind::ModArithmetic,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            TaskKind::AssociativeRecall => "assoc_recall",
            TaskKind::Induction => "induction",
            TaskKind::Copy => "copy",
            TaskKind::Reverse => "reverse",
            TaskKind::ModArithmetic => "mod_arith",
        }
    }
}

/// One scored example: a fixed-length token row plus the positions whose
/// tokens the model must predict (scored at `pos`, predicting `tokens[pos]`
/// from the prefix `tokens[..pos]`).
#[derive(Debug, Clone)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub answer_pos: Vec<usize>,
}

/// A concrete task instance bound to a sequence length.
#[derive(Debug, Clone)]
pub struct Task {
    pub kind: TaskKind,
    pub seq_len: usize,
}

// byte-token helpers: letters for keys, digits for values, ascii filler
const KEYS: &[u8] = b"abcdefghijklmnopqrstuvwxyz";
const VALS: &[u8] = b"0123456789";
const SEP: u8 = b'#';
const SPACE: u8 = b' ';
const FILL: u8 = b'.';

impl Task {
    pub fn new(kind: TaskKind, seq_len: usize) -> Result<Self> {
        if seq_len < 32 {
            bail!("seq_len {seq_len} too short for the task suite");
        }
        Ok(Self { kind, seq_len })
    }

    /// Generate `count` examples, deterministic in `seed`.
    pub fn generate(&self, count: usize, seed: u64) -> Vec<Example> {
        let mut rng = SplitMix64::new(seed ^ (self.kind.name().len() as u64) << 32);
        (0..count).map(|_| self.generate_one(&mut rng)).collect()
    }

    fn generate_one(&self, rng: &mut SplitMix64) -> Example {
        let mut body: Vec<u8> = Vec::new();
        let mut answers_rel: Vec<usize> = Vec::new();
        match self.kind {
            TaskKind::AssociativeRecall => {
                // pairs "k v " repeated; query "k" then answer v
                let n_pairs = ((self.seq_len - 4) / 3 - 1).min(8).max(2);
                let mut keys: Vec<u8> = KEYS.to_vec();
                rng.shuffle(&mut keys);
                let mut vals = Vec::with_capacity(n_pairs);
                for i in 0..n_pairs {
                    let v = VALS[rng.below(VALS.len())];
                    vals.push(v);
                    body.push(keys[i]);
                    body.push(v);
                    body.push(SPACE);
                }
                let qi = rng.below(n_pairs);
                body.push(keys[qi]);
                answers_rel.push(body.len()); // position of the value token
                body.push(vals[qi]);
            }
            TaskKind::Induction => {
                // random letter stream; plant "x y" early, re-query "x" late
                let x = KEYS[rng.below(KEYS.len())];
                let mut y = KEYS[rng.below(KEYS.len())];
                while y == x {
                    y = KEYS[rng.below(KEYS.len())];
                }
                let stream = (self.seq_len / 2).min(48);
                for i in 0..stream {
                    if i == 2 {
                        body.push(x);
                        body.push(y);
                    } else {
                        let mut c = KEYS[rng.below(KEYS.len())];
                        while c == x {
                            c = KEYS[rng.below(KEYS.len())];
                        }
                        body.push(c);
                    }
                }
                body.push(x);
                answers_rel.push(body.len());
                body.push(y);
            }
            TaskKind::Copy | TaskKind::Reverse => {
                let len = ((self.seq_len - 2) / 2).min(12).max(3);
                let seq: Vec<u8> =
                    (0..len).map(|_| KEYS[rng.below(KEYS.len())]).collect();
                body.extend_from_slice(&seq);
                body.push(SEP);
                let target: Vec<u8> = if self.kind == TaskKind::Copy {
                    seq.clone()
                } else {
                    seq.iter().rev().copied().collect()
                };
                for &t in &target {
                    answers_rel.push(body.len());
                    body.push(t);
                }
            }
            TaskKind::ModArithmetic => {
                let a = rng.below(10);
                let b = rng.below(10);
                let c = (a + b) % 10;
                body.extend_from_slice(
                    format!("{a} + {b} = ").as_bytes(),
                );
                answers_rel.push(body.len());
                body.push(VALS[c]);
            }
        }
        // left-pad with filler so answers sit deep in the context
        let pad = self.seq_len.saturating_sub(body.len());
        let mut tokens: Vec<i32> = Vec::with_capacity(self.seq_len);
        tokens.extend(std::iter::repeat(FILL as i32).take(pad));
        tokens.extend(body.iter().map(|&b| b as i32));
        let answer_pos = answers_rel.iter().map(|&p| p + pad).collect();
        Example { tokens, answer_pos }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_have_right_length_and_valid_answers() {
        for kind in TaskKind::all() {
            let t = Task::new(kind, 128).unwrap();
            for ex in t.generate(20, 0) {
                assert_eq!(ex.tokens.len(), 128, "{kind:?}");
                assert!(!ex.answer_pos.is_empty(), "{kind:?}");
                for &p in &ex.answer_pos {
                    assert!(p > 0 && p < 128, "{kind:?} pos {p}");
                    assert!(ex.tokens[p] < 256 && ex.tokens[p] >= 0);
                }
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let t = Task::new(TaskKind::AssociativeRecall, 64).unwrap();
        let a = t.generate(5, 9);
        let b = t.generate(5, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.answer_pos, y.answer_pos);
        }
    }

    #[test]
    fn recall_answer_is_the_planted_value() {
        let t = Task::new(TaskKind::AssociativeRecall, 64).unwrap();
        for ex in t.generate(50, 3) {
            let p = ex.answer_pos[0];
            let query_key = ex.tokens[p - 1];
            // find the key earlier in context; its successor must equal answer
            let hay = &ex.tokens[..p - 1];
            let found = hay
                .windows(2)
                .rev()
                .find(|w| w[0] == query_key)
                .map(|w| w[1]);
            assert_eq!(found, Some(ex.tokens[p]));
        }
    }

    #[test]
    fn copy_and_reverse_targets_are_correct() {
        for (kind, rev) in [(TaskKind::Copy, false), (TaskKind::Reverse, true)] {
            let t = Task::new(kind, 64).unwrap();
            for ex in t.generate(20, 1) {
                let sep = ex.tokens.iter().position(|&c| c == SEP as i32).unwrap();
                let start = ex.tokens.iter().position(|&c| c != FILL as i32).unwrap();
                let mut src: Vec<i32> = ex.tokens[start..sep].to_vec();
                if rev {
                    src.reverse();
                }
                let tgt: Vec<i32> = ex.answer_pos.iter().map(|&p| ex.tokens[p]).collect();
                assert_eq!(src, tgt);
            }
        }
    }

    #[test]
    fn mod_arith_is_correct() {
        let t = Task::new(TaskKind::ModArithmetic, 32).unwrap();
        for ex in t.generate(30, 2) {
            let p = ex.answer_pos[0];
            let text: String = ex.tokens[..p]
                .iter()
                .map(|&c| c as u8 as char)
                .collect();
            let text = text.trim_start_matches('.');
            let parts: Vec<&str> = text.split_whitespace().collect();
            let a: usize = parts[0].parse().unwrap();
            let b: usize = parts[2].parse().unwrap();
            let want = ((a + b) % 10).to_string();
            assert_eq!(ex.tokens[p] as u8 as char, want.chars().next().unwrap());
        }
    }

    #[test]
    fn rejects_short_context() {
        assert!(Task::new(TaskKind::Copy, 8).is_err());
    }
}
