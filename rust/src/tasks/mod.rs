//! Synthetic reasoning suite — the Table-2 stand-in (DESIGN.md §Substitutions).
//!
//! MMLU/PIQA/ARC need real-world pretraining; at this scale we instead score
//! the in-context abilities the LA literature itself uses as expressivity
//! proxies (Arora et al. 2024): associative recall, induction, copy, reverse,
//! and modular arithmetic.  Each task emits token sequences inside the byte
//! vocabulary (ids < 256, valid for every LM artifact) with designated answer
//! positions; the scorer runs the `lm_*_logits` artifact and counts argmax
//! hits, i.e. 0-shot exact match.

#![forbid(unsafe_code)]

pub mod scorer;
pub mod suite;

pub use scorer::{score_task, TaskScore};
pub use suite::{Example, Task, TaskKind};
