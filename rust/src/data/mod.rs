//! Data substrate: synthetic corpora, tokenization, packing, batching.
//!
//! The paper trains on Wiki-40B (English); this testbed has no network, so we
//! generate a *synthetic grammar corpus* with natural-language-like statistics
//! (Zipfian unigrams, Markov bigram structure, sentence/paragraph segmentation)
//! plus template-based "fact" sentences that give the LM learnable long-range
//! structure.  DESIGN.md §Substitutions records why this preserves the
//! learning-curve comparison the paper makes.

#![forbid(unsafe_code)]

pub mod batcher;
pub mod corpus;
pub mod dataset;
pub mod rng;
pub mod tokenizer;

pub use batcher::Batcher;
pub use corpus::{CorpusConfig, CorpusGenerator, DEFAULT_CORPUS_BYTES};
pub use dataset::{PackedDataset, Split};
pub use tokenizer::{merge_train_slice, ByteTokenizer, DecodeStream, MERGE_TRAIN_CHARS};
