//! Epoch-shuffled batch iterator producing flattened i32 token batches.

use anyhow::{bail, Result};

use super::dataset::{PackedDataset, Split};
use super::rng::SplitMix64;
use crate::runtime::Tensor;

/// Deterministic, epoch-reshuffled batcher over a [`PackedDataset`] split.
///
/// Yields `(B, seq_len+1)` i32 tensors ready for the `lm_*_train_step`
/// artifact. A trailing partial batch is dropped (XLA shapes are static).
pub struct Batcher<'a> {
    ds: &'a PackedDataset,
    split: Split,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a PackedDataset, split: Split, batch: usize, seed: u64) -> Result<Self> {
        if batch == 0 {
            bail!("batch size must be positive");
        }
        if ds.len(split) < batch {
            bail!(
                "split has {} rows < batch size {batch}",
                ds.len(split)
            );
        }
        let mut b = Self {
            ds,
            split,
            batch,
            order: (0..ds.len(split)).collect(),
            cursor: 0,
            epoch: 0,
            seed,
        };
        b.reshuffle();
        Ok(b)
    }

    fn reshuffle(&mut self) {
        let mut rng = SplitMix64::new(self.seed ^ self.epoch.wrapping_mul(0x9E37));
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len(self.split) / self.batch
    }

    /// Next batch, rolling over epochs forever.
    pub fn next_batch(&mut self) -> Result<Tensor> {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let row_len = self.ds.row_len();
        let mut data = Vec::with_capacity(self.batch * row_len);
        let rows = self.ds.rows(self.split);
        for i in 0..self.batch {
            data.extend_from_slice(&rows[self.order[self.cursor + i]]);
        }
        self.cursor += self.batch;
        Tensor::i32(vec![self.batch, row_len], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> PackedDataset {
        let toks: Vec<i32> = (0..2000).collect();
        PackedDataset::pack(&toks, 9, 0.1, 0).unwrap()
    }

    #[test]
    fn batch_shape() {
        let ds = ds();
        let mut b = Batcher::new(&ds, Split::Train, 4, 0).unwrap();
        let t = b.next_batch().unwrap();
        assert_eq!(t.shape(), &[4, 10]);
    }

    #[test]
    fn epochs_roll_and_reshuffle() {
        let ds = ds();
        let mut b = Batcher::new(&ds, Split::Train, 8, 0).unwrap();
        let per_epoch = b.batches_per_epoch();
        let first = b.next_batch().unwrap();
        for _ in 1..per_epoch {
            b.next_batch().unwrap();
        }
        assert_eq!(b.epoch(), 0);
        let second_epoch_first = b.next_batch().unwrap();
        assert_eq!(b.epoch(), 1);
        // overwhelmingly likely the shuffle differs
        assert_ne!(first, second_epoch_first);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = ds();
        let mut a = Batcher::new(&ds, Split::Train, 4, 5).unwrap();
        let mut b = Batcher::new(&ds, Split::Train, 4, 5).unwrap();
        for _ in 0..10 {
            assert_eq!(a.next_batch().unwrap(), b.next_batch().unwrap());
        }
    }

    #[test]
    fn rejects_oversized_batch() {
        let ds = ds();
        assert!(Batcher::new(&ds, Split::Val, 10_000, 0).is_err());
        assert!(Batcher::new(&ds, Split::Train, 0, 0).is_err());
    }
}
