//! Deterministic, dependency-free PRNGs for data generation and shuffling.

/// SplitMix64 — tiny, fast, well-distributed; fine for data synthesis.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Sample an index from cumulative weights (ascending, last = total).
    pub fn sample_cdf(&mut self, cdf: &[f64]) -> usize {
        let total = *cdf.last().expect("empty cdf");
        let x = self.next_f64() * total;
        match cdf.binary_search_by(|p| p.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniformish() {
        let mut r = SplitMix64::new(42);
        let n = 100_000;
        let mean = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn cdf_sampling_respects_weights() {
        let mut r = SplitMix64::new(5);
        // weights 1, 3 → second bucket ~75%
        let cdf = [1.0, 4.0];
        let hits = (0..10_000).filter(|_| r.sample_cdf(&cdf) == 1).count();
        assert!((hits as f64 / 10_000.0 - 0.75).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
