//! Deterministic, dependency-free PRNGs for data generation and shuffling.

use anyhow::{bail, Result};

/// SplitMix64 — tiny, fast, well-distributed; fine for data synthesis.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n) — exact, via rejection sampling.
    ///
    /// A bare `next_u64() % n` over-weights the first `2⁶⁴ mod n` residues;
    /// negligible for tiny `n` but a real bias for large ranges. Draws are
    /// rejected from the short final partial cycle instead, so every residue
    /// is exactly equally likely. The rejection region is < 1/2 of the range
    /// for any `n`, so the expected number of draws is < 2.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0) is meaningless");
        let n64 = n as u64;
        // 2^64 mod n, computed without overflowing u64
        let rem = (u64::MAX % n64).wrapping_add(1) % n64;
        if rem == 0 {
            // n divides 2^64: every residue already appears equally often
            return (self.next_u64() % n64) as usize;
        }
        // accept x ∈ [0, 2^64 − rem): the largest multiple of n below 2^64
        let zone_end = u64::MAX - rem + 1;
        loop {
            let x = self.next_u64();
            if x < zone_end {
                return (x % n64) as usize;
            }
        }
    }

    /// Sample an index from cumulative weights (ascending, last = total).
    ///
    /// Errors on an empty CDF, non-finite weights (NaN/∞ used to panic via
    /// `partial_cmp(..).unwrap()`), or a non-positive total (an all-zero CDF
    /// used to silently return a biased index).
    pub fn sample_cdf(&mut self, cdf: &[f64]) -> Result<usize> {
        let Some(&total) = cdf.last() else {
            bail!("sample_cdf: empty cdf");
        };
        if cdf.iter().any(|w| !w.is_finite()) {
            bail!("sample_cdf: non-finite weight in cdf");
        }
        if total <= 0.0 {
            bail!("sample_cdf: cdf total must be positive, got {total}");
        }
        let x = self.next_f64() * total;
        Ok(match cdf.binary_search_by(|p| p.total_cmp(&x)) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        })
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniformish() {
        let mut r = SplitMix64::new(42);
        let n = 100_000;
        let mean = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        // power-of-two fast path
        for _ in 0..1000 {
            assert!(r.below(64) < 64);
        }
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn below_is_unbiased_across_buckets() {
        // distribution sanity: every residue of a non-power-of-two modulus
        // lands within a few percent of uniform
        let mut r = SplitMix64::new(0xD157);
        let n = 7usize;
        let draws = 70_000;
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[r.below(n)] += 1;
        }
        let expect = draws as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bucket {i}: {c} vs {expect} ({dev:.3})");
        }
    }

    #[test]
    fn below_rejects_the_biased_tail() {
        // for a huge non-power-of-two n the partial final cycle is a sizable
        // fraction of the range; rejection sampling must stay in range and
        // still terminate quickly (acceptance = ⌊2⁶⁴/n⌋·n / 2⁶⁴ ≈ 3/4 here)
        let n = (1usize << 62) + 3;
        let mut r = SplitMix64::new(77);
        for _ in 0..64 {
            assert!(r.below(n) < n);
        }
    }

    #[test]
    fn cdf_sampling_respects_weights() {
        let mut r = SplitMix64::new(5);
        // weights 1, 3 → second bucket ~75%
        let cdf = [1.0, 4.0];
        let hits = (0..10_000)
            .filter(|_| r.sample_cdf(&cdf).unwrap() == 1)
            .count();
        assert!((hits as f64 / 10_000.0 - 0.75).abs() < 0.03);
    }

    #[test]
    fn cdf_rejects_nan_weights() {
        let mut r = SplitMix64::new(1);
        assert!(r.sample_cdf(&[1.0, f64::NAN, 3.0]).is_err());
        assert!(r.sample_cdf(&[1.0, f64::INFINITY]).is_err());
    }

    #[test]
    fn cdf_rejects_degenerate_totals() {
        let mut r = SplitMix64::new(2);
        assert!(r.sample_cdf(&[]).is_err());
        assert!(r.sample_cdf(&[0.0, 0.0, 0.0]).is_err());
        assert!(r.sample_cdf(&[-2.0, -1.0]).is_err());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
