//! Byte-level tokenizer with a frequency-ranked vocabulary remap.
//!
//! The LM artifacts bake a `vocab_size` (256/512/1024/2048); raw bytes cover
//! only 0..256, so to exercise larger vocabularies we extend byte tokens with
//! learned *bigram merges* (a miniature BPE): the most frequent byte pairs in
//! a training text are assigned the ids above 256, greedily and
//! deterministically.  Round-tripping is exact.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Byte tokenizer + optional bigram merges up to `vocab_size`.
#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    vocab_size: usize,
    /// merge list in priority order: (left, right) -> new id (256 + rank)
    merges: Vec<(u32, u32)>,
    merge_lookup: HashMap<(u32, u32), u32>,
}

impl ByteTokenizer {
    /// Pure byte tokenizer (vocab 256), no merges.
    pub fn bytes_only() -> Self {
        Self { vocab_size: 256, merges: vec![], merge_lookup: HashMap::new() }
    }

    /// Train merges on `text` until the vocabulary reaches `vocab_size`.
    pub fn train(text: &str, vocab_size: usize) -> Result<Self> {
        if vocab_size < 256 {
            bail!("vocab_size must be ≥ 256, got {vocab_size}");
        }
        let mut toks: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        let mut merges = Vec::new();
        let mut merge_lookup = HashMap::new();
        for next_id in 256..vocab_size as u32 {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in toks.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: highest count, ties by smallest pair
            let best = counts
                .iter()
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)))
                .map(|(&pair, &c)| (pair, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break; // nothing left worth merging
            }
            merges.push(pair);
            merge_lookup.insert(pair, next_id);
            toks = Self::apply_merge(&toks, pair, next_id);
        }
        Ok(Self { vocab_size, merges, merge_lookup })
    }

    fn apply_merge(toks: &[u32], pair: (u32, u32), id: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(toks.len());
        let mut i = 0;
        while i < toks.len() {
            if i + 1 < toks.len() && (toks[i], toks[i + 1]) == pair {
                out.push(id);
                i += 2;
            } else {
                out.push(toks[i]);
                i += 1;
            }
        }
        out
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Token id a (left, right) pair merges into, if that merge was learned.
    pub fn merge_id(&self, left: u32, right: u32) -> Option<u32> {
        self.merge_lookup.get(&(left, right)).copied()
    }

    /// Encode text to token ids (< vocab_size).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut toks: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        // apply merges in training order (priority = rank)
        for (rank, &pair) in self.merges.iter().enumerate() {
            let id = 256 + rank as u32;
            if toks.len() < 2 {
                break;
            }
            toks = Self::apply_merge(&toks, pair, id);
        }
        toks.into_iter().map(|t| t as i32).collect()
    }

    /// Decode ids back to text (lossless inverse of `encode`).
    pub fn decode(&self, ids: &[i32]) -> Result<String> {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.push_bytes(id as u32, &mut bytes)?;
        }
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) -> Result<()> {
        if id < 256 {
            out.push(id as u8);
            return Ok(());
        }
        let rank = (id - 256) as usize;
        if rank >= self.merges.len() {
            bail!("token id {id} out of vocabulary");
        }
        let (l, r) = self.merges[rank];
        self.push_bytes(l, out)?;
        self.push_bytes(r, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_only_roundtrip() {
        let t = ByteTokenizer::bytes_only();
        let s = "hello, linear attention!";
        assert_eq!(t.decode(&t.encode(s)).unwrap(), s);
    }

    #[test]
    fn merges_reduce_length_and_roundtrip() {
        let text = "the cat sat on the mat. the cat sat on the mat. again the cat.";
        let t = ByteTokenizer::train(text, 300).unwrap();
        assert!(t.n_merges() > 0);
        let ids = t.encode(text);
        assert!(ids.len() < text.len(), "{} !< {}", ids.len(), text.len());
        assert_eq!(t.decode(&ids).unwrap(), text);
        assert!(ids.iter().all(|&i| (i as usize) < t.vocab_size()));
    }

    #[test]
    fn merge_id_lookup_consistent() {
        let t = ByteTokenizer::train("ababab ababab", 280).unwrap();
        assert!(t.n_merges() > 0);
        // every learned merge is addressable and maps above the byte range
        for rank in 0..t.n_merges() {
            let (l, r) = t.merges[rank];
            assert_eq!(t.merge_id(l, r), Some(256 + rank as u32));
        }
        assert_eq!(t.merge_id(999, 999), None);
    }

    #[test]
    fn train_is_deterministic() {
        let text = "abab abab abab cdcd cdcd";
        let a = ByteTokenizer::train(text, 280).unwrap();
        let b = ByteTokenizer::train(text, 280).unwrap();
        assert_eq!(a.encode(text), b.encode(text));
    }

    #[test]
    fn roundtrip_on_unseen_text() {
        let t = ByteTokenizer::train("aaa bbb aaa bbb", 270).unwrap();
        let s = "completely different text 123!";
        assert_eq!(t.decode(&t.encode(s)).unwrap(), s);
    }

    #[test]
    fn rejects_small_vocab() {
        assert!(ByteTokenizer::train("x", 100).is_err());
    }

    #[test]
    fn decode_rejects_oov() {
        let t = ByteTokenizer::bytes_only();
        assert!(t.decode(&[300]).is_err());
    }
}
