//! Byte-level tokenizer with a frequency-ranked vocabulary remap.
//!
//! The LM artifacts bake a `vocab_size` (256/512/1024/2048); raw bytes cover
//! only 0..256, so to exercise larger vocabularies we extend byte tokens with
//! learned *bigram merges* (a miniature BPE): the most frequent byte pairs in
//! a training text are assigned the ids above 256, greedily and
//! deterministically.  Round-tripping is exact.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::corpus::{CorpusConfig, CorpusGenerator};

/// Characters of corpus the BPE merges are trained on. The trainer and the
/// inference path both slice the (deterministic, seed-keyed) synthetic
/// corpus at this boundary before training merges, so a checkpoint's
/// tokenizer can be reconstructed exactly from its seed — checkpoints never
/// serialize the tokenizer.
pub const MERGE_TRAIN_CHARS: usize = 100_000;

/// The corpus prefix merges are trained on (first [`MERGE_TRAIN_CHARS`]
/// characters) — shared by the trainer and [`ByteTokenizer::for_artifact`].
pub fn merge_train_slice(corpus: &str) -> &str {
    let end = corpus
        .char_indices()
        .nth(MERGE_TRAIN_CHARS)
        .map(|(i, _)| i)
        .unwrap_or(corpus.len());
    &corpus[..end]
}

/// Byte tokenizer + optional bigram merges up to `vocab_size`.
#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    vocab_size: usize,
    /// merge list in priority order: (left, right) -> new id (256 + rank)
    merges: Vec<(u32, u32)>,
    merge_lookup: HashMap<(u32, u32), u32>,
}

impl ByteTokenizer {
    /// Pure byte tokenizer (vocab 256), no merges.
    pub fn bytes_only() -> Self {
        Self { vocab_size: 256, merges: vec![], merge_lookup: HashMap::new() }
    }

    /// Reconstruct the tokenizer a training run built for an artifact with
    /// this `vocab_size` and run `seed` — byte-level below 257, otherwise
    /// BPE merges trained on the same corpus prefix the trainer used. The
    /// corpus generator emits an identical stream prefix regardless of the
    /// target size, so only [`MERGE_TRAIN_CHARS`] + slack bytes are
    /// synthesized here, not the full training corpus.
    ///
    /// Caveat: checkpoints written *before* the trainer adopted this
    /// canonical construction, by runs that set a custom corpus smaller
    /// than the merge-training slice (`--corpus-bytes` below ~100 KB on a
    /// BPE preset), trained their merges on that smaller corpus; they are
    /// not reconstructible (the checkpoint does not record the corpus
    /// size) and must be retrained to be served.
    pub fn for_artifact(vocab_size: usize, seed: u64) -> Result<Self> {
        if vocab_size <= 256 {
            return Ok(Self::bytes_only());
        }
        let corpus = CorpusGenerator::new(CorpusConfig {
            seed,
            target_bytes: MERGE_TRAIN_CHARS + 4096,
            ..Default::default()
        })
        .generate();
        Self::train(merge_train_slice(&corpus), vocab_size)
    }

    /// Train merges on `text` until the vocabulary reaches `vocab_size`.
    pub fn train(text: &str, vocab_size: usize) -> Result<Self> {
        if vocab_size < 256 {
            bail!("vocab_size must be ≥ 256, got {vocab_size}");
        }
        let mut toks: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        let mut merges = Vec::new();
        let mut merge_lookup = HashMap::new();
        for next_id in 256..vocab_size as u32 {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in toks.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: highest count, ties by smallest pair
            let best = counts
                .iter()
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)))
                .map(|(&pair, &c)| (pair, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break; // nothing left worth merging
            }
            merges.push(pair);
            merge_lookup.insert(pair, next_id);
            toks = Self::apply_merge(&toks, pair, next_id);
        }
        Ok(Self { vocab_size, merges, merge_lookup })
    }

    fn apply_merge(toks: &[u32], pair: (u32, u32), id: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(toks.len());
        let mut i = 0;
        while i < toks.len() {
            // in_bounds: both reads sit behind `i + 1 < toks.len()`
            if i + 1 < toks.len() && (toks[i], toks[i + 1]) == pair {
                out.push(id);
                i += 2;
            } else {
                // in_bounds: the loop condition holds `i < toks.len()`
                out.push(toks[i]);
                i += 1;
            }
        }
        out
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Token id a (left, right) pair merges into, if that merge was learned.
    pub fn merge_id(&self, left: u32, right: u32) -> Option<u32> {
        self.merge_lookup.get(&(left, right)).copied()
    }

    /// Encode text to token ids (< vocab_size).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut toks: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        // apply merges in training order (priority = rank)
        for (rank, &pair) in self.merges.iter().enumerate() {
            let id = 256 + rank as u32;
            if toks.len() < 2 {
                break;
            }
            toks = Self::apply_merge(&toks, pair, id);
        }
        toks.into_iter().map(|t| t as i32).collect()
    }

    /// Decode ids back to text (lossless inverse of `encode`).
    pub fn decode(&self, ids: &[i32]) -> Result<String> {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.push_bytes(id as u32, &mut bytes)?;
        }
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }

    // no_panic
    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) -> Result<()> {
        if id < 256 {
            out.push(id as u8);
            return Ok(());
        }
        let rank = (id - 256) as usize;
        if rank >= self.merges.len() {
            bail!("token id {id} out of vocabulary");
        }
        // in_bounds: rank checked against merges.len() just above
        let (l, r) = self.merges[rank];
        self.push_bytes(l, out)?;
        self.push_bytes(r, out)?;
        Ok(())
    }

    /// Streaming decoder over this tokenizer — see [`DecodeStream`].
    pub fn decode_stream(&self) -> DecodeStream<'_> {
        DecodeStream { tok: self, buf: Vec::new() }
    }
}

/// Incremental, UTF-8-safe token decoding for generation.
///
/// [`ByteTokenizer::decode`] is all-or-nothing, but byte-level BPE emits
/// *bytes*, and a multi-byte UTF-8 scalar can straddle a token boundary
/// mid-generation. `DecodeStream` buffers bytes across [`push`](Self::push)
/// calls and only releases complete UTF-8 sequences: an incomplete trailing
/// sequence (at most 3 bytes — a prefix of one scalar) stays buffered
/// instead of erroring, and bytes that can never complete a valid sequence
/// are replaced with U+FFFD, so a streaming consumer always receives valid
/// UTF-8 and the concatenation of all pushes (+ [`finish`](Self::finish))
/// equals the batch `decode` of the same ids.
pub struct DecodeStream<'a> {
    tok: &'a ByteTokenizer,
    buf: Vec<u8>,
}

impl DecodeStream<'_> {
    /// Feed one token id; returns the text that became decodable (possibly
    /// empty). Errors only on an out-of-vocabulary id.
    // no_panic
    pub fn push(&mut self, id: i32) -> Result<String> {
        if id < 0 {
            bail!("token id {id} out of vocabulary");
        }
        self.tok.push_bytes(id as u32, &mut self.buf)?;
        Ok(self.drain())
    }

    /// Bytes still buffered (a partial multi-byte sequence), if any.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Flush whatever remains, replacing an unfinished trailing sequence
    /// with U+FFFD (end-of-generation can legitimately cut a scalar short).
    // no_panic
    pub fn finish(mut self) -> String {
        let mut out = self.drain();
        if !self.buf.is_empty() {
            out.push_str(&String::from_utf8_lossy(&self.buf));
            self.buf.clear();
        }
        out
    }

    /// Release every complete UTF-8 sequence from the front of the buffer,
    /// keeping only an incomplete trailing prefix.
    fn drain(&mut self) -> String {
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.buf) {
                Ok(s) => {
                    out.push_str(s);
                    self.buf.clear();
                    return out;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    // in_bounds: valid ≤ buf.len() by valid_up_to's contract;
                    // guarded: from_utf8 re-checks exactly the validated prefix
                    out.push_str(std::str::from_utf8(&self.buf[..valid]).expect("validated"));
                    match e.error_len() {
                        // incomplete trailing sequence: keep it buffered for
                        // the next push
                        None => {
                            self.buf.drain(..valid);
                            return out;
                        }
                        // bytes that can never start/continue a valid
                        // sequence: replace and keep scanning
                        Some(bad) => {
                            out.push('\u{FFFD}');
                            self.buf.drain(..valid + bad);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_only_roundtrip() {
        let t = ByteTokenizer::bytes_only();
        let s = "hello, linear attention!";
        assert_eq!(t.decode(&t.encode(s)).unwrap(), s);
    }

    #[test]
    fn merges_reduce_length_and_roundtrip() {
        let text = "the cat sat on the mat. the cat sat on the mat. again the cat.";
        let t = ByteTokenizer::train(text, 300).unwrap();
        assert!(t.n_merges() > 0);
        let ids = t.encode(text);
        assert!(ids.len() < text.len(), "{} !< {}", ids.len(), text.len());
        assert_eq!(t.decode(&ids).unwrap(), text);
        assert!(ids.iter().all(|&i| (i as usize) < t.vocab_size()));
    }

    #[test]
    fn merge_id_lookup_consistent() {
        let t = ByteTokenizer::train("ababab ababab", 280).unwrap();
        assert!(t.n_merges() > 0);
        // every learned merge is addressable and maps above the byte range
        for rank in 0..t.n_merges() {
            let (l, r) = t.merges[rank];
            assert_eq!(t.merge_id(l, r), Some(256 + rank as u32));
        }
        assert_eq!(t.merge_id(999, 999), None);
    }

    #[test]
    fn train_is_deterministic() {
        let text = "abab abab abab cdcd cdcd";
        let a = ByteTokenizer::train(text, 280).unwrap();
        let b = ByteTokenizer::train(text, 280).unwrap();
        assert_eq!(a.encode(text), b.encode(text));
    }

    #[test]
    fn roundtrip_on_unseen_text() {
        let t = ByteTokenizer::train("aaa bbb aaa bbb", 270).unwrap();
        let s = "completely different text 123!";
        assert_eq!(t.decode(&t.encode(s)).unwrap(), s);
    }

    #[test]
    fn rejects_small_vocab() {
        assert!(ByteTokenizer::train("x", 100).is_err());
    }

    #[test]
    fn decode_rejects_oov() {
        let t = ByteTokenizer::bytes_only();
        assert!(t.decode(&[300]).is_err());
    }

    #[test]
    fn decode_stream_roundtrips_multibyte_pushed_one_id_at_a_time() {
        let t = ByteTokenizer::bytes_only();
        // 2-, 3-, and 4-byte scalars: every intermediate push leaves a
        // partial sequence buffered instead of erroring
        let s = "héllo → wörld 🌍 末尾";
        let ids = t.encode(s);
        let mut stream = t.decode_stream();
        let mut out = String::new();
        let mut saw_pending = false;
        for &id in &ids {
            out.push_str(&stream.push(id).unwrap());
            saw_pending |= stream.pending() > 0;
        }
        out.push_str(&stream.finish());
        assert_eq!(out, s);
        assert!(saw_pending, "multi-byte input never straddled a push");
    }

    #[test]
    fn decode_stream_matches_batch_decode_with_merges() {
        let text = "the cat sat on the mat. the cat sat on the mat. déjà vu déjà vu";
        let t = ByteTokenizer::train(text, 300).unwrap();
        let ids = t.encode(text);
        let mut stream = t.decode_stream();
        let mut out = String::new();
        for &id in &ids {
            out.push_str(&stream.push(id).unwrap());
        }
        out.push_str(&stream.finish());
        assert_eq!(out, t.decode(&ids).unwrap());
        assert_eq!(out, text);
    }

    #[test]
    fn decode_stream_flushes_truncated_scalar_as_replacement() {
        let t = ByteTokenizer::bytes_only();
        let euro = "€".as_bytes(); // 3 bytes
        let mut stream = t.decode_stream();
        assert_eq!(stream.push(euro[0] as i32).unwrap(), "");
        assert_eq!(stream.push(euro[1] as i32).unwrap(), "");
        assert_eq!(stream.pending(), 2);
        // generation stops mid-scalar: finish() must not error — the
        // truncated sequence collapses to one replacement char (lossy
        // decoding replaces each maximal ill-formed subpart)
        assert_eq!(stream.finish(), "\u{FFFD}");
    }

    #[test]
    fn decode_stream_replaces_invalid_bytes_and_recovers() {
        let t = ByteTokenizer::bytes_only();
        let mut stream = t.decode_stream();
        // 0xFF can never start a sequence; the following ASCII must survive
        let mut out = stream.push(0xFF).unwrap();
        out.push_str(&stream.push(b'o' as i32).unwrap());
        out.push_str(&stream.push(b'k' as i32).unwrap());
        assert_eq!(out, "\u{FFFD}ok");
        assert_eq!(stream.pending(), 0);
    }

    #[test]
    fn decode_stream_rejects_oov_ids() {
        let t = ByteTokenizer::bytes_only();
        let mut stream = t.decode_stream();
        assert!(stream.push(-1).is_err());
        assert!(stream.push(300).is_err());
    }

    #[test]
    fn for_artifact_bytes_below_257() {
        let t = ByteTokenizer::for_artifact(256, 0).unwrap();
        assert_eq!(t.n_merges(), 0);
        let s = "plain bytes";
        assert_eq!(t.decode(&t.encode(s)).unwrap(), s);
    }

    #[test]
    fn merge_train_slice_is_char_bounded() {
        let short = "tiny";
        assert_eq!(merge_train_slice(short), short);
        let long: String = "é".repeat(MERGE_TRAIN_CHARS + 10);
        let slice = merge_train_slice(&long);
        assert_eq!(slice.chars().count(), MERGE_TRAIN_CHARS);
        assert!(long.is_char_boundary(slice.len()));
    }
}
