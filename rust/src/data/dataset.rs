//! Packed next-token-prediction dataset: token stream → fixed-length rows.

use anyhow::{bail, Result};

use super::rng::SplitMix64;

/// Train/validation split tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

/// Token stream packed into non-overlapping rows of `seq_len + 1` tokens
/// (input = row[..n], target = row[1..]), split deterministically.
#[derive(Debug, Clone)]
pub struct PackedDataset {
    seq_len: usize,
    train_rows: Vec<Vec<i32>>,
    val_rows: Vec<Vec<i32>>,
}

impl PackedDataset {
    /// Pack `tokens` into rows; `val_frac` of rows (deterministically chosen)
    /// go to the validation split.
    pub fn pack(tokens: &[i32], seq_len: usize, val_frac: f64, seed: u64) -> Result<Self> {
        if seq_len == 0 {
            bail!("seq_len must be positive");
        }
        let row_len = seq_len + 1;
        let n_rows = tokens.len() / row_len;
        if n_rows < 2 {
            bail!(
                "corpus too small: {} tokens < 2 rows of {}",
                tokens.len(),
                row_len
            );
        }
        let mut idx: Vec<usize> = (0..n_rows).collect();
        SplitMix64::new(seed ^ 0x5EED).shuffle(&mut idx);
        let n_val = ((n_rows as f64 * val_frac).round() as usize).clamp(1, n_rows - 1);
        let mut train_rows = Vec::with_capacity(n_rows - n_val);
        let mut val_rows = Vec::with_capacity(n_val);
        for (pos, &r) in idx.iter().enumerate() {
            let row = tokens[r * row_len..(r + 1) * row_len].to_vec();
            if pos < n_val {
                val_rows.push(row);
            } else {
                train_rows.push(row);
            }
        }
        Ok(Self { seq_len, train_rows, val_rows })
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn len(&self, split: Split) -> usize {
        self.rows(split).len()
    }

    pub fn is_empty(&self, split: Split) -> bool {
        self.rows(split).is_empty()
    }

    pub fn rows(&self, split: Split) -> &[Vec<i32>] {
        match split {
            Split::Train => &self.train_rows,
            Split::Val => &self.val_rows,
        }
    }

    /// Tokens per row including the shifted target.
    pub fn row_len(&self) -> usize {
        self.seq_len + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn packs_and_splits() {
        let ds = PackedDataset::pack(&toks(1000), 9, 0.2, 0).unwrap();
        assert_eq!(ds.row_len(), 10);
        let total = ds.len(Split::Train) + ds.len(Split::Val);
        assert_eq!(total, 100);
        assert_eq!(ds.len(Split::Val), 20);
        for row in ds.rows(Split::Train) {
            assert_eq!(row.len(), 10);
        }
    }

    #[test]
    fn splits_are_disjoint_and_cover() {
        let ds = PackedDataset::pack(&toks(500), 4, 0.25, 7).unwrap();
        let mut firsts: Vec<i32> = ds
            .rows(Split::Train)
            .iter()
            .chain(ds.rows(Split::Val))
            .map(|r| r[0])
            .collect();
        firsts.sort();
        firsts.dedup();
        assert_eq!(firsts.len(), ds.len(Split::Train) + ds.len(Split::Val));
    }

    #[test]
    fn deterministic_split() {
        let a = PackedDataset::pack(&toks(600), 5, 0.1, 3).unwrap();
        let b = PackedDataset::pack(&toks(600), 5, 0.1, 3).unwrap();
        assert_eq!(a.rows(Split::Val), b.rows(Split::Val));
    }

    #[test]
    fn rejects_tiny_corpus() {
        assert!(PackedDataset::pack(&toks(5), 9, 0.1, 0).is_err());
        assert!(PackedDataset::pack(&toks(100), 0, 0.1, 0).is_err());
    }
}
