//! Synthetic corpus generator — the Wiki-40B stand-in (DESIGN.md §Substitutions).
//!
//! Three mixed sources give the LM non-trivial, learnable structure:
//! 1. a **Zipfian Markov word chain** (natural-language-like unigram/bigram
//!    statistics over a synthetic vocabulary),
//! 2. **template "fact" sentences** with recurring entities ("the <adj>
//!    <noun> of <entity> is <value>.") that reward long-range copying,
//! 3. **arithmetic snippets** ("12 + 7 = 19") that reward induction.
//!
//! The generator is fully deterministic in its seed.

use super::rng::SplitMix64;

/// Base synthetic-corpus size in bytes — the unit the LM presets scale from
/// (`LmConfig::corpus_bytes_hint`) and the trainer's fallback when an
/// artifact manifest carries no `corpus_bytes` field.
pub const DEFAULT_CORPUS_BYTES: usize = 2 << 20;

/// Corpus synthesis parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    /// Approximate corpus size in bytes.
    pub target_bytes: usize,
    /// Synthetic word-vocabulary size for the Markov chain.
    pub vocab_words: usize,
    /// Zipf exponent for word frequencies.
    pub zipf_s: f64,
    /// Mixture weights: (markov, facts, arithmetic).
    pub mix: (f64, f64, f64),
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            target_bytes: DEFAULT_CORPUS_BYTES,
            vocab_words: 512,
            zipf_s: 1.1,
            mix: (0.6, 0.3, 0.1),
        }
    }
}

/// Deterministic synthetic-text generator.
pub struct CorpusGenerator {
    cfg: CorpusConfig,
    words: Vec<String>,
    cdf: Vec<f64>,
    /// per-word successor bias — gives the chain bigram structure
    successor: Vec<usize>,
    entities: Vec<String>,
    adjectives: Vec<&'static str>,
    nouns: Vec<&'static str>,
}

const ADJECTIVES: &[&str] = &[
    "red", "ancient", "bright", "quiet", "northern", "hidden", "rapid",
    "golden", "hollow", "frozen", "eastern", "little",
];
const NOUNS: &[&str] = &[
    "river", "archive", "engine", "garden", "tower", "market", "harbor",
    "forest", "bridge", "library", "square", "mill",
];

impl CorpusGenerator {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = SplitMix64::new(cfg.seed ^ 0xC0FFEE);
        // synthetic word list: CV syllable strings, 2-4 syllables
        let syl_c = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"];
        let syl_v = ["a", "e", "i", "o", "u"];
        let mut words = Vec::with_capacity(cfg.vocab_words);
        while words.len() < cfg.vocab_words {
            let n_syl = 2 + rng.below(3);
            let mut w = String::new();
            for _ in 0..n_syl {
                w.push_str(syl_c[rng.below(syl_c.len())]);
                w.push_str(syl_v[rng.below(syl_v.len())]);
            }
            words.push(w);
        }
        // Zipf CDF over ranks
        let mut cdf = Vec::with_capacity(cfg.vocab_words);
        let mut acc = 0.0;
        for r in 1..=cfg.vocab_words {
            acc += 1.0 / (r as f64).powf(cfg.zipf_s);
            cdf.push(acc);
        }
        let successor = (0..cfg.vocab_words).map(|_| rng.below(cfg.vocab_words)).collect();
        let entities = (0..32)
            .map(|i| {
                let w = &words[rng.below(cfg.vocab_words.min(128))];
                let mut e = w.clone();
                e.push_str(&format!("{i}"));
                e
            })
            .collect();
        Self {
            cfg,
            words,
            cdf,
            successor,
            entities,
            adjectives: ADJECTIVES.to_vec(),
            nouns: NOUNS.to_vec(),
        }
    }

    fn markov_sentence(&self, rng: &mut SplitMix64) -> String {
        let len = 4 + rng.below(12);
        let mut out = String::new();
        // the Zipf cdf is strictly positive by construction (powf of ranks)
        let mut w = rng.sample_cdf(&self.cdf).expect("zipf cdf is positive");
        for i in 0..len {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&self.words[w]);
            // 50%: biased successor (bigram structure); 50%: fresh Zipf draw
            w = if rng.next_f64() < 0.5 {
                self.successor[w]
            } else {
                rng.sample_cdf(&self.cdf).expect("zipf cdf is positive")
            };
        }
        out.push('.');
        out
    }

    fn fact_sentence(&self, rng: &mut SplitMix64) -> String {
        let e = &self.entities[rng.below(self.entities.len())];
        let a = self.adjectives[rng.below(self.adjectives.len())];
        let n = self.nouns[rng.below(self.nouns.len())];
        let v = &self.words[rng.below(self.words.len())];
        match rng.below(3) {
            0 => format!("the {a} {n} of {e} is {v}."),
            1 => format!("{e} keeps a {a} {n} near {v}."),
            _ => format!("in {e} the {n} was {a} and {v}."),
        }
    }

    fn arithmetic_snippet(&self, rng: &mut SplitMix64) -> String {
        let a = rng.below(50);
        let b = rng.below(50);
        match rng.below(2) {
            0 => format!("{a} + {b} = {}.", a + b),
            _ => format!("{a} * {b} = {}.", a * b),
        }
    }

    /// Generate the corpus as one UTF-8 string of ≈ `target_bytes`.
    pub fn generate(&self) -> String {
        let mut rng = SplitMix64::new(self.cfg.seed);
        let (wm, wf, wa) = self.cfg.mix;
        let cdf = [wm, wm + wf, wm + wf + wa];
        let mut out = String::with_capacity(self.cfg.target_bytes + 128);
        let mut sentences_in_par = 0usize;
        while out.len() < self.cfg.target_bytes {
            let s = match rng.sample_cdf(&cdf).expect("mixture weights must be positive") {
                0 => self.markov_sentence(&mut rng),
                1 => self.fact_sentence(&mut rng),
                _ => self.arithmetic_snippet(&mut rng),
            };
            out.push_str(&s);
            sentences_in_par += 1;
            if sentences_in_par >= 5 + rng.below(5) {
                out.push('\n');
                sentences_in_par = 0;
            } else {
                out.push(' ');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let cfg = CorpusConfig { target_bytes: 10_000, ..Default::default() };
        let a = CorpusGenerator::new(cfg.clone()).generate();
        let b = CorpusGenerator::new(cfg).generate();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg = CorpusConfig { target_bytes: 10_000, ..Default::default() };
        let a = CorpusGenerator::new(cfg.clone()).generate();
        cfg.seed = 1;
        let b = CorpusGenerator::new(cfg).generate();
        assert_ne!(a, b);
    }

    #[test]
    fn reaches_target_size_and_is_ascii() {
        let cfg = CorpusConfig { target_bytes: 50_000, ..Default::default() };
        let text = CorpusGenerator::new(cfg).generate();
        assert!(text.len() >= 50_000);
        assert!(text.len() < 51_000);
        assert!(text.is_ascii());
    }

    #[test]
    fn zipf_head_words_dominate() {
        let cfg = CorpusConfig { target_bytes: 200_000, ..Default::default() };
        let g = CorpusGenerator::new(cfg);
        let text = g.generate();
        let head = &g.words[0];
        let count = text.matches(head.as_str()).count();
        // the rank-1 word must appear far more often than a tail word
        let tail = &g.words[g.words.len() - 1];
        let tail_count = text.matches(tail.as_str()).count();
        assert!(count > tail_count, "head {count} vs tail {tail_count}");
    }

    #[test]
    fn facts_repeat_entities() {
        let cfg = CorpusConfig { target_bytes: 100_000, ..Default::default() };
        let g = CorpusGenerator::new(cfg);
        let text = g.generate();
        let hits = g.entities.iter().filter(|e| text.contains(e.as_str())).count();
        assert!(hits > 16, "only {hits}/32 entities appear");
    }
}
