//! Checkpoint → ready-to-decode model: load + validate a saved training
//! state, rebuild the tokenizer deterministically from the checkpoint seed,
//! and run batched recurrent generation.
//!
//! A [`ModelSession`] owns everything `generate`/`serve` need warm across
//! calls: the parameter tensors (the Adam moments are dropped at load — the
//! decoder only needs the first `np` arrays), the reconstructed
//! [`ByteTokenizer`], and the worker [`ThreadPool`]. Loading is hardened:
//! a missing file, a pre-refactor layout-v1 checkpoint, an unrecognized
//! artifact tag, or a state vector that doesn't match the preset/attn
//! contract all fail with a clear error before any decoding starts.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{load_any, CheckpointMeta, LoadedCheckpoint, QuantCheckpoint};
use crate::data::ByteTokenizer;
use crate::native::model::{self, AttnKind, LmConfig, Precision, QuantModel};
use crate::native::pool::ThreadPool;
use crate::runtime::Tensor;

use super::engine::{BatchEngine, EngineConfig};
use super::sampler::{SampleMode, Sampler};
use super::state::DecodeState;

/// Upper bound on concurrent samples per generation — a batch size, not a
/// throughput knob; one request must not be able to allocate an unbounded
/// set of per-layer decode states.
pub const MAX_SAMPLES: usize = 64;

/// One generation request (shared by the CLI and the serve loop).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    /// New tokens to generate (clamped to the remaining context window).
    pub max_new: usize,
    pub mode: SampleMode,
    /// Sampler seed — a fixed seed yields identical output.
    pub seed: u64,
    /// Concurrent samples decoded in one batch (all from the same prompt;
    /// each draws its own tokens from the shared sampler stream).
    pub samples: usize,
    /// Force the token-by-token prefill route (the parity oracle) instead
    /// of the chunked fast path. Off by default; the serve smoke and the
    /// parity tests flip it to compare the two routes.
    pub serial_prefill: bool,
}

impl Default for GenRequest {
    fn default() -> Self {
        Self {
            prompt: String::new(),
            max_new: 64,
            mode: SampleMode::Greedy,
            seed: 0,
            samples: 1,
            serial_prefill: false,
        }
    }
}

/// What one generation produced, with the latency split the serve loop
/// reports per request.
#[derive(Debug, Clone)]
pub struct GenOutcome {
    /// Decoded text per sample (prompt not included).
    pub texts: Vec<String>,
    /// Generated token ids per sample.
    pub token_ids: Vec<Vec<i32>>,
    pub prompt_tokens: usize,
    /// New tokens generated per sample (after context-window clamping).
    pub new_tokens: usize,
    /// Wall-clock of consuming the prompt through the recurrent state.
    pub prefill_s: f64,
    /// Time to first token: request start → the first new token sampled
    /// (prefill + first-token logits + the sample itself). Falls back to
    /// `prefill_s` when `max_new` clamps to zero.
    pub ttft_s: f64,
    /// Wall-clock of the generation loop (steps + sampling + detokenizing).
    pub decode_s: f64,
    /// Attention-state footprint at the end of decoding: constant in the
    /// generated length for `ours`/`gated`, linearly growing for `softmax`.
    pub state_bytes: usize,
}

impl GenOutcome {
    /// Generated tokens per second across the batch (decode phase only).
    pub fn tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            (self.new_tokens * self.texts.len()) as f64 / self.decode_s
        } else {
            0.0
        }
    }

    /// Prompt tokens ingested per second (prefill phase only).
    pub fn prefill_tok_s(&self) -> f64 {
        if self.prefill_s > 0.0 {
            self.prompt_tokens as f64 / self.prefill_s
        } else {
            0.0
        }
    }
}

/// The parameter set a session decodes with: full-precision tensors from a
/// training checkpoint, or a quantized [`QuantModel`] from a layout-v3
/// `repro quantize` artifact.
enum SessionParams {
    /// The first `n_param_arrays` tensors of the checkpoint state.
    F32(Vec<Tensor>),
    Quant(QuantModel),
}

/// A loaded checkpoint kept warm for repeated generation calls.
pub struct ModelSession {
    cfg: LmConfig,
    meta: CheckpointMeta,
    params: SessionParams,
    tokenizer: ByteTokenizer,
    pool: ThreadPool,
}

/// `lm_<preset>_<attn>` → (preset, attn); the inverse of
/// [`RunConfig::artifact_tag`](crate::coordinator::RunConfig::artifact_tag).
fn parse_artifact_tag(tag: &str) -> Result<(String, String)> {
    let rest = tag.strip_prefix("lm_").ok_or_else(|| {
        anyhow::anyhow!(
            "checkpoint artifact tag {tag:?} is not an LM tag (expected lm_<preset>_<attn>)"
        )
    })?;
    let (preset, attn) = rest.rsplit_once('_').ok_or_else(|| {
        anyhow::anyhow!(
            "checkpoint artifact tag {tag:?} is not an LM tag (expected lm_<preset>_<attn>)"
        )
    })?;
    Ok((preset.to_string(), attn.to_string()))
}

impl ModelSession {
    /// Load a checkpoint with a pool sized from `RUST_PALLAS_THREADS`.
    pub fn load(ckpt_path: impl AsRef<Path>) -> Result<Self> {
        Self::load_with_pool(ckpt_path, ThreadPool::from_env())
    }

    /// Load a checkpoint onto an explicit pool (tests, thread sweeps).
    /// Accepts both full-precision training checkpoints (layout v2) and
    /// quantized decode-only ones (layout v3); `cfg().precision` reports
    /// which storage the session decodes with.
    pub fn load_with_pool(ckpt_path: impl AsRef<Path>, pool: ThreadPool) -> Result<Self> {
        let path = ckpt_path.as_ref();
        let loaded = load_any(path)
            .with_context(|| format!("loading checkpoint {}", path.display()))?;
        let ck = match loaded {
            LoadedCheckpoint::Quantized(qck) => {
                return Self::from_quant_checkpoint(qck, pool);
            }
            LoadedCheckpoint::Full(ck) => ck,
        };
        ck.meta.require_current_layout()?;
        let (preset, attn) = parse_artifact_tag(&ck.meta.artifact_tag)?;
        let cfg = LmConfig::by_preset(&preset, AttnKind::from_name(&attn)?)
            .with_context(|| format!("resolving checkpoint artifact {:?}", ck.meta.artifact_tag))?;
        let np = cfg.n_param_arrays();
        if ck.state.len() != 3 * np {
            bail!(
                "checkpoint {:?} carries {} state arrays but preset {preset:?}/{attn:?} \
                 wants {} (params ++ m ++ v) — the state does not match its tag",
                ck.meta.artifact_tag,
                ck.state.len(),
                3 * np
            );
        }
        for ((name, shape), t) in cfg.param_shapes().iter().zip(&ck.state) {
            if t.shape() != shape.as_slice() {
                bail!(
                    "checkpoint {:?}: param {name} has shape {:?} but preset \
                     {preset:?}/{attn:?} wants {shape:?} — the state does not match its tag",
                    ck.meta.artifact_tag,
                    t.shape()
                );
            }
        }
        // tokenizer last: it is the expensive part (BPE merge training) and
        // must not mask a bad checkpoint
        let tokenizer = ByteTokenizer::for_artifact(cfg.vocab, ck.meta.seed)?;
        let mut state = ck.state;
        state.truncate(np); // the Adam moments are dead weight at decode time
        Ok(Self { cfg, meta: ck.meta, params: SessionParams::F32(state), tokenizer, pool })
    }

    /// Session from a layout-v3 quantized checkpoint: same tag → preset
    /// resolution and shape contract as the full path, then the quantized
    /// arrays are validated into a [`QuantModel`] whose config (with
    /// `precision` set) drives state construction and binding.
    fn from_quant_checkpoint(qck: QuantCheckpoint, pool: ThreadPool) -> Result<Self> {
        let (preset, attn) = parse_artifact_tag(&qck.meta.artifact_tag)?;
        let cfg = LmConfig::by_preset(&preset, AttnKind::from_name(&attn)?)
            .with_context(|| format!("resolving checkpoint artifact {:?}", qck.meta.artifact_tag))?;
        let shapes = cfg.param_shapes();
        if qck.arrays.len() != shapes.len() {
            bail!(
                "quantized checkpoint {:?} carries {} arrays but preset \
                 {preset:?}/{attn:?} wants {} — the state does not match its tag",
                qck.meta.artifact_tag,
                qck.arrays.len(),
                shapes.len()
            );
        }
        for ((name, shape), (got, _)) in shapes.iter().zip(&qck.arrays) {
            if got != shape {
                bail!(
                    "quantized checkpoint {:?}: param {name} has shape {got:?} but preset \
                     {preset:?}/{attn:?} wants {shape:?} — the state does not match its tag",
                    qck.meta.artifact_tag
                );
            }
        }
        let arrs = qck.arrays.into_iter().map(|(_, b)| b).collect();
        let qm = QuantModel::from_arrays(&cfg, qck.precision, arrs)?;
        let cfg = *qm.cfg();
        let tokenizer = ByteTokenizer::for_artifact(cfg.vocab, qck.meta.seed)?;
        Ok(Self { cfg, meta: qck.meta, params: SessionParams::Quant(qm), tokenizer, pool })
    }

    pub fn cfg(&self) -> &LmConfig {
        &self.cfg
    }

    pub fn meta(&self) -> &CheckpointMeta {
        &self.meta
    }

    pub fn tokenizer(&self) -> &ByteTokenizer {
        &self.tokenizer
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// One-line summary for startup logs.
    pub fn summary(&self) -> String {
        format!(
            "{} @ step {} ({} params, {} layers × {} heads, n_ctx {}, vocab {}, {})",
            self.meta.artifact_tag,
            self.meta.step,
            self.cfg.n_params(),
            self.cfg.n_layer,
            self.cfg.n_head,
            self.cfg.n_ctx,
            self.cfg.vocab,
            self.cfg.precision,
        )
    }

    /// Run one batched generation: prefill the prompt through the recurrent
    /// state (never re-scanning it), then sample `max_new` tokens per
    /// sample. The prompt is truncated to the last `n_ctx − 1` tokens and
    /// `max_new` is clamped to the remaining window.
    // no_panic
    pub fn generate(&self, req: &GenRequest) -> Result<GenOutcome> {
        if req.samples == 0 || req.samples > MAX_SAMPLES {
            // the cap keeps one request from allocating an unbounded batch
            // of decode states — a malicious/typo'd `samples` must answer
            // with an error, not abort a warm serve process
            bail!("samples must be in [1, {MAX_SAMPLES}], got {}", req.samples);
        }
        let mut ids = self.tokenizer.encode(&req.prompt);
        if ids.len() > self.cfg.n_ctx - 1 {
            ids.drain(..ids.len() - (self.cfg.n_ctx - 1));
        }
        if ids.is_empty() {
            bail!("prompt encodes to zero tokens — provide a non-empty prompt");
        }
        let max_new = req.max_new.min(self.cfg.n_ctx - ids.len());
        let mut sampler = Sampler::new(req.mode, req.seed)?;
        // bind + shape-check the parameters once; the loop below issues one
        // step per token and must not re-validate the layout every call
        let params: Vec<&Tensor>;
        let bound = match &self.params {
            SessionParams::F32(p) => {
                params = p.iter().collect();
                model::DecodeModel::bind(&self.cfg, &params)?
            }
            SessionParams::Quant(qm) => model::DecodeModel::bind_quantized(qm)?,
        };
        let n_seq = req.samples;
        let mut st = DecodeState::new(&self.cfg, n_seq)?;
        // one set of per-token work buffers for the whole generation — after
        // the first step every token decodes without allocating
        let mut sc = model::DecodeScratch::new();
        let mut tok_row = vec![0i32; n_seq];

        let t0 = Instant::now();
        // every prompt token but the last only advances the state — the
        // unembedding GEMM is skipped until logits are actually needed. The
        // default route consumes the whole prompt in one chunkwise pass per
        // layer; `serial_prefill` keeps the token-by-token oracle reachable.
        if ids.len() > 1 {
            if req.serial_prefill {
                // in_bounds: guarded by ids.len() > 1 above
                for &tok in &ids[..ids.len() - 1] {
                    tok_row.fill(tok);
                    bound.prefill_step_scratch(&tok_row, &mut st, &self.pool, &mut sc)?;
                }
            } else {
                let l = ids.len() - 1;
                let mut prompt = Vec::with_capacity(n_seq * l);
                for _ in 0..n_seq {
                    // in_bounds: l = ids.len() - 1 with ids.len() > 1
                    prompt.extend_from_slice(&ids[..l]);
                }
                let mut psc = model::PrefillScratch::new();
                bound.prefill_chunked(&prompt, &mut st, &self.pool, &mut psc)?;
            }
        }
        let last = *ids
            .last()
            .ok_or_else(|| anyhow::anyhow!("prompt tokenized to zero tokens"))?;
        tok_row.fill(last);
        // the scratch's logits view dies at the next step — keep a copy the
        // sampler reads while the scratch is reused
        let mut logits: Vec<f32> = Vec::new();
        logits.extend_from_slice(bound.logits_step_scratch(&tok_row, &mut st, &self.pool, &mut sc)?);
        let prefill_s = t0.elapsed().as_secs_f64();
        let mut ttft_s = prefill_s;

        let t1 = Instant::now();
        let v = self.cfg.vocab;
        // BPE merge training can saturate below the artifact vocabulary
        // (no bigram frequent enough), leaving ids in [256 + n_merges,
        // vocab) that the model can score but the tokenizer cannot decode —
        // sample only over the decodable prefix so generation never aborts
        // on an undecodable id
        let decodable = v.min(256 + self.tokenizer.n_merges());
        let mut token_ids: Vec<Vec<i32>> = vec![Vec::with_capacity(max_new); n_seq];
        let mut streams: Vec<_> = (0..n_seq).map(|_| self.tokenizer.decode_stream()).collect();
        let mut texts = vec![String::new(); n_seq];
        for step in 0..max_new {
            for (row, out) in token_ids.iter_mut().enumerate() {
                // in_bounds: logits holds n_seq rows of v ≥ decodable floats
                let tok = sampler.sample(&logits[row * v..][..decodable])? as i32;
                out.push(tok);
                // in_bounds: texts/streams are n_seq-sized like token_ids
                texts[row].push_str(&streams[row].push(tok)?);
                // in_bounds: tok_row is n_seq-sized
                tok_row[row] = tok;
            }
            if step == 0 {
                ttft_s = t0.elapsed().as_secs_f64();
            }
            if step + 1 < max_new {
                logits.clear();
                logits.extend_from_slice(bound.logits_step_scratch(
                    &tok_row, &mut st, &self.pool, &mut sc,
                )?);
            }
        }
        for (text, stream) in texts.iter_mut().zip(streams) {
            text.push_str(&stream.finish());
        }
        let decode_s = t1.elapsed().as_secs_f64();

        Ok(GenOutcome {
            texts,
            token_ids,
            prompt_tokens: ids.len(),
            new_tokens: max_new,
            prefill_s,
            ttft_s,
            decode_s,
            state_bytes: st.state_bytes(),
        })
    }

    /// Build a continuous-batching [`BatchEngine`] over this session's
    /// parameters (bound once — the engine re-steps without re-validating
    /// the layout), tokenizer, and pool. The engine borrows the session;
    /// the serve loop and `repro loadgen` both run on top of this.
    pub fn engine(&self, conf: EngineConfig) -> Result<BatchEngine<'_>> {
        let params: Vec<&Tensor>;
        let bound = match &self.params {
            SessionParams::F32(p) => {
                params = p.iter().collect();
                model::DecodeModel::bind(&self.cfg, &params)?
            }
            SessionParams::Quant(qm) => model::DecodeModel::bind_quantized(qm)?,
        };
        BatchEngine::new(bound, &self.tokenizer, &self.pool, conf)
    }
}

/// What `repro quantize` measures: the size shrink and a decode-fidelity
/// probe of the quantized parameters against their f32 source.
#[derive(Debug, Clone)]
pub struct QuantizeOutcome {
    pub precision: Precision,
    /// Parameter bytes of the f32 source (params only, moments excluded).
    pub f32_param_bytes: usize,
    /// True stored parameter bytes after quantization (data + scales).
    pub quant_param_bytes: usize,
    /// Probe steps actually compared (0 = probe skipped).
    pub check_tokens: usize,
    /// Max |quantized − f32| over every logit of every probe step.
    pub logit_max_abs_diff: f32,
}

/// Convert a full-precision training checkpoint into a layout-v3 quantized
/// decode-only checkpoint, probing decode fidelity on the way: both
/// parameter sets step through the same deterministic token walk (each with
/// its own state and scratch) and the worst per-logit divergence is
/// reported. Threshold enforcement is the caller's call — the CLI gates on
/// `--max-logit-diff`, tests on their own bounds.
pub fn quantize_checkpoint(
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    precision: Precision,
    check_tokens: usize,
) -> Result<QuantizeOutcome> {
    let sess = ModelSession::load(input.as_ref())?;
    let params = match &sess.params {
        SessionParams::F32(p) => p,
        SessionParams::Quant(_) => bail!(
            "checkpoint {} is already quantized — quantize from the f32 training checkpoint",
            input.as_ref().display()
        ),
    };
    let refs: Vec<&Tensor> = params.iter().collect();
    let qm = QuantModel::from_params(&sess.cfg, &refs, precision)?;
    let f32_param_bytes: usize =
        params.iter().map(|t| t.shape().iter().product::<usize>() * 4).sum();

    let mut logit_max_abs_diff = 0.0f32;
    let steps = check_tokens.min(sess.cfg.n_ctx);
    if steps > 0 {
        let f32_model = model::DecodeModel::bind(&sess.cfg, &refs)?;
        let q_model = model::DecodeModel::bind_quantized(&qm)?;
        let mut st_f = DecodeState::new(&sess.cfg, 1)?;
        let mut st_q = DecodeState::new(qm.cfg(), 1)?;
        let mut sc_f = model::DecodeScratch::new();
        let mut sc_q = model::DecodeScratch::new();
        for i in 0..steps {
            let tok = [((i * 31 + 7) % sess.cfg.vocab) as i32];
            let lf = f32_model.logits_step_scratch(&tok, &mut st_f, &sess.pool, &mut sc_f)?;
            let lq = q_model.logits_step_scratch(&tok, &mut st_q, &sess.pool, &mut sc_q)?;
            for (a, b) in lf.iter().zip(lq) {
                logit_max_abs_diff = logit_max_abs_diff.max((a - b).abs());
            }
        }
    }

    let arrays = sess
        .cfg
        .param_shapes()
        .iter()
        .zip(qm.arrays())
        .map(|((_, shape), buf)| (shape.clone(), buf.clone()))
        .collect();
    let qck = QuantCheckpoint { meta: sess.meta.clone(), precision, arrays };
    qck.save(output.as_ref())
        .with_context(|| format!("writing quantized checkpoint {}", output.as_ref().display()))?;

    Ok(QuantizeOutcome {
        precision,
        f32_param_bytes,
        quant_param_bytes: qm.param_bytes(),
        check_tokens: steps,
        logit_max_abs_diff,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_lm_tags() {
        assert_eq!(
            parse_artifact_tag("lm_tiny_ours").unwrap(),
            ("tiny".to_string(), "ours".to_string())
        );
        assert_eq!(
            parse_artifact_tag("lm_medium_softmax").unwrap(),
            ("medium".to_string(), "softmax".to_string())
        );
        assert!(parse_artifact_tag("layer_ours_fwd").is_err());
        assert!(parse_artifact_tag("lm_onlyonepart").is_err());
    }
}
