//! Warm serve mode: a long-lived JSONL request/response loop over
//! stdin/stdout, scheduled by the continuous-batching [`BatchEngine`].
//!
//! One JSON object per input line, one JSON object per output line; the
//! model, tokenizer, and thread pool stay loaded across requests (loading —
//! checkpoint deserialization plus BPE merge reconstruction — is paid once,
//! not per call). A reader thread feeds lines through a channel so the
//! scheduler can interleave *reading* with *decoding*: requests arriving
//! while a batch decodes are admitted into free slots between steps instead
//! of waiting for the whole batch to finish. Responses complete in decode
//! order but are emitted in **submission order** (a reorder buffer keyed by
//! the admission serial), so clients can rely on positional correspondence.
//! EOF stops admission and drains every in-flight request cleanly, then the
//! engine's occupancy/percentile summary goes to stderr; a malformed line
//! or a failed generation answers `{"ok": false, "error": …}` and the loop
//! continues. When the bounded admission queue overflows, the response is
//! an explicit rejection (`"rejected": true`, `queue_full` in the error) —
//! graceful shedding, never a panic.
//!
//! Request schema (all fields but `prompt` optional; `seed` may be a plain
//! number or — for values above 2⁵³, which don't survive a JSON f64
//! round-trip — a decimal string, the checkpoint-trailer convention;
//! `serial_prefill: true` forces the token-by-token prompt route instead of
//! the default chunked fast path):
//! ```json
//! {"id": 1, "prompt": "the ", "max_new": 32, "mode": "greedy",
//!  "temperature": 1.0, "top_k": 0, "seed": 0, "samples": 1,
//!  "serial_prefill": false}
//! ```
//! Response (`id` echoed verbatim; `ttft_ms` is submission through the
//! first sampled token, `queue_ms` the wait for a free slot, and
//! `occupancy_mean` how many slots were busy on average while this request
//! decoded):
//! ```json
//! {"id": 1, "ok": true, "text": "…", "texts": ["…"], "prompt_tokens": 2,
//!  "new_tokens": 32, "prefill_ms": 0.8, "ttft_ms": 1.1,
//!  "prefill_tok_s": 2500.0, "decode_ms": 11.2, "tokens_per_s": 2857.1,
//!  "state_bytes": 69632, "queue_ms": 0.1, "decode_tok_s": 2857.1,
//!  "occupancy_mean": 1.0}
//! ```

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};
use std::sync::mpsc::{self, TryRecvError};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::engine::{EngineConfig, EngineOutput, EngineStats};
use super::sampler::SampleMode;
use super::session::{GenRequest, ModelSession};

/// End-of-loop summary (also logged to stderr by the CLI): line counters
/// plus the engine's full occupancy/latency statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Non-empty input lines seen (valid or not).
    pub requests: usize,
    /// Requests answered `"ok": false` (malformed, invalid, or failed).
    pub errors: usize,
    /// The subset of `errors` shed by the bounded admission queue.
    pub rejected: usize,
    /// Scheduler-level statistics (occupancy, TTFT/latency percentiles).
    pub engine: EngineStats,
}

impl ServeStats {
    /// Multi-line shutdown report: serve counters + engine percentiles.
    pub fn summary(&self) -> String {
        format!(
            "serve: {} request(s), {} error(s), {} rejected\n{}",
            self.requests,
            self.errors,
            self.rejected,
            self.engine.summary(),
        )
    }
}

/// Build a [`GenRequest`] from one parsed request object.
// no_panic
fn build_request(v: &Json, default_max_new: usize) -> Result<GenRequest> {
    let prompt = v
        .req("prompt")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("\"prompt\" must be a string"))?
        .to_string();
    let max_new = match v.get("max_new") {
        None => default_max_new,
        Some(x) => x
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("\"max_new\" must be a non-negative integer"))?,
    };
    let mode_name = match v.get("mode") {
        None => "greedy",
        Some(x) => x
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("\"mode\" must be a string (greedy|sample)"))?,
    };
    let temperature = match v.get("temperature") {
        None => 1.0,
        Some(x) => x
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("\"temperature\" must be a number"))? as f32,
    };
    let top_k = match v.get("top_k") {
        None => 0,
        Some(x) => x
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("\"top_k\" must be a non-negative integer"))?,
    };
    // seeds above 2^53 don't survive a JSON f64 round-trip — accept the
    // checkpoint convention (decimal string) alongside plain numbers, and
    // reject numbers past the exactly-representable range instead of
    // silently rounding them (reproducibility would break without a signal)
    const SEED_F64_MAX: f64 = 9_007_199_254_740_992.0; // 2^53
    let seed = match v.get("seed") {
        None => 0,
        Some(Json::Str(s)) => s.parse().map_err(|_| {
            anyhow::anyhow!("\"seed\" must be a non-negative integer (number or decimal string)")
        })?,
        Some(x) => x
            .as_f64()
            .filter(|s| *s >= 0.0 && s.fract() == 0.0 && *s <= SEED_F64_MAX)
            .map(|s| s as u64)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "\"seed\" must be a non-negative integer ≤ 2^53 as a number; send larger \
                     seeds as a decimal string"
                )
            })?,
    };
    let samples = match v.get("samples") {
        None => 1,
        Some(x) => x
            .as_usize()
            .filter(|&s| s >= 1)
            .ok_or_else(|| anyhow::anyhow!("\"samples\" must be an integer ≥ 1"))?,
    };
    let serial_prefill = match v.get("serial_prefill") {
        None => false,
        Some(x) => x
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("\"serial_prefill\" must be a boolean"))?,
    };
    let mode = SampleMode::from_flags(mode_name, temperature, top_k)?;
    Ok(GenRequest { prompt, max_new, mode, seed, samples, serial_prefill })
}

fn error_response(id: Json, err: &anyhow::Error) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::str(format!("{err:#}"))),
    ])
}

fn rejected_response(id: Json, err: &anyhow::Error) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("rejected", Json::Bool(true)),
        ("error", Json::str(format!("{err:#}"))),
    ])
}

fn ok_response(id: Json, out: &EngineOutput) -> Json {
    let prefill_tok_s = if out.prefill_s > 0.0 {
        out.prompt_tokens as f64 / out.prefill_s
    } else {
        0.0
    };
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(true)),
        ("text", Json::str(out.texts.first().cloned().unwrap_or_default())),
        ("texts", Json::Arr(out.texts.iter().map(|t| Json::str(t.clone())).collect())),
        ("prompt_tokens", Json::num(out.prompt_tokens as f64)),
        ("new_tokens", Json::num(out.new_tokens as f64)),
        ("prefill_ms", Json::num(out.prefill_s * 1e3)),
        ("ttft_ms", Json::num(out.ttft_s * 1e3)),
        ("prefill_tok_s", Json::num(prefill_tok_s)),
        ("decode_ms", Json::num(out.decode_s * 1e3)),
        ("tokens_per_s", Json::num(out.decode_tok_s)),
        ("state_bytes", Json::num(out.state_bytes as f64)),
        ("queue_ms", Json::num(out.queue_s * 1e3)),
        ("decode_tok_s", Json::num(out.decode_tok_s)),
        ("occupancy_mean", Json::num(out.occupancy_mean)),
    ])
}

/// Drive the request/response loop until EOF with the default engine
/// configuration. Generic over the streams so tests can run it against
/// in-memory buffers.
// no_panic
pub fn serve_loop(
    session: &ModelSession,
    input: impl BufRead + Send,
    output: impl Write,
    default_max_new: usize,
) -> Result<ServeStats> {
    serve_loop_with(session, EngineConfig::default(), input, output, default_max_new)
}

/// [`serve_loop`] with explicit scheduler knobs (`--slots`, `--queue`,
/// `--prefill-budget`).
///
/// A scoped reader thread pumps `input` into a channel; the scheduler
/// thread alternates between ingesting whatever lines have arrived
/// (blocking only when the engine is idle) and running engine cycles, so
/// new requests join a busy batch between decode steps.
// no_panic
pub fn serve_loop_with(
    session: &ModelSession,
    conf: EngineConfig,
    input: impl BufRead + Send,
    mut output: impl Write,
    default_max_new: usize,
) -> Result<ServeStats> {
    let mut engine = session.engine(conf)?;
    let mut stats = ServeStats::default();
    let tag = session.meta().artifact_tag.clone();
    std::thread::scope(|scope| -> Result<()> {
        let (tx, rx) = mpsc::channel::<std::io::Result<String>>();
        scope.spawn(move || {
            for line in input.lines() {
                if tx.send(line).is_err() {
                    return; // scheduler gone — stop reading
                }
            }
        });

        // responses keyed by admission serial; emitted strictly in order
        let mut next_serial: u64 = 0;
        let mut emit_next: u64 = 0;
        let mut ready: BTreeMap<u64, Json> = BTreeMap::new();
        let mut ids: HashMap<u64, Json> = HashMap::new();
        let mut eof = false;
        loop {
            // ingest: drain whatever lines have arrived; block only when
            // the engine has nothing else to do
            while !eof {
                let line = if engine.is_idle() && ready.is_empty() {
                    match rx.recv() {
                        Ok(l) => l,
                        Err(_) => {
                            eof = true;
                            break;
                        }
                    }
                } else {
                    match rx.try_recv() {
                        Ok(l) => l,
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            eof = true;
                            break;
                        }
                    }
                };
                let line = line.context("reading request line")?;
                if line.trim().is_empty() {
                    continue;
                }
                stats.requests += 1;
                let serial = next_serial;
                next_serial += 1;
                match Json::parse(&line).context("malformed JSON request") {
                    Err(e) => {
                        stats.errors += 1;
                        ready.insert(serial, error_response(Json::Null, &e));
                    }
                    Ok(v) => {
                        // the id is echoed even when validation fails —
                        // clients correlate responses by it
                        let id = v.get("id").cloned().unwrap_or(Json::Null);
                        match build_request(&v, default_max_new) {
                            Err(e) => {
                                stats.errors += 1;
                                ready.insert(serial, error_response(id, &e));
                            }
                            Ok(req) => {
                                ids.insert(serial, id);
                                engine.submit(serial, req);
                            }
                        }
                    }
                }
            }

            // one scheduler cycle; a systemic error answers everything
            // in flight instead of killing the warm server
            if let Err(e) = engine.step() {
                engine.fail_all(&e);
            }

            for resp in engine.take_finished() {
                let id = ids.remove(&resp.serial).unwrap_or(Json::Null);
                let json = match &resp.result {
                    Ok(out) => {
                        eprintln!(
                            "serve: {tag} prompt={}t new={}t queue {:.1} ms prefill {:.1} ms \
                             ttft {:.1} ms decode {:.1} ms ({:.0} tok/s, occ {:.2}, state {} B)",
                            out.prompt_tokens,
                            out.new_tokens,
                            out.queue_s * 1e3,
                            out.prefill_s * 1e3,
                            out.ttft_s * 1e3,
                            out.decode_s * 1e3,
                            out.decode_tok_s,
                            out.occupancy_mean,
                            out.state_bytes,
                        );
                        ok_response(id, out)
                    }
                    Err(e) => {
                        stats.errors += 1;
                        if resp.rejected {
                            stats.rejected += 1;
                            rejected_response(id, e)
                        } else {
                            error_response(id, e)
                        }
                    }
                };
                ready.insert(resp.serial, json);
            }

            while let Some(json) = ready.remove(&emit_next) {
                emit_next += 1;
                writeln!(output, "{}", json.to_string())?;
                output.flush()?;
            }

            if eof && engine.is_idle() && ready.is_empty() {
                break;
            }
        }
        Ok(())
    })?;
    stats.engine = engine.stats().clone();
    Ok(stats)
}
