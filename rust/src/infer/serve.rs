//! Warm serve mode: a long-lived JSONL request/response loop over
//! stdin/stdout.
//!
//! One JSON object per input line, one JSON object per output line; the
//! model, tokenizer, and thread pool stay loaded across requests (loading —
//! checkpoint deserialization plus BPE merge reconstruction — is paid once,
//! not per call). EOF exits cleanly with a session summary on stderr; a
//! malformed line or a failed generation answers `{"ok": false, "error":
//! …}` and the loop continues.
//!
//! Request schema (all fields but `prompt` optional; `seed` may be a plain
//! number or — for values above 2⁵³, which don't survive a JSON f64
//! round-trip — a decimal string, the checkpoint-trailer convention;
//! `serial_prefill: true` forces the token-by-token prompt route instead of
//! the default chunked fast path):
//! ```json
//! {"id": 1, "prompt": "the ", "max_new": 32, "mode": "greedy",
//!  "temperature": 1.0, "top_k": 0, "seed": 0, "samples": 1,
//!  "serial_prefill": false}
//! ```
//! Response (`id` echoed verbatim; `ttft_ms` is time-to-first-token —
//! prompt ingestion through the first sampled token — and `prefill_tok_s`
//! is prompt tokens per second of the prefill phase alone):
//! ```json
//! {"id": 1, "ok": true, "text": "…", "texts": ["…"], "prompt_tokens": 2,
//!  "new_tokens": 32, "prefill_ms": 0.8, "ttft_ms": 1.1,
//!  "prefill_tok_s": 2500.0, "decode_ms": 11.2, "tokens_per_s": 2857.1,
//!  "state_bytes": 69632}
//! ```

use std::io::{BufRead, Write};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::sampler::SampleMode;
use super::session::{GenRequest, ModelSession};

/// End-of-loop summary (also logged to stderr by the CLI).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: usize,
    pub errors: usize,
}

/// Build a [`GenRequest`] from one parsed request object.
// no_panic
fn build_request(v: &Json, default_max_new: usize) -> Result<GenRequest> {
    let prompt = v
        .req("prompt")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("\"prompt\" must be a string"))?
        .to_string();
    let max_new = match v.get("max_new") {
        None => default_max_new,
        Some(x) => x
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("\"max_new\" must be a non-negative integer"))?,
    };
    let mode_name = match v.get("mode") {
        None => "greedy",
        Some(x) => x
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("\"mode\" must be a string (greedy|sample)"))?,
    };
    let temperature = match v.get("temperature") {
        None => 1.0,
        Some(x) => x
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("\"temperature\" must be a number"))? as f32,
    };
    let top_k = match v.get("top_k") {
        None => 0,
        Some(x) => x
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("\"top_k\" must be a non-negative integer"))?,
    };
    // seeds above 2^53 don't survive a JSON f64 round-trip — accept the
    // checkpoint convention (decimal string) alongside plain numbers, and
    // reject numbers past the exactly-representable range instead of
    // silently rounding them (reproducibility would break without a signal)
    const SEED_F64_MAX: f64 = 9_007_199_254_740_992.0; // 2^53
    let seed = match v.get("seed") {
        None => 0,
        Some(Json::Str(s)) => s.parse().map_err(|_| {
            anyhow::anyhow!("\"seed\" must be a non-negative integer (number or decimal string)")
        })?,
        Some(x) => x
            .as_f64()
            .filter(|s| *s >= 0.0 && s.fract() == 0.0 && *s <= SEED_F64_MAX)
            .map(|s| s as u64)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "\"seed\" must be a non-negative integer ≤ 2^53 as a number; send larger \
                     seeds as a decimal string"
                )
            })?,
    };
    let samples = match v.get("samples") {
        None => 1,
        Some(x) => x
            .as_usize()
            .filter(|&s| s >= 1)
            .ok_or_else(|| anyhow::anyhow!("\"samples\" must be an integer ≥ 1"))?,
    };
    let serial_prefill = match v.get("serial_prefill") {
        None => false,
        Some(x) => x
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("\"serial_prefill\" must be a boolean"))?,
    };
    let mode = SampleMode::from_flags(mode_name, temperature, top_k)?;
    Ok(GenRequest { prompt, max_new, mode, seed, samples, serial_prefill })
}

fn error_response(id: Json, err: &anyhow::Error) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::str(format!("{err:#}"))),
    ])
}

/// Drive the request/response loop until EOF. Generic over the streams so
/// tests can run it against in-memory buffers.
// no_panic
pub fn serve_loop(
    session: &ModelSession,
    input: impl BufRead,
    mut output: impl Write,
    default_max_new: usize,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    for line in input.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        stats.requests += 1;
        let response = match Json::parse(&line).context("malformed JSON request") {
            Err(e) => {
                stats.errors += 1;
                error_response(Json::Null, &e)
            }
            Ok(v) => {
                // the id is echoed even when field validation fails below —
                // clients correlate responses to in-flight requests by it
                let id = v.get("id").cloned().unwrap_or(Json::Null);
                match build_request(&v, default_max_new)
                    .and_then(|req| session.generate(&req))
                {
                    Err(e) => {
                        stats.errors += 1;
                        error_response(id, &e)
                    }
                    Ok(out) => {
                        eprintln!(
                            "serve: {} prompt={}t new={}t prefill {:.1} ms ({:.0} tok/s) \
                             ttft {:.1} ms decode {:.1} ms ({:.0} tok/s, state {} B)",
                            session.meta().artifact_tag,
                            out.prompt_tokens,
                            out.new_tokens,
                            out.prefill_s * 1e3,
                            out.prefill_tok_s(),
                            out.ttft_s * 1e3,
                            out.decode_s * 1e3,
                            out.tokens_per_s(),
                            out.state_bytes,
                        );
                        Json::obj(vec![
                            ("id", id),
                            ("ok", Json::Bool(true)),
                            // in_bounds: samples ≥ 1 is validated above, so
                            // texts is non-empty
                            ("text", Json::str(out.texts[0].clone())),
                            (
                                "texts",
                                Json::Arr(
                                    out.texts.iter().map(|t| Json::str(t.clone())).collect(),
                                ),
                            ),
                            ("prompt_tokens", Json::num(out.prompt_tokens as f64)),
                            ("new_tokens", Json::num(out.new_tokens as f64)),
                            ("prefill_ms", Json::num(out.prefill_s * 1e3)),
                            ("ttft_ms", Json::num(out.ttft_s * 1e3)),
                            ("prefill_tok_s", Json::num(out.prefill_tok_s())),
                            ("decode_ms", Json::num(out.decode_s * 1e3)),
                            ("tokens_per_s", Json::num(out.tokens_per_s())),
                            ("state_bytes", Json::num(out.state_bytes as f64)),
                        ])
                    }
                }
            }
        };
        writeln!(output, "{}", response.to_string())?;
        output.flush()?;
    }
    Ok(stats)
}
