//! Aggregate engine statistics: request counters, batch occupancy, and the
//! per-request latency/TTFT distributions summarized through the bench
//! harness's [`TimingStats`] (same non-finite filtering, same percentile
//! definitions), plus the raw per-step samples the traffic-model fit
//! consumes.

use crate::bench::timing::TimingStats;

/// Counters and sample sets accumulated over an engine's lifetime. Cheap to
/// update per event; the percentile summaries are computed on demand (at
/// shutdown or when the bench serializes a serve section).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Requests handed to `submit` (including rejected ones).
    pub submitted: usize,
    /// Requests answered with a completed generation.
    pub completed: usize,
    /// Requests shed by the bounded admission queue (`queue_full`).
    pub rejected: usize,
    /// Requests answered with a validation/decoding error.
    pub errors: usize,
    /// Masked decode steps executed.
    pub decode_steps: usize,
    /// Tokens produced across all slots (one per active slot per step).
    pub slot_tokens: usize,
    /// Highest number of simultaneously occupied slots observed.
    pub max_occupancy: usize,
    occupancy_sum: usize,
    /// Per-request time-to-first-token (submission → first token), seconds.
    ttft_s: Vec<f64>,
    /// Per-request total latency (submission → completion), seconds.
    latency_s: Vec<f64>,
    /// Per-request queue wait, seconds.
    queue_s: Vec<f64>,
    /// Per-request decode throughput, tokens/s.
    decode_tok_s: Vec<f64>,
    /// Per-decode-step `(bytes moved estimate, measured seconds)` — the
    /// traffic-model calibration's sample set.
    step_samples: Vec<(f64, f64)>,
}

impl EngineStats {
    /// Record one masked decode step: how many slots were occupied, how
    /// long it took, and the modeled bytes it moved.
    pub(crate) fn record_step(&mut self, occupancy: usize, bytes: f64, seconds: f64) {
        self.decode_steps += 1;
        self.slot_tokens += occupancy;
        self.occupancy_sum += occupancy;
        self.max_occupancy = self.max_occupancy.max(occupancy);
        self.step_samples.push((bytes, seconds));
    }

    /// Record one completed request's latency split.
    pub(crate) fn record_request(
        &mut self,
        queue_s: f64,
        ttft_s: f64,
        latency_s: f64,
        decode_tok_s: f64,
    ) {
        self.completed += 1;
        self.queue_s.push(queue_s);
        self.ttft_s.push(ttft_s);
        self.latency_s.push(latency_s);
        self.decode_tok_s.push(decode_tok_s);
    }

    /// Mean occupied slots per decode step (0 when no step ran).
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.decode_steps as f64
        }
    }

    /// TTFT distribution over completed requests.
    pub fn ttft_stats(&self) -> Option<TimingStats> {
        TimingStats::from_samples(self.ttft_s.clone())
    }

    /// Total-latency distribution over completed requests.
    pub fn latency_stats(&self) -> Option<TimingStats> {
        TimingStats::from_samples(self.latency_s.clone())
    }

    /// Queue-wait distribution over completed requests.
    pub fn queue_stats(&self) -> Option<TimingStats> {
        TimingStats::from_samples(self.queue_s.clone())
    }

    /// Decode-throughput distribution over completed requests.
    pub fn decode_tok_s_stats(&self) -> Option<TimingStats> {
        TimingStats::from_samples(self.decode_tok_s.clone())
    }

    /// Per-step `(bytes, seconds)` samples for the traffic-model fit.
    pub fn step_samples(&self) -> &[(f64, f64)] {
        &self.step_samples
    }

    /// Multi-line shutdown report: counters, occupancy, and p50/p95/p99
    /// latency + TTFT percentiles (the serve CLI logs this to stderr).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "engine: {} submitted, {} completed, {} rejected, {} error(s); \
             {} decode steps, occupancy mean {:.2} max {}",
            self.submitted,
            self.completed,
            self.rejected,
            self.errors,
            self.decode_steps,
            self.mean_occupancy(),
            self.max_occupancy,
        );
        let line = |name: &str, st: &TimingStats| {
            format!(
                "\nengine: {name} p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms",
                st.p50 * 1e3,
                st.p95 * 1e3,
                st.p99 * 1e3,
            )
        };
        if let Some(st) = self.ttft_stats() {
            s.push_str(&line("ttft", &st));
        }
        if let Some(st) = self.latency_stats() {
            s.push_str(&line("latency", &st));
        }
        if let Some(st) = self.decode_tok_s_stats() {
            s.push_str(&format!(
                "\nengine: decode {:.0} tok/s p50 ({:.0} p10, {:.0} p90)",
                st.p50, st.p10, st.p90,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_percentiles_accumulate() {
        let mut st = EngineStats::default();
        assert_eq!(st.mean_occupancy(), 0.0);
        assert!(st.ttft_stats().is_none());
        st.record_step(1, 10.0, 0.001);
        st.record_step(3, 30.0, 0.003);
        assert_eq!(st.decode_steps, 2);
        assert_eq!(st.slot_tokens, 4);
        assert_eq!(st.max_occupancy, 3);
        assert!((st.mean_occupancy() - 2.0).abs() < 1e-12);
        assert_eq!(st.step_samples().len(), 2);

        for i in 0..5 {
            st.record_request(0.0, 0.01 * (i + 1) as f64, 0.1, 100.0);
        }
        assert_eq!(st.completed, 5);
        let ttft = st.ttft_stats().unwrap();
        assert_eq!(ttft.reps, 5);
        assert!((ttft.p50 - 0.03).abs() < 1e-12);
        assert!(ttft.p99 >= ttft.p50);
        let sum = st.summary();
        assert!(sum.contains("occupancy mean 2.00 max 3"));
        assert!(sum.contains("ttft p50"));
    }
}
