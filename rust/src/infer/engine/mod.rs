//! Continuous-batching serve engine: dynamic join/leave over one shared
//! batched decode state.
//!
//! `repro serve` used to answer one request at a time, leaving the batched
//! [`DecodeModel`] machinery (which already steps `n_seq` sequences per
//! token) idle under concurrent load. [`BatchEngine`] closes that gap with
//! a slot-based scheduler:
//!
//! - **Slots** — a fixed-capacity pool of decode lanes backed by *one*
//!   shared [`DecodeState`]/[`DecodeScratch`] pair (`--slots` wide). Each
//!   admitted request owns `samples` slots until it finishes.
//! - **Admission** — queued requests are prefilled through a one-sequence
//!   *staging* state (budgeted to `prefill_budget` prompt tokens per
//!   scheduler cycle so a long prompt cannot stall in-flight decodes), then
//!   adopted into their reserved slots between decode steps
//!   ([`DecodeState::adopt_seq`] — a raw per-lane copy, so decoding from
//!   the slot is bit-identical to decoding from the staging state).
//! - **Decode** — one [`DecodeModel::decode_step_masked`] call per cycle
//!   advances every occupied slot at its own position; every decode op is
//!   row-independent, so a request's tokens are bit-identical whether it
//!   runs alone or joins a busy batch mid-stream (the parity tests in
//!   `tests/engine.rs` pin this per `AttnKind`).
//! - **Eviction** — finished/capped sequences release their slots
//!   immediately ([`DecodeState::clear_seq`], allocation-free) so the next
//!   admission can reuse them on the very next cycle.
//! - **Backpressure** — the admission queue is bounded; overflow answers an
//!   explicit `queue_full` rejection instead of growing without bound, and
//!   nothing in the engine panics (`// no_panic`, machine-checked by
//!   `xtask lint`).
//!
//! The engine is synchronous and in-process: callers interleave
//! [`submit`](BatchEngine::submit) / [`step`](BatchEngine::step) /
//! [`take_finished`](BatchEngine::take_finished) however their transport
//! requires (the serve loop polls a reader thread between cycles; the load
//! generator replays seeded arrival traces).

pub mod loadgen;
pub mod request;
pub mod stats;

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::data::{ByteTokenizer, DecodeStream};
use crate::native::model::{DecodeModel, DecodeScratch, Precision, PrefillScratch};
use crate::native::pool::ThreadPool;

use super::sampler::Sampler;
use super::session::{GenRequest, MAX_SAMPLES};
use super::state::DecodeState;

pub use request::{EngineOutput, EngineRequest, EngineResponse};
pub use stats::EngineStats;

/// Scheduler knobs. Defaults suit the tiny/small presets the tests and CI
/// drive; the serve CLI exposes each as a flag.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Decode-batch width: how many sequences share the batched step.
    pub slots: usize,
    /// Admission-queue bound; submissions past it are shed (`queue_full`).
    pub queue: usize,
    /// Prompt tokens prefilled per scheduler cycle — the knob trading new
    /// requests' TTFT against in-flight requests' inter-token latency.
    pub prefill_budget: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { slots: 4, queue: 32, prefill_budget: 64 }
    }
}

impl EngineConfig {
    fn validate(&self) -> Result<()> {
        if self.slots == 0 || self.slots > MAX_SAMPLES {
            bail!("engine slots must be in [1, {MAX_SAMPLES}], got {}", self.slots);
        }
        if self.queue == 0 {
            bail!("engine queue bound must be ≥ 1");
        }
        if self.prefill_budget == 0 {
            bail!("engine prefill budget must be ≥ 1");
        }
        Ok(())
    }
}

/// One validated, tokenized submission waiting for slots.
struct Queued {
    serial: u64,
    gen: GenRequest,
    /// Prompt ids, already truncated to the last `n_ctx − 1`.
    ids: Vec<i32>,
    /// `max_new` after context-window clamping.
    max_new: usize,
    sampler: Sampler,
    arrival: Instant,
}

/// The request currently being prefilled through the staging state.
struct Prefilling {
    req: Queued,
    /// Reserved slot indices, ascending (sample order).
    slots: Vec<usize>,
    /// Prompt tokens already consumed (of `ids.len() − 1`).
    consumed: usize,
    /// When the slots were reserved and prefill began.
    admit: Instant,
    /// Accumulated staging-prefill wall-clock across cycles.
    prefill_s: f64,
}

/// A request decoding in its slots.
struct InFlight<'a> {
    serial: u64,
    sampler: Sampler,
    /// Slot indices, ascending — within a request, sample order follows
    /// slot order, so the per-request RNG stream draws exactly like
    /// [`generate`](crate::infer::session::ModelSession::generate)'s
    /// row-major loop.
    slots: Vec<usize>,
    max_new: usize,
    prompt_tokens: usize,
    arrival: Instant,
    queue_s: f64,
    prefill_s: f64,
    ttft_s: f64,
    decode_start: Instant,
    generated: usize,
    token_ids: Vec<Vec<i32>>,
    texts: Vec<String>,
    streams: Vec<DecodeStream<'a>>,
    occ_sum: usize,
    occ_steps: usize,
    /// Set when sampling failed mid-stream (diverged logits); the request
    /// is evicted and answered with this error.
    failed: Option<anyhow::Error>,
}

/// The continuous-batching scheduler. See the module docs for the slot
/// model; lifetimes tie the engine to the session that owns the parameter
/// tensors, tokenizer, and thread pool.
pub struct BatchEngine<'a> {
    model: DecodeModel<'a>,
    tokenizer: &'a ByteTokenizer,
    pool: &'a ThreadPool,
    conf: EngineConfig,
    /// The shared batch state: one sequence lane per slot.
    batch: DecodeState,
    sc: DecodeScratch,
    /// One-sequence staging state prompts are prefilled through before
    /// adoption (so a half-prefilled prompt never occupies batch lanes).
    staging: DecodeState,
    staging_sc: DecodeScratch,
    staging_psc: PrefillScratch,
    /// Per-slot occupancy mask — the masked decode step's `active`.
    active: Vec<bool>,
    /// Per-slot next token to feed (last prompt token at adoption, then
    /// each freshly sampled token).
    pending: Vec<i32>,
    queue: VecDeque<Queued>,
    prefilling: Option<Prefilling>,
    inflight: Vec<InFlight<'a>>,
    done: Vec<EngineResponse>,
    stats: EngineStats,
    /// Modeled parameter bytes streamed per decode step (precision-aware) —
    /// the constant term of the per-step traffic estimate the calibration
    /// fit consumes.
    step_param_bytes: f64,
}

impl<'a> BatchEngine<'a> {
    /// Build an engine over a bound model. The `DecodeState`s and scratch
    /// buffers are allocated here, once; steady-state scheduling reuses
    /// them (the per-token hot path stays allocation-free — pinned in
    /// `tests/alloc_gate.rs`).
    pub fn new(
        model: DecodeModel<'a>,
        tokenizer: &'a ByteTokenizer,
        pool: &'a ThreadPool,
        conf: EngineConfig,
    ) -> Result<Self> {
        conf.validate()?;
        let cfg = *model.cfg();
        let batch = DecodeState::new(&cfg, conf.slots)?;
        let staging = DecodeState::new(&cfg, 1)?;
        let per_elem = match cfg.precision {
            Precision::F32 => 4.0,
            Precision::Bf16 => 2.0,
            Precision::Int8 => 1.0,
        };
        let step_param_bytes = cfg.n_params() as f64 * per_elem;
        Ok(Self {
            model,
            tokenizer,
            pool,
            conf,
            batch,
            sc: DecodeScratch::new(),
            staging,
            staging_sc: DecodeScratch::new(),
            staging_psc: PrefillScratch::new(),
            active: vec![false; conf.slots],
            pending: vec![0; conf.slots],
            queue: VecDeque::new(),
            prefilling: None,
            inflight: Vec::new(),
            done: Vec::new(),
            stats: EngineStats::default(),
            step_param_bytes,
        })
    }

    /// The scheduler configuration this engine runs with.
    pub fn config(&self) -> &EngineConfig {
        &self.conf
    }

    /// Aggregate statistics so far (occupancy, percentiles, fit samples).
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// True when nothing is queued, prefilling, or decoding.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.prefilling.is_none() && self.inflight.is_empty()
    }

    /// Currently occupied decode slots.
    pub fn occupancy(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Completed/rejected/failed responses accumulated since the last call,
    /// in completion order (transports needing arrival order re-sort by
    /// `serial`).
    pub fn take_finished(&mut self) -> Vec<EngineResponse> {
        std::mem::take(&mut self.done)
    }

    /// Validate and enqueue one request. Invalid requests and
    /// backpressure rejections are answered immediately through
    /// [`take_finished`](Self::take_finished); nothing here panics and
    /// nothing blocks.
    // no_panic
    pub fn submit(&mut self, serial: u64, gen: GenRequest) {
        self.stats.submitted += 1;
        if gen.samples == 0 || gen.samples > MAX_SAMPLES {
            // same contract as `generate`: an absurd batch size answers an
            // error, it must not abort (or starve) a warm server
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(
                serial,
                anyhow!("samples must be in [1, {MAX_SAMPLES}], got {}", gen.samples),
            ));
            return;
        }
        if gen.samples > self.conf.slots {
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(
                serial,
                anyhow!(
                    "samples {} exceeds the engine's {} decode slot(s) — raise --slots \
                     or lower samples",
                    gen.samples,
                    self.conf.slots
                ),
            ));
            return;
        }
        let sampler = match Sampler::new(gen.mode, gen.seed) {
            Ok(s) => s,
            Err(e) => {
                self.stats.errors += 1;
                self.done.push(EngineResponse::failed(serial, e));
                return;
            }
        };
        if self.queue.len() >= self.conf.queue {
            // explicit load shedding: the bounded queue is the engine's
            // backpressure valve — answer now, don't grow without bound
            self.stats.rejected += 1;
            self.done.push(EngineResponse::shed(
                serial,
                anyhow!(
                    "queue_full: admission queue at capacity {} — retry later or raise --queue",
                    self.conf.queue
                ),
            ));
            return;
        }
        let n_ctx = self.model.cfg().n_ctx;
        let mut ids = self.tokenizer.encode(&gen.prompt);
        if ids.len() > n_ctx - 1 {
            ids.drain(..ids.len() - (n_ctx - 1));
        }
        if ids.is_empty() {
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(
                serial,
                anyhow!("prompt encodes to zero tokens — provide a non-empty prompt"),
            ));
            return;
        }
        let max_new = gen.max_new.min(n_ctx - ids.len());
        let arrival = Instant::now();
        self.queue.push_back(Queued { serial, gen, ids, max_new, sampler, arrival });
    }

    /// One scheduler cycle: admit queued requests into free slots (staging
    /// prefill under the budget, then adoption), then advance every
    /// occupied slot by one masked decode step, retiring finished requests.
    /// Returns `false` when the engine was idle (nothing to do). Errors are
    /// systemic (a broken state); per-request failures are answered through
    /// [`take_finished`](Self::take_finished) instead.
    // no_panic
    // bounds: slot indices come from `active`/`pending`/the batch state,
    // all sized to conf.slots at construction; logits rows are slot-indexed
    pub fn step(&mut self) -> Result<bool> {
        if self.is_idle() {
            return Ok(false);
        }
        self.admit_cycle()?;
        if self.inflight.is_empty() {
            // admission made progress (prefill slice or an answered
            // request) but nothing decodes yet
            return Ok(true);
        }

        let occupancy = self.occupancy();
        let mut lane_bytes = 0usize;
        for (i, &a) in self.active.iter().enumerate() {
            if a {
                lane_bytes += self.batch.seq_state_bytes(i);
            }
        }
        let bytes = self.step_param_bytes + 2.0 * lane_bytes as f64;

        let t0 = Instant::now();
        let logits = self.model.decode_step_masked(
            &self.pending,
            &self.active,
            &mut self.batch,
            self.pool,
            &mut self.sc,
        )?;
        let v = self.model.cfg().vocab;
        // BPE merge training can saturate below the artifact vocabulary —
        // sample only over the decodable prefix, exactly like `generate`
        let decodable = v.min(256 + self.tokenizer.n_merges());
        for fl in &mut self.inflight {
            let first = fl.generated == 0;
            'sample: for (si, &slot) in fl.slots.iter().enumerate() {
                let tok = match fl.sampler.sample(&logits[slot * v..][..decodable]) {
                    Ok(t) => t as i32,
                    Err(e) => {
                        // diverged logits: answer this request with the
                        // error and evict it; its batch-mates continue
                        fl.failed = Some(e);
                        break 'sample;
                    }
                };
                fl.token_ids[si].push(tok);
                match fl.streams[si].push(tok) {
                    Ok(piece) => fl.texts[si].push_str(&piece),
                    Err(e) => {
                        fl.failed = Some(e);
                        break 'sample;
                    }
                }
                self.pending[slot] = tok;
            }
            if fl.failed.is_none() {
                fl.generated += 1;
                if first {
                    fl.ttft_s = fl.arrival.elapsed().as_secs_f64();
                }
            }
            fl.occ_sum += occupancy;
            fl.occ_steps += 1;
        }
        let step_s = t0.elapsed().as_secs_f64();
        self.stats.record_step(occupancy, bytes, step_s);

        // retire finished (or failed) requests in admission order and free
        // their slots for the next cycle's admissions
        let inflight = std::mem::take(&mut self.inflight);
        for fl in inflight {
            if fl.failed.is_some() || fl.generated >= fl.max_new {
                self.retire(fl)?;
            } else {
                self.inflight.push(fl);
            }
        }
        Ok(true)
    }

    /// Run the scheduler until every queued and in-flight request is
    /// answered — the EOF drain of the serve loop. Terminates because each
    /// cycle consumes prompt tokens or produces decode tokens, both
    /// bounded.
    // no_panic
    pub fn drain(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Admission half of a cycle: spend up to `prefill_budget` prompt
    /// tokens on the staging prefill, adopting completed prompts into
    /// their reserved slots; start new prefills while budget and slots
    /// remain (smallest-serial first — strict arrival order).
    // no_panic
    // bounds: reserved slot indices come from the free-slot scan over
    // `active` (conf.slots wide); prompt windows are carved from `consumed`,
    // which is bounded by ids.len() − 1
    fn admit_cycle(&mut self) -> Result<()> {
        let mut budget = self.conf.prefill_budget;
        loop {
            if self.prefilling.is_none() {
                let need = match self.queue.front() {
                    None => break,
                    Some(q) => q.gen.samples,
                };
                let free: Vec<usize> =
                    (0..self.conf.slots).filter(|&i| !self.active[i]).collect();
                if free.len() < need {
                    break; // head-of-line waits for evictions; order stays deterministic
                }
                let req = match self.queue.pop_front() {
                    Some(q) => q,
                    None => break,
                };
                self.staging.reset();
                let mut slots = free;
                slots.truncate(need);
                self.prefilling =
                    Some(Prefilling { req, slots, consumed: 0, admit: Instant::now(), prefill_s: 0.0 });
            }
            if budget == 0 {
                break;
            }
            let pf = match self.prefilling.as_mut() {
                Some(p) => p,
                None => break,
            };
            // every prompt token but the last only advances the state; the
            // last is fed to the first decode step (logits + first sample)
            let prompt = pf.req.ids.len() - 1;
            let take = (prompt - pf.consumed).min(budget);
            if take > 0 {
                let t0 = Instant::now();
                let window = &pf.req.ids[pf.consumed..pf.consumed + take];
                if pf.req.gen.serial_prefill {
                    for &tok in window {
                        self.model.prefill_step_scratch(
                            &[tok],
                            &mut self.staging,
                            self.pool,
                            &mut self.staging_sc,
                        )?;
                    }
                } else {
                    self.model.prefill_chunked(
                        window,
                        &mut self.staging,
                        self.pool,
                        &mut self.staging_psc,
                    )?;
                }
                pf.consumed += take;
                pf.prefill_s += t0.elapsed().as_secs_f64();
                budget -= take;
            }
            if pf.consumed < prompt {
                break; // budget exhausted mid-prompt; resume next cycle
            }
            // prompt fully staged — adopt into the reserved slots
            let pf = match self.prefilling.take() {
                Some(p) => p,
                None => break,
            };
            self.adopt(pf)?;
        }
        Ok(())
    }

    /// Move a fully-prefilled request from staging into its slots and the
    /// in-flight set (or answer it directly when `max_new` clamped to 0).
    // no_panic
    // bounds: slot indices were reserved from the free-slot scan; per-slot
    // arrays are conf.slots wide
    fn adopt(&mut self, pf: Prefilling) -> Result<()> {
        let Prefilling { req, slots, admit, prefill_s, .. } = pf;
        let queue_s = admit.duration_since(req.arrival).as_secs_f64();
        let n = req.gen.samples;
        if req.max_new == 0 {
            // nothing to decode: answer now, slots were never dirtied
            let state_bytes = self.staging.seq_state_bytes(0) * n;
            let ttft_s = req.arrival.elapsed().as_secs_f64();
            self.stats.record_request(queue_s, ttft_s, ttft_s, 0.0);
            self.done.push(EngineResponse::done(
                req.serial,
                EngineOutput {
                    texts: vec![String::new(); n],
                    token_ids: vec![Vec::new(); n],
                    prompt_tokens: req.ids.len(),
                    new_tokens: 0,
                    queue_s,
                    prefill_s,
                    ttft_s,
                    decode_s: 0.0,
                    decode_tok_s: 0.0,
                    occupancy_mean: 0.0,
                    state_bytes,
                },
            ));
            return Ok(());
        }
        let last = match req.ids.last() {
            Some(&t) => t,
            None => bail!("internal: admitted request with an empty prompt"),
        };
        for &slot in &slots {
            self.batch.adopt_seq(slot, &self.staging)?;
            self.active[slot] = true;
            self.pending[slot] = last;
        }
        self.inflight.push(InFlight {
            serial: req.serial,
            sampler: req.sampler,
            slots,
            max_new: req.max_new,
            prompt_tokens: req.ids.len(),
            arrival: req.arrival,
            queue_s,
            prefill_s,
            ttft_s: 0.0,
            decode_start: Instant::now(),
            generated: 0,
            token_ids: vec![Vec::new(); n],
            texts: vec![String::new(); n],
            streams: (0..n).map(|_| self.tokenizer.decode_stream()).collect(),
            occ_sum: 0,
            occ_steps: 0,
            failed: None,
        });
        Ok(())
    }

    /// Evict one finished/failed request: free its slots (allocation-free
    /// per-lane reset) and push its response.
    // no_panic
    fn retire(&mut self, fl: InFlight<'a>) -> Result<()> {
        let mut state_bytes = 0usize;
        for &slot in &fl.slots {
            state_bytes += self.batch.seq_state_bytes(slot);
            self.batch.clear_seq(slot)?;
            // in_bounds: slot < conf.slots — reserved from the free-slot scan
            self.active[slot] = false;
            // in_bounds: same slot bound as the line above
            self.pending[slot] = 0;
        }
        if let Some(err) = fl.failed {
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(fl.serial, err));
            return Ok(());
        }
        let decode_s = fl.decode_start.elapsed().as_secs_f64();
        let latency_s = fl.arrival.elapsed().as_secs_f64();
        let new_tokens = fl.generated;
        let n = fl.slots.len();
        let decode_tok_s =
            if decode_s > 0.0 { (new_tokens * n) as f64 / decode_s } else { 0.0 };
        let occupancy_mean =
            if fl.occ_steps > 0 { fl.occ_sum as f64 / fl.occ_steps as f64 } else { 0.0 };
        let mut texts = fl.texts;
        for (text, stream) in texts.iter_mut().zip(fl.streams) {
            text.push_str(&stream.finish());
        }
        let ttft_s = if fl.ttft_s > 0.0 { fl.ttft_s } else { latency_s };
        self.stats.record_request(fl.queue_s, ttft_s, latency_s, decode_tok_s);
        self.done.push(EngineResponse::done(
            fl.serial,
            EngineOutput {
                texts,
                token_ids: fl.token_ids,
                prompt_tokens: fl.prompt_tokens,
                new_tokens,
                queue_s: fl.queue_s,
                prefill_s: fl.prefill_s,
                ttft_s,
                decode_s,
                decode_tok_s,
                occupancy_mean,
                state_bytes,
            },
        ));
        Ok(())
    }

    /// Systemic-failure recovery: answer every queued and in-flight
    /// request with `err`, clear all slots, and return the engine to an
    /// idle (but warm) state. The serve loop calls this when
    /// [`step`](Self::step) itself errors so one broken request can never
    /// wedge the server.
    // no_panic
    pub fn fail_all(&mut self, err: &anyhow::Error) {
        let msg = format!("{err:#}");
        for q in std::mem::take(&mut self.queue) {
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(q.serial, anyhow!("{msg}")));
        }
        if let Some(pf) = self.prefilling.take() {
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(pf.req.serial, anyhow!("{msg}")));
        }
        for fl in std::mem::take(&mut self.inflight) {
            self.stats.errors += 1;
            self.done.push(EngineResponse::failed(fl.serial, anyhow!("{msg}")));
        }
        self.batch.reset();
        self.staging.reset();
        self.active.fill(false);
        self.pending.fill(0);
    }
}
