//! Deterministic in-process load generator (`repro loadgen`).
//!
//! Replays a seeded arrival trace ([`ArrivalPattern::trace`]) against a
//! [`BatchEngine`] on a **virtual clock**: arrivals are mapped to scheduler
//! cycles (`cycles_per_s` cycles per virtual second), so the submission
//! schedule — which requests overlap, which get shed — is a pure function
//! of `(pattern, n, seed, cycles_per_s)` and replays identically across
//! machines regardless of their actual decode speed. Only the *measured
//! latencies* (what the traffic-model fit consumes) come from the real
//! clock.
//!
//! Prompts are synthesized from the same seed with varying lengths, so
//! softmax runs see varying KV-lane footprints — the spread the serve fit
//! needs to identify a bandwidth slope, not just an intercept.

use anyhow::{bail, Result};

use crate::data::rng::SplitMix64;
use crate::simulator::{ArrivalPattern, ServeFit};

use super::super::sampler::SampleMode;
use super::super::session::GenRequest;
use super::stats::EngineStats;
use super::BatchEngine;

/// One load run's shape. Defaults give the CI smoke: a burst of 8
/// overlapping short requests.
#[derive(Debug, Clone, Copy)]
pub struct LoadGenConfig {
    pub n_requests: usize,
    pub pattern: ArrivalPattern,
    /// Seeds both the arrival trace and the synthetic prompts.
    pub seed: u64,
    /// Prompt lengths are drawn uniformly from `[1, prompt_len]` chars.
    pub prompt_len: usize,
    pub max_new: usize,
    /// Virtual scheduler cycles per virtual second — the knob mapping
    /// trace timestamps onto cycles.
    pub cycles_per_s: f64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            n_requests: 8,
            pattern: ArrivalPattern::Burst { burst: 8, gap_s: 1.0 },
            seed: 0,
            prompt_len: 24,
            max_new: 16,
            cycles_per_s: 100.0,
        }
    }
}

/// What a load run produced: request counters, engine statistics, and the
/// traffic-model calibration fitted to the run's per-step samples.
#[derive(Debug, Clone)]
pub struct LoadGenReport {
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub errors: usize,
    /// Scheduler cycles executed (virtual-clock ticks).
    pub cycles: usize,
    pub stats: EngineStats,
    /// `None` when the run produced under two usable step samples.
    pub fit: Option<ServeFit>,
}

impl LoadGenReport {
    /// One-paragraph run summary (the loadgen CLI prints this).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "loadgen: {} submitted, {} completed, {} rejected, {} error(s) over {} cycles",
            self.submitted, self.completed, self.rejected, self.errors, self.cycles,
        );
        s.push('\n');
        s.push_str(&self.stats.summary());
        if let Some(fit) = &self.fit {
            s.push_str(&format!(
                "\nfit: overhead {:.3} ms, bandwidth {:.3} GB/s, rms residual {:.3} ms \
                 ({} samples)",
                fit.launch_overhead_s * 1e3,
                fit.bytes_per_s / 1e9,
                fit.rms_residual_s * 1e3,
                fit.n_samples,
            ));
        }
        s
    }
}

/// Synthesize request `i`'s prompt: seeded lowercase text with a length in
/// `[1, max_len]` so state footprints vary across requests.
fn synth_prompt(rng: &mut SplitMix64, max_len: usize) -> String {
    let max_len = max_len.max(1);
    let len = 1 + (rng.next_u64() as usize) % max_len;
    (0..len).map(|_| (b'a' + (rng.next_u64() % 26) as u8) as char).collect()
}

/// Drive `engine` through one load run. Every submitted request is
/// answered (completed, shed, or failed) before this returns; shed and
/// failed requests are counted, not errors of the run itself.
// no_panic
pub fn run(engine: &mut BatchEngine<'_>, conf: &LoadGenConfig) -> Result<LoadGenReport> {
    if conf.n_requests == 0 {
        bail!("loadgen wants at least one request");
    }
    if !(conf.cycles_per_s.is_finite() && conf.cycles_per_s > 0.0) {
        bail!("loadgen cycles_per_s must be a positive finite rate, got {}", conf.cycles_per_s);
    }
    let trace = conf.pattern.trace(conf.n_requests, conf.seed);
    let mut prompts = SplitMix64::new(conf.seed ^ 0x9e37_79b9_7f4a_7c15);
    let mut next = 0usize;
    let mut cycle = 0usize;
    let mut answered = 0usize;
    while next < trace.len() || !engine.is_idle() {
        let vt = cycle as f64 / conf.cycles_per_s;
        while next < trace.len() && trace.get(next).is_some_and(|&t| t <= vt) {
            let gen = GenRequest {
                prompt: synth_prompt(&mut prompts, conf.prompt_len),
                max_new: conf.max_new,
                mode: SampleMode::Greedy,
                seed: conf.seed.wrapping_add(next as u64),
                samples: 1,
                serial_prefill: false,
            };
            engine.submit(next as u64, gen);
            next += 1;
        }
        let progressed = engine.step()?;
        answered += engine.take_finished().len();
        if !progressed {
            if let Some(&t) = trace.get(next) {
                // idle with the next arrival in the future: jump the
                // virtual clock instead of spinning empty cycles
                let jump = (t * conf.cycles_per_s).ceil() as usize;
                cycle = jump.max(cycle + 1);
                continue;
            }
        }
        cycle += 1;
    }
    answered += engine.take_finished().len();
    let stats = engine.stats().clone();
    if answered != conf.n_requests {
        bail!(
            "loadgen answered {answered} of {} requests — the drain loop leaked responses",
            conf.n_requests
        );
    }
    let fit = ServeFit::from_samples(stats.step_samples());
    Ok(LoadGenReport {
        submitted: stats.submitted,
        completed: stats.completed,
        rejected: stats.rejected,
        errors: stats.errors,
        cycles: cycle,
        stats,
        fit,
    })
}
