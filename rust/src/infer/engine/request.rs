//! Request/response types of the continuous-batching engine.
//!
//! The engine is transport-agnostic: the serve loop maps JSONL lines to
//! [`EngineRequest`]s and [`EngineResponse`]s back to JSONL; the load
//! generator fabricates requests directly. `serial` is the engine-assigned
//! admission ticket — responses carry it so callers can re-order completions
//! (slots finish in decode order, not arrival order) back into arrival
//! order when their protocol needs it.

use anyhow::Error;

use crate::infer::session::GenRequest;

/// One queued generation: the session-level request plus the caller's
/// correlation handle.
#[derive(Debug, Clone)]
pub struct EngineRequest {
    /// Caller-chosen correlation id (the serve loop stores its JSON `id`
    /// out-of-band and uses the submission serial instead).
    pub serial: u64,
    pub gen: GenRequest,
}

/// What a completed request produced, with the latency split the serve
/// responses report. Mirrors
/// [`GenOutcome`](crate::infer::session::GenOutcome) plus the queueing and
/// batching figures that only exist under concurrency.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    /// Decoded text per sample (prompt not included).
    pub texts: Vec<String>,
    /// Generated token ids per sample.
    pub token_ids: Vec<Vec<i32>>,
    pub prompt_tokens: usize,
    /// New tokens generated per sample (after context-window clamping).
    pub new_tokens: usize,
    /// Submission → admission (time spent waiting for a free slot).
    pub queue_s: f64,
    /// Wall-clock of consuming the prompt through the staging state
    /// (budget-sliced across scheduler cycles; this sums the slices).
    pub prefill_s: f64,
    /// Submission → first sampled token (queueing + prefill + first step).
    pub ttft_s: f64,
    /// First decode step → last token (shared batch steps included).
    pub decode_s: f64,
    /// Generated tokens per second across this request's samples, decode
    /// phase only.
    pub decode_tok_s: f64,
    /// Mean number of occupied slots over this request's decode steps —
    /// how much batching the request actually experienced.
    pub occupancy_mean: f64,
    /// Attention-state footprint of this request's slots at completion.
    pub state_bytes: usize,
}

/// Terminal answer for one submission: completed, rejected by backpressure,
/// or failed validation/decoding.
#[derive(Debug)]
pub struct EngineResponse {
    /// Echo of [`EngineRequest::serial`].
    pub serial: u64,
    /// True when the request was shed by the bounded admission queue
    /// (`queue_full`) — the explicit load-shedding signal, distinct from a
    /// request that was simply invalid.
    pub rejected: bool,
    pub result: Result<EngineOutput, Error>,
}

impl EngineResponse {
    pub(crate) fn done(serial: u64, out: EngineOutput) -> Self {
        Self { serial, rejected: false, result: Ok(out) }
    }

    pub(crate) fn failed(serial: u64, err: Error) -> Self {
        Self { serial, rejected: false, result: Err(err) }
    }

    pub(crate) fn shed(serial: u64, err: Error) -> Self {
        Self { serial, rejected: true, result: Err(err) }
    }
}
