//! Seedable token sampling over one logits row: greedy argmax, temperature
//! softmax, and top-k — hardened against non-finite logits.
//!
//! A diverged model can emit NaN/∞ logits mid-generation; following the
//! task scorer's `total_cmp` pattern, a non-finite logit never panics and
//! never wins: greedy ignores non-finite entries, and the softmax modes give
//! them zero probability mass. Sampling draws come from the same
//! [`SplitMix64`] stream the data pipeline uses, so a fixed seed yields an
//! identical token sequence on any thread count.

use anyhow::{bail, Result};

use crate::data::rng::SplitMix64;

/// How the next token is chosen from a logits row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SampleMode {
    /// Deterministic argmax (ties break toward the lowest token id).
    Greedy,
    /// Temperature-scaled softmax over the `k` highest logits; `k = 0` or
    /// `k ≥ vocab` degrades to the full softmax (no truncation).
    TopK { k: usize, temperature: f32 },
}

impl SampleMode {
    /// Parse the CLI/serve surface: `greedy`, or `sample` with knobs.
    pub fn from_flags(mode: &str, temperature: f32, top_k: usize) -> Result<Self> {
        match mode {
            "greedy" => Ok(SampleMode::Greedy),
            "sample" => Ok(SampleMode::TopK { k: top_k, temperature }),
            other => bail!("unknown sampling mode {other:?} (expected greedy|sample)"),
        }
    }
}

/// A seeded sampler: mode + private RNG stream.
#[derive(Debug, Clone)]
pub struct Sampler {
    mode: SampleMode,
    rng: SplitMix64,
}

impl Sampler {
    pub fn new(mode: SampleMode, seed: u64) -> Result<Self> {
        if let SampleMode::TopK { temperature, .. } = mode {
            if !temperature.is_finite() || temperature <= 0.0 {
                bail!("sampling temperature must be finite and > 0, got {temperature}");
            }
        }
        Ok(Self { mode, rng: SplitMix64::new(seed) })
    }

    pub fn mode(&self) -> SampleMode {
        self.mode
    }

    /// Choose the next token id from one logits row. Errors (never panics)
    /// when every logit is non-finite — a diverged model, surfaced clearly.
    // no_panic
    pub fn sample(&mut self, logits: &[f32]) -> Result<usize> {
        match self.mode {
            SampleMode::Greedy => greedy(logits),
            SampleMode::TopK { k, temperature } => self.top_k(logits, k, temperature),
        }
    }

    // no_panic
    fn top_k(&mut self, logits: &[f32], k: usize, temperature: f32) -> Result<usize> {
        let mut finite: Vec<(usize, f32)> = logits
            .iter()
            .enumerate()
            .filter(|(_, x)| x.is_finite())
            .map(|(i, &x)| (i, x))
            .collect();
        if finite.is_empty() {
            bail!("cannot sample: all {} logits are non-finite", logits.len());
        }
        if k > 0 && k < finite.len() {
            // highest logit first; ties break toward the lowest token id so
            // truncation is deterministic
            finite.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            finite.truncate(k);
        }
        let m = finite.iter().map(|&(_, x)| x).fold(f32::NEG_INFINITY, f32::max);
        let mut cdf = Vec::with_capacity(finite.len());
        let mut acc = 0.0f64;
        for &(_, x) in &finite {
            acc += (((x - m) / temperature) as f64).exp();
            cdf.push(acc);
        }
        let pick = self.rng.sample_cdf(&cdf)?;
        // in_bounds: sample_cdf returns an index < cdf.len() == finite.len()
        Ok(finite[pick].0)
    }
}

/// Argmax with `total_cmp` over the finite entries only.
// no_panic
fn greedy(logits: &[f32]) -> Result<usize> {
    logits
        .iter()
        .enumerate()
        .filter(|(_, x)| x.is_finite())
        .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .ok_or_else(|| {
            anyhow::anyhow!("cannot sample: all {} logits are non-finite", logits.len())
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax_and_ignores_non_finite() {
        let mut s = Sampler::new(SampleMode::Greedy, 0).unwrap();
        assert_eq!(s.sample(&[0.1, 2.0, -1.0]).unwrap(), 1);
        // NaN/∞ never win, even when "larger"
        assert_eq!(s.sample(&[0.1, f32::INFINITY, f32::NAN, 0.3]).unwrap(), 3);
        assert_eq!(s.sample(&[f32::NAN, 5.0, f32::NAN]).unwrap(), 1);
    }

    #[test]
    fn greedy_ties_break_to_lowest_id() {
        let mut s = Sampler::new(SampleMode::Greedy, 0).unwrap();
        assert_eq!(s.sample(&[1.0, 3.0, 3.0, 0.0]).unwrap(), 1);
    }

    #[test]
    fn all_non_finite_is_an_error_not_a_panic() {
        for mode in [SampleMode::Greedy, SampleMode::TopK { k: 2, temperature: 1.0 }] {
            let mut s = Sampler::new(mode, 0).unwrap();
            assert!(s.sample(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]).is_err());
        }
    }

    #[test]
    fn non_finite_logits_get_zero_mass_when_sampling() {
        let mut s = Sampler::new(SampleMode::TopK { k: 0, temperature: 1.0 }, 7).unwrap();
        for _ in 0..200 {
            let pick = s.sample(&[f32::NAN, 1.0, f32::INFINITY, 1.0]).unwrap();
            assert!(pick == 1 || pick == 3, "non-finite logit won: {pick}");
        }
    }

    #[test]
    fn top_k_at_or_above_vocab_matches_full_softmax() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 7) % 5) as f32 * 0.3).collect();
        let mut full = Sampler::new(SampleMode::TopK { k: 0, temperature: 0.8 }, 42).unwrap();
        let mut at = Sampler::new(SampleMode::TopK { k: 16, temperature: 0.8 }, 42).unwrap();
        let mut above = Sampler::new(SampleMode::TopK { k: 99, temperature: 0.8 }, 42).unwrap();
        for _ in 0..100 {
            let want = full.sample(&logits).unwrap();
            assert_eq!(at.sample(&logits).unwrap(), want);
            assert_eq!(above.sample(&logits).unwrap(), want);
        }
    }

    #[test]
    fn top_k_truncates_to_the_k_best() {
        let logits = [0.0, 10.0, 9.0, -5.0, 8.0];
        let mut s = Sampler::new(SampleMode::TopK { k: 3, temperature: 1.0 }, 3).unwrap();
        for _ in 0..200 {
            let pick = s.sample(&logits).unwrap();
            assert!([1, 2, 4].contains(&pick), "picked outside top-3: {pick}");
        }
    }

    #[test]
    fn fixed_seed_is_reproducible() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let mode = SampleMode::TopK { k: 8, temperature: 1.2 };
        let a: Vec<usize> = {
            let mut s = Sampler::new(mode, 9).unwrap();
            (0..50).map(|_| s.sample(&logits).unwrap()).collect()
        };
        let b: Vec<usize> = {
            let mut s = Sampler::new(mode, 9).unwrap();
            (0..50).map(|_| s.sample(&logits).unwrap()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<usize> = {
            let mut s = Sampler::new(mode, 10).unwrap();
            (0..50).map(|_| s.sample(&logits).unwrap()).collect()
        };
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn rejects_bad_temperature() {
        for t in [0.0, -1.0, f32::NAN, f32::INFINITY] {
            assert!(Sampler::new(SampleMode::TopK { k: 0, temperature: t }, 0).is_err());
        }
        assert!(SampleMode::from_flags("beam", 1.0, 0).is_err());
        assert_eq!(SampleMode::from_flags("greedy", 1.0, 0).unwrap(), SampleMode::Greedy);
    }
}
