//! Inference subsystem: O(1)-state recurrent decoding, batched generation,
//! and a warm `serve` mode.
//!
//! Training demonstrates the paper's *parallel-form* claim (chunkwise linear
//! attention trains as fast as softmax); this module demonstrates the
//! *recurrent-form* claim — "Transformers are RNNs" (Katharopoulos et al.):
//! at decode time the `ours`/`gated` mixers carry a **constant-size state**
//! per layer and head (the running `S = Σ γ^{t-s} k_s vᵀ_s` matrix plus the
//! normalizer channel, O(hd²) floats), updated in O(hd²) per token without
//! ever re-scanning the prefix, while the `softmax` baseline must keep a KV
//! cache that grows linearly with the generated length. Both families decode
//! through the same incremental API ([`DecodeState`] +
//! [`model::logits_step`](crate::native::model::logits_step)), so their
//! state footprints and per-token costs are directly comparable — the
//! CPU-measurable analog of the paper's inference memory claim.
//!
//! - [`state`] — the per-layer, per-head [`DecodeState`] (recurrent matrix
//!   for the linear variants, growing KV cache for softmax) with a
//!   `state_bytes()` footprint probe;
//! - [`sampler`] — seedable greedy / temperature / top-k sampling with the
//!   non-finite-hardening the task scorer uses (`total_cmp`, NaN never wins);
//! - [`session`] — [`ModelSession`]: checkpoint → ready-to-decode model
//!   (tokenizer rebuilt deterministically from the checkpoint seed), batched
//!   [`generate`](ModelSession::generate);
//! - [`engine`] — the continuous-batching [`BatchEngine`]: slot-based
//!   scheduling of many concurrent requests over **one** shared batched
//!   decode state, with dynamic join/leave, budgeted prefill/decode
//!   interleaving, bounded-queue load shedding, and the deterministic
//!   load generator behind `repro loadgen`;
//! - [`serve`] — the long-lived JSONL request/response loop behind
//!   `repro serve`, now a thin transport over the engine, keeping model +
//!   tokenizer + thread pool warm across requests.

#![forbid(unsafe_code)]

pub mod engine;
pub mod sampler;
pub mod serve;
pub mod session;
pub mod state;

pub use engine::loadgen::{LoadGenConfig, LoadGenReport};
pub use engine::{BatchEngine, EngineConfig, EngineOutput, EngineRequest, EngineResponse, EngineStats};
pub use sampler::{SampleMode, Sampler};
pub use serve::{serve_loop, ServeStats};
pub use session::{quantize_checkpoint, GenOutcome, GenRequest, ModelSession, QuantizeOutcome};
pub use state::{AttnState, DecodeState};
