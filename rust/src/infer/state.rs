//! Decode-time attention state: the recurrent matrix of the linear variants
//! vs the growing KV cache of the softmax baseline, behind one type.
//!
//! One [`DecodeState`] tracks `n_seq` concurrent sequences through every
//! layer of one model. Per layer the state is an [`AttnState`]:
//!
//! - **`Linear`** (`ours` / `gated`): for each `(seq, head)` pair, the
//!   running `hd × (hd+1)` matrix `S_t = γ·S_{t-1} + φ(k_t)·[v_t, 1]ᵀ` — the
//!   value columns plus the ones-channel normalizer row the training-time
//!   scan uses. The footprint is **constant in the decoded length**:
//!   O(n_seq · H · hd²) elements, full stop.
//! - **`Softmax`**: the per-token key/value cache, appended each step —
//!   O(n_seq · H · hd · t) elements after `t` tokens, the linearly-growing
//!   baseline the paper's memory comparison is made against.
//!
//! Both live in a [`QuantBuf`] at `cfg.precision`, so the decode state can
//! be stored in bf16 (2 B/elem) or int8 (1 B/elem + one f32 scale per row)
//! while the scan itself always accumulates in f32;
//! [`state_bytes`](DecodeState::state_bytes) reports the true quantized
//! footprint.
//!
//! The buffers are written by
//! [`model::logits_step`](crate::native::model::logits_step) (the
//! incremental one-token forward); this module owns layout, construction,
//! and the [`state_bytes`](DecodeState::state_bytes) probe the decode bench
//! reports.

use anyhow::{bail, Result};

use crate::native::model::{attn_gamma, AttnKind, LmConfig, Precision};
use crate::native::quant::QuantBuf;

/// Attention state of one layer (all `(seq, head)` pairs folded).
#[derive(Debug, Clone)]
pub enum AttnState {
    /// Running linear-attention state: `n_seq · n_head` blocks of
    /// `hd × (hd+1)` (value columns ++ normalizer column), decayed by
    /// `gamma` each step (1.0 = undecayed `ours`). Int8 storage quantizes
    /// per state row (`hd + 1` elements each).
    Linear { s: QuantBuf, gamma: f32 },
    /// Growing KV cache: each step appends one `n_seq · n_head · hd` block
    /// to both `k` and `v` (token-major: block `t` holds every `(seq,
    /// head)` row of token `t`). Int8 storage quantizes per cached head row
    /// (`hd` elements each).
    Softmax { k: QuantBuf, v: QuantBuf },
}

impl AttnState {
    fn new(
        kind: AttnKind,
        prec: Precision,
        n_seq: usize,
        n_head: usize,
        hd: usize,
        n_ctx: usize,
    ) -> Self {
        match kind {
            // Reserve the full-window KV cache up front: the per-token
            // `append_rows` in `block_step` then never reallocates, so
            // softmax decode is allocation-free per step too (the cache
            // *length* still grows linearly — `state_bytes` reports length,
            // not capacity, and the memory comparison stands).
            AttnKind::Softmax => AttnState::Softmax {
                k: QuantBuf::reserved(prec, n_seq * n_head * hd * n_ctx, hd),
                v: QuantBuf::reserved(prec, n_seq * n_head * hd * n_ctx, hd),
            },
            kind => AttnState::Linear {
                s: QuantBuf::zeros(prec, n_seq * n_head * hd * (hd + 1), hd + 1),
                gamma: attn_gamma(kind),
            },
        }
    }

    /// Bytes currently held by this layer's attention state (true stored
    /// footprint: quantized data plus any per-row scale vectors).
    fn bytes(&self) -> usize {
        match self {
            AttnState::Linear { s, .. } => s.bytes(),
            AttnState::Softmax { k, v } => k.bytes() + v.bytes(),
        }
    }

    fn reset(&mut self) {
        match self {
            AttnState::Linear { s, .. } => s.fill_zero(),
            AttnState::Softmax { k, v } => {
                k.clear();
                v.clear();
            }
        }
    }
}

/// Incremental decoding state for `n_seq` concurrent sequences: one
/// [`AttnState`] per layer plus the shared position cursor. All sequences in
/// the batch advance in lockstep (one token each per
/// [`logits_step`](crate::native::model::logits_step) call).
#[derive(Debug, Clone)]
pub struct DecodeState {
    layers: Vec<AttnState>,
    n_seq: usize,
    n_head: usize,
    head_dim: usize,
    n_ctx: usize,
    attn: AttnKind,
    precision: Precision,
    pos: usize,
}

impl DecodeState {
    /// Fresh state (position 0) for `n_seq` concurrent sequences of `cfg`'s
    /// architecture, stored at `cfg.precision`.
    pub fn new(cfg: &LmConfig, n_seq: usize) -> Result<Self> {
        cfg.validate()?;
        if n_seq == 0 {
            bail!("DecodeState needs at least one sequence");
        }
        let hd = cfg.head_dim();
        let layers = (0..cfg.n_layer)
            .map(|_| AttnState::new(cfg.attn, cfg.precision, n_seq, cfg.n_head, hd, cfg.n_ctx))
            .collect();
        Ok(Self {
            layers,
            n_seq,
            n_head: cfg.n_head,
            head_dim: hd,
            n_ctx: cfg.n_ctx,
            attn: cfg.attn,
            precision: cfg.precision,
            pos: 0,
        })
    }

    /// Guard every incremental-forward call goes through: the state must
    /// have been built for exactly this architecture (and storage
    /// precision — a bf16 state fed to an f32-bound model would silently
    /// decode garbage otherwise).
    pub fn check(&self, cfg: &LmConfig) -> Result<()> {
        if self.layers.len() != cfg.n_layer
            || self.n_head != cfg.n_head
            || self.head_dim != cfg.head_dim()
            || self.n_ctx != cfg.n_ctx
            || self.attn != cfg.attn
            || self.precision != cfg.precision
        {
            bail!(
                "DecodeState was built for a different architecture \
                 ({} layers × {} heads, hd {}, n_ctx {}, {:?}, {}) than the model \
                 ({} layers × {} heads, hd {}, n_ctx {}, {:?}, {})",
                self.layers.len(),
                self.n_head,
                self.head_dim,
                self.n_ctx,
                self.attn,
                self.precision,
                cfg.n_layer,
                cfg.n_head,
                cfg.head_dim(),
                cfg.n_ctx,
                cfg.attn,
                cfg.precision,
            );
        }
        Ok(())
    }

    /// Number of concurrent sequences this state tracks.
    pub fn n_seq(&self) -> usize {
        self.n_seq
    }

    /// Tokens consumed so far (the position the *next* token will occupy).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Positions still available before the context window is exhausted.
    pub fn remaining(&self) -> usize {
        self.n_ctx.saturating_sub(self.pos)
    }

    /// Storage precision the attention states were built with.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Mutable access to one layer's attention state (the incremental
    /// forward's write path).
    pub(crate) fn layer_mut(&mut self, layer: usize) -> &mut AttnState {
        &mut self.layers[layer]
    }

    /// Advance the position cursor after one successful token step.
    pub(crate) fn advance(&mut self) {
        self.pos += 1;
    }

    /// Advance the position cursor by a whole prompt window — the chunked
    /// prefill's single jump after consuming `n` tokens in one pass.
    pub(crate) fn advance_by(&mut self, n: usize) {
        self.pos += n;
    }

    /// Total bytes held by the attention states across all layers — the
    /// decode-memory figure the bench compares across AttnKinds and
    /// precisions: constant for the linear variants, growing linearly in
    /// `pos` for softmax, and shrunk by bf16/int8 storage.
    pub fn state_bytes(&self) -> usize {
        self.layers.iter().map(AttnState::bytes).sum()
    }

    /// Rewind to position 0, dropping all accumulated context (buffers are
    /// kept allocated for reuse).
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
        self.pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_state_is_preallocated_and_constant_size() {
        for attn in [AttnKind::Ours, AttnKind::Gated] {
            let cfg = LmConfig::tiny(attn);
            let st = DecodeState::new(&cfg, 3).unwrap();
            let hd = cfg.head_dim();
            let expect = cfg.n_layer * 3 * cfg.n_head * hd * (hd + 1) * 4;
            assert_eq!(st.state_bytes(), expect);
            assert_eq!(st.pos(), 0);
            assert_eq!(st.remaining(), cfg.n_ctx);
        }
    }

    #[test]
    fn quantized_linear_state_shrinks_the_footprint() {
        let mut cfg = LmConfig::tiny(AttnKind::Ours);
        let f32_bytes = DecodeState::new(&cfg, 2).unwrap().state_bytes();

        cfg.precision = Precision::Bf16;
        let bf16_bytes = DecodeState::new(&cfg, 2).unwrap().state_bytes();
        assert_eq!(bf16_bytes * 2, f32_bytes);

        cfg.precision = Precision::Int8;
        let int8_bytes = DecodeState::new(&cfg, 2).unwrap().state_bytes();
        // 1 byte per element + one f32 scale per (hd+1)-element row
        let hd = cfg.head_dim();
        let elems = cfg.n_layer * 2 * cfg.n_head * hd * (hd + 1);
        assert_eq!(int8_bytes, elems + (elems / (hd + 1)) * 4);
        assert!(int8_bytes * 2 < f32_bytes);
    }

    #[test]
    fn softmax_state_starts_empty() {
        let cfg = LmConfig::tiny(AttnKind::Softmax);
        let st = DecodeState::new(&cfg, 2).unwrap();
        assert_eq!(st.state_bytes(), 0);
    }

    #[test]
    fn check_rejects_architecture_mismatch() {
        let tiny = LmConfig::tiny(AttnKind::Ours);
        let small = LmConfig::small(AttnKind::Ours);
        let gated = LmConfig::tiny(AttnKind::Gated);
        let st = DecodeState::new(&tiny, 1).unwrap();
        assert!(st.check(&tiny).is_ok());
        assert!(st.check(&small).is_err());
        assert!(st.check(&gated).is_err());
    }

    #[test]
    fn check_rejects_precision_mismatch() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let mut q = cfg;
        q.precision = Precision::Int8;
        let st = DecodeState::new(&cfg, 1).unwrap();
        assert!(st.check(&cfg).is_ok());
        assert!(st.check(&q).is_err());
        let stq = DecodeState::new(&q, 1).unwrap();
        assert_eq!(stq.precision(), Precision::Int8);
        assert!(stq.check(&q).is_ok());
        assert!(stq.check(&cfg).is_err());
    }

    #[test]
    fn rejects_zero_sequences() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        assert!(DecodeState::new(&cfg, 0).is_err());
    }

    #[test]
    fn reset_rewinds_and_clears() {
        let cfg = LmConfig::tiny(AttnKind::Softmax);
        let mut st = DecodeState::new(&cfg, 1).unwrap();
        if let AttnState::Softmax { k, v } = st.layer_mut(0) {
            k.append_rows(&[1.0; 8]);
            v.append_rows(&[2.0; 8]);
        }
        st.advance();
        assert!(st.state_bytes() > 0);
        st.reset();
        assert_eq!(st.pos(), 0);
        assert_eq!(st.state_bytes(), 0);
    }
}
