//! Decode-time attention state: the recurrent matrix of the linear variants
//! vs the growing KV cache of the softmax baseline, behind one type.
//!
//! One [`DecodeState`] tracks `n_seq` concurrent sequences through every
//! layer of one model. Per layer the state is an [`AttnState`]:
//!
//! - **`Linear`** (`ours` / `gated`): for each `(seq, head)` pair, the
//!   running `hd × (hd+1)` matrix `S_t = γ·S_{t-1} + φ(k_t)·[v_t, 1]ᵀ` — the
//!   value columns plus the ones-channel normalizer row the training-time
//!   scan uses. The footprint is **constant in the decoded length**:
//!   O(n_seq · H · hd²) elements, full stop.
//! - **`Softmax`**: the per-token key/value cache in fixed per-sequence
//!   lanes — O(n_seq · H · hd · t) cached elements after `t` tokens, the
//!   linearly-growing baseline the paper's memory comparison is made
//!   against. Lanes let the continuous-batching engine evict and re-admit
//!   one sequence without moving its batch-mates' rows.
//!
//! Both live in a [`QuantBuf`] at `cfg.precision`, so the decode state can
//! be stored in bf16 (2 B/elem) or int8 (1 B/elem + one f32 scale per row)
//! while the scan itself always accumulates in f32;
//! [`state_bytes`](DecodeState::state_bytes) reports the true quantized
//! footprint.
//!
//! The buffers are written by
//! [`model::logits_step`](crate::native::model::logits_step) (the
//! incremental one-token forward); this module owns layout, construction,
//! and the [`state_bytes`](DecodeState::state_bytes) probe the decode bench
//! reports.

use anyhow::{bail, Result};

use crate::native::model::{attn_gamma, AttnKind, LmConfig, Precision};
use crate::native::quant::QuantBuf;

/// Attention state of one layer (all `(seq, head)` pairs folded).
#[derive(Debug, Clone)]
pub enum AttnState {
    /// Running linear-attention state: `n_seq · n_head` blocks of
    /// `hd × (hd+1)` (value columns ++ normalizer column), decayed by
    /// `gamma` each step (1.0 = undecayed `ours`). Int8 storage quantizes
    /// per state row (`hd + 1` elements each).
    Linear { s: QuantBuf, gamma: f32 },
    /// KV cache in per-sequence **lanes**: each sequence owns a fixed
    /// `n_ctx`-token span so slots can join, leave, and rewind
    /// independently. Row `(s·n_ctx + t)·n_head + h` holds token `t` of
    /// sequence `s` for head `h`; the cached length of lane `s` is
    /// [`DecodeState`]'s `seq_pos[s]` (rows past it are dead, never read).
    /// The buffer is allocated to the full window up front, so per-token
    /// lane writes never reallocate. Int8 storage quantizes per cached
    /// head row (`hd` elements each).
    Softmax { k: QuantBuf, v: QuantBuf },
}

impl AttnState {
    fn new(
        kind: AttnKind,
        prec: Precision,
        n_seq: usize,
        n_head: usize,
        hd: usize,
        n_ctx: usize,
    ) -> Self {
        match kind {
            // Allocate the full-window lanes up front: the per-token
            // `store_rows` in `block_step` then never reallocates, so
            // softmax decode is allocation-free per step too (the cached
            // *length* still grows linearly — `state_bytes` reports cached
            // rows, not capacity, and the memory comparison stands).
            AttnKind::Softmax => AttnState::Softmax {
                k: QuantBuf::zeros(prec, n_seq * n_ctx * n_head * hd, hd),
                v: QuantBuf::zeros(prec, n_seq * n_ctx * n_head * hd, hd),
            },
            kind => AttnState::Linear {
                s: QuantBuf::zeros(prec, n_seq * n_head * hd * (hd + 1), hd + 1),
                gamma: attn_gamma(kind),
            },
        }
    }

    fn reset(&mut self) {
        match self {
            AttnState::Linear { s, .. } => s.fill_zero(),
            // lane contents past each sequence's cursor are never read —
            // zeroing is hygiene, not correctness
            AttnState::Softmax { k, v } => {
                k.fill_zero();
                v.fill_zero();
            }
        }
    }
}

/// Stored bytes of one cached KV head row at `prec` (data + int8 scale).
fn kv_row_bytes(prec: Precision, hd: usize) -> usize {
    match prec {
        Precision::F32 => hd * 4,
        Precision::Bf16 => hd * 2,
        Precision::Int8 => hd + 4,
    }
}

/// Incremental decoding state for `n_seq` concurrent sequences: one
/// [`AttnState`] per layer plus a per-sequence position cursor. Sequences
/// may advance in lockstep (one token each per
/// [`logits_step`](crate::native::model::logits_step) call) or — the
/// continuous-batching serve engine's mode — independently, with an active
/// mask selecting which rows a step touches and
/// [`clear_seq`](Self::clear_seq)/[`adopt_seq`](Self::adopt_seq) recycling
/// one slot without disturbing its batch-mates.
#[derive(Debug, Clone)]
pub struct DecodeState {
    layers: Vec<AttnState>,
    n_seq: usize,
    n_head: usize,
    head_dim: usize,
    n_ctx: usize,
    attn: AttnKind,
    precision: Precision,
    seq_pos: Vec<usize>,
}

impl DecodeState {
    /// Fresh state (position 0) for `n_seq` concurrent sequences of `cfg`'s
    /// architecture, stored at `cfg.precision`.
    pub fn new(cfg: &LmConfig, n_seq: usize) -> Result<Self> {
        cfg.validate()?;
        if n_seq == 0 {
            bail!("DecodeState needs at least one sequence");
        }
        let hd = cfg.head_dim();
        let layers = (0..cfg.n_layer)
            .map(|_| AttnState::new(cfg.attn, cfg.precision, n_seq, cfg.n_head, hd, cfg.n_ctx))
            .collect();
        Ok(Self {
            layers,
            n_seq,
            n_head: cfg.n_head,
            head_dim: hd,
            n_ctx: cfg.n_ctx,
            attn: cfg.attn,
            precision: cfg.precision,
            seq_pos: vec![0; n_seq],
        })
    }

    /// Guard every incremental-forward call goes through: the state must
    /// have been built for exactly this architecture (and storage
    /// precision — a bf16 state fed to an f32-bound model would silently
    /// decode garbage otherwise).
    pub fn check(&self, cfg: &LmConfig) -> Result<()> {
        if self.layers.len() != cfg.n_layer
            || self.n_head != cfg.n_head
            || self.head_dim != cfg.head_dim()
            || self.n_ctx != cfg.n_ctx
            || self.attn != cfg.attn
            || self.precision != cfg.precision
        {
            bail!(
                "DecodeState was built for a different architecture \
                 ({} layers × {} heads, hd {}, n_ctx {}, {:?}, {}) than the model \
                 ({} layers × {} heads, hd {}, n_ctx {}, {:?}, {})",
                self.layers.len(),
                self.n_head,
                self.head_dim,
                self.n_ctx,
                self.attn,
                self.precision,
                cfg.n_layer,
                cfg.n_head,
                cfg.head_dim(),
                cfg.n_ctx,
                cfg.attn,
                cfg.precision,
            );
        }
        Ok(())
    }

    /// Number of concurrent sequences this state tracks.
    pub fn n_seq(&self) -> usize {
        self.n_seq
    }

    /// Tokens consumed so far by the furthest-ahead sequence (the position
    /// its *next* token will occupy). Equal to every sequence's cursor under
    /// the lockstep API; the masked engine path reads
    /// [`seq_positions`](Self::seq_positions) instead.
    pub fn pos(&self) -> usize {
        self.seq_pos.iter().copied().max().unwrap_or(0)
    }

    /// Per-sequence position cursors (tokens consumed by each sequence).
    pub fn seq_positions(&self) -> &[usize] {
        &self.seq_pos
    }

    /// Positions still available before the context window is exhausted
    /// for the furthest-ahead sequence.
    pub fn remaining(&self) -> usize {
        self.n_ctx.saturating_sub(self.pos())
    }

    /// Storage precision the attention states were built with.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Mutable access to one layer's attention state (the incremental
    /// forward's write path).
    pub(crate) fn layer_mut(&mut self, layer: usize) -> &mut AttnState {
        &mut self.layers[layer]
    }

    /// Advance every position cursor after one successful lockstep token.
    pub(crate) fn advance(&mut self) {
        for p in &mut self.seq_pos {
            *p += 1;
        }
    }

    /// Advance every position cursor by a whole prompt window — the chunked
    /// prefill's single jump after consuming `n` tokens in one pass.
    pub(crate) fn advance_by(&mut self, n: usize) {
        for p in &mut self.seq_pos {
            *p += n;
        }
    }

    /// Advance only the cursors of active sequences — the masked decode
    /// step's bookkeeping (`active.len() == n_seq`, checked by the caller).
    pub(crate) fn advance_masked(&mut self, active: &[bool]) {
        for (p, &a) in self.seq_pos.iter_mut().zip(active) {
            if a {
                *p += 1;
            }
        }
    }

    /// Total bytes held by the attention states across all layers — the
    /// decode-memory figure the bench compares across AttnKinds and
    /// precisions: constant for the linear variants, growing linearly in
    /// the cached positions for softmax, and shrunk by bf16/int8 storage.
    /// Softmax lanes are accounted by *cached rows* (each sequence's
    /// cursor), not allocated capacity — the same figure the append-based
    /// cache reported, so the memory comparison is unchanged.
    pub fn state_bytes(&self) -> usize {
        let cached: usize = self.seq_pos.iter().sum();
        let kv_bytes = 2 * cached * self.n_head * kv_row_bytes(self.precision, self.head_dim);
        self.layers
            .iter()
            .map(|l| match l {
                AttnState::Linear { s, .. } => s.bytes(),
                AttnState::Softmax { .. } => kv_bytes,
            })
            .sum()
    }

    /// Attention-state bytes attributable to **one** sequence lane — what
    /// the batch engine reports per request and feeds (summed over occupied
    /// slots) into the per-step traffic estimate. Linear lanes carry an
    /// equal share of the constant recurrent state; a softmax lane is its
    /// own cached K/V rows, so the figure grows with that sequence's
    /// cursor. Out-of-range indices report 0.
    pub fn seq_state_bytes(&self, i: usize) -> usize {
        let Some(&pos) = self.seq_pos.get(i) else {
            return 0;
        };
        let kv_bytes = 2 * pos * self.n_head * kv_row_bytes(self.precision, self.head_dim);
        self.layers
            .iter()
            .map(|l| match l {
                AttnState::Linear { s, .. } => s.bytes() / self.n_seq,
                AttnState::Softmax { .. } => kv_bytes,
            })
            .sum()
    }

    /// Rewind to position 0, dropping all accumulated context (buffers are
    /// kept allocated for reuse).
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
        self.seq_pos.fill(0);
    }

    /// Rewind **one** sequence to position 0 without reallocating or
    /// touching its batch-mates: the slot-eviction reset of the
    /// continuous-batching engine. Zeroes the sequence's recurrent `S`
    /// blocks (they accumulate additively, so stale contributions must go)
    /// and truncates its KV-cache lane by cursor alone (rows past the
    /// cursor are never read). Allocation-free — `tests/alloc_gate.rs`
    /// pins a warm admit→decode→evict→admit cycle at zero events.
    pub fn clear_seq(&mut self, i: usize) -> Result<()> {
        if i >= self.n_seq {
            bail!("clear_seq: sequence {i} out of range [0, {})", self.n_seq);
        }
        let (nh, hd) = (self.n_head, self.head_dim);
        for l in &mut self.layers {
            if let AttnState::Linear { s, .. } = l {
                s.zero_rows(i * nh * hd, nh * hd, hd + 1);
            }
        }
        // in_bounds: i < n_seq == seq_pos.len() is checked above
        self.seq_pos[i] = 0;
        Ok(())
    }

    /// Adopt a fully-prefilled single-sequence staging state into slot
    /// `slot`: a raw precision-exact copy of every layer's per-sequence
    /// span (recurrent `S` block, or the first `seq_pos` cached KV lane
    /// rows), so decoding from the slot is bit-identical to decoding from
    /// the staging state. The admission half of slot recycling;
    /// allocation-free on success.
    pub fn adopt_seq(&mut self, slot: usize, src: &DecodeState) -> Result<()> {
        if slot >= self.n_seq {
            bail!("adopt_seq: slot {slot} out of range [0, {})", self.n_seq);
        }
        if src.n_seq != 1 {
            bail!("adopt_seq: staging state must hold exactly 1 sequence, has {}", src.n_seq);
        }
        if src.layers.len() != self.layers.len()
            || src.n_head != self.n_head
            || src.head_dim != self.head_dim
            || src.n_ctx != self.n_ctx
            || src.attn != self.attn
            || src.precision != self.precision
        {
            bail!(
                "adopt_seq: staging state architecture ({} layers × {} heads, hd {}, n_ctx {}, \
                 {:?}, {}) does not match the batch state ({} layers × {} heads, hd {}, n_ctx \
                 {}, {:?}, {})",
                src.layers.len(),
                src.n_head,
                src.head_dim,
                src.n_ctx,
                src.attn,
                src.precision,
                self.layers.len(),
                self.n_head,
                self.head_dim,
                self.n_ctx,
                self.attn,
                self.precision,
            );
        }
        let (nh, hd, n_ctx) = (self.n_head, self.head_dim, self.n_ctx);
        // in_bounds: src.n_seq == 1 is checked above
        let src_pos = src.seq_pos[0];
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            match (dst, s) {
                (AttnState::Linear { s: d, .. }, AttnState::Linear { s: sr, .. }) => {
                    d.copy_rows_from(slot * nh * hd, sr, 0, nh * hd, hd + 1)?;
                }
                (AttnState::Softmax { k, v }, AttnState::Softmax { k: sk, v: sv }) => {
                    k.copy_rows_from(slot * n_ctx * nh, sk, 0, src_pos * nh, hd)?;
                    v.copy_rows_from(slot * n_ctx * nh, sv, 0, src_pos * nh, hd)?;
                }
                // the architecture check above makes mixed kinds unreachable
                _ => bail!("adopt_seq: mismatched per-layer attention kinds"),
            }
        }
        // in_bounds: slot < n_seq == seq_pos.len() is checked above
        self.seq_pos[slot] = src_pos;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_state_is_preallocated_and_constant_size() {
        for attn in [AttnKind::Ours, AttnKind::Gated] {
            let cfg = LmConfig::tiny(attn);
            let st = DecodeState::new(&cfg, 3).unwrap();
            let hd = cfg.head_dim();
            let expect = cfg.n_layer * 3 * cfg.n_head * hd * (hd + 1) * 4;
            assert_eq!(st.state_bytes(), expect);
            assert_eq!(st.pos(), 0);
            assert_eq!(st.remaining(), cfg.n_ctx);
        }
    }

    #[test]
    fn quantized_linear_state_shrinks_the_footprint() {
        let mut cfg = LmConfig::tiny(AttnKind::Ours);
        let f32_bytes = DecodeState::new(&cfg, 2).unwrap().state_bytes();

        cfg.precision = Precision::Bf16;
        let bf16_bytes = DecodeState::new(&cfg, 2).unwrap().state_bytes();
        assert_eq!(bf16_bytes * 2, f32_bytes);

        cfg.precision = Precision::Int8;
        let int8_bytes = DecodeState::new(&cfg, 2).unwrap().state_bytes();
        // 1 byte per element + one f32 scale per (hd+1)-element row
        let hd = cfg.head_dim();
        let elems = cfg.n_layer * 2 * cfg.n_head * hd * (hd + 1);
        assert_eq!(int8_bytes, elems + (elems / (hd + 1)) * 4);
        assert!(int8_bytes * 2 < f32_bytes);
    }

    #[test]
    fn softmax_state_starts_empty() {
        let cfg = LmConfig::tiny(AttnKind::Softmax);
        let st = DecodeState::new(&cfg, 2).unwrap();
        assert_eq!(st.state_bytes(), 0);
    }

    #[test]
    fn check_rejects_architecture_mismatch() {
        let tiny = LmConfig::tiny(AttnKind::Ours);
        let small = LmConfig::small(AttnKind::Ours);
        let gated = LmConfig::tiny(AttnKind::Gated);
        let st = DecodeState::new(&tiny, 1).unwrap();
        assert!(st.check(&tiny).is_ok());
        assert!(st.check(&small).is_err());
        assert!(st.check(&gated).is_err());
    }

    #[test]
    fn check_rejects_precision_mismatch() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let mut q = cfg;
        q.precision = Precision::Int8;
        let st = DecodeState::new(&cfg, 1).unwrap();
        assert!(st.check(&cfg).is_ok());
        assert!(st.check(&q).is_err());
        let stq = DecodeState::new(&q, 1).unwrap();
        assert_eq!(stq.precision(), Precision::Int8);
        assert!(stq.check(&q).is_ok());
        assert!(stq.check(&cfg).is_err());
    }

    #[test]
    fn rejects_zero_sequences() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        assert!(DecodeState::new(&cfg, 0).is_err());
    }

    #[test]
    fn reset_rewinds_and_clears() {
        let cfg = LmConfig::tiny(AttnKind::Softmax);
        let mut st = DecodeState::new(&cfg, 1).unwrap();
        st.advance();
        assert!(st.state_bytes() > 0);
        st.reset();
        assert_eq!(st.pos(), 0);
        assert_eq!(st.state_bytes(), 0);
    }

    /// Softmax accounting is per cached row at the storage precision — the
    /// exact figure the append-based cache reported before the lane layout.
    #[test]
    fn softmax_state_bytes_grow_per_sequence() {
        for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
            let mut cfg = LmConfig::tiny(AttnKind::Softmax);
            cfg.precision = prec;
            let hd = cfg.head_dim();
            let row_bytes = match prec {
                Precision::F32 => hd * 4,
                Precision::Bf16 => hd * 2,
                Precision::Int8 => hd + 4,
            };
            let mut st = DecodeState::new(&cfg, 2).unwrap();
            st.advance(); // both sequences cache one token
            assert_eq!(st.state_bytes(), cfg.n_layer * 2 * 2 * cfg.n_head * row_bytes);
            st.advance_masked(&[true, false]); // only sequence 0 advances
            assert_eq!(st.state_bytes(), cfg.n_layer * 2 * 3 * cfg.n_head * row_bytes);
            assert_eq!(st.seq_positions(), &[2, 1]);
            assert_eq!(st.pos(), 2);
            // per-lane accounting splits the same total by each cursor
            assert_eq!(st.seq_state_bytes(0), cfg.n_layer * 2 * 2 * cfg.n_head * row_bytes);
            assert_eq!(st.seq_state_bytes(1), cfg.n_layer * 2 * cfg.n_head * row_bytes);
            assert_eq!(st.seq_state_bytes(0) + st.seq_state_bytes(1), st.state_bytes());
            assert_eq!(st.seq_state_bytes(2), 0);
        }
    }

    /// Linear lanes hold an equal share of the constant recurrent state,
    /// independent of the cursor.
    #[test]
    fn linear_seq_state_bytes_are_an_equal_constant_share() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let mut st = DecodeState::new(&cfg, 2).unwrap();
        let share = st.seq_state_bytes(0);
        assert!(share > 0);
        assert_eq!(share * 2, st.state_bytes());
        st.advance_masked(&[true, false]);
        assert_eq!(st.seq_state_bytes(0), share);
        assert_eq!(st.seq_state_bytes(1), share);
        assert_eq!(st.seq_state_bytes(9), 0);
    }

    #[test]
    fn clear_seq_rewinds_one_slot_only() {
        for attn in [AttnKind::Ours, AttnKind::Softmax] {
            let cfg = LmConfig::tiny(attn);
            let mut st = DecodeState::new(&cfg, 3).unwrap();
            st.advance();
            st.advance();
            st.clear_seq(1).unwrap();
            assert_eq!(st.seq_positions(), &[2, 0, 2]);
            assert!(st.clear_seq(3).is_err());
        }
    }

    /// Adopting a staging sequence copies its exact stored rows into the
    /// slot's span and nothing else.
    #[test]
    fn adopt_seq_copies_the_staging_state_bit_for_bit() {
        for attn in [AttnKind::Ours, AttnKind::Softmax] {
            for prec in [Precision::F32, Precision::Int8] {
                let mut cfg = LmConfig::tiny(attn);
                cfg.precision = prec;
                let mut staging = DecodeState::new(&cfg, 1).unwrap();
                // fill the staging state's layer 0 with recognizable rows
                // (two tokens' worth for the KV lanes)
                let (nh, hd) = (cfg.n_head, cfg.head_dim());
                match staging.layer_mut(0) {
                    AttnState::Linear { s, .. } => {
                        let vals: Vec<f32> =
                            (0..nh * hd * (hd + 1)).map(|i| (i as f32 * 0.11).sin()).collect();
                        s.store_rows(0, hd + 1, &vals);
                    }
                    AttnState::Softmax { k, v } => {
                        let vals: Vec<f32> =
                            (0..2 * nh * hd).map(|i| (i as f32 * 0.07).cos()).collect();
                        k.store_rows(0, hd, &vals);
                        v.store_rows(0, hd, &vals);
                    }
                }
                staging.advance();
                staging.advance();

                let mut batch = DecodeState::new(&cfg, 3).unwrap();
                batch.adopt_seq(2, &staging).unwrap();
                assert_eq!(batch.seq_positions(), &[0, 0, 2]);

                // slot 2's layer-0 span decodes to exactly the staging rows
                let probe = |st: &mut DecodeState, seq: usize| -> Vec<f32> {
                    match st.layer_mut(0) {
                        AttnState::Linear { s, .. } => {
                            let mut all = vec![0.0f32; s.len()];
                            s.dequantize_into(&mut all);
                            all[seq * nh * hd * (hd + 1)..][..nh * hd * (hd + 1)].to_vec()
                        }
                        AttnState::Softmax { k, .. } => {
                            let mut all = vec![0.0f32; k.len()];
                            k.dequantize_into(&mut all);
                            all[seq * cfg.n_ctx * nh * hd..][..2 * nh * hd].to_vec()
                        }
                    }
                };
                let want = probe(&mut staging, 0);
                let got = probe(&mut batch, 2);
                assert_eq!(want, got, "{attn:?}/{prec}");

                // mismatched staging shapes are rejected
                let wide = DecodeState::new(&cfg, 2).unwrap();
                assert!(batch.adopt_seq(0, &wide).is_err());
            }
        }
    }
}
