//! Device specifications for the roofline/traffic model.

/// A GPU (or TPU-like) device for the analytic model.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Off-chip (HBM/GDDR) bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Peak fp32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// On-chip scratch (shared memory / VMEM) per compute unit, bytes.
    pub sram_bytes: f64,
    /// Fixed cost per kernel launch, seconds.
    pub launch_overhead: f64,
}

impl DeviceSpec {
    /// NVIDIA RTX A6000 — the paper's evaluation device.
    /// 768 GB/s GDDR6, 38.7 TFLOP/s fp32, 100 KB smem/SM usable.
    pub fn a6000() -> Self {
        Self {
            name: "A6000",
            mem_bw: 768e9,
            peak_flops: 38.7e12,
            sram_bytes: 100e3,
            launch_overhead: 5e-6,
        }
    }

    /// A TPUv4-like core for the §Hardware-Adaptation estimates:
    /// 1.2 TB/s HBM, 275 TFLOP/s bf16 (≈ 34 TFLOP/s fp32 VPU path is not the
    /// relevant number for matmul; we model MXU fp32-accumulate), 16 MiB VMEM.
    pub fn tpu_v4_like() -> Self {
        Self {
            name: "TPUv4-like",
            mem_bw: 1.2e12,
            peak_flops: 137.5e12,
            sram_bytes: 16.0 * 1024.0 * 1024.0,
            launch_overhead: 2e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a6000_numbers() {
        let d = DeviceSpec::a6000();
        assert_eq!(d.name, "A6000");
        assert!(d.mem_bw > 7e11 && d.mem_bw < 8e11);
        // machine balance: flops per byte — sanity window
        let balance = d.peak_flops / d.mem_bw;
        assert!(balance > 30.0 && balance < 80.0, "balance {balance}");
    }
}
