//! Analytic GPU data-movement / roofline simulator (DESIGN.md §Substitutions).
//!
//! The paper's Fig. 4 measures off-chip traffic of CUDA kernels on an A6000
//! with profiling counters.  Without that hardware we compute the traffic
//! *algorithmically*: every implementation's §4-style access pattern implies
//! an exact count of off-chip bytes moved per forward pass, and combining it
//! with the device's bandwidth and (derated) peak FLOP/s yields movement
//! time, compute time, and the movement-to-total ratio the paper plots.
//!
//! The model is conservative (no compute/copy overlap) and deliberately
//! simple; what it preserves is the *ordering and rough factors* between
//! implementations, which is the figure's claim.

#![forbid(unsafe_code)]

pub mod device;
pub mod traffic;
pub mod vmem;

pub use device::DeviceSpec;
pub use traffic::{ArrivalPattern, Impl, ServeFit, TrafficModel, TrafficReport};
pub use vmem::VmemModel;
