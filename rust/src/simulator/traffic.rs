//! Per-implementation off-chip traffic equations (forward pass, causal).
//!
//! Derivations follow each system's published access pattern; elements are
//! fp32 (4 bytes), all counts per full layer (B·H heads folded in).
//!
//! | impl      | pattern                                                         |
//! |-----------|------------------------------------------------------------------|
//! | Ours      | one fused kernel: Q,K,V read once, O + g written once (§4)        |
//! | Gated LA  | chunkwise, separate inter/intra/state phases; per-chunk D×D state |
//! |           | materialized to HBM for the backward (Yang et al. §4)             |
//! | Baseline  | eager tensor-wise ops: every intermediate (N×N scores, mask,      |
//! |           | row-sums) round-trips HBM (paper §5.1 "100×" discussion)          |
//! | Spec-dec  | quadratic materialization, fewer passes than eager baseline       |
//! | Flash     | K,V re-streamed once per Q block of rows Br = M/(16·D)            |
//! | Softmax   | naive: N² scores written + read twice (softmax, then AV)          |

use super::device::DeviceSpec;

const ELT: f64 = 4.0; // fp32 bytes

/// Attention implementation, as named in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Impl {
    Ours,
    Gated,
    Baseline,
    SpecDec,
    Flash,
    Softmax,
}

impl Impl {
    pub fn name(self) -> &'static str {
        match self {
            Impl::Ours => "ours",
            Impl::Gated => "gated",
            Impl::Baseline => "quadratic",
            Impl::SpecDec => "specdec",
            Impl::Flash => "flash",
            Impl::Softmax => "softmax",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "ours" | "ours_scan" => Impl::Ours,
            "gated" => Impl::Gated,
            "quadratic" | "baseline" => Impl::Baseline,
            "specdec" => Impl::SpecDec,
            "flash" => Impl::Flash,
            "softmax" => Impl::Softmax,
            _ => return None,
        })
    }

    /// All LA implementations (the Fig-4 set).
    pub fn la_impls() -> [Impl; 4] {
        [Impl::Ours, Impl::Gated, Impl::SpecDec, Impl::Baseline]
    }

    /// Achievable fraction of peak FLOP/s for this implementation's compute
    /// pattern (fused custom kernel vs eager element-wise chains).
    pub fn compute_efficiency(self) -> f64 {
        match self {
            Impl::Ours => 0.35,     // D×D MACs per thread-block, fused
            Impl::Gated => 0.30,    // chunked matmuls, extra phases
            Impl::Flash => 0.55,    // big tiled matmuls
            Impl::Softmax => 0.50,  // cuBLAS matmuls + softmax pass
            // eager chains run their two big matmuls through cuBLAS at high
            // efficiency — their *time* is dominated by the element-wise
            // HBM round-trips, which the movement term accounts for.
            Impl::Baseline => 0.70,
            Impl::SpecDec => 0.70,
        }
    }
}

/// Result of the traffic model for one (impl, shape) point.
#[derive(Debug, Clone, Copy)]
pub struct TrafficReport {
    pub impl_: Impl,
    pub bh: usize,
    pub n: usize,
    pub d: usize,
    /// Off-chip bytes moved (read + write).
    pub bytes: f64,
    /// FLOPs executed.
    pub flops: f64,
    /// Seconds spent moving data at device bandwidth.
    pub move_s: f64,
    /// Seconds of compute at derated peak.
    pub compute_s: f64,
    /// Modeled total (no overlap) incl. launch overheads.
    pub total_s: f64,
    /// Peak resident off-chip memory, bytes.
    pub mem_bytes: f64,
}

impl TrafficReport {
    /// The Fig-4 left panel: movement / total.
    pub fn move_ratio(&self) -> f64 {
        self.move_s / self.total_s
    }
}

/// The analytic model over a device.
#[derive(Debug, Clone, Copy)]
pub struct TrafficModel {
    pub dev: DeviceSpec,
    /// Sequence chunk used by chunkwise implementations.
    pub chunk: f64,
}

impl TrafficModel {
    pub fn new(dev: DeviceSpec) -> Self {
        Self { dev, chunk: 64.0 }
    }

    /// Off-chip element transfers for one forward pass.
    fn elements(&self, imp: Impl, bh: f64, n: f64, d: f64) -> f64 {
        let c = self.chunk;
        let io = 4.0 * n * d + n; // Q,K,V in + O out + g
        bh * match imp {
            // §4: fully fused; inputs once, outputs once.
            Impl::Ours => io,
            // GLA: 3 phases each re-touching the chunk inputs, per-chunk D×D
            // state spilled + reloaded, intra-chunk C×C scores via HBM in the
            // non-fused form.
            Impl::Gated => 3.0 * 3.0 * n * d + n * d + 2.0 * (n / c) * d * d + 2.0 * n * c,
            // eager PyTorch: scores(N²) write, mask materialize + rw, masked
            // mul rw, row-sum read, AV read, broadcast-div r+r+w, plus the
            // autograd graph saving score/mask copies → ≈12 N² round-trips.
            Impl::Baseline => 12.0 * n * n + 6.0 * n * d,
            // spec-dec: quadratic materialization, fewer passes (~8 N²).
            Impl::SpecDec => 8.0 * n * n + 6.0 * n * d,
            // FA-2: K,V streamed once per Q row-block; Br rows fit in SRAM.
            Impl::Flash => {
                let br = (self.dev.sram_bytes / (16.0 * d)).max(1.0);
                2.0 * n * d * (n / br) + 2.0 * n * d
            }
            // naive softmax: scores written, softmaxed (rw), then read for AV.
            Impl::Softmax => 4.0 * n * n + 3.0 * n * d,
        }
    }

    /// FLOPs for one forward pass.
    fn flops(&self, imp: Impl, bh: f64, n: f64, d: f64) -> f64 {
        bh * match imp {
            // intra-chunk (2NCD) + inter (2ND²) + state update (2ND²) + norm
            Impl::Ours | Impl::Gated => 4.0 * n * d * d + 2.0 * n * self.chunk * d,
            _ => 4.0 * n * n * d, // QKᵀ + AV
        }
    }

    /// Kernel launches for one forward pass (adds fixed overhead).
    fn launches(&self, imp: Impl, _n: f64) -> f64 {
        match imp {
            Impl::Ours => 2.0, // constant + linear phases
            Impl::Gated => 6.0, // inter/intra/state kernels (chunk loop inside)
            Impl::Baseline => 8.0,
            Impl::SpecDec => 6.0,
            Impl::Flash => 1.0,
            Impl::Softmax => 4.0,
        }
    }

    /// Peak resident off-chip bytes (the Fig-2/3 memory panels).
    pub fn memory_bytes(&self, imp: Impl, bh: usize, n: usize, d: usize) -> f64 {
        let (bh, n, d) = (bh as f64, n as f64, d as f64);
        let io = 4.0 * n * d + n;
        ELT * bh
            * match imp {
                Impl::Ours => io,                       // O(N·D)
                Impl::Flash => io,                      // O(N·D)
                Impl::Gated => io + 2.0 * (n / self.chunk) * d * d, // chunk states
                Impl::Softmax => io + n * n,            // O(N²)
                Impl::Baseline => io + 2.0 * n * n,     // scores + mask copies
                Impl::SpecDec => io + n * d * d / 64.0, // causal autodiff residuals O(N·D²)/heads nuance
            }
    }

    /// Full report for one point.
    pub fn report(&self, imp: Impl, bh: usize, n: usize, d: usize) -> TrafficReport {
        let (bhf, nf, df) = (bh as f64, n as f64, d as f64);
        let bytes = ELT * self.elements(imp, bhf, nf, df);
        let flops = self.flops(imp, bhf, nf, df);
        let move_s = bytes / self.dev.mem_bw;
        let compute_s = flops / (self.dev.peak_flops * imp.compute_efficiency());
        let total_s = move_s + compute_s + self.launches(imp, nf) * self.dev.launch_overhead;
        TrafficReport {
            impl_: imp,
            bh,
            n,
            d,
            bytes,
            flops,
            move_s,
            compute_s,
            total_s,
            mem_bytes: self.memory_bytes(imp, bh, n, d),
        }
    }
}

// --- serve-side calibration ---------------------------------------------------
//
// The analytic model above predicts; the serve engine measures. These pieces
// close the loop: seeded arrival traces drive the engine reproducibly
// (`repro loadgen`), and the per-step (bytes-moved estimate, seconds)
// samples the engine records are fitted back to the model's two serve-side
// constants — per-step fixed overhead (the launch-overhead analogue) and
// effective bytes/s — with the residual quantifying how well the linear
// traffic model explains measured decode latency.

/// Synthetic arrival process for the in-process load generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Memoryless arrivals at `rate_hz` (exponential inter-arrival gaps) —
    /// the steady-traffic case.
    Poisson { rate_hz: f64 },
    /// `burst` simultaneous arrivals every `gap_s` — the worst case for a
    /// bounded admission queue (exercises slot contention and shedding).
    Burst { burst: usize, gap_s: f64 },
}

impl ArrivalPattern {
    /// Deterministic arrival timestamps (seconds from start, nondecreasing):
    /// the same `(pattern, n, seed)` always yields the same trace, so load
    /// runs are replayable bit-for-bit.
    pub fn trace(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::data::rng::SplitMix64::new(seed);
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalPattern::Poisson { rate_hz } => {
                let rate = if rate_hz.is_finite() && rate_hz > 0.0 { rate_hz } else { 1.0 };
                let mut t = 0.0;
                for _ in 0..n {
                    // inverse-CDF exponential; next_f64 ∈ [0,1) keeps ln(1−u) finite
                    let u = rng.next_f64();
                    t += -(1.0 - u).ln() / rate;
                    out.push(t);
                }
            }
            ArrivalPattern::Burst { burst, gap_s } => {
                let burst = burst.max(1);
                let gap = if gap_s.is_finite() && gap_s > 0.0 { gap_s } else { 1.0 };
                for i in 0..n {
                    out.push((i / burst) as f64 * gap);
                }
            }
        }
        out
    }
}

/// Least-squares calibration of the serve-side latency model
/// `step_s ≈ overhead + bytes / bytes_per_s` against the engine's measured
/// per-step samples.
#[derive(Debug, Clone, Copy)]
pub struct ServeFit {
    /// Fixed per-step cost (scheduling + launch analogue), seconds.
    pub launch_overhead_s: f64,
    /// Effective streaming bandwidth implied by the slope; 0 when the
    /// samples cannot identify a slope (constant bytes — e.g. pure
    /// linear-attention state at fixed occupancy — or a non-positive one).
    pub bytes_per_s: f64,
    /// RMS residual of the fit, seconds — how much measured latency the
    /// linear traffic model fails to explain.
    pub rms_residual_s: f64,
    pub n_samples: usize,
}

impl ServeFit {
    /// Fit `(bytes, seconds)` samples; `None` below two samples (a line
    /// needs two points — with exactly constant x the slope falls back to 0
    /// and the intercept to the mean).
    pub fn from_samples(samples: &[(f64, f64)]) -> Option<Self> {
        let pts: Vec<(f64, f64)> =
            samples.iter().copied().filter(|(x, y)| x.is_finite() && y.is_finite()).collect();
        let n = pts.len();
        if n < 2 {
            return None;
        }
        let nf = n as f64;
        let mx = pts.iter().map(|p| p.0).sum::<f64>() / nf;
        let my = pts.iter().map(|p| p.1).sum::<f64>() / nf;
        let sxx = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum::<f64>();
        let sxy = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum::<f64>();
        // degenerate x (all steps moved the same bytes) cannot identify a
        // slope — fall back to the pure-overhead model instead of dividing
        // by ~0 and reporting a garbage bandwidth
        let slope = if sxx > 1e-9 * mx.abs().max(1.0) { sxy / sxx } else { 0.0 };
        let slope = if slope.is_finite() && slope > 0.0 { slope } else { 0.0 };
        let intercept = my - slope * mx;
        let ss_res =
            pts.iter().map(|p| { let r = p.1 - (intercept + slope * p.0); r * r }).sum::<f64>();
        Some(Self {
            launch_overhead_s: intercept,
            bytes_per_s: if slope > 0.0 { 1.0 / slope } else { 0.0 },
            rms_residual_s: (ss_res / nf).sqrt(),
            n_samples: n,
        })
    }

    /// Predicted step latency under the fitted constants.
    pub fn predict(&self, bytes: f64) -> f64 {
        let move_s = if self.bytes_per_s > 0.0 { bytes / self.bytes_per_s } else { 0.0 };
        self.launch_overhead_s + move_s
    }

    /// Write the fitted constants back into a [`DeviceSpec`] (only the
    /// identifiable ones), yielding a [`TrafficModel`] calibrated against
    /// this machine's measured serving behaviour.
    pub fn apply(&self, mut dev: DeviceSpec) -> DeviceSpec {
        if self.launch_overhead_s.is_finite() && self.launch_overhead_s > 0.0 {
            dev.launch_overhead = self.launch_overhead_s;
        }
        if self.bytes_per_s.is_finite() && self.bytes_per_s > 0.0 {
            dev.mem_bw = self.bytes_per_s;
        }
        dev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TrafficModel {
        TrafficModel::new(DeviceSpec::a6000())
    }

    /// The paper's Table-1 point: B=4, H=16, D=128, N=10⁴.
    const BH: usize = 64;
    const N: usize = 10_000;
    const D: usize = 128;

    #[test]
    fn ours_moves_least() {
        let m = model();
        let ours = m.report(Impl::Ours, BH, N, D);
        for imp in [Impl::Gated, Impl::Baseline, Impl::SpecDec] {
            let r = m.report(imp, BH, N, D);
            assert!(r.bytes > 2.0 * ours.bytes, "{imp:?} bytes {} vs ours {}", r.bytes, ours.bytes);
        }
    }

    #[test]
    fn ours_ratio_is_lowest_and_baseline_traffic_is_100x() {
        let m = model();
        let ours = m.report(Impl::Ours, BH, N, D);
        let gated = m.report(Impl::Gated, BH, N, D);
        let base = m.report(Impl::Baseline, BH, N, D);
        assert!(ours.move_ratio() < gated.move_ratio());
        assert!(gated.move_ratio() < base.move_ratio());
        // paper: baseline data movement ~100× ours
        let factor = base.move_s / ours.move_s;
        assert!(factor > 30.0, "factor {factor}");
        // paper: gated ratio ≈ 71%, ours ≈ one-third of that — loose bands
        assert!(gated.move_ratio() > 0.5, "gated ratio {}", gated.move_ratio());
        assert!(ours.move_ratio() < 0.5, "ours ratio {}", ours.move_ratio());
    }

    #[test]
    fn linear_vs_quadratic_scaling() {
        let m = model();
        let t1 = m.report(Impl::Ours, BH, 4096, D).total_s;
        let t2 = m.report(Impl::Ours, BH, 8192, D).total_s;
        let ratio = t2 / t1;
        assert!(ratio > 1.7 && ratio < 2.3, "linear impl ratio {ratio}");
        let q1 = m.report(Impl::Softmax, BH, 4096, D).total_s;
        let q2 = m.report(Impl::Softmax, BH, 8192, D).total_s;
        let qratio = q2 / q1;
        assert!(qratio > 3.3, "quadratic impl ratio {qratio}");
    }

    #[test]
    fn crossover_with_flash_is_in_the_thousands() {
        // paper §5.1: ours faster than FlashAttention-2 for N > ~3000
        let m = model();
        let mut crossover = None;
        for n in (512..32768).step_by(256) {
            let ours = m.report(Impl::Ours, BH, n, D).total_s;
            let flash = m.report(Impl::Flash, BH, n, D).total_s;
            if ours < flash {
                crossover = Some(n);
                break;
            }
        }
        // the model places the crossover earlier than the paper's measured
        // ~3000 (FA-2's tensor-core constants are better than a generic
        // efficiency factor captures); the *shape* claim is that a finite
        // crossover exists and ours wins beyond it.
        let n = crossover.expect("no crossover found");
        assert!(n <= 8192, "crossover at {n}");
        let big_ours = m.report(Impl::Ours, BH, 32768, D).total_s;
        let big_flash = m.report(Impl::Flash, BH, 32768, D).total_s;
        assert!(big_flash / big_ours > 3.0, "long-N win factor {}", big_flash / big_ours);
    }

    #[test]
    fn memory_ours_matches_flash_and_beats_gated() {
        // paper: ours & FA-2 lowest memory (overlapping lines), gated 3.6×
        let m = model();
        let ours = m.memory_bytes(Impl::Ours, BH, N, D);
        let flash = m.memory_bytes(Impl::Flash, BH, N, D);
        let gated = m.memory_bytes(Impl::Gated, BH, N, D);
        assert!((ours - flash).abs() / ours < 1e-9);
        assert!(gated > 1.5 * ours, "gated {gated} vs ours {ours}");
    }

    #[test]
    fn poisson_trace_is_seeded_and_monotone() {
        let p = ArrivalPattern::Poisson { rate_hz: 50.0 };
        let a = p.trace(100, 7);
        let b = p.trace(100, 7);
        assert_eq!(a, b, "same seed must replay the same trace");
        assert_ne!(a, p.trace(100, 8), "different seed, different trace");
        assert_eq!(a.len(), 100);
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "arrivals must be nondecreasing");
        assert!(a.iter().all(|t| t.is_finite() && *t >= 0.0));
        // mean inter-arrival ≈ 1/rate within a loose band
        let mean_gap = a.last().unwrap() / 100.0;
        assert!(mean_gap > 0.005 && mean_gap < 0.08, "mean gap {mean_gap}");
    }

    #[test]
    fn burst_trace_groups_arrivals() {
        let p = ArrivalPattern::Burst { burst: 4, gap_s: 0.5 };
        let t = p.trace(10, 0);
        assert_eq!(t[0..4], [0.0; 4]);
        assert_eq!(t[4..8], [0.5; 4]);
        assert_eq!(t[8..10], [1.0; 2]);
    }

    #[test]
    fn serve_fit_recovers_a_known_line() {
        // t = 2ms + bytes / 1e9
        let samples: Vec<(f64, f64)> =
            (1..=20).map(|i| { let b = i as f64 * 1e6; (b, 2e-3 + b / 1e9) }).collect();
        let fit = ServeFit::from_samples(&samples).unwrap();
        assert!((fit.launch_overhead_s - 2e-3).abs() < 1e-9, "{}", fit.launch_overhead_s);
        assert!((fit.bytes_per_s - 1e9).abs() / 1e9 < 1e-6, "{}", fit.bytes_per_s);
        assert!(fit.rms_residual_s < 1e-9);
        assert_eq!(fit.n_samples, 20);
        assert!((fit.predict(5e6) - (2e-3 + 5e-3)).abs() < 1e-9);
        // calibration writes the identifiable constants back into the device
        let dev = fit.apply(DeviceSpec::a6000());
        assert!((dev.mem_bw - 1e9).abs() / 1e9 < 1e-6);
        assert!((dev.launch_overhead - 2e-3).abs() < 1e-9);
    }

    #[test]
    fn serve_fit_degenerate_x_falls_back_to_overhead_only() {
        // constant bytes (fixed-occupancy linear attention): slope is not
        // identifiable — the fit must not report a garbage bandwidth
        let samples = vec![(1e6, 3e-3), (1e6, 5e-3), (1e6, 4e-3)];
        let fit = ServeFit::from_samples(&samples).unwrap();
        assert_eq!(fit.bytes_per_s, 0.0);
        assert!((fit.launch_overhead_s - 4e-3).abs() < 1e-12);
        assert!(fit.rms_residual_s > 0.0);
        // unidentifiable constants leave the device spec untouched
        let dev = fit.apply(DeviceSpec::a6000());
        assert_eq!(dev.mem_bw, DeviceSpec::a6000().mem_bw);
        assert!(ServeFit::from_samples(&[(1.0, 1.0)]).is_none());
        assert!(ServeFit::from_samples(&[]).is_none());
    }

    #[test]
    fn impl_name_roundtrip() {
        for imp in [Impl::Ours, Impl::Gated, Impl::Baseline, Impl::SpecDec, Impl::Flash, Impl::Softmax] {
            assert_eq!(Impl::from_name(imp.name()), Some(imp));
        }
        assert_eq!(Impl::from_name("nope"), None);
    }
}
