//! VMEM-footprint model for the Pallas kernel (DESIGN.md §Hardware-Adaptation).
//!
//! On a real TPU the forward kernel keeps, per grid step:
//!   - the carried state: S (D×D) + z (D) + t (D) + n (1)
//!   - the pipelined chunk blocks: q, k, v in + o out, each (C×D), with
//!     double-buffering (×2) on the inputs so the next chunk's HBM→VMEM DMA
//!     overlaps compute,
//!   - the (C×C) intra-chunk score tile.
//! The backward adds the Ω̂ block and the (D×D) reverse states A plus c, u.
//!
//! Everything is fp32 here (the kernels accumulate in f32; a bf16 variant
//! would halve the streaming blocks but not the f32 state accumulators).

const ELT: usize = 4;

/// Footprint model for one (C, D) kernel configuration.
#[derive(Debug, Clone, Copy)]
pub struct VmemModel {
    pub chunk: usize,
    pub d: usize,
}

impl VmemModel {
    pub fn new(chunk: usize, d: usize) -> Self {
        Self { chunk, d }
    }

    /// Forward-kernel VMEM bytes.
    pub fn forward_bytes(&self) -> usize {
        let (c, d) = (self.chunk, self.d);
        let state = d * d + 2 * d + 1;
        let blocks = 2 * (3 * c * d) + c * d + c; // in ×2 (dbl-buf), out o + g
        let scores = c * c;
        ELT * (state + blocks + scores)
    }

    /// Backward-kernel VMEM bytes (the dKV reverse scan is the larger one).
    pub fn backward_bytes(&self) -> usize {
        let (c, d) = (self.chunk, self.d);
        let state = d * d + 2 * d; // A + c + u
        let blocks = 2 * (5 * c * d) + 2 * c * d; // q,k,v,o,Ω̂ in ×2; dk,dv out
        let scores = 2 * c * c;
        ELT * (state + blocks + scores)
    }

    /// Fraction of a VMEM budget consumed by the forward kernel.
    pub fn forward_occupancy(&self, vmem_budget: usize) -> f64 {
        self.forward_bytes() as f64 / vmem_budget as f64
    }

    /// MXU utilization estimate: fraction of issued MACs that are "useful"
    /// relative to an ideal dense schedule.  The causal-masked intra-chunk
    /// (C×C) matmul wastes half its tile; inter-chunk (C×D)×(D×D) work is
    /// dense.  Utilization = useful / issued.
    pub fn mxu_utilization(&self) -> f64 {
        let (c, d) = (self.chunk as f64, self.d as f64);
        // issued MACs per chunk: intra c*c*d (half masked) + inter c*d*d + update c*d*d
        let issued = c * c * d + 2.0 * c * d * d;
        let useful = 0.5 * c * c * d + 2.0 * c * d * d;
        useful / issued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const VMEM: usize = 16 * 1024 * 1024;

    #[test]
    fn paper_shape_fits_vmem_easily() {
        // D=128, C=128 — the bench default
        let m = VmemModel::new(128, 128);
        assert!(m.forward_bytes() < 1024 * 1024, "{} B", m.forward_bytes());
        assert!(m.forward_occupancy(VMEM) < 0.10);
        assert!(m.backward_bytes() < 2 * 1024 * 1024);
    }

    #[test]
    fn largest_d_still_fits() {
        // D=512 is the paper's stated upper bound (§4.1)
        let m = VmemModel::new(128, 512);
        assert!(m.forward_occupancy(VMEM) < 0.25, "{}", m.forward_occupancy(VMEM));
    }

    #[test]
    fn utilization_improves_with_d_over_c() {
        // more inter-chunk (dense) work per masked intra tile → better MXU use
        let low = VmemModel::new(128, 32).mxu_utilization();
        let high = VmemModel::new(128, 256).mxu_utilization();
        assert!(high > low);
        assert!(high > 0.85, "high-D utilization {high}");
    }
}
