//! `repro` — launcher for the linear-attention reproduction.
//!
//! Subcommands map 1:1 to the paper's evaluation artifacts (DESIGN.md
//! per-experiment index):
//!   - `train`         Fig 5 learning curves (one run per attention impl)
//!   - `bench-layer`   Figs 2-3 / Table 1 standalone-layer sweeps
//!   - `bench-native`  parallel-vs-scalar kernel speedups → BENCH_native.json
//!   - `bench-traffic` Fig 4 data-movement analysis (analytic A6000 model)
//!   - `eval-tasks`    Table 2 synthetic reasoning suite
//!   - `generate`      autoregressive decoding from a checkpoint (recurrent
//!                     O(1)-state for ours/gated, KV cache for softmax)
//!   - `quantize`      convert an f32 training checkpoint to a bf16/int8
//!                     decode-only checkpoint (layout v3)
//!   - `serve`         warm JSONL request/response loop over stdin/stdout
//!   - `report`        summarize finished training runs
//!   - `inspect`       list available artifacts

#![forbid(unsafe_code)]

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use repro::bench::{report as rpt, SweepRunner};
use repro::coordinator::config::{DataSection, OutputSection, TrainSection};
use repro::coordinator::{Checkpoint, MetricsLog, RunConfig, Trainer};
use repro::runtime::Engine;
use repro::simulator::{DeviceSpec, TrafficModel, VmemModel};
use repro::tasks::{score_task, TaskKind};
use repro::util::cli::Args;

const USAGE: &str = "\
repro — linear-attention reproduction launcher

USAGE: repro <subcommand> [flags]

SUBCOMMANDS
  train          --preset tiny|small|medium --attn ours --steps 200 --out runs
                 [--config run.toml] [--seed 0] [--eval-every 25]
                 [--corpus-bytes 0]  (0 = auto, scaled to the preset)
  bench-layer    --kind layer_fwd|layer_fwdbwd [--impls a,b,c] [--reps 5]
                 [--warmup 2] [--csv out.csv]
  bench-native   [--kinds layer_fwd,layer_fwdbwd] [--impls ours,ours_scan]
                 [--reps 5] [--warmup 2] [--max-n 0] [--out BENCH_native.json]
                 [--lm-presets tiny,small] [--lm-attns ours,softmax]
                 [--lm-steps 6] [--opt-reps 20] [--decode-tokens 64]
                 [--decode-precisions f32,bf16,int8]
                 [--prefill-lens 512,4096] [--prefill-presets tiny]
                 [--prefill-attns ours,gated,softmax]
                 [--prefill-precisions f32] [--prefill-reps 3]
                 [--prefill-chunk 0]  (0 = RUST_PALLAS_CHUNK)
                 [--serve-requests 8] [--serve-slots 4]
                 [--serve-presets tiny] [--serve-attns ours,softmax]
                 [--serve-precisions f32]
                 measures the parallel/tiled kernels (RUST_PALLAS_THREADS)
                 against the scalar single-thread reference, per-step LM
                 training cost/loss for each (preset, attn) pair through
                 both the in-place and the preserved rebuild optimizer
                 routes, the AdamW-update microbench (in-place vs rebuild),
                 the decode section (recurrent vs full-recompute tokens/s,
                 state/param bytes, and quantized-vs-f32 quality drift per
                 precision; 0 disables), the prefill section (chunked vs
                 serial prompt ingestion with TTFT per prompt length; empty
                 --prefill-lens disables), the serve section (continuous-
                 batching engine under a deterministic burst load with a
                 traffic-model fit; --serve-requests 0 disables), and writes
                 the machine-readable speedup artifact
  bench-traffic  [--csv out.csv]
  eval-tasks     --ckpt runs/lm_tiny_ours/final.ckpt [--count 64] [--seed 0]
  generate       --ckpt runs/lm_tiny_ours/final.ckpt [--prompt \"the \"]
                 [--max-new 64] [--mode greedy|sample] [--temperature 1.0]
                 [--top-k 0] [--seed 0] [--samples 1] [--serial-prefill]
                 decodes through the constant-size recurrent state
                 (ours/gated) or the growing KV cache (softmax); the prompt
                 is ingested through the chunked prefill fast path unless
                 --serial-prefill forces the token-by-token oracle; stats
                 (incl. ttft) on stderr, text on stdout; accepts f32 and
                 quantized checkpoints alike
  prefill-check  [--preset tiny] [--attn ours] [--prompt-len 2048]
                 [--precision f32] [--chunk 0] [--max-new 16] [--seed 0]
                 [--max-logit-diff 0.5]
                 parity gate for the two prefill routes on seeded weights
                 (no checkpoint needed; n_ctx is widened to the prompt):
                 ingests one deterministic prompt chunked AND serially,
                 greedily continues both, prints one JSON line with timings
                 and exits nonzero if the routes diverge
  quantize       --ckpt runs/lm_tiny_ours/final.ckpt --out q.ckpt
                 [--precision int8|bf16] [--check-tokens 32]
                 [--max-logit-diff 0.5]
                 converts an f32 training checkpoint into a decode-only
                 layout-v3 checkpoint (GEMM-dominant weights quantized,
                 optimizer moments dropped), probes per-token logit drift
                 against the f32 source, and fails if it exceeds the bound
  serve          --ckpt runs/lm_tiny_ours/final.ckpt [--max-new 64]
                 [--slots 4] [--queue 32] [--prefill-budget 64]
                 long-lived JSONL loop over the continuous-batching engine:
                 one request object per stdin line ({\"prompt\": ...,
                 \"max_new\": ..., \"mode\": ...}), one response per stdout
                 line (emitted in submission order); concurrent requests
                 share the decode batch, overflow past --queue is shed with
                 an explicit rejection; EOF drains in-flight work cleanly
  loadgen        --ckpt runs/lm_tiny_ours/final.ckpt [--requests 8]
                 [--pattern burst|poisson] [--rate 50] [--burst 8]
                 [--gap-s 1.0] [--seed 0] [--prompt-len 24] [--max-new 16]
                 [--slots 4] [--queue 32] [--prefill-budget 64]
                 deterministic in-process load run: replays a seeded
                 arrival trace against the engine, prints occupancy and
                 latency percentiles, and fits the traffic model's
                 overhead/bandwidth constants to the measured steps
  report         [--runs runs]
  inspect        [--filter substr]
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("bench-layer") => cmd_bench_layer(&args),
        Some("bench-native") => cmd_bench_native(&args),
        Some("bench-traffic") => cmd_bench_traffic(&args),
        Some("eval-tasks") => cmd_eval_tasks(&args),
        Some("generate") => cmd_generate(&args),
        Some("prefill-check") => cmd_prefill_check(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("report") => cmd_report(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("run-artifact") => cmd_run_artifact(&args),
        Some(other) => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(p) => RunConfig::load(p)?,
        None => RunConfig {
            train: TrainSection {
                preset: args.get_or("preset", "tiny").to_string(),
                attn: args.get_or("attn", "ours").to_string(),
                steps: args.get_usize("steps", 200)?,
                eval_every: args.get_usize("eval-every", 25)?,
                ckpt_every: args.get_usize("ckpt-every", 0)?,
                seed: args.get_u64("seed", 0)?,
            },
            data: DataSection {
                corpus_bytes: args.get_usize("corpus-bytes", 0)?,
                ..DataSection::default()
            },
            output: OutputSection { dir: args.get_or("out", "runs").to_string() },
        },
    };
    let engine = Engine::discover()?;
    let trainer = Trainer::new(&engine, cfg.clone())?;
    eprintln!(
        "training {} | batch {} × ctx {} | {} steps",
        cfg.artifact_tag(),
        trainer.batch_size(),
        trainer.seq_len(),
        cfg.train.steps
    );
    let outcome = trainer.run()?;
    println!(
        "done: final loss {:.4} (val {:?}) in {:.1}s — {:.0} tok/s → {}",
        outcome.final_loss,
        outcome.final_val_loss,
        outcome.wall_s,
        outcome.tokens_per_s,
        outcome.run_dir.display()
    );
    Ok(())
}

fn cmd_bench_layer(args: &Args) -> Result<()> {
    let kind = args.get_or("kind", "layer_fwd").to_string();
    let engine = Engine::discover()?;
    let mut runner = SweepRunner::new(&engine);
    runner.reps = args.get_usize("reps", 5)?;
    runner.warmup = args.get_usize("warmup", runner.warmup)?;
    let impl_list: Vec<String> = match args.get("impls") {
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        None => ["ours", "ours_scan", "gated", "quadratic", "specdec", "flash", "softmax"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let mut points = Vec::new();
    for imp in &impl_list {
        eprintln!("sweeping {kind} / {imp} …");
        points.extend(runner.run_series(&kind, imp)?);
    }
    println!("{}", rpt::sweep_markdown(&format!("{kind} sweep"), &points));
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rpt::sweep_csv(&points))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// Measure every requested sweep artifact twice — once on the parallel/tiled
/// kernels (pool from `RUST_PALLAS_THREADS`), once on the scalar
/// single-thread reference — plus the LM per-step training cost of each
/// requested (preset, attn) pair, and write the joined report as
/// `BENCH_native.json`, so every perf PR leaves a trajectory artifact.
fn cmd_bench_native(args: &Args) -> Result<()> {
    use repro::native::pool::ThreadPool;
    use repro::native::NativeBackend;

    let out_path = args.get_or("out", "BENCH_native.json").to_string();
    let reps = args.get_usize("reps", 5)?;
    let warmup = args.get_usize("warmup", 2)?;
    let max_n = args.get_usize("max-n", 0)?; // 0 = uncapped
    let split_list = |s: &str| -> Vec<String> {
        s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
    };
    let kinds = split_list(args.get_or("kinds", "layer_fwd"));
    let impls = split_list(args.get_or("impls", "ours,ours_scan"));
    let lm_presets = split_list(args.get_or("lm-presets", "tiny,small"));
    let lm_attns = split_list(args.get_or("lm-attns", "ours,softmax"));
    let lm_steps = args.get_usize("lm-steps", 6)?;
    let opt_reps = args.get_usize("opt-reps", 20)?;
    let decode_tokens = args.get_usize("decode-tokens", 64)?;
    let decode_precisions = split_list(args.get_or("decode-precisions", "f32,bf16,int8"));
    let prefill_lens: Vec<usize> = split_list(args.get_or("prefill-lens", "512,4096"))
        .iter()
        .map(|s| s.parse().map_err(|_| anyhow!("--prefill-lens expects integers, got {s:?}")))
        .collect::<Result<Vec<usize>>>()?
        .into_iter()
        .filter(|&l| l > 0)
        .collect();
    let prefill_presets = split_list(args.get_or("prefill-presets", "tiny"));
    let prefill_attns = split_list(args.get_or("prefill-attns", "ours,gated,softmax"));
    let prefill_precisions = split_list(args.get_or("prefill-precisions", "f32"));
    let prefill_reps = args.get_usize("prefill-reps", 3)?;
    let prefill_chunk = args.get_usize("prefill-chunk", 0)?; // 0 = RUST_PALLAS_CHUNK
    let serve_requests = args.get_usize("serve-requests", 8)?; // 0 disables
    let serve_slots = args.get_usize("serve-slots", 4)?;
    let serve_presets = split_list(args.get_or("serve-presets", "tiny"));
    let serve_attns = split_list(args.get_or("serve-attns", "ours,softmax"));
    let serve_precisions = split_list(args.get_or("serve-precisions", "f32"));

    let threads = ThreadPool::env_threads();
    let par_engine = Engine::with_backend(Box::new(NativeBackend::new()))?;
    let ref_engine = Engine::with_backend(Box::new(NativeBackend::scalar_reference()))?;
    let mut par_runner = SweepRunner::new(&par_engine);
    let mut ref_runner = SweepRunner::new(&ref_engine);
    for r in [&mut par_runner, &mut ref_runner] {
        r.reps = reps;
        r.warmup = warmup;
        if max_n > 0 {
            r.max_n = max_n;
        }
    }

    let mut parallel = Vec::new();
    let mut scalar = Vec::new();
    for kind in &kinds {
        for imp in &impls {
            eprintln!("bench-native: {kind} / {imp} (threads={threads}) …");
            parallel.extend(par_runner.run_series(kind, imp)?);
            eprintln!("bench-native: {kind} / {imp} (scalar reference baseline) …");
            scalar.extend(ref_runner.run_series(kind, imp)?);
        }
    }

    let mut lm_points = Vec::new();
    if lm_steps > 0 {
        for preset in &lm_presets {
            // corpus + (for BPE presets) merge training depend only on the
            // preset — build once, share across the attention variants
            let ds = repro::bench::lm::build_preset_dataset(&par_engine, preset)?;
            for attn in &lm_attns {
                eprintln!("bench-native: lm {preset}/{attn} ({lm_steps} steps) …");
                lm_points.push(repro::bench::lm::measure_lm(
                    &par_engine,
                    preset,
                    attn,
                    lm_steps,
                    &ds,
                )?);
            }
        }
    }

    // AdamW-update microbench: the in-place-vs-rebuild optimizer speedup,
    // isolated from the forward/backward cost
    let mut opt_points = Vec::new();
    if opt_reps > 0 {
        for preset in &lm_presets {
            let attn = lm_attns.first().map(String::as_str).unwrap_or("ours");
            eprintln!("bench-native: adamw {preset} ({opt_reps} reps, in-place vs rebuild) …");
            opt_points.push(repro::bench::lm::measure_adamw(preset, attn, opt_reps, warmup)?);
        }
    }

    // decode section: recurrent vs full-recompute autoregressive decoding
    // (the inference-side memory/latency claim, per preset × attn ×
    // storage precision — quantized points carry their f32-oracle drift)
    let mut decode_points = Vec::new();
    if decode_tokens > 0 {
        for preset in &lm_presets {
            for attn in &lm_attns {
                for precision in &decode_precisions {
                    eprintln!(
                        "bench-native: decode {preset}/{attn}/{precision} \
                         ({decode_tokens} tokens) …"
                    );
                    decode_points.push(repro::bench::lm::measure_decode(
                        preset,
                        attn,
                        decode_tokens,
                        precision,
                    )?);
                }
            }
        }
    }

    // prefill section: chunked vs serial prompt ingestion with TTFT (the
    // long-prompt time-to-first-token claim, per preset × attn × precision ×
    // prompt length; an empty --prefill-lens disables)
    let mut prefill_points = Vec::new();
    if prefill_reps > 0 {
        for preset in &prefill_presets {
            for attn in &prefill_attns {
                for precision in &prefill_precisions {
                    for &len in &prefill_lens {
                        eprintln!(
                            "bench-native: prefill {preset}/{attn}/{precision} \
                             ({len}-token prompt, chunked vs serial) …"
                        );
                        prefill_points.push(repro::bench::lm::measure_prefill(
                            preset,
                            attn,
                            len,
                            precision,
                            prefill_chunk,
                            prefill_reps,
                        )?);
                    }
                }
            }
        }
    }

    // serve section: the continuous-batching engine under a deterministic
    // burst load run — occupancy, request percentiles, and the traffic-model
    // constants fitted to measured per-step latencies (0 requests disables)
    let mut serve_points = Vec::new();
    if serve_requests > 0 {
        for preset in &serve_presets {
            for attn in &serve_attns {
                for precision in &serve_precisions {
                    eprintln!(
                        "bench-native: serve {preset}/{attn}/{precision} \
                         ({serve_requests} requests, {serve_slots} slots) …"
                    );
                    serve_points.push(repro::bench::lm::measure_serve(
                        preset,
                        attn,
                        precision,
                        serve_requests,
                        serve_slots,
                    )?);
                }
            }
        }
    }

    println!("{}", rpt::bench_native_markdown(&parallel, &scalar));
    if !lm_points.is_empty() {
        println!("{}", rpt::bench_lm_markdown(&lm_points));
    }
    if !opt_points.is_empty() {
        println!("{}", rpt::bench_opt_markdown(&opt_points));
    }
    if !decode_points.is_empty() {
        println!("{}", rpt::bench_decode_markdown(&decode_points));
    }
    if !prefill_points.is_empty() {
        println!("{}", rpt::bench_prefill_markdown(&prefill_points));
    }
    if !serve_points.is_empty() {
        println!("{}", rpt::bench_serve_markdown(&serve_points));
    }
    let json = rpt::bench_native_json(
        &parallel,
        &scalar,
        &lm_points,
        &opt_points,
        &decode_points,
        &prefill_points,
        &serve_points,
        threads,
        repro::native::ours_chunk(),
    );
    std::fs::write(&out_path, &json)?;
    eprintln!("wrote {out_path}");
    Ok(())
}

fn cmd_bench_traffic(args: &Args) -> Result<()> {
    let model = TrafficModel::new(DeviceSpec::a6000());
    println!("## Table 1 (analytic A6000 model, B=4 H=16 D=128 N=10⁴)\n");
    println!("{}", rpt::table1_markdown(&model));
    let ns = [2048, 4096, 8192, 16384, 32768];
    println!("\n## Fig 4 (data movement, LA implementations)\n");
    println!("{}", rpt::fig4_markdown(&model, &ns));
    let vm = VmemModel::new(128, 128);
    println!(
        "\nPallas kernel VMEM: fwd {} / bwd {} (16 MiB budget → {:.1}% occupancy), \
         MXU utilization est. {:.0}%",
        rpt::fmt_bytes(vm.forward_bytes() as f64),
        rpt::fmt_bytes(vm.backward_bytes() as f64),
        vm.forward_occupancy(16 << 20) * 100.0,
        vm.mxu_utilization() * 100.0
    );
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rpt::fig4_csv(&model, &ns))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_eval_tasks(args: &Args) -> Result<()> {
    let ckpt_path = args
        .get("ckpt")
        .ok_or_else(|| anyhow!("--ckpt is required"))?;
    let count = args.get_usize("count", 64)?;
    let seed = args.get_u64("seed", 0)?;
    let engine = Engine::discover()?;
    let ck = Checkpoint::load(ckpt_path)?;
    ck.meta.require_current_layout()?;
    let logits_artifact = format!("{}_logits", ck.meta.artifact_tag);
    println!(
        "| task | accuracy | correct/positions | ckpt |",
    );
    println!("|---|---|---|---|");
    for kind in TaskKind::all() {
        let s = score_task(&engine, &logits_artifact, &ck.state, kind, count, seed)?;
        println!(
            "| {} | {:.1}% | {}/{} | {} @ step {} |",
            s.task,
            s.accuracy() * 100.0,
            s.correct,
            s.positions,
            ck.meta.artifact_tag,
            ck.meta.step
        );
    }
    Ok(())
}

/// Autoregressive decoding from a checkpoint: the recurrent constant-size
/// state for `ours`/`gated`, the growing KV cache for `softmax`. Generated
/// text goes to stdout (one sample per `---`-separated block), stats to
/// stderr.
fn cmd_generate(args: &Args) -> Result<()> {
    use repro::infer::{GenRequest, ModelSession, SampleMode};

    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt is required"))?;
    let session = ModelSession::load(ckpt)?;
    eprintln!("loaded {}", session.summary());
    let mode = SampleMode::from_flags(
        args.get_or("mode", "greedy"),
        args.get_or("temperature", "1.0")
            .parse::<f32>()
            .map_err(|_| anyhow!("--temperature expects a number"))?,
        args.get_usize("top-k", 0)?,
    )?;
    let req = GenRequest {
        prompt: args.get_or("prompt", "the ").to_string(),
        max_new: args.get_usize("max-new", 64)?,
        mode,
        seed: args.get_u64("seed", 0)?,
        samples: args.get_usize("samples", 1)?,
        serial_prefill: args.has("serial-prefill"),
    };
    let out = session.generate(&req)?;
    for (i, text) in out.texts.iter().enumerate() {
        if i > 0 {
            println!("---");
        }
        println!("{text}");
    }
    eprintln!(
        "generated {} × {} tokens from a {}-token prompt: prefill {:.1} ms ({:.0} tok/s, \
         {}), ttft {:.1} ms, decode {:.1} ms ({:.0} tok/s), attention state {} B ({})",
        out.texts.len(),
        out.new_tokens,
        out.prompt_tokens,
        out.prefill_s * 1e3,
        out.prefill_tok_s(),
        if args.has("serial-prefill") { "serial route" } else { "chunked route" },
        out.ttft_s * 1e3,
        out.decode_s * 1e3,
        out.tokens_per_s(),
        out.state_bytes,
        match session.cfg().attn {
            repro::native::model::AttnKind::Softmax => "KV cache, grows with length",
            _ => "recurrent, constant in length",
        },
    );
    Ok(())
}

/// Prefill-route parity check: ingest one long deterministic prompt through
/// both prefill routes — token-by-token `prefill_step` (the oracle) and the
/// chunked fast path — from seeded parameters, then continue greedily and
/// compare. Exits nonzero on divergence, so CI can gate the chunked route
/// on arbitrarily long prompts without training a wide-context checkpoint.
fn cmd_prefill_check(args: &Args) -> Result<()> {
    use std::time::Instant;

    use repro::infer::DecodeState;
    use repro::native::model::{self, AttnKind, LmConfig, Precision, QuantModel};
    use repro::native::pool::ThreadPool;
    use repro::runtime::Tensor;
    use repro::util::json::Json;

    let preset = args.get_or("preset", "tiny");
    let attn = AttnKind::from_name(args.get_or("attn", "ours"))?;
    let prompt_len = args.get_usize("prompt-len", 2048)?;
    if prompt_len < 2 {
        bail!("--prompt-len must be at least 2");
    }
    let max_new = args.get_usize("max-new", 16)?.max(1);
    let chunk = args.get_usize("chunk", 0)?; // 0 = RUST_PALLAS_CHUNK default
    let precision = Precision::from_name(args.get_or("precision", "f32"))?;
    let seed = args.get_u64("seed", 0)?;
    let max_logit_diff = args
        .get_or("max-logit-diff", "0.5")
        .parse::<f32>()
        .map_err(|_| anyhow!("--max-logit-diff expects a number"))?;

    let mut cfg = LmConfig::by_preset(preset, attn)?;
    // the presets cap n_ctx well below long-prompt territory — widen the
    // window before init_state (wpe rows are sized from n_ctx)
    cfg.n_ctx = cfg.n_ctx.max(prompt_len + max_new + 1);
    let mut params = cfg.init_state(seed);
    params.truncate(cfg.n_param_arrays());
    let refs: Vec<&Tensor> = params.iter().collect();
    let pool = ThreadPool::from_env();

    let qm;
    let run_cfg;
    let bound = if precision.is_quantized() {
        qm = QuantModel::from_params(&cfg, &refs, precision)?;
        run_cfg = *qm.cfg();
        model::DecodeModel::bind_quantized(&qm)?
    } else {
        run_cfg = cfg;
        model::DecodeModel::bind(&cfg, &refs)?
    };

    let toks: Vec<i32> =
        (0..prompt_len).map(|i| ((i * 31 + 7) % run_cfg.vocab) as i32).collect();
    let greedy = |logits: &[f32]| -> i32 {
        logits
            .iter()
            .enumerate()
            .filter(|(_, x)| x.is_finite())
            .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i as i32)
            .expect("all logits non-finite")
    };
    let mut sc = model::DecodeScratch::new();

    // serial oracle: one prefill_step per prompt token
    let mut st_s = DecodeState::new(&run_cfg, 1)?;
    let t0 = Instant::now();
    for &t in &toks[..prompt_len - 1] {
        bound.prefill_step_scratch(&[t], &mut st_s, &pool, &mut sc)?;
    }
    let serial_prefill_s = t0.elapsed().as_secs_f64();
    let logits_s =
        bound.logits_step_scratch(&[toks[prompt_len - 1]], &mut st_s, &pool, &mut sc)?.to_vec();
    let serial_ttft_s = t0.elapsed().as_secs_f64();
    let mut gen_s = Vec::with_capacity(max_new);
    let mut cur = greedy(&logits_s);
    for _ in 0..max_new {
        gen_s.push(cur);
        cur = greedy(bound.logits_step_scratch(&[cur], &mut st_s, &pool, &mut sc)?);
    }

    // chunked fast path: the whole prompt in one pass per layer
    let mut psc = model::PrefillScratch::new();
    let mut st_c = DecodeState::new(&run_cfg, 1)?;
    let t1 = Instant::now();
    if chunk > 0 {
        bound.prefill_chunked_with(chunk, &toks[..prompt_len - 1], &mut st_c, &pool, &mut psc)?;
    } else {
        bound.prefill_chunked(&toks[..prompt_len - 1], &mut st_c, &pool, &mut psc)?;
    }
    let chunked_prefill_s = t1.elapsed().as_secs_f64();
    let logits_c =
        bound.logits_step_scratch(&[toks[prompt_len - 1]], &mut st_c, &pool, &mut sc)?.to_vec();
    let chunked_ttft_s = t1.elapsed().as_secs_f64();
    let mut gen_c = Vec::with_capacity(max_new);
    let mut cur = greedy(&logits_c);
    for _ in 0..max_new {
        gen_c.push(cur);
        cur = greedy(bound.logits_step_scratch(&[cur], &mut st_c, &pool, &mut sc)?);
    }

    let logit_diff = logits_s
        .iter()
        .zip(&logits_c)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // greedy-token equality is the hard gate at f32 (reassociation noise is
    // orders of magnitude below any realistic argmax margin); quantized
    // states legitimately differ — one requantization per layer instead of
    // per token — so there only the logit bound applies
    let tokens_match = gen_s == gen_c;
    let ok = logit_diff <= max_logit_diff && (tokens_match || precision.is_quantized());
    let denom = (prompt_len - 1).max(1) as f64;
    let used_chunk = if chunk > 0 { chunk } else { repro::native::ours_chunk() };
    println!(
        "{}",
        Json::obj(vec![
            ("ok", Json::Bool(ok)),
            ("preset", Json::str(preset.to_string())),
            ("attn", Json::str(format!("{attn:?}").to_lowercase())),
            ("precision", Json::str(run_cfg.precision.to_string())),
            ("prompt_tokens", Json::num(prompt_len as f64)),
            ("chunk", Json::num(used_chunk as f64)),
            ("tokens_match", Json::Bool(tokens_match)),
            ("logit_max_abs_diff", Json::num(logit_diff as f64)),
            ("serial_prefill_ms", Json::num(serial_prefill_s * 1e3)),
            ("serial_ttft_ms", Json::num(serial_ttft_s * 1e3)),
            ("serial_tok_s", Json::num(denom / serial_prefill_s.max(1e-12))),
            ("chunked_prefill_ms", Json::num(chunked_prefill_s * 1e3)),
            ("chunked_ttft_ms", Json::num(chunked_ttft_s * 1e3)),
            ("chunked_tok_s", Json::num(denom / chunked_prefill_s.max(1e-12))),
            ("speedup_vs_serial", Json::num(serial_prefill_s / chunked_prefill_s.max(1e-12))),
        ])
        .to_string()
    );
    if !ok {
        bail!(
            "prefill routes diverged for {preset}/{attn:?}/{}: tokens_match={tokens_match}, \
             max |logit diff| {logit_diff:.4} (bound {max_logit_diff})",
            run_cfg.precision
        );
    }
    Ok(())
}

/// Offline checkpoint quantization: f32 training checkpoint in, layout-v3
/// decode-only checkpoint out, with a fidelity probe gating the conversion.
fn cmd_quantize(args: &Args) -> Result<()> {
    use repro::infer::quantize_checkpoint;
    use repro::native::model::Precision;

    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt is required"))?;
    let out = args.get("out").ok_or_else(|| anyhow!("--out is required"))?;
    let precision = Precision::from_name(args.get_or("precision", "int8"))?;
    if !precision.is_quantized() {
        bail!("--precision must be bf16 or int8 (f32 is what the input already is)");
    }
    let check_tokens = args.get_usize("check-tokens", 32)?;
    let max_logit_diff = args
        .get_or("max-logit-diff", "0.5")
        .parse::<f32>()
        .map_err(|_| anyhow!("--max-logit-diff expects a number"))?;
    let outcome = quantize_checkpoint(ckpt, out, precision, check_tokens)?;
    eprintln!(
        "quantized {ckpt} → {out} ({}): params {} B → {} B ({:.2}×), \
         max |logit drift| {:.4} over {} probe tokens",
        outcome.precision,
        outcome.f32_param_bytes,
        outcome.quant_param_bytes,
        outcome.f32_param_bytes as f64 / outcome.quant_param_bytes.max(1) as f64,
        outcome.logit_max_abs_diff,
        outcome.check_tokens,
    );
    if outcome.check_tokens > 0 && !(outcome.logit_max_abs_diff <= max_logit_diff) {
        // remove the artifact: a failed gate must not leave a checkpoint
        // that looks valid on disk
        let _ = std::fs::remove_file(out);
        bail!(
            "quantization drift gate failed: max |logit diff| {:.4} > {max_logit_diff} — \
             try bf16, or raise --max-logit-diff if the loss is acceptable",
            outcome.logit_max_abs_diff
        );
    }
    Ok(())
}

/// Engine knobs shared by `serve` and `loadgen`.
fn engine_config(args: &Args) -> Result<repro::infer::EngineConfig> {
    Ok(repro::infer::EngineConfig {
        slots: args.get_usize("slots", 4)?,
        queue: args.get_usize("queue", 32)?,
        prefill_budget: args.get_usize("prefill-budget", 64)?,
    })
}

/// Warm serve mode: keep the loaded model, tokenizer, and thread pool
/// resident, answering JSONL requests on stdin until EOF through the
/// continuous-batching engine.
fn cmd_serve(args: &Args) -> Result<()> {
    use repro::infer::{serve::serve_loop_with, ModelSession};

    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt is required"))?;
    let default_max_new = args.get_usize("max-new", 64)?;
    let conf = engine_config(args)?;
    let session = ModelSession::load(ckpt)?;
    eprintln!(
        "serving {} (JSONL on stdin, EOF to exit; {} slot(s), queue {}, prefill budget {})",
        session.summary(),
        conf.slots,
        conf.queue,
        conf.prefill_budget
    );
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let stats = serve_loop_with(&session, conf, stdin.lock(), stdout.lock(), default_max_new)?;
    eprintln!("{}", stats.summary());
    Ok(())
}

/// Deterministic load run: replay a seeded arrival trace against the
/// engine and fit the traffic model's serve-side constants.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use repro::infer::{engine::loadgen, LoadGenConfig, ModelSession};
    use repro::simulator::ArrivalPattern;

    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt is required"))?;
    let parse_f64 = |name: &str, default: f64| -> Result<f64> {
        match args.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{name} must be a number, got {s:?}")),
        }
    };
    let pattern = match args.get_or("pattern", "burst") {
        "poisson" => ArrivalPattern::Poisson { rate_hz: parse_f64("rate", 50.0)? },
        "burst" => ArrivalPattern::Burst {
            burst: args.get_usize("burst", 8)?,
            gap_s: parse_f64("gap-s", 1.0)?,
        },
        other => bail!("--pattern must be poisson or burst, got {other:?}"),
    };
    let conf = LoadGenConfig {
        n_requests: args.get_usize("requests", 8)?,
        pattern,
        seed: args.get_u64("seed", 0)?,
        prompt_len: args.get_usize("prompt-len", 24)?,
        max_new: args.get_usize("max-new", 16)?,
        cycles_per_s: parse_f64("cycles-per-s", 100.0)?,
    };
    let session = ModelSession::load(ckpt)?;
    eprintln!("loadgen over {}", session.summary());
    let mut engine = session.engine(engine_config(args)?)?;
    let report = loadgen::run(&mut engine, &conf)?;
    println!("{}", report.summary());
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let runs = PathBuf::from(args.get_or("runs", "runs"));
    println!("| run | steps | final loss | tail-10 loss | tok/s | wall |");
    println!("|---|---|---|---|---|---|");
    let mut entries: Vec<_> = std::fs::read_dir(&runs)
        .map_err(|e| anyhow!("reading {runs:?}: {e}"))?
        .filter_map(|e| e.ok())
        .collect();
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let metrics = entry.path().join("metrics.jsonl");
        if !metrics.exists() {
            continue;
        }
        let log = MetricsLog::read_jsonl(&metrics)?;
        let recs = log.records();
        if recs.is_empty() {
            continue;
        }
        println!(
            "| {} | {} | {:.4} | {:.4} | {:.0} | {:.1}s |",
            entry.file_name().to_string_lossy(),
            recs.len(),
            recs.last().unwrap().loss,
            log.tail_mean_loss(10).unwrap_or(f32::NAN),
            log.tokens_per_second().unwrap_or(0.0),
            recs.last().unwrap().wall_s
        );
    }
    Ok(())
}

/// Debug utility: execute one artifact with synthetic inputs and print
/// output summary statistics (finite check, min/max/mean).
fn cmd_run_artifact(args: &Args) -> Result<()> {
    let name = args.get("name").ok_or_else(|| anyhow!("--name required"))?;
    let engine = Engine::discover()?;
    let exe = engine.load(name)?;
    let mut inputs = Vec::new();
    for (i, spec) in exe.meta.inputs.iter().enumerate() {
        let t = match spec.dtype.as_str() {
            "i32" | "s32" => {
                // token-like inputs: small non-negative ids; scalars: zero
                let n: usize = spec.shape.iter().product();
                repro::runtime::Tensor::i32(
                    spec.shape.clone(),
                    (0..n).map(|j| (j % 97) as i32).collect(),
                )?
            }
            _ => {
                let mut t = repro::runtime::Tensor::randn(
                    spec.shape.clone(),
                    0xA11CE + i as u64,
                );
                if i < 2 && exe.meta.kind.starts_with("layer") {
                    t.normalize_rows();
                }
                t
            }
        };
        inputs.push(t);
    }
    let out = exe.run(&inputs)?;
    for (i, t) in out.iter().enumerate() {
        match t {
            repro::runtime::Tensor::F32 { data, shape } => {
                let finite = data.iter().all(|x| x.is_finite());
                let mx = data.iter().cloned().fold(f32::MIN, f32::max);
                let mn = data.iter().cloned().fold(f32::MAX, f32::min);
                let mean = data.iter().sum::<f32>() / data.len().max(1) as f32;
                println!(
                    "out[{i}] f32{shape:?} finite={finite} min={mn:.4e} max={mx:.4e} mean={mean:.4e}"
                );
            }
            repro::runtime::Tensor::I32 { shape, .. } => {
                println!("out[{i}] i32{shape:?}");
            }
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let engine = Engine::discover()?;
    println!("platform: {}", engine.platform());
    for (name, meta) in &engine.manifest.artifacts {
        if let Some(f) = args.get("filter") {
            if !name.contains(f) {
                continue;
            }
        }
        println!(
            "{name}  kind={} inputs={} outputs={}",
            meta.kind,
            meta.inputs.len(),
            meta.outputs.len()
        );
    }
    Ok(())
}
