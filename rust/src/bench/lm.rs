//! End-to-end LM training measurement shared by `repro bench-native` and the
//! fig5 bench harness: median per-step wall-clock plus the loss endpoints of
//! a short run — the deep-model `ours` vs `softmax` cost/convergence
//! comparison in one reusable piece. Every point is measured twice, through
//! the in-place (owned-state) step and the preserved rebuild step, so the
//! allocator win of the mutable-state optimizer is a recorded artifact; the
//! [`measure_adamw`] microbench isolates the optimizer update itself.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::config::{DataSection, OutputSection, TrainSection};
use crate::coordinator::{RunConfig, Trainer};
use crate::data::{Batcher, PackedDataset, Split};
use crate::native::model::{self, AttnKind, LmConfig};
use crate::native::pool::ThreadPool;
use crate::runtime::{Engine, Tensor};

use crate::data::ByteTokenizer;
use crate::infer::engine::loadgen;
use crate::infer::{BatchEngine, DecodeState, EngineConfig, LoadGenConfig};
use crate::simulator::ArrivalPattern;

use super::report::{
    DecodeBenchPoint, LmBenchPoint, OptBenchPoint, PrefillBenchPoint, ServeBenchPoint,
};
use super::timing::TimingStats;

/// Corpus size every LM bench trains on.
pub const BENCH_CORPUS_BYTES: usize = 1 << 20;

fn run_config(preset: &str, attn: &str, steps: usize) -> RunConfig {
    RunConfig {
        train: TrainSection {
            preset: preset.to_string(),
            attn: attn.to_string(),
            steps,
            eval_every: 0,
            ckpt_every: 0,
            seed: 0,
        },
        data: DataSection { corpus_bytes: BENCH_CORPUS_BYTES, val_frac: 0.05 },
        output: OutputSection { dir: "bench_out/lm".to_string() },
    }
}

/// Build the packed dataset for one preset once — it depends only on the
/// preset's tokenizer contract and the seed, not on the attention variant,
/// so benching `ours` vs `softmax` must not pay corpus generation (or, for
/// BPE presets, merge training) twice.
pub fn build_preset_dataset(engine: &Engine, preset: &str) -> Result<PackedDataset> {
    let trainer = Trainer::new(engine, run_config(preset, "ours", 1))?;
    let (_tok, ds) = trainer.build_dataset()?;
    Ok(ds)
}

/// p50 of a sample vector (NaN-tolerant: total order, no panic).
fn p50(mut times: Vec<f64>) -> f64 {
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// Time `steps` optimizer steps of one (preset, attn) pair on a prebuilt
/// dataset — once through the preserved rebuild step (the allocation-heavy
/// baseline), once through the in-place owned-state step — and return the
/// measured point for reports. Both runs see the identical batch sequence.
pub fn measure_lm(
    engine: &Engine,
    preset: &str,
    attn: &str,
    steps: usize,
    ds: &PackedDataset,
) -> Result<LmBenchPoint> {
    ensure!(steps > 0, "measure_lm needs at least one step");
    ensure!(steps > 0, "measure_lm needs at least one step");
    let trainer = Trainer::new(engine, run_config(preset, attn, steps))?;
    eprintln!("  {}", trainer.model_summary());
    let mut batcher = Batcher::new(ds, Split::Train, trainer.batch_size(), 0)?;
    let batches: Vec<Tensor> =
        (0..steps).map(|_| batcher.next_batch()).collect::<Result<_>>()?;

    // rebuild baseline: fresh state tensors allocated every step
    let mut state = trainer.init_state()?;
    let mut times_rebuild = Vec::with_capacity(steps);
    for (step, batch) in batches.iter().enumerate() {
        let t0 = Instant::now();
        let (_m, new_state) = trainer.step_rebuild(state, batch, step)?;
        times_rebuild.push(t0.elapsed().as_secs_f64());
        state = new_state;
    }

    // in-place: the state buffers are mutated, zero per-step state allocation
    let mut state = trainer.init_state()?;
    let mut times = Vec::with_capacity(steps);
    let mut loss_first = f32::NAN;
    let mut loss_last = f32::NAN;
    let mut grad_norm_last = f32::NAN;
    for (step, batch) in batches.iter().enumerate() {
        let t0 = Instant::now();
        let m = trainer.step(&mut state, batch, step)?;
        times.push(t0.elapsed().as_secs_f64());
        if step == 0 {
            loss_first = m.loss;
        }
        loss_last = m.loss;
        grad_norm_last = m.grad_norm;
    }

    Ok(LmBenchPoint {
        preset: preset.to_string(),
        attn: attn.to_string(),
        n_layer: trainer.model_field("n_layer").unwrap_or(1),
        n_head: trainer.model_field("n_head").unwrap_or(1),
        d_model: trainer.model_field("d_model").unwrap_or(0),
        n_params: trainer.n_params(),
        steps,
        tokens_per_step: trainer.batch_size() * (trainer.seq_len() + 1),
        step_s_p50: p50(times),
        step_s_p50_rebuild: p50(times_rebuild),
        weight_decay: trainer.train_field("weight_decay").unwrap_or(0.0),
        clip_norm: trainer.train_field("clip_norm").unwrap_or(0.0),
        grad_norm_last,
        loss_first,
        loss_last,
    })
}

/// Bound on the mean next-token NLL drift a quantized decode may show
/// against its f32 oracle before [`measure_decode`] fails the run. Reduced
/// precision must buy memory/speed, not a silently different model.
pub const DECODE_QUALITY_GATE_NATS: f64 = 0.5;

/// Next-token negative log-likelihood of one logit row (log-softmax in f64
/// so the gate compares model quality, not summation noise).
fn nll(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse = m + logits.iter().map(|&x| (x as f64 - m).exp()).sum::<f64>().ln();
    lse - logits[target] as f64
}

/// Measure autoregressive decoding of one (preset, attn, precision) triple:
/// `tokens` tokens (capped at the context window) through the **recurrent**
/// incremental path (`DecodeState` + `logits_step`, the prefix is never
/// re-scanned), against the **full-recompute** baseline where every token
/// replays the entire prefix through a fresh state (via the prefill fast
/// path, so the baseline is the strongest stateless decoder, not a straw
/// man). Also records the per-token cost of the first vs second
/// half of the recurrent run and the attention-state byte endpoints: flat
/// cost and constant state for `ours`/`gated`, linearly growing KV-cache
/// state for `softmax` — the paper's decode-memory claim as a measured
/// artifact. Weights are freshly initialized (decode cost is
/// data-independent).
///
/// For `bf16`/`int8` the weights are quantized on the fly, the decode state
/// is stored at the same precision, and an untimed f32 oracle replays the
/// same token walk: the point records the worst per-logit divergence and
/// the mean next-token NLL delta, gated by [`DECODE_QUALITY_GATE_NATS`].
pub fn measure_decode(
    preset: &str,
    attn: &str,
    tokens: usize,
    precision: &str,
) -> Result<DecodeBenchPoint> {
    ensure!(tokens >= 4, "measure_decode needs at least 4 tokens");
    let cfg = LmConfig::by_preset(preset, AttnKind::from_name(attn)?)?;
    let prec = model::Precision::from_name(precision)?;
    let pool = ThreadPool::from_env();
    let state = cfg.init_state(0);
    let np = cfg.n_param_arrays();
    let params: Vec<&Tensor> = state[..np].iter().collect();
    // bind once — the per-token cost under measurement is the step, not
    // parameter-layout validation (or, for the quantized points,
    // quantization itself)
    let qm;
    let (bound, run_cfg, param_bytes) = if prec.is_quantized() {
        qm = model::QuantModel::from_params(&cfg, &params, prec)?;
        (model::DecodeModel::bind_quantized(&qm)?, *qm.cfg(), qm.param_bytes())
    } else {
        let bytes = params.iter().map(|t| t.shape().iter().product::<usize>() * 4).sum();
        (model::DecodeModel::bind(&cfg, &params)?, cfg, bytes)
    };
    let t_total = tokens.min(cfg.n_ctx);
    let toks: Vec<i32> = (0..t_total).map(|i| (i % cfg.vocab) as i32).collect();

    // recurrent: one state advanced token by token, reusing one scratch so
    // the measured per-token cost is arithmetic, not allocator traffic; the
    // logits copy for the fidelity probe happens outside the timer
    let mut st = DecodeState::new(&run_cfg, 1)?;
    let mut sc = model::DecodeScratch::new();
    let mut step_s = Vec::with_capacity(t_total);
    let mut run_logits: Vec<f32> = Vec::with_capacity(t_total * cfg.vocab);
    let mut state_bytes_first = 0usize;
    for (t, &tok) in toks.iter().enumerate() {
        let t0 = Instant::now();
        let l = bound.logits_step_scratch(&[tok], &mut st, &pool, &mut sc)?;
        step_s.push(t0.elapsed().as_secs_f64());
        run_logits.extend_from_slice(l);
        if t == 0 {
            state_bytes_first = st.state_bytes();
        }
    }
    let state_bytes_last = st.state_bytes();
    let recurrent_s: f64 = step_s.iter().sum();
    let half = t_total / 2;
    let (first, second) = step_s.split_at(half);

    // untimed f32 oracle over the same walk: worst per-logit divergence and
    // mean next-token NLL drift of the quantized run (both 0 for f32 — the
    // f32 decode path is bit-identical to the oracle)
    let (mut logit_maxabs, mut nll_delta) = (0.0f64, 0.0f64);
    if prec.is_quantized() {
        let oracle = model::DecodeModel::bind(&cfg, &params)?;
        let mut st_f = DecodeState::new(&cfg, 1)?;
        let mut sc_f = model::DecodeScratch::new();
        let v = cfg.vocab;
        let (mut nll_run, mut nll_f32, mut scored) = (0.0f64, 0.0f64, 0usize);
        for (t, &tok) in toks.iter().enumerate() {
            let lf = oracle.logits_step_scratch(&[tok], &mut st_f, &pool, &mut sc_f)?;
            let lr = &run_logits[t * v..][..v];
            for (a, b) in lf.iter().zip(lr) {
                logit_maxabs = logit_maxabs.max((a - b).abs() as f64);
            }
            if t + 1 < toks.len() {
                let target = toks[t + 1] as usize;
                nll_run += nll(lr, target);
                nll_f32 += nll(lf, target);
                scored += 1;
            }
        }
        if scored > 0 {
            nll_delta = (nll_run - nll_f32) / scored as f64;
        }
        ensure!(
            nll_delta.abs() <= DECODE_QUALITY_GATE_NATS,
            "quantized decode quality gate: |Δnll| {:.4} nats > {} for \
             {preset}/{attn}/{precision}",
            nll_delta,
            DECODE_QUALITY_GATE_NATS
        );
    }

    // full recompute: producing token t replays tokens 0..t from scratch.
    // The replayed prefix goes through the chunked prefill fast path (one
    // chunkwise pass per layer, state only, no unembedding) with a single
    // logits step at the end — the best a stateless decoder could do, so
    // the recurrent speedup is not inflated by charging the baseline t
    // token-by-token replays or t redundant unembedding GEMMs
    let mut psc = model::PrefillScratch::new();
    let t0 = Instant::now();
    for t in 0..t_total {
        let mut st = DecodeState::new(&run_cfg, 1)?;
        if t > 0 {
            bound.prefill_chunked(&toks[..t], &mut st, &pool, &mut psc)?;
        }
        bound.logits_step_scratch(&[toks[t]], &mut st, &pool, &mut sc)?;
    }
    let recompute_s = t0.elapsed().as_secs_f64();

    Ok(DecodeBenchPoint {
        preset: preset.to_string(),
        attn: attn.to_string(),
        precision: prec.name().to_string(),
        n_params: cfg.n_params(),
        param_bytes,
        tokens: t_total,
        recurrent_tok_s: t_total as f64 / recurrent_s.max(1e-12),
        recompute_tok_s: t_total as f64 / recompute_s.max(1e-12),
        step_s_p50_first_half: p50(first.to_vec()),
        step_s_p50_second_half: p50(second.to_vec()),
        state_bytes_first,
        state_bytes_last,
        logit_maxabs_vs_f32: logit_maxabs,
        nll_delta_vs_f32: nll_delta,
    })
}

/// Teacher-forced tail length the quantized chunked-prefill quality gate
/// scores (and the extra window [`measure_prefill`] reserves past the
/// prompt).
const PREFILL_NLL_TAIL: usize = 32;

/// Measure prompt ingestion of one (preset, attn, precision, prompt length)
/// point through both prefill routes: the **chunked** fast path (the whole
/// prompt in one chunkwise pass per layer) against the **serial**
/// token-by-token oracle. Both end with the same first-logits step, so
/// `ttft_ms` is true time-to-first-token. Weights are freshly initialized
/// (prefill cost is data-independent) and `n_ctx` is widened to the prompt —
/// the presets' training windows stop far short of the 512–16k-token
/// prompts this section sweeps.
///
/// For `bf16`/`int8` an untimed f32 oracle chunk-prefills the same prompt
/// and both models score the same teacher-forced tail; the mean next-token
/// NLL drift is gated by [`DECODE_QUALITY_GATE_NATS`] — reduced precision
/// must buy prefill speed, not a silently different model.
pub fn measure_prefill(
    preset: &str,
    attn: &str,
    prompt_len: usize,
    precision: &str,
    chunk: usize,
    reps: usize,
) -> Result<PrefillBenchPoint> {
    ensure!(prompt_len >= 2, "measure_prefill needs at least 2 prompt tokens");
    ensure!(reps > 0, "measure_prefill needs at least one rep");
    let mut cfg = LmConfig::by_preset(preset, AttnKind::from_name(attn)?)?;
    // widen the window before init_state — wpe rows are sized from n_ctx
    cfg.n_ctx = cfg.n_ctx.max(prompt_len + PREFILL_NLL_TAIL + 1);
    let prec = model::Precision::from_name(precision)?;
    let pool = ThreadPool::from_env();
    let state = cfg.init_state(0);
    let np = cfg.n_param_arrays();
    let params: Vec<&Tensor> = state[..np].iter().collect();
    let qm;
    let (bound, run_cfg) = if prec.is_quantized() {
        qm = model::QuantModel::from_params(&cfg, &params, prec)?;
        (model::DecodeModel::bind_quantized(&qm)?, *qm.cfg())
    } else {
        (model::DecodeModel::bind(&cfg, &params)?, cfg)
    };
    let chunk_used = if chunk > 0 { chunk } else { crate::native::ours_chunk() };
    let toks: Vec<i32> = (0..prompt_len + PREFILL_NLL_TAIL)
        .map(|i| ((i * 31 + 7) % cfg.vocab) as i32)
        .collect();
    // the first prompt_len − 1 tokens are ingested state-only; the last
    // prompt token produces the first logits (the TTFT endpoint)
    let l = prompt_len - 1;

    let mut sc = model::DecodeScratch::new();
    let mut psc = model::PrefillScratch::new();

    // chunked fast path: p50 over reps (the first rep also pays scratch
    // sizing, which p50 absorbs for reps ≥ 2)
    let mut chunked_prefill = Vec::with_capacity(reps);
    let mut chunked_ttft = Vec::with_capacity(reps);
    let mut chunked_logits = Vec::new();
    for rep in 0..reps {
        let mut st = DecodeState::new(&run_cfg, 1)?;
        let t0 = Instant::now();
        bound.prefill_chunked_with(chunk_used, &toks[..l], &mut st, &pool, &mut psc)?;
        chunked_prefill.push(t0.elapsed().as_secs_f64());
        let lg = bound.logits_step_scratch(&[toks[l]], &mut st, &pool, &mut sc)?;
        chunked_ttft.push(t0.elapsed().as_secs_f64());
        if rep == 0 {
            chunked_logits = lg.to_vec();
        }
    }

    // serial oracle: the identical prompt token by token
    let mut serial_prefill = Vec::with_capacity(reps);
    let mut serial_logits = Vec::new();
    for rep in 0..reps {
        let mut st = DecodeState::new(&run_cfg, 1)?;
        let t0 = Instant::now();
        for &tok in &toks[..l] {
            bound.prefill_step_scratch(&[tok], &mut st, &pool, &mut sc)?;
        }
        serial_prefill.push(t0.elapsed().as_secs_f64());
        let lg = bound.logits_step_scratch(&[toks[l]], &mut st, &pool, &mut sc)?;
        if rep == 0 {
            serial_logits = lg.to_vec();
        }
    }

    let logit_maxabs_vs_serial = chunked_logits
        .iter()
        .zip(&serial_logits)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);

    let mut nll_delta_vs_f32 = 0.0f64;
    if prec.is_quantized() {
        let oracle = model::DecodeModel::bind(&cfg, &params)?;
        let mut st_q = DecodeState::new(&run_cfg, 1)?;
        let mut st_f = DecodeState::new(&cfg, 1)?;
        let mut psc_f = model::PrefillScratch::new();
        let mut sc_f = model::DecodeScratch::new();
        bound.prefill_chunked_with(chunk_used, &toks[..l], &mut st_q, &pool, &mut psc)?;
        oracle.prefill_chunked_with(chunk_used, &toks[..l], &mut st_f, &pool, &mut psc_f)?;
        let (mut nq, mut nf, mut scored) = (0.0f64, 0.0f64, 0usize);
        for t in l..l + PREFILL_NLL_TAIL {
            let target = toks[t + 1] as usize;
            let lq = bound.logits_step_scratch(&[toks[t]], &mut st_q, &pool, &mut sc)?;
            nq += nll(lq, target);
            let lf = oracle.logits_step_scratch(&[toks[t]], &mut st_f, &pool, &mut sc_f)?;
            nf += nll(lf, target);
            scored += 1;
        }
        nll_delta_vs_f32 = (nq - nf) / scored as f64;
        ensure!(
            nll_delta_vs_f32.abs() <= DECODE_QUALITY_GATE_NATS,
            "quantized chunked-prefill quality gate: |Δnll| {:.4} nats > {} for \
             {preset}/{attn}/{precision} @ {prompt_len} tokens",
            nll_delta_vs_f32,
            DECODE_QUALITY_GATE_NATS
        );
    }

    let prefill_s = p50(chunked_prefill);
    let serial_s = p50(serial_prefill);
    Ok(PrefillBenchPoint {
        preset: preset.to_string(),
        attn: attn.to_string(),
        precision: prec.name().to_string(),
        prompt_tokens: prompt_len,
        chunk: chunk_used,
        ttft_ms: p50(chunked_ttft) * 1e3,
        prefill_tok_s: l as f64 / prefill_s.max(1e-12),
        serial_tok_s: l as f64 / serial_s.max(1e-12),
        speedup_vs_serial: serial_s / prefill_s.max(1e-12),
        logit_maxabs_vs_serial,
        nll_delta_vs_f32,
    })
}

/// Microbench the AdamW state update alone (no forward/backward): fixed
/// synthetic gradients against the same initial state, `reps` repetitions of
/// the fused in-place route vs the preserved rebuild route. This isolates
/// exactly what the owned-state refactor removed — the per-step allocation
/// and re-materialization of `3·np` state tensors.
pub fn measure_adamw(
    preset: &str,
    attn: &str,
    reps: usize,
    warmup: usize,
) -> Result<OptBenchPoint> {
    ensure!(reps > 0, "measure_adamw needs at least one rep");
    let cfg = LmConfig::by_preset(preset, AttnKind::from_name(attn)?)?;
    let pool = ThreadPool::from_env();
    let grads: Vec<Vec<f32>> = cfg
        .param_shapes()
        .iter()
        .enumerate()
        .map(|(i, (_, shape))| {
            let t = Tensor::randn(shape.clone(), 0xADA7 + i as u64);
            t.as_f32().map(|d| d.to_vec())
        })
        .collect::<Result<_>>()?;

    // in-place: one state, mutated every rep
    let mut state = cfg.init_state(0);
    let mut t_inplace = Vec::with_capacity(reps);
    for rep in 0..warmup + reps {
        let t0 = Instant::now();
        model::adamw_update_mut(&cfg, &mut state, &grads, rep, &pool)?;
        if rep >= warmup {
            t_inplace.push(t0.elapsed().as_secs_f64());
        }
    }

    // rebuild: every rep allocates the full replacement state
    let mut state = cfg.init_state(0);
    let mut t_rebuild = Vec::with_capacity(reps);
    for rep in 0..warmup + reps {
        let refs: Vec<&Tensor> = state.iter().collect();
        let t0 = Instant::now();
        let (_norm, new_state) = model::adamw_update_rebuild(&cfg, &refs, &grads, rep)?;
        if rep >= warmup {
            t_rebuild.push(t0.elapsed().as_secs_f64());
        }
        drop(refs);
        state = new_state;
    }

    let inplace = TimingStats::from_samples(t_inplace)
        .ok_or_else(|| anyhow::anyhow!("no in-place samples"))?;
    let rebuild = TimingStats::from_samples(t_rebuild)
        .ok_or_else(|| anyhow::anyhow!("no rebuild samples"))?;
    Ok(OptBenchPoint {
        preset: preset.to_string(),
        n_params: cfg.n_params(),
        n_param_arrays: cfg.n_param_arrays(),
        inplace_s_p50: inplace.p50,
        rebuild_s_p50: rebuild.p50,
    })
}

/// Measure the continuous-batching serve engine on one (preset, attn,
/// precision) triple: a deterministic burst load run (`requests` requests
/// arriving in slot-sized groups, so admissions genuinely overlap in-flight
/// decodes) through a [`BatchEngine`], summarized as occupancy, per-request
/// TTFT/latency/throughput percentiles, and the traffic-model calibration
/// fitted to the engine's per-step `(bytes, seconds)` samples. Weights are
/// freshly initialized (serve cost is data-independent); the queue is sized
/// to the run so nothing is shed — a bench point measures the engine, not
/// the load-shedding policy.
pub fn measure_serve(
    preset: &str,
    attn: &str,
    precision: &str,
    requests: usize,
    slots: usize,
) -> Result<ServeBenchPoint> {
    ensure!(requests >= 2, "measure_serve needs at least 2 requests to overlap");
    ensure!(slots >= 1, "measure_serve needs at least one decode slot");
    let cfg = LmConfig::by_preset(preset, AttnKind::from_name(attn)?)?;
    let prec = model::Precision::from_name(precision)?;
    let pool = ThreadPool::from_env();
    let state = cfg.init_state(0);
    let np = cfg.n_param_arrays();
    let params: Vec<&Tensor> = state[..np].iter().collect();
    let qm;
    let bound = if prec.is_quantized() {
        qm = model::QuantModel::from_params(&cfg, &params, prec)?;
        model::DecodeModel::bind_quantized(&qm)?
    } else {
        model::DecodeModel::bind(&cfg, &params)?
    };
    let tokenizer = ByteTokenizer::for_artifact(cfg.vocab, 0)?;
    let mut engine = BatchEngine::new(
        bound,
        &tokenizer,
        &pool,
        EngineConfig { slots, queue: requests, prefill_budget: 64 },
    )?;
    let conf = LoadGenConfig {
        n_requests: requests,
        pattern: ArrivalPattern::Burst { burst: slots, gap_s: 0.02 },
        seed: 0,
        prompt_len: 24,
        max_new: 16,
        cycles_per_s: 200.0,
    };
    let report = loadgen::run(&mut engine, &conf)?;
    ensure!(
        report.completed == requests,
        "serve bench completed {}/{} requests ({} rejected, {} errors) for \
         {preset}/{attn}/{precision}",
        report.completed,
        requests,
        report.rejected,
        report.errors,
    );
    let pct = |st: &Option<TimingStats>, sel: fn(&TimingStats) -> f64| {
        st.as_ref().map(sel).unwrap_or(0.0)
    };
    let ttft = report.stats.ttft_stats();
    let lat = report.stats.latency_stats();
    let tok = report.stats.decode_tok_s_stats();
    Ok(ServeBenchPoint {
        preset: preset.to_string(),
        attn: attn.to_string(),
        precision: prec.name().to_string(),
        slots,
        requests,
        rejected: report.rejected,
        occupancy_mean: report.stats.mean_occupancy(),
        occupancy_max: report.stats.max_occupancy,
        ttft_ms_p50: pct(&ttft, |s| s.p50) * 1e3,
        ttft_ms_p95: pct(&ttft, |s| s.p95) * 1e3,
        ttft_ms_p99: pct(&ttft, |s| s.p99) * 1e3,
        latency_ms_p50: pct(&lat, |s| s.p50) * 1e3,
        latency_ms_p95: pct(&lat, |s| s.p95) * 1e3,
        latency_ms_p99: pct(&lat, |s| s.p99) * 1e3,
        decode_tok_s_p50: pct(&tok, |s| s.p50),
        fit_overhead_ms: report.fit.as_ref().map(|f| f.launch_overhead_s * 1e3).unwrap_or(0.0),
        fit_bytes_per_s: report.fit.as_ref().map(|f| f.bytes_per_s).unwrap_or(0.0),
        fit_rms_residual_ms: report.fit.as_ref().map(|f| f.rms_residual_s * 1e3).unwrap_or(0.0),
        fit_samples: report.fit.as_ref().map(|f| f.n_samples).unwrap_or(0),
    })
}
