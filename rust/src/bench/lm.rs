//! End-to-end LM training measurement shared by `repro bench-native` and the
//! fig5 bench harness: median per-step wall-clock plus the loss endpoints of
//! a short run — the deep-model `ours` vs `softmax` cost/convergence
//! comparison in one reusable piece.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::config::{DataSection, OutputSection, TrainSection};
use crate::coordinator::{RunConfig, Trainer};
use crate::data::{Batcher, PackedDataset, Split};
use crate::runtime::Engine;

use super::report::LmBenchPoint;

/// Corpus size every LM bench trains on.
pub const BENCH_CORPUS_BYTES: usize = 1 << 20;

fn run_config(preset: &str, attn: &str, steps: usize) -> RunConfig {
    RunConfig {
        train: TrainSection {
            preset: preset.to_string(),
            attn: attn.to_string(),
            steps,
            eval_every: 0,
            ckpt_every: 0,
            seed: 0,
        },
        data: DataSection { corpus_bytes: BENCH_CORPUS_BYTES, val_frac: 0.05 },
        output: OutputSection { dir: "bench_out/lm".to_string() },
    }
}

/// Build the packed dataset for one preset once — it depends only on the
/// preset's tokenizer contract and the seed, not on the attention variant,
/// so benching `ours` vs `softmax` must not pay corpus generation (or, for
/// BPE presets, merge training) twice.
pub fn build_preset_dataset(engine: &Engine, preset: &str) -> Result<PackedDataset> {
    let trainer = Trainer::new(engine, run_config(preset, "ours", 1))?;
    let (_tok, ds) = trainer.build_dataset()?;
    Ok(ds)
}

/// Time `steps` optimizer steps of one (preset, attn) pair on a prebuilt
/// dataset; returns the measured point for reports.
pub fn measure_lm(
    engine: &Engine,
    preset: &str,
    attn: &str,
    steps: usize,
    ds: &PackedDataset,
) -> Result<LmBenchPoint> {
    ensure!(steps > 0, "measure_lm needs at least one step");
    let trainer = Trainer::new(engine, run_config(preset, attn, steps))?;
    eprintln!("  {}", trainer.model_summary());
    let mut batcher = Batcher::new(ds, Split::Train, trainer.batch_size(), 0)?;
    let mut state = trainer.init_state()?;
    let mut times = Vec::with_capacity(steps);
    let mut loss_first = f32::NAN;
    let mut loss_last = f32::NAN;
    for step in 0..steps {
        let batch = batcher.next_batch()?;
        let t0 = Instant::now();
        let (loss, new_state) = trainer.step(state, &batch, step)?;
        times.push(t0.elapsed().as_secs_f64());
        state = new_state;
        if step == 0 {
            loss_first = loss;
        }
        loss_last = loss;
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(LmBenchPoint {
        preset: preset.to_string(),
        attn: attn.to_string(),
        n_layer: trainer.model_field("n_layer").unwrap_or(1),
        n_head: trainer.model_field("n_head").unwrap_or(1),
        d_model: trainer.model_field("d_model").unwrap_or(0),
        n_params: trainer.n_params(),
        steps,
        tokens_per_step: trainer.batch_size() * (trainer.seq_len() + 1),
        step_s_p50: times[times.len() / 2],
        loss_first,
        loss_last,
    })
}
