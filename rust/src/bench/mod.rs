//! Benchmark harness: regenerate every table and figure of the paper's §5.
//!
//! - [`timing`] — explicit warmup + trimmed-mean / percentile (p10/p50/p90)
//!   measurement of artifact execution;
//! - [`sweep`] — drive the per-(impl, N, D) layer artifacts (Figs 2-3, Table 1);
//! - [`lm`] — end-to-end LM per-step training measurement (Fig 5 in bench
//!   form, shared by `repro bench-native` and `benches/fig5_train`), the
//!   AdamW-update microbench, and the autoregressive-decode measurement
//!   (recurrent incremental state vs full prefix recompute);
//! - [`report`] — markdown/CSV emitters matching the paper's rows and series,
//!   plus the `BENCH_native.json` perf-trajectory artifact (parallel/tiled
//!   kernels vs the scalar single-thread reference — see `repro bench-native`).
//!
//! Memory columns are analytic (the [`crate::simulator`] model): a CPU host
//! cannot observe GPU residency, but the per-implementation formulas are
//! exact element counts of each algorithm's live buffers.

#![forbid(unsafe_code)]

pub mod lm;
pub mod report;
pub mod sweep;
pub mod timing;

pub use sweep::{SweepPoint, SweepRunner};
pub use timing::{measure, TimingStats};
