//! Report emitters: markdown tables and CSV series matching the paper's
//! figures/tables (consumed by EXPERIMENTS.md and any plotting tool).

use std::fmt::Write as _;

use crate::simulator::{Impl, TrafficModel, TrafficReport};

use super::sweep::SweepPoint;

/// Human units.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else {
        format!("{:.1} KB", b / 1e3)
    }
}

/// Fig-2/3 CSV: one row per measured point.
pub fn sweep_csv(points: &[SweepPoint]) -> String {
    let mut out = String::from(
        "impl,kind,bh,n,d,chunk,cpu_s_p50,cpu_s_trimmed,model_total_s,model_move_s,model_bytes,mem_bytes\n",
    );
    for p in points {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e}",
            p.impl_name,
            p.kind,
            p.bh,
            p.n,
            p.d,
            p.chunk,
            p.cpu_s.p50,
            p.cpu_s.trimmed_mean,
            p.model_total_s,
            p.model_move_s,
            p.model_bytes,
            p.mem_bytes
        );
    }
    out
}

/// Fig-2/3 markdown: series grouped per implementation, one row per N (or D).
pub fn sweep_markdown(title: &str, points: &[SweepPoint]) -> String {
    let mut out = format!("### {title}\n\n");
    let _ = writeln!(
        out,
        "| impl | N | D | C | CPU p50 | model (A6000) | model move | mem (model) |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for p in points {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            p.impl_name,
            p.n,
            p.d,
            p.chunk,
            fmt_time(p.cpu_s.p50),
            fmt_time(p.model_total_s),
            fmt_time(p.model_move_s),
            fmt_bytes(p.mem_bytes)
        );
    }
    out
}

/// Table 1: the complexity/latency summary at the paper's point
/// (B=4, H=16 → BH=64, D=128, N=10⁴), fully analytic.
pub fn table1_markdown(model: &TrafficModel) -> String {
    let (bh, n, d) = (64, 10_000, 128);
    let rows: &[(&str, &str, &str, &str, Impl)] = &[
        ("Regular Attention", "exp x", "O(N²D)", "O(N²+ND)", Impl::Softmax),
        ("FlashAttention-2", "exp x", "O(N²D)", "O(ND)", Impl::Flash),
        ("Spec. Decoding LA", "bx", "O(ND²)", "O(ND²)", Impl::SpecDec),
        ("Gated LA", "bx", "O(ND²)", "O(ND)", Impl::Gated),
        ("Our LA", "a+bx", "O(ND²)", "O(ND)", Impl::Ours),
    ];
    let mut out = String::from(
        "| Mechanism | Kernel | Time | Memory (causal) | Fwd time (model) | Fwd memory (model) |\n",
    );
    out.push_str("|---|---|---|---|---|---|\n");
    for (name, kernel, time_c, mem_c, imp) in rows {
        let r: TrafficReport = model.report(*imp, bh, n, d);
        let _ = writeln!(
            out,
            "| {name} | {kernel} | {time_c} | {mem_c} | {} | {} |",
            fmt_time(r.total_s),
            fmt_bytes(r.mem_bytes),
        );
    }
    out
}

/// Fig-4 markdown: movement ratio + movement time per LA implementation
/// across sequence lengths.
pub fn fig4_markdown(model: &TrafficModel, ns: &[usize]) -> String {
    let (bh, d) = (64, 128);
    let mut out = String::from("| impl |");
    for n in ns {
        let _ = write!(out, " ratio@N={n} |");
    }
    for n in ns {
        let _ = write!(out, " move@N={n} |");
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in 0..ns.len() * 2 {
        out.push_str("---|");
    }
    out.push('\n');
    for imp in Impl::la_impls() {
        let _ = write!(out, "| {} |", imp.name());
        for &n in ns {
            let r = model.report(imp, bh, n, d);
            let _ = write!(out, " {:.0}% |", r.move_ratio() * 100.0);
        }
        for &n in ns {
            let r = model.report(imp, bh, n, d);
            let _ = write!(out, " {} |", fmt_time(r.move_s));
        }
        out.push('\n');
    }
    out
}

/// Fig-4 CSV.
pub fn fig4_csv(model: &TrafficModel, ns: &[usize]) -> String {
    let (bh, d) = (64, 128);
    let mut out = String::from("impl,n,move_ratio,move_s,total_s,bytes\n");
    for imp in Impl::la_impls() {
        for &n in ns {
            let r = model.report(imp, bh, n, d);
            let _ = writeln!(
                out,
                "{},{},{:.4},{:.6e},{:.6e},{:.6e}",
                imp.name(),
                n,
                r.move_ratio(),
                r.move_s,
                r.total_s,
                r.bytes
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::DeviceSpec;

    #[test]
    fn units() {
        assert_eq!(fmt_time(2.0), "2.00 s");
        assert_eq!(fmt_time(0.0025), "2.50 ms");
        assert_eq!(fmt_time(2.5e-6), "2.5 µs");
        assert_eq!(fmt_bytes(1.5e9), "1.50 GB");
        assert_eq!(fmt_bytes(2e6), "2.00 MB");
    }

    #[test]
    fn table1_contains_all_rows() {
        let m = TrafficModel::new(DeviceSpec::a6000());
        let t = table1_markdown(&m);
        for name in ["Regular Attention", "FlashAttention-2", "Gated LA", "Our LA"] {
            assert!(t.contains(name), "missing {name}");
        }
        assert_eq!(t.lines().count(), 2 + 5);
    }

    #[test]
    fn fig4_markdown_and_csv_shape() {
        let m = TrafficModel::new(DeviceSpec::a6000());
        let ns = [2048, 4096];
        let md = fig4_markdown(&m, &ns);
        assert!(md.contains("ours"));
        assert!(md.contains("quadratic"));
        let csv = fig4_csv(&m, &ns);
        assert_eq!(csv.lines().count(), 1 + 4 * ns.len());
    }
}
