//! Report emitters: markdown tables and CSV series matching the paper's
//! figures/tables (consumed by EXPERIMENTS.md and any plotting tool).

use std::fmt::Write as _;

use crate::simulator::{Impl, TrafficModel, TrafficReport};
use crate::util::json::Json;

use super::sweep::SweepPoint;

/// Human units.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else {
        format!("{:.1} KB", b / 1e3)
    }
}

/// Fig-2/3 CSV: one row per measured point.
pub fn sweep_csv(points: &[SweepPoint]) -> String {
    let mut out = String::from(
        "impl,kind,bh,n,d,chunk,cpu_s_p50,cpu_s_p10,cpu_s_p90,cpu_s_trimmed,model_total_s,model_move_s,model_bytes,mem_bytes\n",
    );
    for p in points {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e}",
            p.impl_name,
            p.kind,
            p.bh,
            p.n,
            p.d,
            p.chunk,
            p.cpu_s.p50,
            p.cpu_s.p10,
            p.cpu_s.p90,
            p.cpu_s.trimmed_mean,
            p.model_total_s,
            p.model_move_s,
            p.model_bytes,
            p.mem_bytes
        );
    }
    out
}

/// One measured LM training point of the `bench-native` end-to-end section:
/// per-step wall-clock plus the loss trajectory endpoints of a short run on
/// one (preset, attn) pair — Fig 5 in bench form, on the deep model.
#[derive(Debug, Clone)]
pub struct LmBenchPoint {
    pub preset: String,
    pub attn: String,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    /// True scalar parameter count (from the artifact manifest).
    pub n_params: u64,
    pub steps: usize,
    pub tokens_per_step: usize,
    /// p50 per-step wall-clock through the in-place (owned-state) route.
    pub step_s_p50: f64,
    /// p50 per-step wall-clock through the preserved rebuild route.
    pub step_s_p50_rebuild: f64,
    /// AdamW knobs baked into the artifact.
    pub weight_decay: f64,
    pub clip_norm: f64,
    /// Pre-clip global gradient norm at the final measured step.
    pub grad_norm_last: f32,
    pub loss_first: f32,
    pub loss_last: f32,
}

impl LmBenchPoint {
    /// Full-step speedup of the in-place route over the rebuild route.
    pub fn speedup_inplace(&self) -> f64 {
        if self.step_s_p50 > 0.0 {
            self.step_s_p50_rebuild / self.step_s_p50
        } else {
            0.0
        }
    }
}

/// One measured point of the AdamW-update microbench: the optimizer state
/// update alone (fixed gradients, no forward/backward), in-place vs the
/// preserved rebuild — the direct evidence for the owned-state refactor.
#[derive(Debug, Clone)]
pub struct OptBenchPoint {
    pub preset: String,
    pub n_params: u64,
    pub n_param_arrays: usize,
    pub inplace_s_p50: f64,
    pub rebuild_s_p50: f64,
}

impl OptBenchPoint {
    pub fn speedup_inplace(&self) -> f64 {
        if self.inplace_s_p50 > 0.0 {
            self.rebuild_s_p50 / self.inplace_s_p50
        } else {
            0.0
        }
    }
}

/// One measured point of the `decode` section: autoregressive decoding of
/// `tokens` tokens through the incremental [`DecodeState`] path (recurrent:
/// the prefix is never re-scanned) vs the full-recompute baseline (every
/// token replays the whole prefix through a fresh state — what a
/// stateless decoder would pay). The per-token cost split between the first
/// and second half of the run plus the state-bytes endpoints are the
/// flat-cost / constant-memory evidence for the linear variants, against
/// softmax's linearly growing KV cache.
#[derive(Debug, Clone)]
pub struct DecodeBenchPoint {
    pub preset: String,
    pub attn: String,
    /// Storage precision of weights + decode state (`f32`/`bf16`/`int8`).
    pub precision: String,
    pub n_params: u64,
    /// True stored parameter bytes at this precision (data + int8 scales).
    pub param_bytes: usize,
    /// Tokens decoded (capped at the preset's context window).
    pub tokens: usize,
    /// Tokens/s through the recurrent incremental path.
    pub recurrent_tok_s: f64,
    /// Tokens/s when every token replays the prefix from scratch.
    pub recompute_tok_s: f64,
    /// p50 per-token seconds over the first half of the recurrent run.
    pub step_s_p50_first_half: f64,
    /// p50 per-token seconds over the second half (≈ first half ⇒ flat).
    pub step_s_p50_second_half: f64,
    /// Attention-state bytes after the first token…
    pub state_bytes_first: usize,
    /// …and after the last: equal for `ours`/`gated`, ≈ `tokens ×` first
    /// for `softmax`.
    pub state_bytes_last: usize,
    /// Worst per-logit |quantized − f32| across the run (0 for f32).
    pub logit_maxabs_vs_f32: f64,
    /// Mean next-token NLL drift vs the f32 oracle, in nats (0 for f32);
    /// bounded by the bench's quality gate.
    pub nll_delta_vs_f32: f64,
}

impl DecodeBenchPoint {
    /// Recurrent-vs-recompute decode speedup.
    pub fn speedup_recurrent(&self) -> f64 {
        if self.recompute_tok_s > 0.0 {
            self.recurrent_tok_s / self.recompute_tok_s
        } else {
            0.0
        }
    }

    /// State growth over the run (1.0 = constant).
    pub fn state_growth(&self) -> f64 {
        if self.state_bytes_first > 0 {
            self.state_bytes_last as f64 / self.state_bytes_first as f64
        } else {
            0.0
        }
    }
}

/// One measured point of the `prefill` section: prompt ingestion through
/// the **chunked** fast path (the whole prompt in one chunkwise-kernel pass
/// per layer) vs the **serial** token-by-token oracle, both ending with the
/// first-logits step so `ttft_ms` is true time-to-first-token. Quantized
/// points carry their NLL drift against an f32 oracle over a teacher-forced
/// tail, gated by the bench's quality bound.
#[derive(Debug, Clone)]
pub struct PrefillBenchPoint {
    pub preset: String,
    pub attn: String,
    /// Storage precision of weights + decode state (`f32`/`bf16`/`int8`).
    pub precision: String,
    /// Prompt length ingested (the last token produces the first logits).
    pub prompt_tokens: usize,
    /// Chunk length the chunked route ran with.
    pub chunk: usize,
    /// p50 time-to-first-token through the chunked route, milliseconds.
    pub ttft_ms: f64,
    /// Prompt tokens/s through the chunked route (prefill phase alone).
    pub prefill_tok_s: f64,
    /// Prompt tokens/s through the serial token-by-token route.
    pub serial_tok_s: f64,
    /// Chunked-over-serial prefill speedup (p50 over p50).
    pub speedup_vs_serial: f64,
    /// Worst per-logit |chunked − serial| on the first-logits step.
    pub logit_maxabs_vs_serial: f64,
    /// Mean next-token NLL drift vs the f32 oracle, nats (0 for f32).
    pub nll_delta_vs_f32: f64,
}

/// One measured point of the `serve` section: a seeded load-generator run
/// through the continuous-batching engine (burst arrivals, so slots
/// genuinely overlap), summarized as occupancy, per-request TTFT/latency/
/// throughput percentiles, and the traffic-model calibration fitted to the
/// engine's per-step `(bytes, seconds)` samples — the serve-side closing
/// of the loop between the analytic model and measured decode latency.
#[derive(Debug, Clone)]
pub struct ServeBenchPoint {
    pub preset: String,
    pub attn: String,
    /// Storage precision of weights + decode state (`f32`/`bf16`/`int8`).
    pub precision: String,
    /// Decode slots the engine ran with.
    pub slots: usize,
    /// Requests submitted by the load run.
    pub requests: usize,
    /// Requests shed by the bounded admission queue.
    pub rejected: usize,
    /// Mean/max occupied slots per decode step.
    pub occupancy_mean: f64,
    pub occupancy_max: usize,
    /// Per-request time-to-first-token percentiles, milliseconds.
    pub ttft_ms_p50: f64,
    pub ttft_ms_p95: f64,
    pub ttft_ms_p99: f64,
    /// Per-request total-latency percentiles, milliseconds.
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    /// Median per-request decode throughput, tokens/s.
    pub decode_tok_s_p50: f64,
    /// Fitted fixed per-step overhead, milliseconds.
    pub fit_overhead_ms: f64,
    /// Fitted effective bandwidth, bytes/s (0 = slope not identifiable).
    pub fit_bytes_per_s: f64,
    /// RMS residual of the fit, milliseconds — how much measured latency
    /// the linear traffic model fails to explain.
    pub fit_rms_residual_ms: f64,
    /// Step samples the fit consumed.
    pub fit_samples: usize,
}

/// Machine-readable perf trajectory artifact (`BENCH_native.json`): one entry
/// per artifact measured on the parallel/tiled path, joined with the scalar
/// single-thread reference baseline for the speedup column, plus the LM
/// per-step section (`lm`, in-place vs rebuild), the AdamW-update
/// microbench (`opt`), the autoregressive decoding section (`decode`,
/// recurrent vs full-recompute), and the prompt-ingestion section
/// (`prefill`, chunked vs serial with TTFT), and the continuous-batching
/// section (`serve`, engine occupancy + request percentiles + traffic-model
/// fit). Times are nanoseconds (median plus p10/p90 spread) for kernels,
/// seconds for LM/optimizer steps.
#[allow(clippy::too_many_arguments)]
pub fn bench_native_json(
    parallel: &[SweepPoint],
    scalar: &[SweepPoint],
    lm: &[LmBenchPoint],
    opt: &[OptBenchPoint],
    decode: &[DecodeBenchPoint],
    prefill: &[PrefillBenchPoint],
    serve: &[ServeBenchPoint],
    threads: usize,
    chunk: usize,
) -> String {
    let arts: Vec<Json> = parallel
        .iter()
        .map(|p| {
            let mut fields = vec![
                ("name", Json::str(p.name.clone())),
                ("impl", Json::str(p.impl_name.clone())),
                ("kind", Json::str(p.kind.clone())),
                ("bh", Json::num(p.bh as f64)),
                ("n", Json::num(p.n as f64)),
                ("d", Json::num(p.d as f64)),
                ("chunk", Json::num(p.chunk as f64)),
                ("median_ns", Json::num(p.cpu_s.p50 * 1e9)),
                ("p10_ns", Json::num(p.cpu_s.p10 * 1e9)),
                ("p90_ns", Json::num(p.cpu_s.p90 * 1e9)),
            ];
            if let Some(s) = scalar.iter().find(|s| s.name == p.name) {
                fields.push(("scalar_median_ns", Json::num(s.cpu_s.p50 * 1e9)));
                if p.cpu_s.p50 > 0.0 {
                    fields.push(("speedup_vs_scalar", Json::num(s.cpu_s.p50 / p.cpu_s.p50)));
                }
            }
            Json::obj(fields)
        })
        .collect();
    let lm_arts: Vec<Json> = lm
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("preset", Json::str(p.preset.clone())),
                ("attn", Json::str(p.attn.clone())),
                ("n_layer", Json::num(p.n_layer as f64)),
                ("n_head", Json::num(p.n_head as f64)),
                ("d_model", Json::num(p.d_model as f64)),
                ("n_params", Json::num(p.n_params as f64)),
                ("steps", Json::num(p.steps as f64)),
                ("tokens_per_step", Json::num(p.tokens_per_step as f64)),
                ("step_s_p50", Json::num(p.step_s_p50)),
                ("step_s_p50_rebuild", Json::num(p.step_s_p50_rebuild)),
                ("speedup_inplace", Json::num(p.speedup_inplace())),
                ("weight_decay", Json::num(p.weight_decay)),
                ("clip_norm", Json::num(p.clip_norm)),
                (
                    "grad_norm_last",
                    if p.grad_norm_last.is_finite() {
                        Json::num(p.grad_norm_last as f64)
                    } else {
                        Json::Null
                    },
                ),
                ("tokens_per_s", Json::num(p.tokens_per_step as f64 / p.step_s_p50.max(1e-12))),
                ("loss_first", Json::num(p.loss_first as f64)),
                ("loss_last", Json::num(p.loss_last as f64)),
            ])
        })
        .collect();
    let opt_arts: Vec<Json> = opt
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("preset", Json::str(p.preset.clone())),
                ("n_params", Json::num(p.n_params as f64)),
                ("n_param_arrays", Json::num(p.n_param_arrays as f64)),
                ("inplace_s_p50", Json::num(p.inplace_s_p50)),
                ("rebuild_s_p50", Json::num(p.rebuild_s_p50)),
                ("speedup_inplace", Json::num(p.speedup_inplace())),
            ])
        })
        .collect();
    let decode_arts: Vec<Json> = decode
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("preset", Json::str(p.preset.clone())),
                ("attn", Json::str(p.attn.clone())),
                ("precision", Json::str(p.precision.clone())),
                ("n_params", Json::num(p.n_params as f64)),
                ("param_bytes", Json::num(p.param_bytes as f64)),
                ("tokens", Json::num(p.tokens as f64)),
                ("recurrent_tok_s", Json::num(p.recurrent_tok_s)),
                ("recompute_tok_s", Json::num(p.recompute_tok_s)),
                ("speedup_recurrent", Json::num(p.speedup_recurrent())),
                ("step_s_p50_first_half", Json::num(p.step_s_p50_first_half)),
                ("step_s_p50_second_half", Json::num(p.step_s_p50_second_half)),
                ("state_bytes_first", Json::num(p.state_bytes_first as f64)),
                ("state_bytes_last", Json::num(p.state_bytes_last as f64)),
                ("state_growth", Json::num(p.state_growth())),
                ("logit_maxabs_vs_f32", Json::num(p.logit_maxabs_vs_f32)),
                ("nll_delta_vs_f32", Json::num(p.nll_delta_vs_f32)),
            ])
        })
        .collect();
    let prefill_arts: Vec<Json> = prefill
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("preset", Json::str(p.preset.clone())),
                ("attn", Json::str(p.attn.clone())),
                ("precision", Json::str(p.precision.clone())),
                ("prompt_tokens", Json::num(p.prompt_tokens as f64)),
                ("chunk", Json::num(p.chunk as f64)),
                ("ttft_ms", Json::num(p.ttft_ms)),
                ("prefill_tok_s", Json::num(p.prefill_tok_s)),
                ("serial_tok_s", Json::num(p.serial_tok_s)),
                ("speedup_vs_serial", Json::num(p.speedup_vs_serial)),
                ("logit_maxabs_vs_serial", Json::num(p.logit_maxabs_vs_serial)),
                ("nll_delta_vs_f32", Json::num(p.nll_delta_vs_f32)),
            ])
        })
        .collect();
    let serve_arts: Vec<Json> = serve
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("preset", Json::str(p.preset.clone())),
                ("attn", Json::str(p.attn.clone())),
                ("precision", Json::str(p.precision.clone())),
                ("slots", Json::num(p.slots as f64)),
                ("requests", Json::num(p.requests as f64)),
                ("rejected", Json::num(p.rejected as f64)),
                ("occupancy_mean", Json::num(p.occupancy_mean)),
                ("occupancy_max", Json::num(p.occupancy_max as f64)),
                ("ttft_ms_p50", Json::num(p.ttft_ms_p50)),
                ("ttft_ms_p95", Json::num(p.ttft_ms_p95)),
                ("ttft_ms_p99", Json::num(p.ttft_ms_p99)),
                ("latency_ms_p50", Json::num(p.latency_ms_p50)),
                ("latency_ms_p95", Json::num(p.latency_ms_p95)),
                ("latency_ms_p99", Json::num(p.latency_ms_p99)),
                ("decode_tok_s_p50", Json::num(p.decode_tok_s_p50)),
                ("fit_overhead_ms", Json::num(p.fit_overhead_ms)),
                ("fit_bytes_per_s", Json::num(p.fit_bytes_per_s)),
                ("fit_rms_residual_ms", Json::num(p.fit_rms_residual_ms)),
                ("fit_samples", Json::num(p.fit_samples as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::str("bench_native/v7")),
        ("threads", Json::num(threads as f64)),
        ("chunk", Json::num(chunk as f64)),
        ("artifacts", Json::Arr(arts)),
        ("lm", Json::Arr(lm_arts)),
        ("opt", Json::Arr(opt_arts)),
        ("decode", Json::Arr(decode_arts)),
        ("prefill", Json::Arr(prefill_arts)),
        ("serve", Json::Arr(serve_arts)),
    ])
    .to_string()
}

/// Human-readable companion of the `serve` section: engine occupancy,
/// request-level percentiles, and the calibrated traffic-model constants.
pub fn bench_serve_markdown(serve: &[ServeBenchPoint]) -> String {
    let mut out = String::from(
        "| preset | attn | prec | slots | reqs | shed | occ mean/max | ttft p50/p95 | \
         latency p50/p95 | tok/s p50 | fit overhead | fit GB/s | fit rms |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for p in serve {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {:.2}/{} | {}/{} | {}/{} | {:.0} | {} | {:.2} | {} |",
            p.preset,
            p.attn,
            p.precision,
            p.slots,
            p.requests,
            p.rejected,
            p.occupancy_mean,
            p.occupancy_max,
            fmt_time(p.ttft_ms_p50 / 1e3),
            fmt_time(p.ttft_ms_p95 / 1e3),
            fmt_time(p.latency_ms_p50 / 1e3),
            fmt_time(p.latency_ms_p95 / 1e3),
            p.decode_tok_s_p50,
            fmt_time(p.fit_overhead_ms / 1e3),
            p.fit_bytes_per_s / 1e9,
            fmt_time(p.fit_rms_residual_ms / 1e3),
        );
    }
    out
}

/// Human-readable companion of the `prefill` section: chunked prompt
/// ingestion rate, TTFT, and the speedup over the serial oracle.
pub fn bench_prefill_markdown(prefill: &[PrefillBenchPoint]) -> String {
    let mut out = String::from(
        "| preset | attn | prec | prompt | chunk | ttft | chunked tok/s | serial tok/s | \
         speedup | Δnll vs f32 |\n|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for p in prefill {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {:.0} | {:.0} | {:.2}× | {:.4} |",
            p.preset,
            p.attn,
            p.precision,
            p.prompt_tokens,
            p.chunk,
            fmt_time(p.ttft_ms / 1e3),
            p.prefill_tok_s,
            p.serial_tok_s,
            p.speedup_vs_serial,
            p.nll_delta_vs_f32,
        );
    }
    out
}

/// Human-readable companion of the `decode` section: recurrent decode rate,
/// the recompute baseline, per-token flatness, the state footprint
/// endpoints, and the quantized-vs-f32 quality drift.
pub fn bench_decode_markdown(decode: &[DecodeBenchPoint]) -> String {
    let mut out = String::from(
        "| preset | attn | prec | tokens | recurrent tok/s | recompute tok/s | speedup | \
         tok cost 1st→2nd half | state 1st→last | params | Δnll vs f32 |\n\
         |---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for p in decode {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {:.0} | {:.0} | {:.1}× | {} → {} | {} → {} ({:.1}×) | {} | {:.4} |",
            p.preset,
            p.attn,
            p.precision,
            p.tokens,
            p.recurrent_tok_s,
            p.recompute_tok_s,
            p.speedup_recurrent(),
            fmt_time(p.step_s_p50_first_half),
            fmt_time(p.step_s_p50_second_half),
            fmt_bytes(p.state_bytes_first as f64),
            fmt_bytes(p.state_bytes_last as f64),
            p.state_growth(),
            fmt_bytes(p.param_bytes as f64),
            p.nll_delta_vs_f32,
        );
    }
    out
}

/// Human-readable companion of the AdamW-update microbench (`opt` section).
pub fn bench_opt_markdown(opt: &[OptBenchPoint]) -> String {
    let mut out = String::from(
        "| preset | params | rebuild p50 | in-place p50 | speedup |\n|---|---|---|---|---|\n",
    );
    for p in opt {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {:.2}× |",
            p.preset,
            p.n_params,
            fmt_time(p.rebuild_s_p50),
            fmt_time(p.inplace_s_p50),
            p.speedup_inplace(),
        );
    }
    out
}

/// Human-readable companion of the LM section of [`bench_native_json`].
pub fn bench_lm_markdown(lm: &[LmBenchPoint]) -> String {
    let mut out = String::from(
        "| preset | attn | layers×heads | params | step p50 | vs rebuild | tok/s | loss (first→last) |\n\
         |---|---|---|---|---|---|---|---|\n",
    );
    for p in lm {
        let _ = writeln!(
            out,
            "| {} | {} | {}×{} | {} | {} | {:.2}× | {:.0} | {:.4} → {:.4} |",
            p.preset,
            p.attn,
            p.n_layer,
            p.n_head,
            p.n_params,
            fmt_time(p.step_s_p50),
            p.speedup_inplace(),
            p.tokens_per_step as f64 / p.step_s_p50.max(1e-12),
            p.loss_first,
            p.loss_last,
        );
    }
    out
}

/// Human-readable companion of [`bench_native_json`].
pub fn bench_native_markdown(parallel: &[SweepPoint], scalar: &[SweepPoint]) -> String {
    let mut out = String::from(
        "| artifact | scalar p50 | parallel p50 | speedup |\n|---|---|---|---|\n",
    );
    for p in parallel {
        let base = scalar.iter().find(|s| s.name == p.name);
        let (scalar_s, speedup) = match base {
            Some(s) if p.cpu_s.p50 > 0.0 => {
                (fmt_time(s.cpu_s.p50), format!("{:.2}×", s.cpu_s.p50 / p.cpu_s.p50))
            }
            Some(s) => (fmt_time(s.cpu_s.p50), "—".to_string()),
            None => ("—".to_string(), "—".to_string()),
        };
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} |",
            p.name,
            scalar_s,
            fmt_time(p.cpu_s.p50),
            speedup
        );
    }
    out
}

/// Fig-2/3 markdown: series grouped per implementation, one row per N (or D).
pub fn sweep_markdown(title: &str, points: &[SweepPoint]) -> String {
    let mut out = format!("### {title}\n\n");
    let _ = writeln!(
        out,
        "| impl | N | D | C | CPU p50 | model (A6000) | model move | mem (model) |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for p in points {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            p.impl_name,
            p.n,
            p.d,
            p.chunk,
            fmt_time(p.cpu_s.p50),
            fmt_time(p.model_total_s),
            fmt_time(p.model_move_s),
            fmt_bytes(p.mem_bytes)
        );
    }
    out
}

/// Table 1: the complexity/latency summary at the paper's point
/// (B=4, H=16 → BH=64, D=128, N=10⁴), fully analytic.
pub fn table1_markdown(model: &TrafficModel) -> String {
    let (bh, n, d) = (64, 10_000, 128);
    let rows: &[(&str, &str, &str, &str, Impl)] = &[
        ("Regular Attention", "exp x", "O(N²D)", "O(N²+ND)", Impl::Softmax),
        ("FlashAttention-2", "exp x", "O(N²D)", "O(ND)", Impl::Flash),
        ("Spec. Decoding LA", "bx", "O(ND²)", "O(ND²)", Impl::SpecDec),
        ("Gated LA", "bx", "O(ND²)", "O(ND)", Impl::Gated),
        ("Our LA", "a+bx", "O(ND²)", "O(ND)", Impl::Ours),
    ];
    let mut out = String::from(
        "| Mechanism | Kernel | Time | Memory (causal) | Fwd time (model) | Fwd memory (model) |\n",
    );
    out.push_str("|---|---|---|---|---|---|\n");
    for (name, kernel, time_c, mem_c, imp) in rows {
        let r: TrafficReport = model.report(*imp, bh, n, d);
        let _ = writeln!(
            out,
            "| {name} | {kernel} | {time_c} | {mem_c} | {} | {} |",
            fmt_time(r.total_s),
            fmt_bytes(r.mem_bytes),
        );
    }
    out
}

/// Fig-4 markdown: movement ratio + movement time per LA implementation
/// across sequence lengths.
pub fn fig4_markdown(model: &TrafficModel, ns: &[usize]) -> String {
    let (bh, d) = (64, 128);
    let mut out = String::from("| impl |");
    for n in ns {
        let _ = write!(out, " ratio@N={n} |");
    }
    for n in ns {
        let _ = write!(out, " move@N={n} |");
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in 0..ns.len() * 2 {
        out.push_str("---|");
    }
    out.push('\n');
    for imp in Impl::la_impls() {
        let _ = write!(out, "| {} |", imp.name());
        for &n in ns {
            let r = model.report(imp, bh, n, d);
            let _ = write!(out, " {:.0}% |", r.move_ratio() * 100.0);
        }
        for &n in ns {
            let r = model.report(imp, bh, n, d);
            let _ = write!(out, " {} |", fmt_time(r.move_s));
        }
        out.push('\n');
    }
    out
}

/// Fig-4 CSV.
pub fn fig4_csv(model: &TrafficModel, ns: &[usize]) -> String {
    let (bh, d) = (64, 128);
    let mut out = String::from("impl,n,move_ratio,move_s,total_s,bytes\n");
    for imp in Impl::la_impls() {
        for &n in ns {
            let r = model.report(imp, bh, n, d);
            let _ = writeln!(
                out,
                "{},{},{:.4},{:.6e},{:.6e},{:.6e}",
                imp.name(),
                n,
                r.move_ratio(),
                r.move_s,
                r.total_s,
                r.bytes
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::DeviceSpec;

    #[test]
    fn units() {
        assert_eq!(fmt_time(2.0), "2.00 s");
        assert_eq!(fmt_time(0.0025), "2.50 ms");
        assert_eq!(fmt_time(2.5e-6), "2.5 µs");
        assert_eq!(fmt_bytes(1.5e9), "1.50 GB");
        assert_eq!(fmt_bytes(2e6), "2.00 MB");
    }

    #[test]
    fn table1_contains_all_rows() {
        let m = TrafficModel::new(DeviceSpec::a6000());
        let t = table1_markdown(&m);
        for name in ["Regular Attention", "FlashAttention-2", "Gated LA", "Our LA"] {
            assert!(t.contains(name), "missing {name}");
        }
        assert_eq!(t.lines().count(), 2 + 5);
    }

    #[test]
    fn bench_native_json_joins_scalar_baseline() {
        use crate::bench::TimingStats;
        let point = |name: &str, secs: f64| SweepPoint {
            name: name.to_string(),
            impl_name: "ours".to_string(),
            kind: "layer_fwd".to_string(),
            bh: 4,
            n: 1024,
            d: 128,
            chunk: 128,
            cpu_s: TimingStats::from_samples(vec![secs, secs, secs]).unwrap(),
            model_total_s: 1.0,
            model_move_s: 0.5,
            model_bytes: 1e6,
            mem_bytes: 1e6,
        };
        let par = vec![point("layer_ours_fwd_n1024_d128", 0.010)];
        let base = vec![point("layer_ours_fwd_n1024_d128", 0.040)];
        let lm = vec![LmBenchPoint {
            preset: "small".into(),
            attn: "ours".into(),
            n_layer: 4,
            n_head: 4,
            d_model: 128,
            n_params: 934_016,
            steps: 6,
            tokens_per_step: 1032,
            step_s_p50: 0.5,
            step_s_p50_rebuild: 0.6,
            weight_decay: 0.01,
            clip_norm: 1.0,
            grad_norm_last: 2.5,
            loss_first: 6.2,
            loss_last: 5.9,
        }];
        let opt = vec![OptBenchPoint {
            preset: "small".into(),
            n_params: 934_016,
            n_param_arrays: 38,
            inplace_s_p50: 0.002,
            rebuild_s_p50: 0.005,
        }];
        let decode = vec![DecodeBenchPoint {
            preset: "small".into(),
            attn: "ours".into(),
            precision: "int8".into(),
            n_params: 934_016,
            param_bytes: 1_100_000,
            tokens: 64,
            recurrent_tok_s: 4000.0,
            recompute_tok_s: 400.0,
            step_s_p50_first_half: 2.5e-4,
            step_s_p50_second_half: 2.5e-4,
            state_bytes_first: 69_632,
            state_bytes_last: 69_632,
            logit_maxabs_vs_f32: 0.03,
            nll_delta_vs_f32: 0.0015,
        }];
        let prefill = vec![PrefillBenchPoint {
            preset: "small".into(),
            attn: "ours".into(),
            precision: "f32".into(),
            prompt_tokens: 4096,
            chunk: 128,
            ttft_ms: 120.0,
            prefill_tok_s: 34_000.0,
            serial_tok_s: 8_500.0,
            speedup_vs_serial: 4.0,
            logit_maxabs_vs_serial: 1.5e-4,
            nll_delta_vs_f32: 0.0,
        }];
        let serve = vec![ServeBenchPoint {
            preset: "small".into(),
            attn: "ours".into(),
            precision: "f32".into(),
            slots: 4,
            requests: 8,
            rejected: 1,
            occupancy_mean: 2.5,
            occupancy_max: 4,
            ttft_ms_p50: 15.0,
            ttft_ms_p95: 40.0,
            ttft_ms_p99: 55.0,
            latency_ms_p50: 80.0,
            latency_ms_p95: 150.0,
            latency_ms_p99: 180.0,
            decode_tok_s_p50: 1200.0,
            fit_overhead_ms: 0.2,
            fit_bytes_per_s: 8.5e9,
            fit_rms_residual_ms: 0.05,
            fit_samples: 96,
        }];
        let text = bench_native_json(&par, &base, &lm, &opt, &decode, &prefill, &serve, 4, 128);
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("bench_native/v7"));
        assert_eq!(v.get("threads").unwrap().as_usize(), Some(4));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let a = &arts[0];
        assert_eq!(a.get("name").unwrap().as_str(), Some("layer_ours_fwd_n1024_d128"));
        let speedup = a.get("speedup_vs_scalar").unwrap().as_f64().unwrap();
        assert!((speedup - 4.0).abs() < 1e-6, "speedup {speedup}");
        assert!((a.get("median_ns").unwrap().as_f64().unwrap() - 1e7).abs() < 1.0);
        let lms = v.get("lm").unwrap().as_arr().unwrap();
        assert_eq!(lms.len(), 1);
        assert_eq!(lms[0].get("preset").unwrap().as_str(), Some("small"));
        assert_eq!(lms[0].get("n_params").unwrap().as_usize(), Some(934_016));
        assert!((lms[0].get("tokens_per_s").unwrap().as_f64().unwrap() - 2064.0).abs() < 1.0);
        assert!((lms[0].get("speedup_inplace").unwrap().as_f64().unwrap() - 1.2).abs() < 1e-9);
        assert_eq!(lms[0].get("weight_decay").unwrap().as_f64(), Some(0.01));
        assert_eq!(lms[0].get("clip_norm").unwrap().as_f64(), Some(1.0));
        let opts = v.get("opt").unwrap().as_arr().unwrap();
        assert_eq!(opts.len(), 1);
        assert!((opts[0].get("speedup_inplace").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        let dec = v.get("decode").unwrap().as_arr().unwrap();
        assert_eq!(dec.len(), 1);
        assert_eq!(dec[0].get("tokens").unwrap().as_usize(), Some(64));
        assert!((dec[0].get("speedup_recurrent").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
        assert!((dec[0].get("state_growth").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert_eq!(dec[0].get("precision").unwrap().as_str(), Some("int8"));
        assert_eq!(dec[0].get("param_bytes").unwrap().as_usize(), Some(1_100_000));
        assert_eq!(dec[0].get("nll_delta_vs_f32").unwrap().as_f64(), Some(0.0015));
        let pre = v.get("prefill").unwrap().as_arr().unwrap();
        assert_eq!(pre.len(), 1);
        assert_eq!(pre[0].get("prompt_tokens").unwrap().as_usize(), Some(4096));
        assert_eq!(pre[0].get("chunk").unwrap().as_usize(), Some(128));
        assert_eq!(pre[0].get("ttft_ms").unwrap().as_f64(), Some(120.0));
        assert_eq!(pre[0].get("prefill_tok_s").unwrap().as_f64(), Some(34_000.0));
        assert!((pre[0].get("speedup_vs_serial").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
        let srv = v.get("serve").unwrap().as_arr().unwrap();
        assert_eq!(srv.len(), 1);
        assert_eq!(srv[0].get("slots").unwrap().as_usize(), Some(4));
        assert_eq!(srv[0].get("requests").unwrap().as_usize(), Some(8));
        assert_eq!(srv[0].get("rejected").unwrap().as_usize(), Some(1));
        assert_eq!(srv[0].get("occupancy_mean").unwrap().as_f64(), Some(2.5));
        assert_eq!(srv[0].get("ttft_ms_p50").unwrap().as_f64(), Some(15.0));
        assert_eq!(srv[0].get("latency_ms_p99").unwrap().as_f64(), Some(180.0));
        assert_eq!(srv[0].get("fit_bytes_per_s").unwrap().as_f64(), Some(8.5e9));
        assert_eq!(srv[0].get("fit_samples").unwrap().as_usize(), Some(96));
        let smd = bench_serve_markdown(&serve);
        assert!(smd.contains("2.50/4"), "serve markdown occupancy:\n{smd}");
        assert!(smd.contains("8.50"), "serve markdown fit GB/s:\n{smd}");
        let pmd = bench_prefill_markdown(&prefill);
        assert!(pmd.contains("4096") && pmd.contains("4.00×"), "prefill markdown:\n{pmd}");
        assert!(pmd.contains("120.00 ms"), "prefill markdown missing ttft:\n{pmd}");
        let dmd = bench_decode_markdown(&decode);
        assert!(dmd.contains("10.0×") && dmd.contains("1.0×"), "decode markdown:\n{dmd}");
        assert!(dmd.contains("int8") && dmd.contains("0.0015"), "decode markdown:\n{dmd}");
        let md = bench_native_markdown(&par, &base);
        assert!(md.contains("4.00×"), "markdown:\n{md}");
        let lmd = bench_lm_markdown(&lm);
        assert!(lmd.contains("small") && lmd.contains("4×4"), "lm markdown:\n{lmd}");
        assert!(lmd.contains("1.20×"), "lm markdown missing speedup:\n{lmd}");
        let omd = bench_opt_markdown(&opt);
        assert!(omd.contains("2.50×"), "opt markdown:\n{omd}");
    }

    #[test]
    fn non_finite_grad_norm_emits_valid_json() {
        let lm = vec![LmBenchPoint {
            preset: "tiny".into(),
            attn: "ours".into(),
            n_layer: 2,
            n_head: 2,
            d_model: 64,
            n_params: 104_000,
            steps: 1,
            tokens_per_step: 520,
            step_s_p50: 0.1,
            step_s_p50_rebuild: 0.1,
            weight_decay: 0.01,
            clip_norm: 1.0,
            grad_norm_last: f32::NAN,
            loss_first: 5.5,
            loss_last: 5.5,
        }];
        let text = bench_native_json(&[], &[], &lm, &[], &[], &[], &[], 1, 128);
        let v = Json::parse(&text).unwrap();
        let lms = v.get("lm").unwrap().as_arr().unwrap();
        assert_eq!(lms[0].get("grad_norm_last"), Some(&Json::Null));
    }

    #[test]
    fn fig4_markdown_and_csv_shape() {
        let m = TrafficModel::new(DeviceSpec::a6000());
        let ns = [2048, 4096];
        let md = fig4_markdown(&m, &ns);
        assert!(md.contains("ours"));
        assert!(md.contains("quadratic"));
        let csv = fig4_csv(&m, &ns);
        assert_eq!(csv.lines().count(), 1 + 4 * ns.len());
    }
}
