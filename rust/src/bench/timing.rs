//! Robust timing: warmup, repetitions, trimmed statistics.

/// Summary statistics over repeated measurements (seconds).
#[derive(Debug, Clone, Copy)]
pub struct TimingStats {
    pub reps: usize,
    /// Non-finite samples (NaN/∞) filtered out before the statistics were
    /// computed — nonzero flags a corrupted measurement, it must not abort
    /// the whole sweep.
    pub dropped: usize,
    pub mean: f64,
    pub trimmed_mean: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl TimingStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Option<Self> {
        let raw = samples.len();
        samples.retain(|x| x.is_finite());
        let dropped = raw - samples.len();
        if samples.is_empty() {
            return None;
        }
        // total order — a NaN slipping past the filter must never panic here
        samples.sort_by(f64::total_cmp);
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        // drop top/bottom ≥10% (at least one sample each side when n ≥ 3)
        let cut = if n >= 3 { (n / 10).max(1) } else { 0 };
        let core = &samples[cut..n - cut];
        let trimmed = core.iter().sum::<f64>() / core.len() as f64;
        let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
        Some(Self {
            reps: n,
            dropped,
            mean,
            trimmed_mean: trimmed,
            p10: pct(0.10),
            p50: pct(0.50),
            p90: pct(0.90),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
        })
    }

    /// Relative spread — the harness aims for < 5% jitter (DESIGN.md §Perf).
    pub fn jitter(&self) -> f64 {
        if self.p50 == 0.0 {
            0.0
        } else {
            (self.p95 - self.p50) / self.p50
        }
    }
}

/// Measure `f` with `warmup` explicit throwaway calls (cold caches, page
/// faults, and lazy one-time setup land here, not in the samples) followed
/// by `reps` recorded samples.
pub fn measure<F: FnMut() -> anyhow::Result<f64>>(
    warmup: usize,
    reps: usize,
    mut f: F,
) -> anyhow::Result<TimingStats> {
    for _ in 0..warmup {
        f()?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        samples.push(f()?);
    }
    TimingStats::from_samples(samples)
        .ok_or_else(|| anyhow::anyhow!("no samples collected"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_samples() {
        let s = TimingStats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 100.0]).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 3.0);
        // p10 rounds to the lowest sample, p90/p99 to the highest of five
        assert_eq!(s.p10, 1.0);
        assert_eq!(s.p90, 100.0);
        assert_eq!(s.p99, 100.0);
        assert!(s.p10 <= s.p50 && s.p50 <= s.p90);
        assert!((s.mean - 22.0).abs() < 1e-9);
        // trimmed mean must be robust to the 100.0 outlier vs the raw mean
        assert!(s.trimmed_mean < s.mean);
    }

    #[test]
    fn empty_is_none() {
        assert!(TimingStats::from_samples(vec![]).is_none());
    }

    #[test]
    fn non_finite_samples_are_filtered_not_fatal() {
        // a NaN in the middle used to abort the whole sweep via
        // sort_by(partial_cmp().unwrap())
        let s = TimingStats::from_samples(vec![2.0, f64::NAN, 1.0, f64::INFINITY, 3.0]).unwrap();
        assert_eq!(s.reps, 3);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.mean.is_finite() && s.trimmed_mean.is_finite());
        // all-non-finite collapses to None instead of panicking
        assert!(TimingStats::from_samples(vec![f64::NAN, f64::NAN]).is_none());
    }

    #[test]
    fn measure_counts_reps() {
        let mut calls = 0;
        let s = measure(2, 5, || {
            calls += 1;
            Ok(0.001)
        })
        .unwrap();
        assert_eq!(calls, 7);
        assert_eq!(s.reps, 5);
        assert!(s.jitter() < 1e-9);
    }
}
