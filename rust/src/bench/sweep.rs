//! Sweep driver: execute the per-(impl, N, D) layer artifacts and join the
//! measured wall-clock with the analytic traffic/memory model.

use anyhow::Result;

use crate::runtime::{Engine, Tensor};
use crate::simulator::{DeviceSpec, Impl, TrafficModel};

use super::timing::{measure, TimingStats};

/// One measured point of a Fig-2/3 series.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Artifact name the point was measured from.
    pub name: String,
    pub impl_name: String,
    pub kind: String,
    pub bh: usize,
    pub n: usize,
    pub d: usize,
    /// Sequence chunk length of chunked implementations (0 = n/a).
    pub chunk: usize,
    /// Measured CPU execution time (whichever backend is active).
    pub cpu_s: TimingStats,
    /// Analytic A6000 model for the same point.
    pub model_total_s: f64,
    pub model_move_s: f64,
    pub model_bytes: f64,
    /// Analytic peak memory (bytes) — the paper's memory panels.
    pub mem_bytes: f64,
}

/// Runs layer artifacts for a set of implementations.
pub struct SweepRunner<'e> {
    engine: &'e Engine,
    model: TrafficModel,
    pub warmup: usize,
    pub reps: usize,
    /// Skip artifacts whose input+output footprint exceeds this many bytes
    /// (protects small hosts from the quadratic baselines at large N).
    pub max_bytes: usize,
    /// Skip artifacts above this sequence length (`usize::MAX` = no cap);
    /// lets CI smoke runs stay fast without a separate artifact set.
    pub max_n: usize,
}

impl<'e> SweepRunner<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        Self {
            engine,
            model: TrafficModel::new(DeviceSpec::a6000()),
            warmup: 2,
            reps: 5,
            max_bytes: 8 << 30,
            max_n: usize::MAX,
        }
    }

    /// Deterministic inputs for a layer artifact: normalized q, k; plain v
    /// (and upstream gradient for fwdbwd artifacts).
    fn inputs(&self, name: &str) -> Result<Vec<Tensor>> {
        let meta = self.engine.manifest.get(name)?;
        let mut tensors = Vec::with_capacity(meta.inputs.len());
        for (i, spec) in meta.inputs.iter().enumerate() {
            let mut t = Tensor::randn(spec.shape.clone(), 0x5EED + i as u64);
            if i < 2 {
                t.normalize_rows(); // q, k — paper §3.3
            }
            tensors.push(t);
        }
        Ok(tensors)
    }

    /// Measure one artifact; `kind` is `layer_fwd` or `layer_fwdbwd`.
    pub fn run_artifact(&self, name: &str) -> Result<SweepPoint> {
        let exe = self.engine.load(name)?;
        let meta = exe.meta.clone();
        let inputs = self.inputs(name)?;
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let stats = measure(self.warmup, self.reps, || {
            let (_out, secs) = exe.run_timed(&refs)?;
            Ok(secs)
        })?;
        let impl_name = meta.implementation().unwrap_or("?").to_string();
        let (bh, n, d) = (
            meta.bh.unwrap_or(0),
            meta.n.unwrap_or(0),
            meta.d.unwrap_or(0),
        );
        let imp = Impl::from_name(&impl_name).unwrap_or(Impl::Ours);
        let rep = self.model.report(imp, bh, n, d);
        // backward ≈ 2× forward traffic (two scans) in the analytic model
        let bwd_scale = if meta.kind == "layer_fwdbwd" { 3.0 } else { 1.0 };
        Ok(SweepPoint {
            name: name.to_string(),
            impl_name,
            kind: meta.kind.clone(),
            bh,
            n,
            d,
            chunk: meta.chunk.unwrap_or(0),
            cpu_s: stats,
            model_total_s: rep.total_s * bwd_scale,
            model_move_s: rep.move_s * bwd_scale,
            model_bytes: rep.bytes * bwd_scale,
            mem_bytes: self.model.memory_bytes(imp, bh, n, d) * bwd_scale.min(2.0),
        })
    }

    /// Whether an artifact fits the host budget.
    pub fn fits(&self, name: &str) -> bool {
        self.engine
            .manifest
            .get(name)
            .map(|m| {
                if m.n.unwrap_or(0) > self.max_n {
                    return false;
                }
                let io: usize = m
                    .inputs
                    .iter()
                    .chain(m.outputs.iter())
                    .map(|s| s.size_bytes())
                    .sum();
                // The native quadratic/softmax kernels are tile-blocked
                // (O(64²) score tiles per worker) and never materialize an
                // N×N buffer; charge one score row per sequence position as
                // a conservative stand-in for per-worker scratch. Non-native
                // backends (pjrt) may materialize more — revisit if a dense
                // N×N HLO path is ever benched through this guard.
                let intermediate = match (m.implementation(), m.n) {
                    (Some("quadratic" | "specdec" | "softmax"), Some(n)) => {
                        m.bh.unwrap_or(1) * n * 4
                    }
                    _ => 0,
                };
                io + intermediate < self.max_bytes
            })
            .unwrap_or(false)
    }

    /// Run the full sweep for one (kind, impl) series, ordered by (N, D).
    pub fn run_series(&self, kind: &str, impl_name: &str) -> Result<Vec<SweepPoint>> {
        let names: Vec<String> = self
            .engine
            .manifest
            .layer_sweep(kind, impl_name)
            .iter()
            .map(|(name, _)| (*name).clone())
            .collect();
        let mut out = Vec::new();
        for name in names {
            if !self.fits(&name) {
                continue;
            }
            out.push(self.run_artifact(&name)?);
        }
        Ok(out)
    }
}
