//! L3 coordinator: configuration, training loop, checkpoints, metrics.
//!
//! The paper's contribution lives in the L1 kernel, so the coordinator is the
//! *driver framework around it*: it owns process lifecycle, the data pipeline,
//! the step loop over the `lm_*_train_step` artifact, learning-rate /
//! schedule bookkeeping, checkpointing, and metrics emission (JSONL + CSV for
//! the Fig-5 learning curves).

#![forbid(unsafe_code)]

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use checkpoint::{
    load_any, Checkpoint, CheckpointMeta, LoadedCheckpoint, QuantCheckpoint,
    PARAM_LAYOUT_VERSION, QUANT_PARAM_LAYOUT_VERSION,
};
pub use config::{RunConfig, TrainSection};
pub use metrics::{MetricsLog, StepRecord};
pub use schedule::CosineSchedule;
pub use trainer::{StepMetrics, TrainOutcome, Trainer};
