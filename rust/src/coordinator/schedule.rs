//! Host-side mirror of the cosine warmup/decay schedule baked into the
//! train-step artifact — used for logging and plan estimation (the authoritative
//! schedule runs inside the HLO; `python/tests/test_train.py` cross-checks).

/// Cosine warmup → decay between `lr_min` and `lr_max` (paper §5.2).
#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub lr_max: f64,
    pub lr_min: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
}

impl CosineSchedule {
    pub fn new(lr_max: f64, lr_min: f64, warmup_steps: usize, total_steps: usize) -> Self {
        Self { lr_max, lr_min, warmup_steps, total_steps }
    }

    /// Paper defaults: max 1e-3, min 5e-5.
    pub fn paper_defaults(warmup_steps: usize, total_steps: usize) -> Self {
        Self::new(1e-3, 5e-5, warmup_steps, total_steps)
    }

    /// Learning rate at 0-based `step` — must match `train.lr_at_step`.
    pub fn lr(&self, step: usize) -> f64 {
        let s = step as f64;
        if step < self.warmup_steps {
            return self.lr_max * s / (self.warmup_steps.max(1) as f64);
        }
        let span = (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let frac = ((s - self.warmup_steps as f64) / span).clamp(0.0, 1.0);
        self.lr_min + 0.5 * (self.lr_max - self.lr_min) * (1.0 + (std::f64::consts::PI * frac).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_rises_linearly() {
        let s = CosineSchedule::paper_defaults(10, 100);
        assert_eq!(s.lr(0), 0.0);
        assert!((s.lr(5) - 5e-4).abs() < 1e-12);
        assert!(s.lr(9) < s.lr_max);
    }

    #[test]
    fn peak_at_warmup_end_then_decays_to_min() {
        let s = CosineSchedule::paper_defaults(10, 100);
        assert!((s.lr(10) - 1e-3).abs() < 1e-12);
        assert!(s.lr(50) < s.lr(10));
        assert!((s.lr(100) - 5e-5).abs() < 1e-9);
        assert!((s.lr(500) - 5e-5).abs() < 1e-9); // clamps past the end
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = CosineSchedule::paper_defaults(20, 200);
        let mut prev = f64::INFINITY;
        for step in 20..=200 {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-15, "step {step}");
            prev = lr;
        }
    }
}
