//! The training loop: drive the `lm_*` artifacts from Rust.
//!
//! Per step: pull a batch from the [`Batcher`], execute the train-step
//! artifact through the **owned-state** route
//! ([`Executable::run_owned`]: state is mutated in place, the step returns
//! loss + pre-clip grad norm), log metrics, and periodically evaluate /
//! checkpoint. On the native backend the `params ++ m ++ v` buffers are
//! updated with zero per-step state allocation; other backends transparently
//! fall back to execute-and-write-back. Checkpoints serialize straight from
//! borrows of the live state ([`Checkpoint::write`]), never from a clone.

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::{Batcher, ByteTokenizer, CorpusConfig, CorpusGenerator, PackedDataset, Split};
use crate::runtime::{Engine, Executable, Tensor};

use super::checkpoint::{Checkpoint, CheckpointMeta, PARAM_LAYOUT_VERSION};
use super::config::RunConfig;
use super::metrics::{MetricsLog, StepRecord};
use super::schedule::CosineSchedule;

/// Metrics reported by one optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub loss: f32,
    /// Global gradient norm *before* clipping — the divergence early-warning
    /// signal the run logs alongside the loss.
    pub grad_norm: f32,
}

/// Result summary of a training run.
#[derive(Debug)]
pub struct TrainOutcome {
    pub final_loss: f32,
    pub final_val_loss: Option<f32>,
    pub steps: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub run_dir: PathBuf,
}

/// Orchestrates one end-to-end training run.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    step_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    init_exe: Rc<Executable>,
    n_param_arrays: usize,
    batch: usize,
    seq_len: usize,
    schedule: CosineSchedule,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let tag = cfg.artifact_tag();
        let step_exe = engine
            .load(&format!("{tag}_train_step"))
            .with_context(|| format!("loading train-step artifact for {tag}"))?;
        let eval_exe = engine.load(&format!("{tag}_eval"))?;
        let init_exe = engine.load(&format!("{tag}_init"))?;

        let meta = &step_exe.meta;
        let n_param_arrays = meta
            .n_param_arrays
            .ok_or_else(|| anyhow!("artifact missing n_param_arrays"))?;
        let batch = meta.batch.ok_or_else(|| anyhow!("artifact missing batch"))?;
        let n_ctx = meta
            .model_field_usize("n_ctx")
            .ok_or_else(|| anyhow!("artifact missing model.n_ctx"))?;
        let schedule = CosineSchedule::new(
            meta.train_field_f64("lr_max").unwrap_or(1e-3),
            meta.train_field_f64("lr_min").unwrap_or(5e-5),
            meta.train_field_f64("warmup_steps").unwrap_or(50.0) as usize,
            meta.train_field_f64("total_steps").unwrap_or(500.0) as usize,
        );
        Ok(Self {
            engine,
            cfg,
            step_exe,
            eval_exe,
            init_exe,
            n_param_arrays,
            batch,
            seq_len: n_ctx,
            schedule,
        })
    }

    /// Vocabulary size baked into the artifact (tokenizer must match).
    pub fn vocab_size(&self) -> usize {
        self.step_exe.meta.model_field_usize("vocab_size").unwrap_or(256)
    }

    /// True scalar parameter count baked into the artifact (0 if the
    /// manifest predates the field).
    pub fn n_params(&self) -> u64 {
        self.step_exe.meta.n_params.unwrap_or(0)
    }

    pub fn n_param_arrays(&self) -> usize {
        self.n_param_arrays
    }

    /// Model-section field of the train-step artifact (n_layer, n_head, …).
    pub fn model_field(&self, key: &str) -> Option<usize> {
        self.step_exe.meta.model_field_usize(key)
    }

    /// One-line model summary (parameter count, depth, heads) for startup
    /// logs and bench manifests.
    pub fn model_summary(&self) -> String {
        let meta = &self.step_exe.meta;
        format!(
            "{}: {} params in {} arrays ({} layers × {} heads, d_model {}, vocab {})",
            self.cfg.artifact_tag(),
            self.n_params(),
            self.n_param_arrays,
            meta.model_field_usize("n_layer").unwrap_or(1),
            meta.model_field_usize("n_head").unwrap_or(1),
            meta.model_field_usize("d_model").unwrap_or(0),
            self.vocab_size(),
        )
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Train-section field of the train-step artifact (weight_decay,
    /// clip_norm, corpus_bytes, …).
    pub fn train_field(&self, key: &str) -> Option<f64> {
        self.step_exe.meta.train_field_f64(key)
    }

    /// Corpus size this run trains on: the run config's explicit
    /// `data.corpus_bytes`, or — when left on auto (0) — the preset-scaled
    /// hint baked into the artifact manifest.
    pub fn corpus_bytes(&self) -> usize {
        if self.cfg.data.corpus_bytes > 0 {
            return self.cfg.data.corpus_bytes;
        }
        self.train_field("corpus_bytes")
            .map(|b| b as usize)
            .unwrap_or(crate::data::DEFAULT_CORPUS_BYTES)
    }

    /// Build the synthetic dataset matching this model's tokenizer contract.
    pub fn build_dataset(&self) -> Result<(ByteTokenizer, PackedDataset)> {
        let corpus = CorpusGenerator::new(CorpusConfig {
            seed: self.cfg.train.seed,
            target_bytes: self.corpus_bytes(),
            ..Default::default()
        })
        .generate();
        let vocab = self.vocab_size();
        // the canonical seed-keyed construction, shared with the inference
        // path: merges always train on the same fixed-size corpus prefix
        // regardless of this run's `corpus_bytes`, so the tokenizer a
        // checkpoint implies is reconstructible from (vocab, seed) alone —
        // a run with a custom corpus size must not silently produce a
        // tokenizer that `generate`/`serve` cannot rebuild
        let tokenizer = ByteTokenizer::for_artifact(vocab, self.cfg.train.seed)?;
        let tokens = tokenizer.encode(&corpus);
        let ds = PackedDataset::pack(&tokens, self.seq_len, self.cfg.data.val_frac,
                                     self.cfg.train.seed)?;
        Ok((tokenizer, ds))
    }

    /// Initialize the training state via the init artifact.
    pub fn init_state(&self) -> Result<Vec<Tensor>> {
        let seed = Tensor::scalar_i32(self.cfg.train.seed as i32);
        self.init_exe.run(&[seed])
    }

    /// Run the configured number of steps; writes metrics + checkpoints into
    /// `<output.dir>/<tag>/`.
    pub fn run(&self) -> Result<TrainOutcome> {
        eprintln!("model {}", self.model_summary());
        let (_tok, ds) = self.build_dataset()?;
        let mut batcher = Batcher::new(&ds, Split::Train, self.batch, self.cfg.train.seed)?;
        let mut val_batcher = Batcher::new(&ds, Split::Val, self.batch, self.cfg.train.seed)
            .ok();

        let run_dir = PathBuf::from(&self.cfg.output.dir).join(self.cfg.artifact_tag());
        std::fs::create_dir_all(&run_dir)?;

        let mut state = self.init_state()?;
        let mut log = MetricsLog::new();
        let t_start = Instant::now();
        let tokens_per_step = self.batch * (self.seq_len + 1);

        let mut last_loss = f32::NAN;
        let mut last_val: Option<f32> = None;
        for step in 0..self.cfg.train.steps {
            let t_step = Instant::now();
            let batch = batcher.next_batch()?;
            let m = self.step(&mut state, &batch, step)?;
            last_loss = m.loss;
            if !m.loss.is_finite() {
                bail!("loss diverged (non-finite) at step {step}");
            }

            let do_eval = self.cfg.train.eval_every > 0
                && (step + 1) % self.cfg.train.eval_every == 0;
            if do_eval {
                if let Some(vb) = val_batcher.as_mut() {
                    last_val = Some(self.eval(&state, &vb.next_batch()?)?);
                }
            }
            log.push(StepRecord {
                step,
                loss: m.loss,
                wall_s: t_start.elapsed().as_secs_f64(),
                step_s: t_step.elapsed().as_secs_f64(),
                lr: self.schedule.lr(step),
                tokens: tokens_per_step,
                val_loss: if do_eval { last_val } else { None },
                grad_norm: Some(m.grad_norm),
            });

            if self.cfg.train.ckpt_every > 0 && (step + 1) % self.cfg.train.ckpt_every == 0 {
                self.save_checkpoint(&state, step, m.loss,
                                     &run_dir.join(format!("step{:06}.ckpt", step + 1)))?;
            }
        }

        let wall = t_start.elapsed().as_secs_f64();
        // a zero-step run still writes the initial state (step stays 0)
        self.save_checkpoint(&state, self.cfg.train.steps.saturating_sub(1), last_loss,
                             &run_dir.join("final.ckpt"))?;
        log.write_jsonl(run_dir.join("metrics.jsonl"))?;
        log.write_csv(run_dir.join("metrics.csv"))?;

        Ok(TrainOutcome {
            final_loss: last_loss,
            final_val_loss: last_val,
            steps: self.cfg.train.steps,
            wall_s: wall,
            tokens_per_s: log.tokens_per_second().unwrap_or(0.0),
            run_dir,
        })
    }

    /// Execute one optimizer step through the owned-state route: `state` is
    /// updated in place (no per-step state reallocation on the native
    /// backend); returns the step metrics.
    pub fn step(
        &self,
        state: &mut [Tensor],
        batch: &Tensor,
        step: usize,
    ) -> Result<StepMetrics> {
        let step_t = Tensor::scalar_i32(step as i32);
        let out = self.step_exe.run_owned(state, &[batch, &step_t])?;
        if out.len() != 2 {
            bail!(
                "train_step returned {} auxiliary outputs (expected loss + grad_norm)",
                out.len()
            );
        }
        Ok(StepMetrics { loss: out[0].scalar()?, grad_norm: out[1].scalar()? })
    }

    /// The preserved rebuild route: same step, but the backend returns a
    /// freshly-allocated state vector. Kept as the in-place path's parity
    /// oracle and the `bench-native` speedup baseline.
    pub fn step_rebuild(
        &self,
        state: Vec<Tensor>,
        batch: &Tensor,
        step: usize,
    ) -> Result<(StepMetrics, Vec<Tensor>)> {
        let step_t = Tensor::scalar_i32(step as i32);
        let mut args: Vec<&Tensor> = state.iter().collect();
        args.push(batch);
        args.push(&step_t);
        let mut out = self.step_exe.run_refs(&args)?;
        if out.len() != 2 + state.len() {
            bail!(
                "train_step returned {} outputs (expected {})",
                out.len(),
                2 + state.len()
            );
        }
        let loss = out.remove(0).scalar()?;
        let grad_norm = out.remove(0).scalar()?;
        Ok((StepMetrics { loss, grad_norm }, out))
    }

    /// Evaluate held-out loss on one batch.
    pub fn eval(&self, state: &[Tensor], batch: &Tensor) -> Result<f32> {
        let mut args: Vec<&Tensor> = state[..self.n_param_arrays].iter().collect();
        args.push(batch);
        let out = self.eval_exe.run_refs(&args)?;
        out[0].scalar()
    }

    fn save_checkpoint(
        &self,
        state: &[Tensor],
        step: usize,
        loss: f32,
        path: &PathBuf,
    ) -> Result<()> {
        // serialize straight from the borrowed live state — no full-state
        // clone per checkpoint
        Checkpoint::write(
            path,
            &CheckpointMeta {
                artifact_tag: self.cfg.artifact_tag(),
                step,
                loss,
                seed: self.cfg.train.seed,
                layout: PARAM_LAYOUT_VERSION,
            },
            state,
        )
    }

    /// Restore a checkpoint into trainer state (resume support). Rejects
    /// checkpoints from a different artifact, an older parameter layout, or
    /// with state tensors that don't match the train-step contract — a
    /// mismatched state must never be silently fed to the optimizer.
    pub fn restore(&self, ckpt: &Checkpoint) -> Result<Vec<Tensor>> {
        if ckpt.meta.artifact_tag != self.cfg.artifact_tag() {
            bail!(
                "checkpoint is for {:?}, trainer is {:?}",
                ckpt.meta.artifact_tag,
                self.cfg.artifact_tag()
            );
        }
        ckpt.meta.require_current_layout()?;
        // the train-step artifact's leading inputs are exactly the state
        let specs = &self.step_exe.meta.inputs;
        let n_state = 3 * self.n_param_arrays;
        if ckpt.state.len() != n_state {
            bail!(
                "checkpoint carries {} state arrays, artifact {:?} wants {}",
                ckpt.state.len(),
                self.cfg.artifact_tag(),
                n_state
            );
        }
        for (i, (t, spec)) in ckpt.state.iter().zip(specs).enumerate() {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "checkpoint state array {i} has shape {:?}, artifact wants {:?}",
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(ckpt.state.clone())
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }
}
