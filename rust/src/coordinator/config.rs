//! TOML run configuration for the `repro` launcher (in-tree TOML subset).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::tomlmini::{self, TomlDoc};

/// `[train]` section.
#[derive(Debug, Clone)]
pub struct TrainSection {
    /// Model preset tag as baked by aot.py ("tiny", "small", ...).
    pub preset: String,
    /// Attention implementation ("ours" | "gated" | "softmax").
    pub attn: String,
    /// Number of optimizer steps to run.
    pub steps: usize,
    /// Evaluate on the val split every `eval_every` steps (0 = never).
    pub eval_every: usize,
    /// Checkpoint every `ckpt_every` steps (0 = only at the end).
    pub ckpt_every: usize,
    /// RNG seed (init artifact + data order).
    pub seed: u64,
}

/// `[data]` section.
#[derive(Debug, Clone)]
pub struct DataSection {
    /// Corpus size in bytes to synthesize; 0 = auto (the preset-scaled hint
    /// from the artifact manifest — bigger presets generate bigger corpora).
    pub corpus_bytes: usize,
    /// Validation fraction.
    pub val_frac: f64,
}

impl Default for DataSection {
    fn default() -> Self {
        Self { corpus_bytes: 0, val_frac: 0.05 }
    }
}

/// `[output]` section.
#[derive(Debug, Clone)]
pub struct OutputSection {
    /// Run directory for metrics + checkpoints.
    pub dir: String,
}

impl Default for OutputSection {
    fn default() -> Self {
        Self { dir: "runs".to_string() }
    }
}

/// Full launcher config.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub train: TrainSection,
    pub data: DataSection,
    pub output: OutputSection,
}

impl RunConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc: TomlDoc = tomlmini::parse(text).context("parsing run config")?;
        let train = doc.get("train").context("missing [train] section")?;
        let gets = |k: &str| train.get(k).and_then(|v| v.as_str().map(str::to_string));
        let getu = |k: &str, d: usize| train.get(k).and_then(|v| v.as_usize()).unwrap_or(d);
        let cfg = RunConfig {
            train: TrainSection {
                preset: gets("preset").context("train.preset is required")?,
                attn: gets("attn").context("train.attn is required")?,
                steps: train
                    .get("steps")
                    .and_then(|v| v.as_usize())
                    .context("train.steps is required")?,
                eval_every: getu("eval_every", 50),
                ckpt_every: getu("ckpt_every", 0),
                seed: train.get("seed").and_then(|v| v.as_u64()).unwrap_or(0),
            },
            data: {
                let mut d = DataSection::default();
                if let Some(sec) = doc.get("data") {
                    if let Some(v) = sec.get("corpus_bytes").and_then(|v| v.as_usize()) {
                        d.corpus_bytes = v;
                    }
                    if let Some(v) = sec.get("val_frac").and_then(|v| v.as_f64()) {
                        d.val_frac = v;
                    }
                }
                d
            },
            output: {
                let mut o = OutputSection::default();
                if let Some(sec) = doc.get("output") {
                    if let Some(v) = sec.get("dir").and_then(|v| v.as_str()) {
                        o.dir = v.to_string();
                    }
                }
                o
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_toml(&text)
    }

    pub fn validate(&self) -> Result<()> {
        const ATTNS: &[&str] = &["ours", "gated", "softmax"];
        if !ATTNS.contains(&self.train.attn.as_str()) {
            bail!("train.attn must be one of {ATTNS:?}, got {:?}", self.train.attn);
        }
        // steps == 0 is legal: the run saves the freshly-initialized state
        // and exits (useful for producing an init checkpoint)
        if !(0.0..1.0).contains(&self.data.val_frac) {
            bail!("data.val_frac must be in [0, 1)");
        }
        Ok(())
    }

    /// Artifact name prefix, e.g. `lm_small_ours`.
    pub fn artifact_tag(&self) -> String {
        format!("lm_{}_{}", self.train.preset.trim_start_matches("lm-"), self.train.attn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        [train]
        preset = "small"
        attn = "ours"
        steps = 200
        eval_every = 25

        [data]
        corpus_bytes = 1048576

        [output]
        dir = "runs/demo"
    "#;

    #[test]
    fn parses_and_validates() {
        let c = RunConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(c.train.steps, 200);
        assert_eq!(c.artifact_tag(), "lm_small_ours");
        assert_eq!(c.data.val_frac, 0.05); // default
        assert_eq!(c.data.corpus_bytes, 1048576);
        assert_eq!(c.output.dir, "runs/demo");
    }

    #[test]
    fn rejects_bad_attn() {
        let bad = SAMPLE.replace("\"ours\"", "\"mamba\"");
        assert!(RunConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn zero_steps_is_a_valid_init_only_run() {
        let zero = SAMPLE.replace("steps = 200", "steps = 0");
        let c = RunConfig::from_toml(&zero).unwrap();
        assert_eq!(c.train.steps, 0);
    }

    #[test]
    fn defaults_fill_in() {
        let min = "[train]\npreset = \"tiny\"\nattn = \"softmax\"\nsteps = 1";
        let c = RunConfig::from_toml(min).unwrap();
        assert_eq!(c.output.dir, "runs");
        assert_eq!(c.train.eval_every, 50);
        // corpus size defaults to auto (preset-scaled)
        assert_eq!(c.data.corpus_bytes, 0);
    }
}
