//! Training metrics: in-memory history + JSONL/CSV emission (Fig 5 series).

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One optimizer step's record.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    /// Seconds since the start of training (wall clock — the Fig-5 x-axis).
    pub wall_s: f64,
    /// Seconds spent in this step (artifact execute + sync).
    pub step_s: f64,
    /// Learning rate according to the host-side schedule mirror.
    pub lr: f64,
    /// Tokens consumed in this step.
    pub tokens: usize,
    /// Validation loss, when measured at this step.
    pub val_loss: Option<f32>,
    /// Pre-clip global gradient norm, when the artifact reports one (absent
    /// in logs written before the AdamW refactor).
    pub grad_norm: Option<f32>,
}

impl StepRecord {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(self.loss as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("step_s", Json::num(self.step_s)),
            ("lr", Json::num(self.lr)),
            ("tokens", Json::num(self.tokens as f64)),
        ];
        if let Some(v) = self.val_loss {
            pairs.push(("val_loss", Json::num(v as f64)));
        }
        if let Some(g) = self.grad_norm {
            // guard the JSONL against a non-finite norm from a diverged step
            pairs.push(("grad_norm", if g.is_finite() { Json::num(g as f64) } else { Json::Null }));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.req(k)?.as_f64().ok_or_else(|| anyhow!("bad field {k:?}"))
        };
        Ok(Self {
            step: num("step")? as usize,
            loss: num("loss")? as f32,
            wall_s: num("wall_s")?,
            step_s: num("step_s")?,
            lr: num("lr")?,
            tokens: num("tokens")? as usize,
            val_loss: v.get("val_loss").and_then(Json::as_f64).map(|x| x as f32),
            grad_norm: v.get("grad_norm").and_then(Json::as_f64).map(|x| x as f32),
        })
    }
}

/// Append-only metrics log.
#[derive(Debug, Default)]
pub struct MetricsLog {
    records: Vec<StepRecord>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.records.push(rec);
    }

    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the final `k` steps (convergence summary).
    pub fn tail_mean_loss(&self, k: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(k)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Aggregate tokens/second across the run.
    pub fn tokens_per_second(&self) -> Option<f64> {
        let total_tokens: usize = self.records.iter().map(|r| r.tokens).sum();
        let wall = self.records.last()?.wall_s;
        if wall <= 0.0 {
            return None;
        }
        Some(total_tokens as f64 / wall)
    }

    /// Write one JSON object per line.
    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        for r in &self.records {
            writeln!(f, "{}", r.to_json().to_string())?;
        }
        Ok(())
    }

    /// Write the Fig-5 CSV: step,wall_s,loss,val_loss,lr,tokens,grad_norm.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        writeln!(f, "step,wall_s,loss,val_loss,lr,tokens,grad_norm")?;
        for r in &self.records {
            let val = r.val_loss.map(|v| v.to_string()).unwrap_or_default();
            let gn = r.grad_norm.map(|g| g.to_string()).unwrap_or_default();
            writeln!(
                f,
                "{},{:.3},{},{},{:.6e},{},{}",
                r.step, r.wall_s, r.loss, val, r.lr, r.tokens, gn
            )?;
        }
        Ok(())
    }

    /// Load back a JSONL file (report generation).
    pub fn read_jsonl(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let mut log = Self::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            log.push(StepRecord::from_json(&Json::parse(line)?)?);
        }
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> StepRecord {
        StepRecord {
            step,
            loss,
            wall_s: step as f64 * 0.5,
            step_s: 0.5,
            lr: 1e-3,
            tokens: 1024,
            val_loss: if step % 2 == 0 { Some(loss + 0.1) } else { None },
            grad_norm: Some(0.5),
        }
    }

    #[test]
    fn tail_mean_and_throughput() {
        let mut log = MetricsLog::new();
        for i in 1..=10 {
            log.push(rec(i, 11.0 - i as f32));
        }
        assert_eq!(log.last_loss(), Some(1.0));
        let tm = log.tail_mean_loss(2).unwrap();
        assert!((tm - 1.5).abs() < 1e-6);
        let tps = log.tokens_per_second().unwrap();
        assert!((tps - 10.0 * 1024.0 / 5.0).abs() < 1e-6);
    }

    #[test]
    fn jsonl_roundtrip() {
        let mut log = MetricsLog::new();
        log.push(rec(1, 5.0));
        log.push(rec(2, 4.0));
        let dir = std::env::temp_dir().join("repro_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.jsonl");
        log.write_jsonl(&p).unwrap();
        let back = MetricsLog::read_jsonl(&p).unwrap();
        assert_eq!(back.records().len(), 2);
        assert_eq!(back.records()[1].loss, 4.0);
        assert_eq!(back.records()[0].val_loss, None);
        assert_eq!(back.records()[1].val_loss, Some(4.1));
        assert_eq!(back.records()[0].grad_norm, Some(0.5));
    }

    #[test]
    fn non_finite_grad_norm_keeps_jsonl_parseable() {
        let mut r = rec(1, 5.0);
        r.grad_norm = Some(f32::INFINITY);
        let line = r.to_json().to_string();
        let back = StepRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.grad_norm, None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = MetricsLog::new();
        log.push(rec(1, 5.0));
        let dir = std::env::temp_dir().join("repro_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        log.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("step,wall_s,loss"));
        assert_eq!(text.lines().count(), 2);
    }
}
