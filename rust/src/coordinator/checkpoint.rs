//! Checkpoints: the flat training state (params ++ adam_m ++ adam_v) on disk.
//!
//! Format (little-endian, version-tagged):
//!   magic "RPRCKPT1" | u32 n_tensors | per tensor:
//!     u8 dtype (0=f32, 1=i32) | u32 rank | u64 dims[rank] | raw data
//! followed by a JSON trailer (u64 length + bytes) carrying run metadata.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use crate::runtime::Tensor;

const MAGIC: &[u8; 8] = b"RPRCKPT1";

/// Version of the *parameter layout* inside the state vector. v1 is the
/// pre-refactor hand-unrolled single-layer model (8 flat arrays); v2 is the
/// block-structured Transformer (layer-indexed arrays, LayerNorm + MLP
/// parameters interleaved per block). Checkpoints written before the header
/// existed parse as v1 — loading them into a v2 trainer is rejected, never
/// silently misinterpreted.
pub const PARAM_LAYOUT_VERSION: u32 = 2;

/// Run metadata stored alongside the tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub artifact_tag: String,
    pub step: usize,
    pub loss: f32,
    pub seed: u64,
    /// Parameter-layout version the state vector was written under.
    pub layout: u32,
}

impl CheckpointMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifact_tag", Json::str(self.artifact_tag.clone())),
            ("step", Json::num(self.step as f64)),
            // a non-finite loss (e.g. a zero-step run that never measured
            // one) must not poison the JSON trailer — NaN is not JSON
            (
                "loss",
                if self.loss.is_finite() {
                    Json::num(self.loss as f64)
                } else {
                    Json::Null
                },
            ),
            // u64 doesn't survive a JSON f64 round-trip above 2^53 — store
            // the seed as a decimal string (found by prop_coordinator).
            ("seed", Json::str(self.seed.to_string())),
            ("layout", Json::num(self.layout as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            artifact_tag: v
                .req("artifact_tag")?
                .as_str()
                .ok_or_else(|| anyhow!("bad artifact_tag"))?
                .to_string(),
            step: v.req("step")?.as_usize().ok_or_else(|| anyhow!("bad step"))?,
            loss: match v.req("loss")? {
                Json::Null => f32::NAN,
                other => other.as_f64().ok_or_else(|| anyhow!("bad loss"))? as f32,
            },
            seed: match v.req("seed")? {
                Json::Str(s) => s.parse().map_err(|_| anyhow!("bad seed"))?,
                other => other.as_f64().ok_or_else(|| anyhow!("bad seed"))? as u64,
            },
            // absent in checkpoints written before the versioned header
            layout: v.get("layout").and_then(Json::as_usize).unwrap_or(1) as u32,
        })
    }

    /// Fails unless the checkpoint was written under the current parameter
    /// layout — the guard every state-consuming path goes through.
    pub fn require_current_layout(&self) -> Result<()> {
        if self.layout != PARAM_LAYOUT_VERSION {
            bail!(
                "checkpoint {:?} uses parameter layout v{} but this build expects v{}; \
                 pre-refactor checkpoints cannot be reinterpreted — re-train, or evaluate \
                 with the binary that wrote them",
                self.artifact_tag,
                self.layout,
                PARAM_LAYOUT_VERSION
            );
        }
        Ok(())
    }
}

/// A saved training state.
#[derive(Debug)]
pub struct Checkpoint {
    pub meta: CheckpointMeta,
    pub state: Vec<Tensor>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        Self::write(path, &self.meta, &self.state)
    }

    /// Serialize a training state directly from borrows — the trainer
    /// checkpoints its live (in-place-updated) state without cloning the
    /// full `params ++ m ++ v` vector first.
    pub fn write(path: impl AsRef<Path>, meta: &CheckpointMeta, state: &[Tensor]) -> Result<()> {
        let tmp = path.as_ref().with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&(state.len() as u32).to_le_bytes())?;
            for t in state {
                let (tag, bytes): (u8, Vec<u8>) = match t {
                    Tensor::F32 { data, .. } => {
                        (0, data.iter().flat_map(|v| v.to_le_bytes()).collect())
                    }
                    Tensor::I32 { data, .. } => {
                        (1, data.iter().flat_map(|v| v.to_le_bytes()).collect())
                    }
                };
                f.write_all(&[tag])?;
                f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
                for &d in t.shape() {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                f.write_all(&bytes)?;
            }
            let meta = meta.to_json().to_string().into_bytes();
            f.write_all(&(meta.len() as u64).to_le_bytes())?;
            f.write_all(&meta)?;
        }
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a repro checkpoint (bad magic)");
        }
        let n = read_u32(&mut f)? as usize;
        let mut state = Vec::with_capacity(n);
        for _ in 0..n {
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut raw = vec![0u8; numel * 4];
            f.read_exact(&mut raw)?;
            let t = match tag[0] {
                0 => Tensor::f32(
                    shape,
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )?,
                1 => Tensor::i32(
                    shape,
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )?,
                other => bail!("unknown dtype tag {other}"),
            };
            state.push(t);
        }
        let meta_len = read_u64(&mut f)? as usize;
        let mut meta_raw = vec![0u8; meta_len];
        f.read_exact(&mut meta_raw)?;
        let meta = CheckpointMeta::from_json(&Json::parse(std::str::from_utf8(&meta_raw)?)?)?;
        Ok(Self { meta, state })
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            meta: CheckpointMeta {
                artifact_tag: "lm_tiny_ours".into(),
                step: 42,
                loss: 3.25,
                seed: 7,
                layout: PARAM_LAYOUT_VERSION,
            },
            state: vec![
                Tensor::randn(vec![4, 8], 1),
                Tensor::i32(vec![3], vec![1, -2, 3]).unwrap(),
                Tensor::scalar_f32(0.5),
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.ckpt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.meta, ck.meta);
        assert_eq!(back.state, ck.state);
    }

    #[test]
    fn layout_guard_rejects_pre_refactor_checkpoints() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("old_layout.ckpt");
        let mut ck = sample();
        ck.meta.layout = 1; // what a pre-header checkpoint parses as
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.meta.layout, 1);
        let err = back.meta.require_current_layout().unwrap_err().to_string();
        assert!(err.contains("layout v1"), "unhelpful error: {err}");
        assert!(sample().meta.require_current_layout().is_ok());
    }

    #[test]
    fn non_finite_loss_survives_roundtrip_as_nan() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("nan_loss.ckpt");
        let mut ck = sample();
        ck.meta.loss = f32::NAN;
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert!(back.meta.loss.is_nan());
        assert_eq!(back.meta.step, ck.meta.step);
        assert_eq!(back.state, ck.state);
    }

    #[test]
    fn borrowed_write_matches_owned_save() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample();
        let p1 = dir.join("owned.ckpt");
        let p2 = dir.join("borrowed.ckpt");
        ck.save(&p1).unwrap();
        Checkpoint::write(&p2, &ck.meta, &ck.state).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}
