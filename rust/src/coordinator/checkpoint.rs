//! Checkpoints: the flat training state (params ++ adam_m ++ adam_v) on disk.
//!
//! Format (little-endian, version-tagged):
//!   magic "RPRCKPT1" | u32 n_tensors | per tensor:
//!     u8 dtype | u32 rank | u64 dims[rank] | raw data
//! followed by a JSON trailer (u64 length + bytes) carrying run metadata.
//!
//! Dtype tags: 0 = f32, 1 = i32 (both raw LE words). Layout-v3 quantized
//! checkpoints additionally use 2 = bf16 (u16 LE per element) and
//! 3 = int8 (u64 n_rows | f32 scales[n_rows] LE | raw i8 data) — the raw
//! section of a tag-3 tensor is *not* `numel · 4` bytes, which is exactly
//! why a v2 reader hitting one fails loudly on the unknown tag instead of
//! misparsing the stream.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use crate::native::quant::{Precision, QuantBuf};
use crate::runtime::Tensor;

const MAGIC: &[u8; 8] = b"RPRCKPT1";

/// Version of the *parameter layout* inside the state vector. v1 is the
/// pre-refactor hand-unrolled single-layer model (8 flat arrays); v2 is the
/// block-structured Transformer (layer-indexed arrays, LayerNorm + MLP
/// parameters interleaved per block). Checkpoints written before the header
/// existed parse as v1 — loading them into a v2 trainer is rejected, never
/// silently misinterpreted.
pub const PARAM_LAYOUT_VERSION: u32 = 2;

/// Layout of a *quantized, decode-only* checkpoint (`repro quantize`
/// output): the v2 parameter walk, params only (no optimizer moments), with
/// the GEMM-dominant weights stored bf16/int8 (tags 2/3). Only
/// [`QuantCheckpoint::load`] accepts it; the trainer-facing
/// [`Checkpoint::load`] rejects the quantized tags with a pointer here, and
/// pre-v3 readers reject them as unknown dtypes.
pub const QUANT_PARAM_LAYOUT_VERSION: u32 = 3;

/// Run metadata stored alongside the tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub artifact_tag: String,
    pub step: usize,
    pub loss: f32,
    pub seed: u64,
    /// Parameter-layout version the state vector was written under.
    pub layout: u32,
}

impl CheckpointMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifact_tag", Json::str(self.artifact_tag.clone())),
            ("step", Json::num(self.step as f64)),
            // a non-finite loss (e.g. a zero-step run that never measured
            // one) must not poison the JSON trailer — NaN is not JSON
            (
                "loss",
                if self.loss.is_finite() {
                    Json::num(self.loss as f64)
                } else {
                    Json::Null
                },
            ),
            // u64 doesn't survive a JSON f64 round-trip above 2^53 — store
            // the seed as a decimal string (found by prop_coordinator).
            ("seed", Json::str(self.seed.to_string())),
            ("layout", Json::num(self.layout as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            artifact_tag: v
                .req("artifact_tag")?
                .as_str()
                .ok_or_else(|| anyhow!("bad artifact_tag"))?
                .to_string(),
            step: v.req("step")?.as_usize().ok_or_else(|| anyhow!("bad step"))?,
            loss: match v.req("loss")? {
                Json::Null => f32::NAN,
                other => other.as_f64().ok_or_else(|| anyhow!("bad loss"))? as f32,
            },
            seed: match v.req("seed")? {
                Json::Str(s) => s.parse().map_err(|_| anyhow!("bad seed"))?,
                other => other.as_f64().ok_or_else(|| anyhow!("bad seed"))? as u64,
            },
            // absent in checkpoints written before the versioned header
            layout: v.get("layout").and_then(Json::as_usize).unwrap_or(1) as u32,
        })
    }

    /// Fails unless the checkpoint was written under the current parameter
    /// layout — the guard every state-consuming path goes through.
    pub fn require_current_layout(&self) -> Result<()> {
        if self.layout != PARAM_LAYOUT_VERSION {
            bail!(
                "checkpoint {:?} uses parameter layout v{} but this build expects v{}; \
                 pre-refactor checkpoints cannot be reinterpreted — re-train, or evaluate \
                 with the binary that wrote them",
                self.artifact_tag,
                self.layout,
                PARAM_LAYOUT_VERSION
            );
        }
        Ok(())
    }
}

/// A saved training state.
#[derive(Debug)]
pub struct Checkpoint {
    pub meta: CheckpointMeta,
    pub state: Vec<Tensor>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        Self::write(path, &self.meta, &self.state)
    }

    /// Serialize a training state directly from borrows — the trainer
    /// checkpoints its live (in-place-updated) state without cloning the
    /// full `params ++ m ++ v` vector first.
    pub fn write(path: impl AsRef<Path>, meta: &CheckpointMeta, state: &[Tensor]) -> Result<()> {
        let tmp = path.as_ref().with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&(state.len() as u32).to_le_bytes())?;
            for t in state {
                let (tag, bytes): (u8, Vec<u8>) = match t {
                    Tensor::F32 { data, .. } => {
                        (0, data.iter().flat_map(|v| v.to_le_bytes()).collect())
                    }
                    Tensor::I32 { data, .. } => {
                        (1, data.iter().flat_map(|v| v.to_le_bytes()).collect())
                    }
                };
                f.write_all(&[tag])?;
                f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
                for &d in t.shape() {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                f.write_all(&bytes)?;
            }
            let meta = meta.to_json().to_string().into_bytes();
            f.write_all(&(meta.len() as u64).to_le_bytes())?;
            f.write_all(&meta)?;
        }
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a repro checkpoint (bad magic)");
        }
        let n = read_u32(&mut f)? as usize;
        let mut state = Vec::with_capacity(n);
        for _ in 0..n {
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut raw = vec![0u8; numel * 4];
            f.read_exact(&mut raw)?;
            let t = match tag[0] {
                0 => Tensor::f32(
                    shape,
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )?,
                1 => Tensor::i32(
                    shape,
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )?,
                t @ (2 | 3) => bail!(
                    "tensor uses quantized dtype tag {t}: this is a layout-v3 \
                     decode-only checkpoint (`repro quantize` output) — load it \
                     through the inference session, not the full-precision path"
                ),
                other => bail!("unknown dtype tag {other}"),
            };
            state.push(t);
        }
        let meta_len = read_u64(&mut f)? as usize;
        let mut meta_raw = vec![0u8; meta_len];
        f.read_exact(&mut meta_raw)?;
        let meta = CheckpointMeta::from_json(&Json::parse(std::str::from_utf8(&meta_raw)?)?)?;
        Ok(Self { meta, state })
    }
}

/// A quantized, decode-only parameter checkpoint (layout v3): the
/// [`PARAM_LAYOUT_VERSION`] parameter walk with the GEMM-dominant weights
/// stored at a reduced [`Precision`]. Carries no optimizer moments — it
/// cannot resume training, only decode.
#[derive(Debug)]
pub struct QuantCheckpoint {
    /// Run metadata (`meta.layout == QUANT_PARAM_LAYOUT_VERSION`).
    pub meta: CheckpointMeta,
    /// Storage precision the quantized arrays were written at.
    pub precision: Precision,
    /// `(shape, data)` per parameter, in the model's parameter-walk order.
    pub arrays: Vec<(Vec<usize>, QuantBuf)>,
}

impl QuantCheckpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let tmp = path.as_ref().with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&(self.arrays.len() as u32).to_le_bytes())?;
            let mut dtypes = Vec::with_capacity(self.arrays.len());
            for (shape, buf) in &self.arrays {
                let numel: usize = shape.iter().product();
                if buf.len() != numel {
                    bail!("quantized array: shape {shape:?} vs {} elements", buf.len());
                }
                let tag: u8 = match buf {
                    QuantBuf::F32(_) => 0,
                    QuantBuf::Bf16(_) => 2,
                    QuantBuf::Int8 { .. } => 3,
                };
                dtypes.push(Json::str(match tag {
                    0 => "f32",
                    2 => "bf16",
                    _ => "int8",
                }));
                f.write_all(&[tag])?;
                f.write_all(&(shape.len() as u32).to_le_bytes())?;
                for &d in shape {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                match buf {
                    QuantBuf::F32(d) => {
                        for v in d {
                            f.write_all(&v.to_le_bytes())?;
                        }
                    }
                    QuantBuf::Bf16(d) => {
                        for v in d {
                            f.write_all(&v.to_le_bytes())?;
                        }
                    }
                    QuantBuf::Int8 { q, scales, row } => {
                        if scales.len() * *row != q.len() {
                            bail!(
                                "int8 array: {} scales × row {} vs {} codes",
                                scales.len(),
                                row,
                                q.len()
                            );
                        }
                        f.write_all(&(scales.len() as u64).to_le_bytes())?;
                        for s in scales {
                            f.write_all(&s.to_le_bytes())?;
                        }
                        // i8 → u8 is a bit-preserving cast
                        for &c in q {
                            f.write_all(&[c as u8])?;
                        }
                    }
                }
            }
            let mut meta = self.meta.clone();
            meta.layout = QUANT_PARAM_LAYOUT_VERSION;
            let trailer = match meta.to_json() {
                Json::Obj(mut m) => {
                    m.insert("precision".to_string(), Json::str(self.precision.name()));
                    m.insert("dtypes".to_string(), Json::Arr(dtypes));
                    Json::Obj(m)
                }
                other => other,
            };
            let trailer = trailer.to_string().into_bytes();
            f.write_all(&(trailer.len() as u64).to_le_bytes())?;
            f.write_all(&trailer)?;
        }
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a repro checkpoint (bad magic)");
        }
        let n = read_u32(&mut f)? as usize;
        let mut arrays = Vec::with_capacity(n);
        for _ in 0..n {
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let buf = match tag[0] {
                0 => {
                    let mut raw = vec![0u8; numel * 4];
                    f.read_exact(&mut raw)?;
                    QuantBuf::F32(
                        raw.chunks_exact(4)
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                2 => {
                    let mut raw = vec![0u8; numel * 2];
                    f.read_exact(&mut raw)?;
                    QuantBuf::Bf16(
                        raw.chunks_exact(2)
                            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                3 => {
                    let n_rows = read_u64(&mut f)? as usize;
                    if n_rows == 0 || numel % n_rows != 0 {
                        bail!("int8 tensor: {n_rows} rows do not divide {numel} elements");
                    }
                    let row = numel / n_rows;
                    let mut sraw = vec![0u8; n_rows * 4];
                    f.read_exact(&mut sraw)?;
                    let scales = sraw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    let mut raw = vec![0u8; numel];
                    f.read_exact(&mut raw)?;
                    QuantBuf::Int8 {
                        q: raw.iter().map(|&b| b as i8).collect(),
                        scales,
                        row,
                    }
                }
                1 => bail!("quantized checkpoints never carry i32 tensors"),
                other => bail!("unknown dtype tag {other}"),
            };
            arrays.push((shape, buf));
        }
        let meta_len = read_u64(&mut f)? as usize;
        let mut meta_raw = vec![0u8; meta_len];
        f.read_exact(&mut meta_raw)?;
        let trailer = Json::parse(std::str::from_utf8(&meta_raw)?)?;
        let meta = CheckpointMeta::from_json(&trailer)?;
        if meta.layout != QUANT_PARAM_LAYOUT_VERSION {
            bail!(
                "checkpoint {:?} uses parameter layout v{}, not the quantized v{} — \
                 load it through the full-precision path",
                meta.artifact_tag,
                meta.layout,
                QUANT_PARAM_LAYOUT_VERSION
            );
        }
        let precision = Precision::from_name(
            trailer
                .req("precision")?
                .as_str()
                .ok_or_else(|| anyhow!("bad precision"))?,
        )?;
        Ok(Self { meta, precision, arrays })
    }
}

/// Either kind of checkpoint a path may hold, for loaders (the inference
/// session) that accept both.
#[derive(Debug)]
pub enum LoadedCheckpoint {
    Full(Checkpoint),
    Quantized(QuantCheckpoint),
}

/// Load a checkpoint of either layout. The full-precision reader runs
/// first (the common case; it fails fast on a quantized checkpoint's first
/// tag-2/3 tensor), then the quantized reader. On a file neither accepts,
/// the full reader's error is returned — it carries the
/// bad-magic/unknown-tag diagnosis.
pub fn load_any(path: impl AsRef<Path>) -> Result<LoadedCheckpoint> {
    let path = path.as_ref();
    match Checkpoint::load(path) {
        Ok(c) => Ok(LoadedCheckpoint::Full(c)),
        Err(full_err) => match QuantCheckpoint::load(path) {
            Ok(q) => Ok(LoadedCheckpoint::Quantized(q)),
            Err(_) => Err(full_err),
        },
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            meta: CheckpointMeta {
                artifact_tag: "lm_tiny_ours".into(),
                step: 42,
                loss: 3.25,
                seed: 7,
                layout: PARAM_LAYOUT_VERSION,
            },
            state: vec![
                Tensor::randn(vec![4, 8], 1),
                Tensor::i32(vec![3], vec![1, -2, 3]).unwrap(),
                Tensor::scalar_f32(0.5),
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.ckpt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.meta, ck.meta);
        assert_eq!(back.state, ck.state);
    }

    #[test]
    fn layout_guard_rejects_pre_refactor_checkpoints() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("old_layout.ckpt");
        let mut ck = sample();
        ck.meta.layout = 1; // what a pre-header checkpoint parses as
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.meta.layout, 1);
        let err = back.meta.require_current_layout().unwrap_err().to_string();
        assert!(err.contains("layout v1"), "unhelpful error: {err}");
        assert!(sample().meta.require_current_layout().is_ok());
    }

    #[test]
    fn non_finite_loss_survives_roundtrip_as_nan() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("nan_loss.ckpt");
        let mut ck = sample();
        ck.meta.loss = f32::NAN;
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert!(back.meta.loss.is_nan());
        assert_eq!(back.meta.step, ck.meta.step);
        assert_eq!(back.state, ck.state);
    }

    #[test]
    fn borrowed_write_matches_owned_save() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample();
        let p1 = dir.join("owned.ckpt");
        let p2 = dir.join("borrowed.ckpt");
        ck.save(&p1).unwrap();
        Checkpoint::write(&p2, &ck.meta, &ck.state).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        assert!(load_any(&p).is_err());
    }

    fn quant_sample() -> QuantCheckpoint {
        let w = [0.5f32, -1.25, 3.0, 0.0, 2.0, -0.125];
        QuantCheckpoint {
            meta: CheckpointMeta {
                artifact_tag: "lm_tiny_ours".into(),
                step: 42,
                loss: 3.25,
                seed: 7,
                layout: QUANT_PARAM_LAYOUT_VERSION,
            },
            precision: Precision::Int8,
            arrays: vec![
                (vec![4], QuantBuf::F32(vec![1.0, -2.0, 0.5, 4.0])),
                (vec![2, 3], QuantBuf::from_f32(&w, 3, Precision::Int8)),
                (vec![3, 2], QuantBuf::from_f32(&w, 2, Precision::Bf16)),
            ],
        }
    }

    #[test]
    fn quantized_roundtrip() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("quant.ckpt");
        let ck = quant_sample();
        ck.save(&p).unwrap();
        let back = QuantCheckpoint::load(&p).unwrap();
        assert_eq!(back.meta.artifact_tag, ck.meta.artifact_tag);
        assert_eq!(back.meta.layout, QUANT_PARAM_LAYOUT_VERSION);
        assert_eq!(back.precision, Precision::Int8);
        assert_eq!(back.arrays, ck.arrays);
    }

    #[test]
    fn full_reader_rejects_quantized_with_a_pointer() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("quant_reject.ckpt");
        quant_sample().save(&p).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("quantize"), "unhelpful error: {err}");
    }

    #[test]
    fn quant_reader_rejects_full_checkpoints() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("full_reject.ckpt");
        sample().save(&p).unwrap();
        assert!(QuantCheckpoint::load(&p).is_err());
    }

    #[test]
    fn load_any_tells_the_layouts_apart() {
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let pf = dir.join("any_full.ckpt");
        let pq = dir.join("any_quant.ckpt");
        sample().save(&pf).unwrap();
        quant_sample().save(&pq).unwrap();
        assert!(matches!(load_any(&pf).unwrap(), LoadedCheckpoint::Full(_)));
        match load_any(&pq).unwrap() {
            LoadedCheckpoint::Quantized(q) => assert_eq!(q.precision, Precision::Int8),
            other => panic!("expected quantized, got {other:?}"),
        }
    }
}
