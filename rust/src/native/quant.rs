//! Low-precision storage primitives: bf16 and per-row-scaled int8.
//!
//! CPU decode is memory-bandwidth bound, so bytes moved per token — not
//! multiply-adds — set the tok/s ceiling (the source paper's 3.6× memory
//! reduction is the precedent). This module owns the storage formats; the
//! compute stays f32 end to end:
//!
//! - **bf16** — the upper 16 bits of an f32 (same exponent range, 8-bit
//!   mantissa). Conversion *to* bf16 rounds to nearest-even; conversion back
//!   is exact (a shift), so a round-trip through bf16 is lossless for every
//!   value bf16 can represent.
//! - **int8, per-row scales** — each row of `row` elements is scaled by
//!   `scale = max_abs / 127` and rounded to `i8`; dequantization error is at
//!   most `scale / 2` per element. Rows that are all zero (or all
//!   non-finite) get `scale = 0` and dequantize to zeros — no division by
//!   zero, no NaN scales.
//!
//! [`QuantBuf`] is the uniform container the decode state
//! (`infer/state.rs`), the quantized parameter blocks (`native/model.rs`)
//! and the layout-v3 checkpoints (`coordinator/checkpoint.rs`) all store:
//! one enum over the three formats, with `bytes()` reporting the *true*
//! footprint (data + scale vectors) so `state_bytes()` stays honest.
//!
//! The GEMM microkernels that consume these formats (widening to f32
//! accumulators) live in [`super::gemm`]; everything here is scalar and
//! allocation-free on the hot paths (marked for, and enforced by, the
//! deny-alloc rule of `cargo run -p xtask -- lint`).

use anyhow::{bail, Result};

/// Storage precision for model parameters and decode state.
///
/// Compute always accumulates in f32; this selects how the bytes at rest are
/// encoded. Plumbed from `LmConfig` through the decode path, the checkpoint
/// layout (v3) and the bench schema (v5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 storage — the bit-exact baseline path.
    F32,
    /// bfloat16 storage (upper half of f32), f32 accumulation.
    Bf16,
    /// int8 storage with one f32 scale per row, f32 accumulation.
    Int8,
}

impl Precision {
    /// Canonical lowercase name (CLI flag / bench column / checkpoint meta).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
        }
    }

    /// Parse a canonical name (as produced by [`Self::name`]).
    pub fn from_name(s: &str) -> Result<Self> {
        match s.trim() {
            "f32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            "int8" => Ok(Precision::Int8),
            other => bail!("unknown precision {other:?} (expected f32, bf16, or int8)"),
        }
    }

    /// True for the reduced-precision formats (anything but f32).
    pub fn is_quantized(self) -> bool {
        !matches!(self, Precision::F32)
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// --- scalar conversion primitives -------------------------------------------

/// f32 → bf16 with round-to-nearest-even; NaN is quieted (payload kept
/// non-zero) so it stays NaN after truncation.
// deny_alloc
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // force a quiet NaN: truncation alone could zero the payload and
        // turn NaN into infinity
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest, ties to even: add 0x7fff + (lsb of the kept part);
    // finite values that overflow bf16's mantissa carry into the exponent,
    // which is exactly RNE overflow-to-infinity
    let round = ((bits >> 16) & 1) + 0x7fff;
    (bits.wrapping_add(round) >> 16) as u16
}

/// bf16 → f32 — exact (bf16 is a prefix of the f32 encoding).
// deny_alloc
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Quantize one row to i8 in place, returning the row's f32 scale.
///
/// `scale = max_abs / 127` over the row's *finite* values; each element is
/// `round(x / scale)` clamped to `[-127, 127]`. Degenerate rows (empty,
/// all-zero, or without any finite value) get scale 0 and all-zero codes —
/// they dequantize to exact zeros. Non-finite elements never panic: NaN
/// encodes to 0, ±inf saturates to ±127.
// deny_alloc
pub fn quantize_row_i8(row: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), q.len());
    let mut max_abs = 0.0f32;
    for &x in row {
        let a = x.abs();
        if a.is_finite() && a > max_abs {
            max_abs = a;
        }
    }
    if max_abs == 0.0 {
        for o in q.iter_mut() {
            *o = 0;
        }
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (o, &x) in q.iter_mut().zip(row) {
        // clamp keeps the code in [-127, 127] (symmetric, so the error bound
        // holds at both ends); NaN.clamp is NaN, and `NaN as i8` is 0
        *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    max_abs / 127.0
}

/// Dequantize one i8 row (`out[i] = q[i] * scale`).
// deny_alloc
pub fn dequantize_row_i8(q: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for (o, &v) in out.iter_mut().zip(q) {
        *o = v as f32 * scale;
    }
}

// --- QuantBuf ----------------------------------------------------------------

/// One flat buffer stored at a chosen [`Precision`].
///
/// The int8 variant carries one f32 scale per `row` contiguous elements
/// (rows of a weight matrix, rows of the KV cache, rows of the recurrent `S`
/// state). The enum is deliberately transparent (public fields) so the
/// checkpoint serializer and the decode state can match on it directly.
#[derive(Debug, Clone, PartialEq)]
pub enum QuantBuf {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32>, row: usize },
}

impl QuantBuf {
    /// Zero-filled buffer of `len` logical elements. `row` is the int8 scale
    /// granularity (must divide `len`); ignored for f32/bf16.
    pub fn zeros(prec: Precision, len: usize, row: usize) -> Self {
        match prec {
            Precision::F32 => QuantBuf::F32(vec![0.0; len]),
            Precision::Bf16 => QuantBuf::Bf16(vec![0; len]),
            Precision::Int8 => {
                assert!(row > 0 && len % row == 0, "int8 zeros: row {row} must divide len {len}");
                QuantBuf::Int8 { q: vec![0; len], scales: vec![0.0; len / row], row }
            }
        }
    }

    /// Empty buffer with capacity for `cap` logical elements reserved up
    /// front — the KV-cache constructor (growth via [`Self::append_rows`]
    /// then stays allocation-free until `cap` is exceeded).
    pub fn reserved(prec: Precision, cap: usize, row: usize) -> Self {
        match prec {
            Precision::F32 => QuantBuf::F32(Vec::with_capacity(cap)),
            Precision::Bf16 => QuantBuf::Bf16(Vec::with_capacity(cap)),
            Precision::Int8 => {
                assert!(row > 0, "int8 reserved: zero row");
                QuantBuf::Int8 {
                    q: Vec::with_capacity(cap),
                    scales: Vec::with_capacity(cap.div_ceil(row)),
                    row,
                }
            }
        }
    }

    /// Quantize an f32 slice (`row` = int8 scale granularity, must divide
    /// `data.len()`; ignored for f32/bf16).
    pub fn from_f32(data: &[f32], row: usize, prec: Precision) -> Self {
        match prec {
            Precision::F32 => QuantBuf::F32(data.to_vec()),
            Precision::Bf16 => QuantBuf::Bf16(data.iter().map(|&x| f32_to_bf16(x)).collect()),
            Precision::Int8 => {
                assert!(
                    row > 0 && data.len() % row == 0,
                    "int8 from_f32: row {row} must divide len {}",
                    data.len()
                );
                let mut q = vec![0i8; data.len()];
                let mut scales = vec![0.0f32; data.len() / row];
                for (r, chunk) in data.chunks_exact(row).enumerate() {
                    scales[r] = quantize_row_i8(chunk, &mut q[r * row..][..row]);
                }
                QuantBuf::Int8 { q, scales, row }
            }
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            QuantBuf::F32(_) => Precision::F32,
            QuantBuf::Bf16(_) => Precision::Bf16,
            QuantBuf::Int8 { .. } => Precision::Int8,
        }
    }

    /// Logical element count.
    pub fn len(&self) -> usize {
        match self {
            QuantBuf::F32(d) => d.len(),
            QuantBuf::Bf16(d) => d.len(),
            QuantBuf::Int8 { q, .. } => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True stored footprint in bytes: element data plus (for int8) the
    /// per-row scale vector. This is what `state_bytes()` reports.
    pub fn bytes(&self) -> usize {
        match self {
            QuantBuf::F32(d) => std::mem::size_of_val(d.as_slice()),
            QuantBuf::Bf16(d) => std::mem::size_of_val(d.as_slice()),
            QuantBuf::Int8 { q, scales, .. } => {
                std::mem::size_of_val(q.as_slice()) + std::mem::size_of_val(scales.as_slice())
            }
        }
    }

    /// Decode the whole buffer into `out` (`out.len() == self.len()`).
    // deny_alloc
    pub fn dequantize_into(&self, out: &mut [f32]) {
        match self {
            QuantBuf::F32(d) => out.copy_from_slice(d),
            QuantBuf::Bf16(d) => {
                for (o, &b) in out.iter_mut().zip(d) {
                    *o = bf16_to_f32(b);
                }
            }
            QuantBuf::Int8 { q, scales, row } => {
                for (r, chunk) in q.chunks_exact(*row).enumerate() {
                    dequantize_row_i8(chunk, scales[r], &mut out[r * row..][..*row]);
                }
            }
        }
    }

    /// Re-encode the whole buffer from `src` (`src.len() == self.len()`),
    /// requantizing per int8 row — the bulk inverse of
    /// [`Self::dequantize_into`], used by chunked prefill to write the
    /// f32-accumulated recurrent state back in one pass.
    // deny_alloc
    pub fn store_f32(&mut self, src: &[f32]) {
        match self {
            QuantBuf::F32(d) => d.copy_from_slice(src),
            QuantBuf::Bf16(d) => {
                debug_assert_eq!(d.len(), src.len());
                for (o, &x) in d.iter_mut().zip(src) {
                    *o = f32_to_bf16(x);
                }
            }
            QuantBuf::Int8 { q, scales, row } => {
                debug_assert_eq!(q.len(), src.len());
                for (r, chunk) in src.chunks_exact(*row).enumerate() {
                    scales[r] = quantize_row_i8(chunk, &mut q[r * *row..][..*row]);
                }
            }
        }
    }

    /// Re-encode `src.len() / rowlen` whole rows starting at row `r`
    /// **in place** (the buffer keeps its length) — the per-sequence-lane
    /// sibling of [`Self::store_f32`], used by the decode step to write one
    /// token's K/V rows into a sequence's pre-sized cache lane. Quantizes
    /// per int8 row with the exact arithmetic [`Self::append_rows`] uses, so
    /// a lane write and an append of the same row store identical codes.
    // deny_alloc
    // bounds: callers carve `r`/`rowlen` spans inside the buffer length —
    // the decode step derives them from the DecodeState lane layout
    pub fn store_rows(&mut self, r: usize, rowlen: usize, src: &[f32]) {
        match self {
            QuantBuf::F32(d) => d[r * rowlen..][..src.len()].copy_from_slice(src),
            QuantBuf::Bf16(d) => {
                for (o, &x) in d[r * rowlen..][..src.len()].iter_mut().zip(src) {
                    *o = f32_to_bf16(x);
                }
            }
            QuantBuf::Int8 { q, scales, row } => {
                debug_assert_eq!(*row, rowlen);
                debug_assert!(src.len() % rowlen == 0, "store_rows: partial int8 row");
                for (i, chunk) in src.chunks_exact(rowlen).enumerate() {
                    scales[r + i] = quantize_row_i8(chunk, &mut q[(r + i) * rowlen..][..rowlen]);
                }
            }
        }
    }

    /// Raw precision-exact copy of `n_rows` stored rows from `src` (codes
    /// and, for int8, their scales — no dequantize/requantize round trip),
    /// used to adopt a staging sequence's state into a batch slot so the
    /// adopted lane is bit-identical to the staging lane.
    pub fn copy_rows_from(
        &mut self,
        dst_row: usize,
        src: &QuantBuf,
        src_row: usize,
        n_rows: usize,
        rowlen: usize,
    ) -> Result<()> {
        let n = n_rows * rowlen;
        match (self, src) {
            (QuantBuf::F32(d), QuantBuf::F32(s)) => {
                d[dst_row * rowlen..][..n].copy_from_slice(&s[src_row * rowlen..][..n]);
            }
            (QuantBuf::Bf16(d), QuantBuf::Bf16(s)) => {
                d[dst_row * rowlen..][..n].copy_from_slice(&s[src_row * rowlen..][..n]);
            }
            (
                QuantBuf::Int8 { q: dq, scales: dsc, row: dr },
                QuantBuf::Int8 { q: sq, scales: ssc, row: sr },
            ) => {
                if *dr != rowlen || *sr != rowlen {
                    bail!("copy_rows_from: int8 row {dr}/{sr} != rowlen {rowlen}");
                }
                dq[dst_row * rowlen..][..n].copy_from_slice(&sq[src_row * rowlen..][..n]);
                dsc[dst_row..][..n_rows].copy_from_slice(&ssc[src_row..][..n_rows]);
            }
            (d, s) => bail!(
                "copy_rows_from: precision mismatch ({} ← {})",
                d.precision().name(),
                s.precision().name()
            ),
        }
        Ok(())
    }

    /// Zero `n_rows` stored rows (codes and, for int8, scales) starting at
    /// row `r`, keeping the length — the slot-eviction reset of one
    /// sequence's recurrent-state block.
    // deny_alloc
    // bounds: callers carve `r`/`rowlen` spans inside the buffer length
    pub fn zero_rows(&mut self, r: usize, n_rows: usize, rowlen: usize) {
        let n = n_rows * rowlen;
        match self {
            QuantBuf::F32(d) => d[r * rowlen..][..n].fill(0.0),
            QuantBuf::Bf16(d) => d[r * rowlen..][..n].fill(0),
            QuantBuf::Int8 { q, scales, row } => {
                debug_assert_eq!(*row, rowlen);
                q[r * rowlen..][..n].fill(0);
                scales[r..][..n_rows].fill(0.0);
            }
        }
    }

    /// Append whole rows (quantizing as needed). `src.len()` must be a
    /// multiple of the int8 `row`; for f32/bf16 any length is a "row".
    /// Allocation-free while the reserved capacity lasts.
    // deny_alloc
    pub fn append_rows(&mut self, src: &[f32]) {
        match self {
            QuantBuf::F32(d) => d.extend_from_slice(src),
            QuantBuf::Bf16(d) => {
                for &x in src {
                    d.push(f32_to_bf16(x));
                }
            }
            QuantBuf::Int8 { q, scales, row } => {
                debug_assert!(src.len() % *row == 0, "append_rows: partial int8 row");
                for chunk in src.chunks_exact(*row) {
                    let start = q.len();
                    q.resize(start + *row, 0);
                    let s = quantize_row_i8(chunk, &mut q[start..]);
                    scales.push(s);
                }
            }
        }
    }

    /// Dot of `x` against stored row `r` (rows of `rowlen` elements). The
    /// int8 scale is applied once, after the integer-code dot.
    // deny_alloc
    pub fn row_dot(&self, r: usize, rowlen: usize, x: &[f32]) -> f32 {
        match self {
            QuantBuf::F32(d) => super::gemm::dot(x, &d[r * rowlen..][..rowlen]),
            QuantBuf::Bf16(d) => super::gemm::dot_bf16(x, &d[r * rowlen..][..rowlen]),
            QuantBuf::Int8 { q, scales, row } => {
                debug_assert_eq!(*row, rowlen);
                super::gemm::dot_i8(x, &q[r * rowlen..][..rowlen]) * scales[r]
            }
        }
    }

    /// `y += alpha · row_r` for stored row `r` of `rowlen` elements.
    // deny_alloc
    pub fn row_axpy(&self, r: usize, rowlen: usize, alpha: f32, y: &mut [f32]) {
        match self {
            QuantBuf::F32(d) => super::gemm::axpy(alpha, &d[r * rowlen..][..rowlen], y),
            QuantBuf::Bf16(d) => super::gemm::axpy_bf16(alpha, &d[r * rowlen..][..rowlen], y),
            QuantBuf::Int8 { q, scales, row } => {
                debug_assert_eq!(*row, rowlen);
                super::gemm::axpy_i8(alpha * scales[r], &q[r * rowlen..][..rowlen], y);
            }
        }
    }

    /// Drop all elements, keeping the reserved capacity (KV-cache rewind).
    pub fn clear(&mut self) {
        match self {
            QuantBuf::F32(d) => d.clear(),
            QuantBuf::Bf16(d) => d.clear(),
            QuantBuf::Int8 { q, scales, .. } => {
                q.clear();
                scales.clear();
            }
        }
    }

    /// Overwrite every element (and scale) with zero, keeping the length —
    /// the recurrent-state rewind (zero codes × zero scales decode to 0.0).
    pub fn fill_zero(&mut self) {
        match self {
            QuantBuf::F32(d) => d.fill(0.0),
            QuantBuf::Bf16(d) => d.fill(0),
            QuantBuf::Int8 { q, scales, .. } => {
                q.fill(0);
                scales.fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trip_is_exact_for_representable_values() {
        // values whose mantissa fits in 8 bits survive f32→bf16→f32 exactly
        for &x in &[
            0.0f32, -0.0, 1.0, -1.0, 0.5, -2.0, 1.5, 0.0078125, 256.0, -1024.0, 3.875,
            f32::INFINITY, f32::NEG_INFINITY, 1.0e-38, 3.3895314e38,
        ] {
            let rt = bf16_to_f32(f32_to_bf16(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "round-trip of {x}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-9 sits exactly halfway between bf16(1.0) and the next
        // representable value; ties go to the even mantissa (here: 1.0)
        let halfway = f32::from_bits(0x3f80_8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(halfway)), 1.0);
        // just above the tie rounds up
        let above = f32::from_bits(0x3f80_8001);
        assert_eq!(bf16_to_f32(f32_to_bf16(above)), f32::from_bits(0x3f81_0000));
        // a tie with an odd even-side rounds away to the even neighbour
        let tie_odd = f32::from_bits(0x3f81_8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(tie_odd)), f32::from_bits(0x3f82_0000));
    }

    #[test]
    fn bf16_nan_stays_nan() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // a NaN whose payload lives only in the truncated bits must not
        // collapse to infinity
        let sneaky = f32::from_bits(0x7f80_0001);
        assert!(sneaky.is_nan());
        assert!(bf16_to_f32(f32_to_bf16(sneaky)).is_nan());
    }

    #[test]
    fn int8_row_error_is_bounded_by_half_scale() {
        // deterministic pseudo-random row
        let mut x = 0x9e3779b97f4a7c15u64;
        let row: Vec<f32> = (0..257)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((x >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 8.0
            })
            .collect();
        let mut q = vec![0i8; row.len()];
        let scale = quantize_row_i8(&row, &mut q);
        assert!(scale > 0.0);
        let mut deq = vec![0.0f32; row.len()];
        dequantize_row_i8(&q, scale, &mut deq);
        // max abs error ≤ scale/2 (tiny fp slop allowance on the bound)
        let bound = scale * 0.5 * (1.0 + 1e-5);
        for (i, (&a, &b)) in row.iter().zip(&deq).enumerate() {
            assert!((a - b).abs() <= bound, "elem {i}: |{a} - {b}| > {bound}");
        }
        // the extremes must reach full code range
        assert!(q.iter().any(|&v| v == 127 || v == -127));
    }

    #[test]
    fn int8_degenerate_rows_do_not_panic_or_divide_by_zero() {
        // all-zero row: scale 0, zero codes, exact zero dequant
        let mut q = [1i8; 5];
        let s = quantize_row_i8(&[0.0; 5], &mut q);
        assert_eq!(s, 0.0);
        assert!(q.iter().all(|&v| v == 0));
        let mut out = [1.0f32; 5];
        dequantize_row_i8(&q, s, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));

        // empty row
        let s = quantize_row_i8(&[], &mut []);
        assert_eq!(s, 0.0);

        // single element round-trips to itself exactly (code ±127)
        let mut q1 = [0i8; 1];
        let s1 = quantize_row_i8(&[-3.25], &mut q1);
        assert_eq!(q1[0], -127);
        assert_eq!(q1[0] as f32 * s1, -3.25);

        // non-finite elements: NaN → 0, ±inf saturates, scale from the
        // finite values only — and a row with no finite values is scale 0
        let mut q4 = [0i8; 4];
        let s4 = quantize_row_i8(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.0], &mut q4);
        assert!(s4.is_finite() && s4 > 0.0);
        assert_eq!(q4, [0, 127, -127, 127]);
        let mut qn = [9i8; 2];
        let sn = quantize_row_i8(&[f32::NAN, f32::INFINITY], &mut qn);
        assert_eq!(sn, 0.0);
        assert_eq!(qn, [0, 0]);
    }

    #[test]
    fn precision_names_round_trip() {
        for p in [Precision::F32, Precision::Bf16, Precision::Int8] {
            assert_eq!(Precision::from_name(p.name()).unwrap(), p);
        }
        assert!(Precision::from_name("fp64").is_err());
        assert!(Precision::F32.name() == "f32" && !Precision::F32.is_quantized());
        assert!(Precision::Int8.is_quantized() && Precision::Bf16.is_quantized());
    }

    #[test]
    fn quantbuf_footprint_and_round_trip() {
        let data: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) * 0.1).collect();
        let f = QuantBuf::from_f32(&data, 8, Precision::F32);
        let b = QuantBuf::from_f32(&data, 8, Precision::Bf16);
        let i = QuantBuf::from_f32(&data, 8, Precision::Int8);
        assert_eq!((f.len(), b.len(), i.len()), (64, 64, 64));
        assert_eq!(f.bytes(), 256);
        assert_eq!(b.bytes(), 128);
        assert_eq!(i.bytes(), 64 + 8 * 4); // codes + one f32 scale per row
        let mut out = vec![0.0f32; 64];
        f.dequantize_into(&mut out);
        assert_eq!(out, data);
        i.dequantize_into(&mut out);
        let QuantBuf::Int8 { scales, .. } = &i else { unreachable!() };
        let max_scale = scales.iter().fold(0.0f32, |m, &s| if s > m { s } else { m });
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() <= max_scale * 0.5 * (1.0 + 1e-5));
        }
    }

    #[test]
    fn quantbuf_append_rows_and_row_ops() {
        for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
            let mut buf = QuantBuf::reserved(prec, 32, 4);
            buf.append_rows(&[1.0, 2.0, 3.0, 4.0]);
            buf.append_rows(&[-4.0, 0.5, 0.25, 1.0]);
            assert_eq!(buf.len(), 8);
            let x = [1.0f32, -1.0, 2.0, 0.5];
            let want0 = 1.0 - 2.0 + 6.0 + 2.0;
            let got = buf.row_dot(0, 4, &x);
            assert!((got - want0).abs() < 0.1, "{prec}: {got} vs {want0}");
            let mut y = [0.0f32; 4];
            buf.row_axpy(1, 4, 2.0, &mut y);
            assert!((y[0] + 8.0).abs() < 0.1, "{prec}");
            buf.clear();
            assert!(buf.is_empty());
        }
    }

    #[test]
    fn quantbuf_fill_zero_rewinds_state() {
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.3 - 1.0).collect();
        for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
            let mut buf = QuantBuf::from_f32(&data, 3, prec);
            buf.fill_zero();
            assert_eq!(buf.len(), 12);
            let mut out = vec![9.0f32; 12];
            buf.dequantize_into(&mut out);
            assert!(out.iter().all(|&v| v == 0.0), "{prec}");
        }
    }

    /// An in-place lane write must store the same encoded bits as appending
    /// the same rows — the decode step's lane store and the legacy append
    /// must be interchangeable for parity.
    #[test]
    fn store_rows_matches_append_rows_bitwise() {
        let rows: Vec<f32> = (0..12).map(|i| ((i * 13) % 7) as f32 * 0.4 - 1.0).collect();
        for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
            let mut appended = QuantBuf::reserved(prec, 12, 4);
            appended.append_rows(&rows);
            let mut stored = QuantBuf::zeros(prec, 12, 4);
            stored.store_rows(0, 4, &rows[..4]);
            stored.store_rows(1, 4, &rows[4..]);
            assert_eq!(appended, stored, "{prec}");
        }
    }

    #[test]
    fn copy_rows_from_is_precision_exact_and_rejects_mismatch() {
        let rows: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).sin()).collect();
        for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
            let src = QuantBuf::from_f32(&rows, 4, prec);
            let mut dst = QuantBuf::zeros(prec, 16, 4);
            dst.copy_rows_from(2, &src, 0, 2, 4).unwrap();
            // the copied rows carry the source's exact codes (and scales)
            let mut out = vec![0.0f32; 16];
            dst.dequantize_into(&mut out);
            let mut want = vec![0.0f32; 8];
            src.dequantize_into(&mut want);
            assert_eq!(&out[8..16], &want[..], "{prec}");
            assert!(out[..8].iter().all(|&v| v == 0.0), "{prec}");
        }
        let f = QuantBuf::zeros(Precision::F32, 8, 4);
        let mut b = QuantBuf::zeros(Precision::Bf16, 8, 4);
        assert!(b.copy_rows_from(0, &f, 0, 1, 4).is_err());
    }

    #[test]
    fn zero_rows_clears_only_the_span() {
        let rows: Vec<f32> = (0..12).map(|i| i as f32 + 1.0).collect();
        for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
            let mut buf = QuantBuf::from_f32(&rows, 4, prec);
            buf.zero_rows(1, 1, 4);
            let mut out = vec![0.0f32; 12];
            buf.dequantize_into(&mut out);
            assert!(out[4..8].iter().all(|&v| v == 0.0), "{prec}");
            assert!(out[..4].iter().all(|&v| v != 0.0), "{prec}");
            assert!(out[8..].iter().all(|&v| v != 0.0), "{prec}");
        }
    }
}
