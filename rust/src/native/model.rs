//! The native tiny language model: a one-block transformer with swappable
//! attention (ours / gated / softmax), hand-derived backward pass, and an
//! in-tree Adam optimizer — the `lm_*` artifact family, executed directly on
//! host `f32` slices.
//!
//! Architecture (single head, head dim = d_model):
//!   h0 = wte[x] + wpe            (token + position embedding)
//!   q,k,v = h0·wq, h0·wk, h0·wv
//!   a = attention(q, k, v)       (causal; variant per `AttnKind`)
//!   h1 = h0 + a·wo               (residual)
//!   logits = h1·wu + bu
//! with mean cross-entropy over next-token targets.
//!
//! The `ours`/`gated` variants run the paper's linear-attention state scan
//! (`kernels::la_scan_*`) over positive features `φ(x) = elu(x)+1`, with the
//! normalizer computed by the standard ones-channel trick: `v` gains a
//! constant-1 channel, so one scan yields both numerator and denominator and
//! the backward pass reuses the same analytic two-pass kernel.

use anyhow::{anyhow, bail, Result};

use crate::runtime::Tensor;

use super::gemm;
use super::kernels::{la_scan_bwd, la_scan_fwd, softmax_bwd, softmax_fwd, LayerShape};
use super::pool::ThreadPool;

/// Normalizer floor for the linear-attention denominator.
const EPS: f32 = 1e-6;
/// Decay of the gated variant's state.
const GATED_DECAY: f32 = 0.95;

/// Attention variant of one LM artifact family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    Ours,
    Gated,
    Softmax,
}

impl AttnKind {
    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "ours" => AttnKind::Ours,
            "gated" => AttnKind::Gated,
            "softmax" => AttnKind::Softmax,
            other => bail!("unknown attention variant {other:?}"),
        })
    }
}

/// Static configuration of one LM preset.
#[derive(Debug, Clone, Copy)]
pub struct LmConfig {
    pub vocab: usize,
    pub n_ctx: usize,
    pub d_model: usize,
    pub batch: usize,
    pub attn: AttnKind,
    pub lr_max: f64,
    pub lr_min: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
}

impl LmConfig {
    /// The `tiny` preset — small enough that a training step is ~10 MFLOP.
    pub fn tiny(attn: AttnKind) -> Self {
        Self {
            vocab: 256,
            n_ctx: 64,
            d_model: 64,
            batch: 8,
            attn,
            lr_max: 5e-2,
            lr_min: 5e-3,
            warmup_steps: 3,
            total_steps: 400,
        }
    }

    /// Parameter arrays, in state order: `(name, shape)`.
    pub fn param_shapes(&self) -> Vec<(&'static str, Vec<usize>)> {
        let (v, l, d) = (self.vocab, self.n_ctx, self.d_model);
        vec![
            ("wte", vec![v, d]),
            ("wpe", vec![l, d]),
            ("wq", vec![d, d]),
            ("wk", vec![d, d]),
            ("wv", vec![d, d]),
            ("wo", vec![d, d]),
            ("wu", vec![d, v]),
            ("bu", vec![v]),
        ]
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes().len()
    }

    /// Learning rate at a 0-based step: linear warmup then cosine decay.
    pub fn lr_at(&self, step: usize) -> f32 {
        if step < self.warmup_steps {
            return (self.lr_max * (step + 1) as f64 / self.warmup_steps as f64) as f32;
        }
        let span = self.total_steps.saturating_sub(self.warmup_steps).max(1) as f64;
        let frac = ((step - self.warmup_steps) as f64 / span).clamp(0.0, 1.0);
        (self.lr_min + 0.5 * (self.lr_max - self.lr_min) * (1.0 + (std::f64::consts::PI * frac).cos()))
            as f32
    }

    /// Fresh training state: params ++ adam_m ++ adam_v.
    pub fn init_state(&self, seed: u64) -> Vec<Tensor> {
        let shapes = self.param_shapes();
        let mut out = Vec::with_capacity(3 * shapes.len());
        for (i, (name, shape)) in shapes.iter().enumerate() {
            if *name == "bu" {
                out.push(Tensor::zeros(crate::runtime::DType::F32, shape.clone()));
            } else {
                let mut t = Tensor::randn(shape.clone(), seed ^ ((i as u64 + 1) * 0x9E3779B9));
                if let Tensor::F32 { data, .. } = &mut t {
                    for x in data.iter_mut() {
                        *x *= 0.02;
                    }
                }
                out.push(t);
            }
        }
        for (_, shape) in shapes.iter().chain(shapes.iter()) {
            out.push(Tensor::zeros(crate::runtime::DType::F32, shape.clone()));
        }
        out
    }
}

/// Borrowed views of the 8 parameter arrays.
struct P<'a> {
    wte: &'a [f32],
    wpe: &'a [f32],
    wq: &'a [f32],
    wk: &'a [f32],
    wv: &'a [f32],
    wo: &'a [f32],
    wu: &'a [f32],
    bu: &'a [f32],
}

impl<'a> P<'a> {
    fn bind(cfg: &LmConfig, params: &'a [&'a Tensor]) -> Result<Self> {
        if params.len() < cfg.n_params() {
            bail!("expected {} parameter arrays, got {}", cfg.n_params(), params.len());
        }
        for ((name, shape), t) in cfg.param_shapes().iter().zip(params) {
            if t.shape() != shape.as_slice() {
                bail!("param {name}: expected shape {shape:?}, got {:?}", t.shape());
            }
        }
        Ok(Self {
            wte: params[0].as_f32()?,
            wpe: params[1].as_f32()?,
            wq: params[2].as_f32()?,
            wk: params[3].as_f32()?,
            wv: params[4].as_f32()?,
            wo: params[5].as_f32()?,
            wu: params[6].as_f32()?,
            bu: params[7].as_f32()?,
        })
    }
}

// --- dense helpers (row-major, accumulate into `out`) -----------------------
//
// Thin aliases over the tiled [`gemm`] microkernels, parallel across output
// row stripes when the product is large enough to amortize a launch.

/// out[r,j] += x[r,c] · w[c,j]
fn matmul(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
) {
    gemm::par_gemm_nn(pool, x, w, rows, cin, cout, out);
}

/// dx[r,c] += dout[r,j] · w[c,j]
fn matmul_dx(
    pool: &ThreadPool,
    dout: &[f32],
    w: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    dx: &mut [f32],
) {
    gemm::par_gemm_nt(pool, dout, w, rows, cout, cin, dx);
}

/// dw[c,j] += x[r,c] · dout[r,j]
fn matmul_dw(
    pool: &ThreadPool,
    x: &[f32],
    dout: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    dw: &mut [f32],
) {
    gemm::par_gemm_tn(pool, x, dout, cin, rows, cout, dw);
}

fn elu1(x: f32) -> f32 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

fn elu1_grad(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else {
        x.exp()
    }
}

// --- forward ----------------------------------------------------------------

/// Everything the backward pass needs from the forward pass.
struct Cache {
    h0: Vec<f32>,
    qp: Vec<f32>,
    kp: Vec<f32>,
    vp: Vec<f32>,
    /// attention output (rows × d)
    a: Vec<f32>,
    /// linear-attention variants: φ(q), φ(k), extended v, raw scan output u
    fq: Vec<f32>,
    fk: Vec<f32>,
    vext: Vec<f32>,
    u: Vec<f32>,
    h1: Vec<f32>,
}

fn attn_gamma(kind: AttnKind) -> f32 {
    match kind {
        AttnKind::Gated => GATED_DECAY,
        _ => 1.0,
    }
}

/// Forward pass over `x` (batch × n_ctx token ids) → (logits, cache).
fn forward(cfg: &LmConfig, p: &P, x: &[i32], pool: &ThreadPool) -> Result<(Vec<f32>, Cache)> {
    let (bsz, l, d, v) = (cfg.batch, cfg.n_ctx, cfg.d_model, cfg.vocab);
    let rows = bsz * l;
    if x.len() != rows {
        bail!("expected {} tokens, got {}", rows, x.len());
    }
    let mut h0 = vec![0.0f32; rows * d];
    for (r, &tok) in x.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} out of range [0, {v})");
        }
        let te = &p.wte[tok as usize * d..][..d];
        let pe = &p.wpe[(r % l) * d..][..d];
        let hr = &mut h0[r * d..][..d];
        for ((h, a), b) in hr.iter_mut().zip(te).zip(pe) {
            *h = a + b;
        }
    }
    let mut qp = vec![0.0f32; rows * d];
    let mut kp = vec![0.0f32; rows * d];
    let mut vp = vec![0.0f32; rows * d];
    matmul(pool, &h0, p.wq, rows, d, d, &mut qp);
    matmul(pool, &h0, p.wk, rows, d, d, &mut kp);
    matmul(pool, &h0, p.wv, rows, d, d, &mut vp);

    let (a, fq, fk, vext, u) = match cfg.attn {
        AttnKind::Softmax => {
            let sh = LayerShape::cube(bsz, l, d);
            let scale = 1.0 / (d as f32).sqrt();
            let a = softmax_fwd(pool, &qp, &kp, &vp, sh, scale);
            (a, Vec::new(), Vec::new(), Vec::new(), Vec::new())
        }
        kind => {
            let gamma = attn_gamma(kind);
            let fq: Vec<f32> = qp.iter().map(|&x| elu1(x)).collect();
            let fk: Vec<f32> = kp.iter().map(|&x| elu1(x)).collect();
            let mut vext = vec![0.0f32; rows * (d + 1)];
            for r in 0..rows {
                vext[r * (d + 1)..][..d].copy_from_slice(&vp[r * d..][..d]);
                vext[r * (d + 1) + d] = 1.0;
            }
            let sh = LayerShape { bh: bsz, n: l, dk: d, dv: d + 1 };
            let u = la_scan_fwd(pool, &fq, &fk, &vext, sh, gamma);
            let mut a = vec![0.0f32; rows * d];
            for r in 0..rows {
                let ur = &u[r * (d + 1)..][..d + 1];
                let z = ur[d] + EPS;
                let ar = &mut a[r * d..][..d];
                for (ax, ux) in ar.iter_mut().zip(ur) {
                    *ax = ux / z;
                }
            }
            (a, fq, fk, vext, u)
        }
    };

    let mut h1 = h0.clone();
    matmul(pool, &a, p.wo, rows, d, d, &mut h1);
    let mut logits = vec![0.0f32; rows * v];
    for r in 0..rows {
        logits[r * v..][..v].copy_from_slice(p.bu);
    }
    matmul(pool, &h1, p.wu, rows, d, v, &mut logits);
    Ok((logits, Cache { h0, qp, kp, vp, a, fq, fk, vext, u, h1 }))
}

/// Mean cross-entropy of `logits` against `y`; optionally fills `dlogits`
/// with the loss gradient (softmax − onehot, scaled by 1/rows).
fn cross_entropy(
    logits: &[f32],
    y: &[i32],
    vocab: usize,
    mut dlogits: Option<&mut [f32]>,
) -> Result<f32> {
    let rows = y.len();
    let inv_rows = 1.0 / rows as f32;
    let mut loss = 0.0f64;
    for (r, &target) in y.iter().enumerate() {
        if target < 0 || target as usize >= vocab {
            bail!("target id {target} out of range [0, {vocab})");
        }
        let lr = &logits[r * vocab..][..vocab];
        let m = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &x in lr {
            z += (x - m).exp();
        }
        loss += (m as f64) + (z as f64).ln() - lr[target as usize] as f64;
        if let Some(dl) = dlogits.as_deref_mut() {
            let dr = &mut dl[r * vocab..][..vocab];
            let inv_z = 1.0 / z;
            for (dx, &x) in dr.iter_mut().zip(lr) {
                *dx = (x - m).exp() * inv_z * inv_rows;
            }
            dr[target as usize] -= inv_rows;
        }
    }
    Ok((loss / rows as f64) as f32)
}

/// Forward + loss, no gradients (the `lm_*_eval` artifact body).
pub fn eval_loss(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &Tensor,
    pool: &ThreadPool,
) -> Result<f32> {
    let p = P::bind(cfg, params)?;
    let (x, y) = split_xy(cfg, tokens)?;
    let (logits, _cache) = forward(cfg, &p, &x, pool)?;
    cross_entropy(&logits, &y, cfg.vocab, None)
}

/// Forward only, over full-context token rows (the `lm_*_logits` artifact).
pub fn logits(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &Tensor,
    pool: &ThreadPool,
) -> Result<Tensor> {
    let p = P::bind(cfg, params)?;
    let x = tokens.as_i32()?;
    if tokens.shape() != [cfg.batch, cfg.n_ctx].as_slice() {
        bail!(
            "logits artifact wants tokens ({}, {}), got {:?}",
            cfg.batch,
            cfg.n_ctx,
            tokens.shape()
        );
    }
    let (lg, _cache) = forward(cfg, &p, x, pool)?;
    Tensor::f32(vec![cfg.batch, cfg.n_ctx, cfg.vocab], lg)
}

/// Split a `(batch, n_ctx+1)` token tensor into model inputs and next-token
/// targets.
fn split_xy(cfg: &LmConfig, tokens: &Tensor) -> Result<(Vec<i32>, Vec<i32>)> {
    if tokens.shape() != [cfg.batch, cfg.n_ctx + 1].as_slice() {
        bail!(
            "train/eval artifact wants tokens ({}, {}), got {:?}",
            cfg.batch,
            cfg.n_ctx + 1,
            tokens.shape()
        );
    }
    let data = tokens.as_i32()?;
    let row = cfg.n_ctx + 1;
    let mut x = Vec::with_capacity(cfg.batch * cfg.n_ctx);
    let mut y = Vec::with_capacity(cfg.batch * cfg.n_ctx);
    for b in 0..cfg.batch {
        let r = &data[b * row..][..row];
        x.extend_from_slice(&r[..cfg.n_ctx]);
        y.extend_from_slice(&r[1..]);
    }
    Ok((x, y))
}

/// Loss + gradients for every parameter array (state order).
fn loss_and_grads(
    cfg: &LmConfig,
    p: &P,
    x: &[i32],
    y: &[i32],
    pool: &ThreadPool,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let (bsz, l, d, v) = (cfg.batch, cfg.n_ctx, cfg.d_model, cfg.vocab);
    let rows = bsz * l;
    let (logits, cache) = forward(cfg, p, x, pool)?;
    let mut dlogits = vec![0.0f32; rows * v];
    let loss = cross_entropy(&logits, y, v, Some(&mut dlogits))?;

    let mut d_wte = vec![0.0f32; v * d];
    let mut d_wpe = vec![0.0f32; l * d];
    let mut d_wq = vec![0.0f32; d * d];
    let mut d_wk = vec![0.0f32; d * d];
    let mut d_wv = vec![0.0f32; d * d];
    let mut d_wo = vec![0.0f32; d * d];
    let mut d_wu = vec![0.0f32; d * v];
    let mut d_bu = vec![0.0f32; v];

    // logits = h1·wu + bu
    for r in 0..rows {
        let dr = &dlogits[r * v..][..v];
        for (db, g) in d_bu.iter_mut().zip(dr) {
            *db += g;
        }
    }
    matmul_dw(pool, &cache.h1, &dlogits, rows, d, v, &mut d_wu);
    let mut dh1 = vec![0.0f32; rows * d];
    matmul_dx(pool, &dlogits, p.wu, rows, d, v, &mut dh1);

    // h1 = h0 + a·wo
    let mut dh0 = dh1.clone();
    matmul_dw(pool, &cache.a, &dh1, rows, d, d, &mut d_wo);
    let mut da = vec![0.0f32; rows * d];
    matmul_dx(pool, &dh1, p.wo, rows, d, d, &mut da);

    // attention
    let (dqp, dkp, dvp) = match cfg.attn {
        AttnKind::Softmax => {
            let sh = LayerShape::cube(bsz, l, d);
            let scale = 1.0 / (d as f32).sqrt();
            softmax_bwd(pool, &cache.qp, &cache.kp, &cache.vp, &da, sh, scale)
        }
        kind => {
            let gamma = attn_gamma(kind);
            // a = u[..d] / z  with z = u[d] + EPS
            let mut du = vec![0.0f32; rows * (d + 1)];
            for r in 0..rows {
                let ur = &cache.u[r * (d + 1)..][..d + 1];
                let z = ur[d] + EPS;
                let dar = &da[r * d..][..d];
                let dur = &mut du[r * (d + 1)..][..d + 1];
                let mut dot = 0.0f32;
                for j in 0..d {
                    dur[j] = dar[j] / z;
                    dot += dar[j] * ur[j];
                }
                dur[d] = -dot / (z * z);
            }
            let sh = LayerShape { bh: bsz, n: l, dk: d, dv: d + 1 };
            let (dfq, dfk, dvext) =
                la_scan_bwd(pool, &cache.fq, &cache.fk, &cache.vext, &du, sh, gamma);
            let mut dqp = vec![0.0f32; rows * d];
            let mut dkp = vec![0.0f32; rows * d];
            let mut dvp = vec![0.0f32; rows * d];
            for i in 0..rows * d {
                dqp[i] = dfq[i] * elu1_grad(cache.qp[i]);
                dkp[i] = dfk[i] * elu1_grad(cache.kp[i]);
            }
            for r in 0..rows {
                dvp[r * d..][..d].copy_from_slice(&dvext[r * (d + 1)..][..d]);
            }
            (dqp, dkp, dvp)
        }
    };

    // q,k,v = h0 · w{q,k,v}
    matmul_dw(pool, &cache.h0, &dqp, rows, d, d, &mut d_wq);
    matmul_dw(pool, &cache.h0, &dkp, rows, d, d, &mut d_wk);
    matmul_dw(pool, &cache.h0, &dvp, rows, d, d, &mut d_wv);
    matmul_dx(pool, &dqp, p.wq, rows, d, d, &mut dh0);
    matmul_dx(pool, &dkp, p.wk, rows, d, d, &mut dh0);
    matmul_dx(pool, &dvp, p.wv, rows, d, d, &mut dh0);

    // h0 = wte[x] + wpe
    for (r, &tok) in x.iter().enumerate() {
        let g = &dh0[r * d..][..d];
        let te = &mut d_wte[tok as usize * d..][..d];
        for (dx, gx) in te.iter_mut().zip(g) {
            *dx += gx;
        }
        let pe = &mut d_wpe[(r % l) * d..][..d];
        for (dx, gx) in pe.iter_mut().zip(g) {
            *dx += gx;
        }
    }

    Ok((loss, vec![d_wte, d_wpe, d_wq, d_wk, d_wv, d_wo, d_wu, d_bu]))
}

/// One Adam step over the full state (the `lm_*_train_step` artifact body).
/// `state` is params ++ m ++ v; returns `[loss] ++ new state`.
pub fn train_step(
    cfg: &LmConfig,
    state: &[&Tensor],
    tokens: &Tensor,
    step: i64,
    pool: &ThreadPool,
) -> Result<Vec<Tensor>> {
    let np = cfg.n_params();
    if state.len() != 3 * np {
        bail!("train_step wants {} state arrays (params ++ m ++ v), got {}", 3 * np, state.len());
    }
    let p = P::bind(cfg, &state[..np])?;
    let (x, y) = split_xy(cfg, tokens)?;
    let (loss, grads) = loss_and_grads(cfg, &p, &x, &y, pool)?;

    let step = step.max(0) as usize;
    let lr = cfg.lr_at(step);
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let t1 = (step + 1) as i32;
    let bc1 = 1.0 - b1.powi(t1);
    let bc2 = 1.0 - b2.powi(t1);

    let shapes = cfg.param_shapes();
    let mut new_params = Vec::with_capacity(np);
    let mut new_m = Vec::with_capacity(np);
    let mut new_v = Vec::with_capacity(np);
    for i in 0..np {
        let pw = state[i].as_f32()?;
        let mw = state[np + i].as_f32()?;
        let vw = state[2 * np + i].as_f32()?;
        let g = &grads[i];
        if pw.len() != g.len() || mw.len() != g.len() || vw.len() != g.len() {
            bail!("state array {} has inconsistent length", shapes[i].0);
        }
        let mut p2 = Vec::with_capacity(g.len());
        let mut m2 = Vec::with_capacity(g.len());
        let mut v2 = Vec::with_capacity(g.len());
        for j in 0..g.len() {
            let m_new = b1 * mw[j] + (1.0 - b1) * g[j];
            let v_new = b2 * vw[j] + (1.0 - b2) * g[j] * g[j];
            let mh = m_new / bc1;
            let vh = v_new / bc2;
            p2.push(pw[j] - lr * mh / (vh.sqrt() + eps));
            m2.push(m_new);
            v2.push(v_new);
        }
        new_params.push(Tensor::f32(shapes[i].1.clone(), p2)?);
        new_m.push(Tensor::f32(shapes[i].1.clone(), m2)?);
        new_v.push(Tensor::f32(shapes[i].1.clone(), v2)?);
    }

    let mut out = Vec::with_capacity(1 + 3 * np);
    out.push(Tensor::scalar_f32(loss));
    out.extend(new_params);
    out.extend(new_m);
    out.extend(new_v);
    Ok(out)
}

/// Scalar from a rank-0/rank-1 tensor (seeds, step counters).
pub fn scalar_i64(t: &Tensor) -> Result<i64> {
    match t {
        Tensor::I32 { data, .. } => {
            data.first().map(|&x| x as i64).ok_or_else(|| anyhow!("empty scalar tensor"))
        }
        Tensor::F32 { data, .. } => {
            data.first().map(|&x| x as i64).ok_or_else(|| anyhow!("empty scalar tensor"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn refs(state: &[Tensor]) -> Vec<&Tensor> {
        state.iter().collect()
    }

    fn pool() -> ThreadPool {
        ThreadPool::new(2)
    }

    fn tiny_tokens(cfg: &LmConfig, seed: u64) -> Tensor {
        let mut rng = crate::data::rng::SplitMix64::new(seed);
        let n = cfg.batch * (cfg.n_ctx + 1);
        Tensor::i32(
            vec![cfg.batch, cfg.n_ctx + 1],
            (0..n).map(|_| rng.below(cfg.vocab) as i32).collect(),
        )
        .unwrap()
    }

    #[test]
    fn init_state_shapes_and_determinism() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let a = cfg.init_state(7);
        let b = cfg.init_state(7);
        assert_eq!(a.len(), 24);
        assert_eq!(a, b);
        let c = cfg.init_state(8);
        assert_ne!(a, c);
        for ((name, shape), t) in cfg.param_shapes().iter().zip(&a) {
            assert_eq!(t.shape(), shape.as_slice(), "{name}");
        }
    }

    #[test]
    fn fresh_model_loss_is_near_uniform() {
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::tiny(attn);
            let state = cfg.init_state(0);
            let toks = tiny_tokens(&cfg, 1);
            let s = refs(&state);
            let loss = eval_loss(&cfg, &s[..cfg.n_params()], &toks, &pool()).unwrap();
            let uniform = (cfg.vocab as f32).ln();
            assert!(
                (loss - uniform).abs() < 0.3,
                "{attn:?}: fresh loss {loss} vs ln(V) {uniform}"
            );
        }
    }

    #[test]
    fn train_step_reduces_loss_on_repeated_batch() {
        // overfit a single highly-structured batch (a short token cycle —
        // next-token is a deterministic function of the current token):
        // a few Adam steps must cut the loss clearly
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::tiny(attn);
            let mut state = cfg.init_state(3);
            let n = cfg.batch * (cfg.n_ctx + 1);
            let toks = Tensor::i32(
                vec![cfg.batch, cfg.n_ctx + 1],
                (0..n).map(|i| (i % 17) as i32).collect(),
            )
            .unwrap();
            let mut first = f32::NAN;
            let mut last = f32::NAN;
            for step in 0..20 {
                let s = refs(&state);
                let out = train_step(&cfg, &s, &toks, step, &pool()).unwrap();
                let loss = out[0].scalar().unwrap();
                assert!(loss.is_finite(), "{attn:?} step {step}");
                if step == 0 {
                    first = loss;
                }
                last = loss;
                state = out[1..].to_vec();
            }
            assert!(
                last < first - 0.3,
                "{attn:?}: loss did not drop ({first} → {last})"
            );
        }
    }

    #[test]
    fn logits_shape_matches_artifact_contract() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let state = cfg.init_state(0);
        let s = refs(&state);
        let toks = Tensor::i32(
            vec![cfg.batch, cfg.n_ctx],
            vec![5; cfg.batch * cfg.n_ctx],
        )
        .unwrap();
        let lg = logits(&cfg, &s[..cfg.n_params()], &toks, &pool()).unwrap();
        assert_eq!(lg.shape(), &[cfg.batch, cfg.n_ctx, cfg.vocab]);
        assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        assert!(cfg.lr_at(0) < cfg.lr_at(cfg.warmup_steps - 1) + 1e-9);
        let peak = cfg.lr_at(cfg.warmup_steps);
        assert!((peak - cfg.lr_max as f32).abs() < 1e-6);
        assert!(cfg.lr_at(cfg.total_steps) <= cfg.lr_min as f32 + 1e-6);
    }

    #[test]
    fn rejects_out_of_range_tokens() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let state = cfg.init_state(0);
        let s = refs(&state);
        let mut data = vec![0i32; cfg.batch * (cfg.n_ctx + 1)];
        data[3] = cfg.vocab as i32; // one past the end
        let toks = Tensor::i32(vec![cfg.batch, cfg.n_ctx + 1], data).unwrap();
        assert!(eval_loss(&cfg, &s[..cfg.n_params()], &toks, &pool()).is_err());
    }
}
