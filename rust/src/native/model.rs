//! The native language model: a block-structured pre-norm Transformer with a
//! pluggable attention mixer (ours / gated / softmax), hand-derived backward
//! pass, and an in-tree AdamW optimizer — the `lm_*` artifact family,
//! executed directly on host `f32` slices.
//!
//! The optimizer ships two routes over the same per-element arithmetic
//! ([`adamw_elem`]): the hot path is [`train_step_mut`], which mutates the
//! `params ++ m ++ v` state buffers in place (fused m/v/param loop,
//! parallelized over the pool one parameter array per task), and the
//! preserved baseline is [`train_step`], which rebuilds the full state as
//! freshly-allocated tensors every call — kept as the parity oracle and the
//! `bench-native` speedup reference. Both apply global grad-norm clipping
//! (`clip_norm`, 0 disables) before the moment update and decoupled weight
//! decay (`weight_decay`, applied to ≥2-D parameter arrays only, never to
//! the Adam moments), and both report the *pre-clip* gradient norm as a
//! training metric.
//!
//! Architecture (`n_layer` blocks, `n_head` heads of dim `d_model/n_head`):
//!   h = wte[x] + wpe                     (token + position embedding)
//!   for each block:
//!     h = h + MHA(LN₁(h))·wo             (pre-norm attention + residual)
//!     h = h + GELU(LN₂(h)·w1 + b1)·w2 + b2   (pre-norm MLP + residual)
//!   logits = LN_f(h)·wu + bu
//! with mean cross-entropy over next-token targets. Only the attention mixer
//! differs between artifact variants — the paper's end-to-end claim is that
//! swapping softmax attention for the linear form preserves expressivity
//! while cutting per-step cost, so everything around the mixer is shared.
//!
//! Per block, the `rows × d_model` projections are split into `n_head`
//! head-major `(B·H, L, hd)` buffers and dispatched through the same
//! parallel kernels the standalone layer artifacts use: the `ours`/`gated`
//! variants run the paper's linear-attention state scan
//! (`kernels::la_scan_*`) over positive features `φ(x) = elu(x)+1`, with the
//! normalizer computed by the standard ones-channel trick (`v` gains a
//! constant-1 channel, so one scan yields both numerator and denominator and
//! the backward reuses the same analytic two-pass kernel); `softmax` runs
//! the streaming causal softmax kernels at scale `1/√hd`.
//!
//! The pre-refactor single-layer, single-head, LayerNorm/MLP-free model is
//! still expressible as [`LmConfig::legacy_tiny`] (`n_layer = 1`, `n_head =
//! 1`, `d_ff = 0`, `layernorm = false`) — the regression test pins the
//! refactor to its exact loss trajectory.

use anyhow::{anyhow, bail, Result};

use crate::infer::state::{AttnState, DecodeState};
use crate::runtime::Tensor;

use super::gemm;
use super::kernels::{
    la_chunk_fwd_carry, la_scan_bwd, la_scan_fwd, softmax_bwd, softmax_fwd, LayerShape,
};
use super::pool::ThreadPool;
use super::quant::{self, QuantBuf};

pub use super::quant::Precision;

/// Normalizer floor for the linear-attention denominator.
const EPS: f32 = 1e-6;
/// Decay of the gated variant's state.
const GATED_DECAY: f32 = 0.95;
/// LayerNorm variance floor.
const LN_EPS: f32 = 1e-5;
/// √(2/π) — the GELU tanh-approximation constant.
const GELU_C: f32 = 0.797_884_56;
/// Cubic coefficient of the GELU tanh approximation.
const GELU_CUBE: f32 = 0.044_715;

/// Attention variant of one LM artifact family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    Ours,
    Gated,
    Softmax,
}

impl AttnKind {
    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "ours" => AttnKind::Ours,
            "gated" => AttnKind::Gated,
            "softmax" => AttnKind::Softmax,
            other => bail!("unknown attention variant {other:?}"),
        })
    }
}

/// Static configuration of one LM preset.
#[derive(Debug, Clone, Copy)]
pub struct LmConfig {
    pub vocab: usize,
    pub n_ctx: usize,
    pub d_model: usize,
    /// Number of Transformer blocks.
    pub n_layer: usize,
    /// Attention heads per block; `d_model` must divide evenly.
    pub n_head: usize,
    /// MLP hidden width; 0 drops the MLP sub-block (legacy architecture).
    pub d_ff: usize,
    /// Pre-norm LayerNorms around each sub-block plus a final LayerNorm;
    /// false is the legacy architecture.
    pub layernorm: bool,
    pub batch: usize,
    pub attn: AttnKind,
    pub lr_max: f64,
    pub lr_min: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    /// Decoupled AdamW weight decay, applied to ≥2-D parameter arrays only
    /// (weights and embeddings; never biases, LayerNorm affines, or the
    /// Adam moments). 0 disables.
    pub weight_decay: f64,
    /// Global gradient-norm clip threshold; gradients are rescaled when the
    /// global L2 norm exceeds it. 0 disables.
    pub clip_norm: f64,
    /// Storage precision of the *decode* path: the GEMM-dominant weight
    /// blocks (attention projections, MLP, unembedding) and the per-session
    /// decode state (recurrent `S` matrices / KV cache). Training always
    /// runs f32; embeddings, LayerNorm affines and biases stay f32 at every
    /// setting. Compute accumulates in f32 regardless.
    pub precision: Precision,
}

impl LmConfig {
    /// The `tiny` preset — 2 blocks × 2 heads, byte vocab; a training step
    /// stays in the tens of MFLOPs so tests can afford dozens of them.
    pub fn tiny(attn: AttnKind) -> Self {
        Self {
            vocab: 256,
            n_ctx: 64,
            d_model: 64,
            n_layer: 2,
            n_head: 2,
            d_ff: 128,
            layernorm: true,
            batch: 8,
            attn,
            lr_max: 1e-2,
            lr_min: 1e-3,
            warmup_steps: 3,
            total_steps: 400,
            weight_decay: 0.01,
            clip_norm: 1.0,
            precision: Precision::F32,
        }
    }

    /// The `small` preset — 4 blocks × 4 heads, wider residual stream, and a
    /// BPE vocabulary above the byte range (exercises the trained
    /// `ByteTokenizer` merges).
    pub fn small(attn: AttnKind) -> Self {
        Self {
            vocab: 512,
            n_ctx: 128,
            d_model: 128,
            n_layer: 4,
            n_head: 4,
            d_ff: 512,
            layernorm: true,
            batch: 8,
            attn,
            lr_max: 5e-3,
            lr_min: 5e-4,
            warmup_steps: 5,
            total_steps: 1000,
            weight_decay: 0.01,
            clip_norm: 1.0,
            precision: Precision::F32,
        }
    }

    /// The `medium` preset — 8 blocks × 8 heads on a 256-wide residual
    /// stream (~6.6M params), trained on a corpus four times the small
    /// preset's (see [`corpus_bytes_hint`](Self::corpus_bytes_hint)).
    pub fn medium(attn: AttnKind) -> Self {
        Self {
            vocab: 512,
            n_ctx: 128,
            d_model: 256,
            n_layer: 8,
            n_head: 8,
            d_ff: 1024,
            layernorm: true,
            batch: 8,
            attn,
            lr_max: 3e-3,
            lr_min: 3e-4,
            warmup_steps: 20,
            total_steps: 2000,
            weight_decay: 0.01,
            clip_norm: 1.0,
            precision: Precision::F32,
        }
    }

    /// The pre-refactor architecture: one block, one head, no LayerNorm, no
    /// MLP, plain Adam (no decay, no clipping). Kept so the block-structured
    /// code path can be regression-pinned against the original hand-unrolled
    /// model.
    pub fn legacy_tiny(attn: AttnKind) -> Self {
        Self {
            vocab: 256,
            n_ctx: 64,
            d_model: 64,
            n_layer: 1,
            n_head: 1,
            d_ff: 0,
            layernorm: false,
            batch: 8,
            attn,
            lr_max: 5e-2,
            lr_min: 5e-3,
            warmup_steps: 3,
            total_steps: 400,
            weight_decay: 0.0,
            clip_norm: 0.0,
            precision: Precision::F32,
        }
    }

    /// Preset lookup by manifest name.
    pub fn by_preset(name: &str, attn: AttnKind) -> Result<Self> {
        let cfg = match name {
            "tiny" => Self::tiny(attn),
            "small" => Self::small(attn),
            "medium" => Self::medium(attn),
            other => bail!("unknown LM preset {other:?} (native ships tiny, small, medium)"),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The presets registered in the native manifest.
    pub fn preset_names() -> &'static [&'static str] {
        &["tiny", "small", "medium"]
    }

    /// Default synthetic-corpus size (bytes) for training this preset —
    /// bigger models want more data. Recorded in the artifact manifest's
    /// train section; the trainer uses it when the run config leaves
    /// `data.corpus_bytes` on auto (0).
    pub fn corpus_bytes_hint(&self) -> usize {
        // scale with capacity: ~6.6M-param medium gets 4× the 2 MiB base
        if self.n_params() > 2_000_000 {
            4 * crate::data::DEFAULT_CORPUS_BYTES
        } else {
            crate::data::DEFAULT_CORPUS_BYTES
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_layer == 0 {
            bail!("n_layer must be ≥ 1");
        }
        if self.n_head == 0 || self.d_model % self.n_head != 0 {
            bail!("n_head {} must divide d_model {}", self.n_head, self.d_model);
        }
        Ok(())
    }

    /// Per-head dimension.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_head
    }

    /// Parameter arrays, in state order: `(name, shape)`. Block parameters
    /// are layer-indexed (`h3.wq`, `h3.ln2_g`, …); the walk order here is
    /// the single source of truth for [`param_idx`](Self::param_idx), the
    /// checkpoint layout, and the Adam state layout.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let (v, l, d, f) = (self.vocab, self.n_ctx, self.d_model, self.d_ff);
        let mut out: Vec<(String, Vec<usize>)> = Vec::new();
        out.push(("wte".to_string(), vec![v, d]));
        out.push(("wpe".to_string(), vec![l, d]));
        for b in 0..self.n_layer {
            if self.layernorm {
                out.push((format!("h{b}.ln1_g"), vec![d]));
                out.push((format!("h{b}.ln1_b"), vec![d]));
            }
            for w in ["wq", "wk", "wv", "wo"] {
                out.push((format!("h{b}.{w}"), vec![d, d]));
            }
            if f > 0 {
                if self.layernorm {
                    out.push((format!("h{b}.ln2_g"), vec![d]));
                    out.push((format!("h{b}.ln2_b"), vec![d]));
                }
                out.push((format!("h{b}.w1"), vec![d, f]));
                out.push((format!("h{b}.b1"), vec![f]));
                out.push((format!("h{b}.w2"), vec![f, d]));
                out.push((format!("h{b}.b2"), vec![d]));
            }
        }
        if self.layernorm {
            out.push(("lnf_g".to_string(), vec![d]));
            out.push(("lnf_b".to_string(), vec![d]));
        }
        out.push(("wu".to_string(), vec![d, v]));
        out.push(("bu".to_string(), vec![v]));
        out
    }

    /// Positions of each parameter array in the state vector; mirrors the
    /// walk order of [`param_shapes`](Self::param_shapes).
    fn param_idx(&self) -> ParamIdx {
        let mut i = 0usize;
        let mut take = |n: usize| {
            let j = i;
            i += n;
            j
        };
        let wte = take(1);
        let wpe = take(1);
        let mut blocks = Vec::with_capacity(self.n_layer);
        for _ in 0..self.n_layer {
            let ln1 = self.layernorm.then(|| take(2));
            let wq = take(4); // wq, wk, wv, wo
            let (ln2, mlp) = if self.d_ff > 0 {
                (self.layernorm.then(|| take(2)), Some(take(4))) // w1, b1, w2, b2
            } else {
                (None, None)
            };
            blocks.push(BlockIdx { ln1, wq, ln2, mlp });
        }
        let lnf = self.layernorm.then(|| take(2));
        let wu = take(1);
        let bu = take(1);
        ParamIdx { wte, wpe, blocks, lnf, wu, bu, count: i }
    }

    /// Number of parameter *arrays* in the state layout.
    pub fn n_param_arrays(&self) -> usize {
        self.param_idx().count
    }

    /// True scalar parameter count (sum over all array elements).
    pub fn n_params(&self) -> u64 {
        self.param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>() as u64)
            .sum()
    }

    /// Learning rate at a 0-based step: linear warmup then cosine decay.
    pub fn lr_at(&self, step: usize) -> f32 {
        if step < self.warmup_steps {
            return (self.lr_max * (step + 1) as f64 / self.warmup_steps as f64) as f32;
        }
        let span = self.total_steps.saturating_sub(self.warmup_steps).max(1) as f64;
        let frac = ((step - self.warmup_steps) as f64 / span).clamp(0.0, 1.0);
        (self.lr_min + 0.5 * (self.lr_max - self.lr_min) * (1.0 + (std::f64::consts::PI * frac).cos()))
            as f32
    }

    /// Fresh training state: params ++ adam_m ++ adam_v. Weights are
    /// `randn × 0.02` seeded per array index; biases and LayerNorm shifts
    /// start at zero, LayerNorm gains at one.
    pub fn init_state(&self, seed: u64) -> Vec<Tensor> {
        let shapes = self.param_shapes();
        let mut out = Vec::with_capacity(3 * shapes.len());
        for (i, (name, shape)) in shapes.iter().enumerate() {
            let last = name.rsplit('.').next().unwrap_or(name);
            let t = match last {
                "ln1_g" | "ln2_g" | "lnf_g" => {
                    let n: usize = shape.iter().product();
                    Tensor::f32(shape.clone(), vec![1.0f32; n]).expect("static shape")
                }
                "ln1_b" | "ln2_b" | "lnf_b" | "b1" | "b2" | "bu" => {
                    Tensor::zeros(crate::runtime::DType::F32, shape.clone())
                }
                _ => {
                    let mut t = Tensor::randn(shape.clone(), seed ^ ((i as u64 + 1) * 0x9E3779B9));
                    if let Tensor::F32 { data, .. } = &mut t {
                        for x in data.iter_mut() {
                            *x *= 0.02;
                        }
                    }
                    t
                }
            };
            out.push(t);
        }
        for (_, shape) in shapes.iter().chain(shapes.iter()) {
            out.push(Tensor::zeros(crate::runtime::DType::F32, shape.clone()));
        }
        out
    }
}

/// Positions of one block's parameter arrays in the state vector.
#[derive(Debug, Clone, Copy)]
struct BlockIdx {
    /// `ln1_g` position (`ln1_b` follows), when `layernorm`.
    ln1: Option<usize>,
    /// `wq` position; `wk`, `wv`, `wo` follow.
    wq: usize,
    /// `ln2_g` position (`ln2_b` follows), when `layernorm` and `d_ff > 0`.
    ln2: Option<usize>,
    /// `w1` position (`b1`, `w2`, `b2` follow), when `d_ff > 0`.
    mlp: Option<usize>,
}

#[derive(Debug, Clone)]
struct ParamIdx {
    wte: usize,
    wpe: usize,
    blocks: Vec<BlockIdx>,
    lnf: Option<usize>,
    wu: usize,
    bu: usize,
    count: usize,
}

/// Borrowed views of every parameter array, shape-checked against the
/// config's layout.
struct P<'a> {
    arrs: Vec<&'a [f32]>,
    idx: ParamIdx,
}

impl<'a> P<'a> {
    // the outer slice only needs to live for the bind itself — the views
    // borrow the tensors, so callers may pass a temporary Vec of refs
    fn bind(cfg: &LmConfig, params: &[&'a Tensor]) -> Result<Self> {
        let shapes = cfg.param_shapes();
        if params.len() < shapes.len() {
            bail!("expected {} parameter arrays, got {}", shapes.len(), params.len());
        }
        let mut arrs = Vec::with_capacity(shapes.len());
        for ((name, shape), t) in shapes.iter().zip(params) {
            if t.shape() != shape.as_slice() {
                bail!("param {name}: expected shape {shape:?}, got {:?}", t.shape());
            }
            arrs.push(t.as_f32()?);
        }
        Ok(Self { arrs, idx: cfg.param_idx() })
    }

    fn at(&self, i: usize) -> &'a [f32] {
        self.arrs[i]
    }
}

// --- decode-side parameter views (any storage precision) ---------------------

/// One decode parameter array at its storage precision. The f32 variant is
/// a plain borrow (the bit-exact baseline path); the quantized variants
/// borrow a [`QuantModel`] block and are consumed by the widening GEMM
/// microkernels.
#[derive(Clone, Copy)]
pub(crate) enum WView<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    Int8 { q: &'a [i8], scales: &'a [f32] },
}

/// Decode twin of [`P`]: the same shape-checked, bind-once parameter walk,
/// but each array is a [`WView`] so the GEMM-dominant weights can live in
/// bf16/int8. Embeddings, LayerNorm affines and biases are always f32 (the
/// construction paths guarantee it), which is what [`Self::at`] relies on.
struct DecodeP<'a> {
    arrs: Vec<WView<'a>>,
    idx: ParamIdx,
}

impl<'a> DecodeP<'a> {
    /// All-f32 views over full-precision tensors — identical binding (and
    /// identical downstream arithmetic) to the pre-quantization decode path.
    fn from_f32(cfg: &LmConfig, params: &[&'a Tensor]) -> Result<Self> {
        let p = P::bind(cfg, params)?;
        Ok(Self { arrs: p.arrs.iter().map(|a| WView::F32(a)).collect(), idx: p.idx })
    }

    /// Views over a quantized parameter set (shape/row-checked per array).
    fn from_quant(cfg: &LmConfig, qm: &'a QuantModel) -> Result<Self> {
        let shapes = cfg.param_shapes();
        if qm.arrs.len() != shapes.len() {
            bail!("expected {} parameter arrays, got {}", shapes.len(), qm.arrs.len());
        }
        let mut arrs = Vec::with_capacity(shapes.len());
        for ((name, shape), buf) in shapes.iter().zip(&qm.arrs) {
            let numel: usize = shape.iter().product();
            if buf.len() != numel {
                bail!("param {name}: expected {numel} elements, got {}", buf.len());
            }
            arrs.push(match buf {
                QuantBuf::F32(d) => WView::F32(d),
                QuantBuf::Bf16(d) => {
                    if !quantized_weight(name) {
                        bail!("param {name} must stay f32 (got bf16)");
                    }
                    WView::Bf16(d)
                }
                QuantBuf::Int8 { q, scales, row } => {
                    if !quantized_weight(name) {
                        bail!("param {name} must stay f32 (got int8)");
                    }
                    let want_row = *shape.last().unwrap_or(&1);
                    if *row != want_row {
                        bail!("param {name}: int8 row {row} != last dim {want_row}");
                    }
                    WView::Int8 { q, scales }
                }
            });
        }
        Ok(Self { arrs, idx: cfg.param_idx() })
    }

    /// The f32 slice of a parameter that is always stored full-precision
    /// (embeddings, LayerNorm affines, biases).
    fn at(&self, i: usize) -> &'a [f32] {
        match self.arrs[i] {
            WView::F32(d) => d,
            // construction rejects quantized storage for these arrays
            _ => unreachable!("non-f32 storage for an always-f32 parameter"),
        }
    }

    /// The storage-precision view of a (possibly quantized) weight block.
    fn w(&self, i: usize) -> WView<'a> {
        self.arrs[i]
    }
}

/// True for the parameter arrays the [`Precision`] knob quantizes: the
/// GEMM-dominant weights of the decode hot path (attention projections, MLP
/// matrices, unembedding). Embeddings (row-gather, negligible traffic),
/// LayerNorm affines and biases stay f32.
fn quantized_weight(name: &str) -> bool {
    let last = name.rsplit('.').next().unwrap_or(name);
    matches!(last, "wq" | "wk" | "wv" | "wo" | "w1" | "w2" | "wu")
}

/// The full parameter set of one LM at a storage [`Precision`]: quantized
/// blocks for the decode-dominant weights, f32 for everything else. This is
/// what a layout-v3 checkpoint stores and what [`DecodeModel::bind_quantized`]
/// binds — the owning counterpart of the borrowed [`WView`]s.
#[derive(Debug, Clone)]
pub struct QuantModel {
    cfg: LmConfig,
    arrs: Vec<QuantBuf>,
}

impl QuantModel {
    /// Quantize a full-precision parameter set offline (`repro quantize`,
    /// the bench's on-the-fly comparison points). `cfg.precision` of the
    /// stored config is forced to `precision` so downstream state
    /// construction agrees with the weights.
    pub fn from_params(cfg: &LmConfig, params: &[&Tensor], precision: Precision) -> Result<Self> {
        let shapes = cfg.param_shapes();
        if params.len() < shapes.len() {
            bail!("expected {} parameter arrays, got {}", shapes.len(), params.len());
        }
        let mut arrs = Vec::with_capacity(shapes.len());
        for ((name, shape), t) in shapes.iter().zip(params) {
            if t.shape() != shape.as_slice() {
                bail!("param {name}: expected shape {shape:?}, got {:?}", t.shape());
            }
            let data = t.as_f32()?;
            let row = *shape.last().unwrap_or(&1);
            let buf = if quantized_weight(name) {
                QuantBuf::from_f32(data, row, precision)
            } else {
                QuantBuf::F32(data.to_vec())
            };
            arrs.push(buf);
        }
        let mut cfg = *cfg;
        cfg.precision = precision;
        Ok(Self { cfg, arrs })
    }

    /// Rebuild from deserialized arrays (the layout-v3 checkpoint load
    /// path). Array order is the [`LmConfig::param_shapes`] walk; every
    /// array is length- and storage-checked.
    pub fn from_arrays(cfg: &LmConfig, precision: Precision, arrs: Vec<QuantBuf>) -> Result<Self> {
        let mut cfg = *cfg;
        cfg.precision = precision;
        let shapes = cfg.param_shapes();
        if arrs.len() != shapes.len() {
            bail!("expected {} parameter arrays, got {}", shapes.len(), arrs.len());
        }
        for ((name, shape), buf) in shapes.iter().zip(&arrs) {
            let numel: usize = shape.iter().product();
            if buf.len() != numel {
                bail!("param {name}: expected {numel} elements, got {}", buf.len());
            }
            if quantized_weight(name) {
                if buf.precision() != precision {
                    bail!(
                        "param {name}: stored as {}, checkpoint precision is {}",
                        buf.precision(),
                        precision
                    );
                }
            } else if buf.precision() != Precision::F32 {
                bail!("param {name} must stay f32 (got {})", buf.precision());
            }
        }
        let qm = Self { cfg, arrs };
        // reuse the view construction for the remaining structural checks
        DecodeP::from_quant(&qm.cfg, &qm)?;
        Ok(qm)
    }

    /// The model config, with `precision` set to this parameter set's
    /// storage precision.
    pub fn cfg(&self) -> &LmConfig {
        &self.cfg
    }

    pub fn precision(&self) -> Precision {
        self.cfg.precision
    }

    /// True stored parameter footprint in bytes (data + scale vectors).
    pub fn param_bytes(&self) -> usize {
        self.arrs.iter().map(|b| b.bytes()).sum()
    }

    /// The stored arrays, in [`LmConfig::param_shapes`] walk order.
    pub fn arrays(&self) -> &[QuantBuf] {
        &self.arrs
    }
}

// --- dense helpers (row-major, accumulate into `out`) -----------------------
//
// Thin aliases over the tiled [`gemm`] microkernels, parallel across output
// row stripes when the product is large enough to amortize a dispatch.

/// out[r,j] += x[r,c] · w[c,j]
fn matmul(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
) {
    gemm::par_gemm_nn(pool, x, w, rows, cin, cout, out);
}

/// out[r,j] += x[r,c] · w[c,j] with `w` at its storage precision. The f32
/// arm is the same call as [`matmul`] — bit-exact with the pre-quantization
/// path — while the bf16/int8 arms widen to f32 accumulators inside the
/// tiled microkernels.
fn matmul_q(
    pool: &ThreadPool,
    x: &[f32],
    w: WView<'_>,
    rows: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
) {
    match w {
        WView::F32(w) => gemm::par_gemm_nn(pool, x, w, rows, cin, cout, out),
        WView::Bf16(w) => gemm::par_gemm_nn_bf16(pool, x, w, rows, cin, cout, out),
        WView::Int8 { q, scales } => {
            gemm::par_gemm_nn_i8(pool, x, q, scales, rows, cin, cout, out)
        }
    }
}

/// dx[r,c] += dout[r,j] · w[c,j]
fn matmul_dx(
    pool: &ThreadPool,
    dout: &[f32],
    w: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    dx: &mut [f32],
) {
    gemm::par_gemm_nt(pool, dout, w, rows, cout, cin, dx);
}

/// dw[c,j] += x[r,c] · dout[r,j]
fn matmul_dw(
    pool: &ThreadPool,
    x: &[f32],
    dout: &[f32],
    rows: usize,
    cin: usize,
    cout: usize,
    dw: &mut [f32],
) {
    gemm::par_gemm_tn(pool, x, dout, cin, rows, cout, dw);
}

// --- elementwise nonlinearities ----------------------------------------------

fn elu1(x: f32) -> f32 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

fn elu1_grad(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else {
        x.exp()
    }
}

/// GELU, tanh approximation.
fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_CUBE * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_CUBE * x * x * x);
    let t = u.tanh();
    let du = GELU_C * (1.0 + 3.0 * GELU_CUBE * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

// --- LayerNorm ----------------------------------------------------------------

/// Per-row mean / inverse stddev saved by the forward pass.
struct LnCache {
    mean: Vec<f32>,
    rstd: Vec<f32>,
}

/// y[r] = g ⊙ (x[r] − mean)·rstd + b, per row of `d` features.
fn ln_fwd(x: &[f32], g: &[f32], b: &[f32], rows: usize, d: usize) -> (Vec<f32>, LnCache) {
    let mut y = vec![0.0f32; rows * d];
    let mut mean = vec![0.0f32; rows];
    let mut rstd = vec![0.0f32; rows];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xr = &x[r * d..][..d];
        let m = xr.iter().sum::<f32>() * inv_d;
        let var = xr.iter().map(|&v| (v - m) * (v - m)).sum::<f32>() * inv_d;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        mean[r] = m;
        rstd[r] = rs;
        let yr = &mut y[r * d..][..d];
        for j in 0..d {
            yr[j] = g[j] * ((xr[j] - m) * rs) + b[j];
        }
    }
    (y, LnCache { mean, rstd })
}

/// [`ln_fwd`] into a caller-held buffer, without building the backward
/// cache — the decode path's allocation-free variant.
// deny_alloc
// bounds: row spans r*d..r*d+d sit inside the entry debug_assert on y.len();
// x/g/b spans match by the caller's shape contract
fn ln_fwd_into(x: &[f32], g: &[f32], b: &[f32], rows: usize, d: usize, y: &mut [f32]) {
    debug_assert_eq!(y.len(), rows * d);
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xr = &x[r * d..][..d];
        let m = xr.iter().sum::<f32>() * inv_d;
        let var = xr.iter().map(|&v| (v - m) * (v - m)).sum::<f32>() * inv_d;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        let yr = &mut y[r * d..][..d];
        for j in 0..d {
            yr[j] = g[j] * ((xr[j] - m) * rs) + b[j];
        }
    }
}

/// Accumulates `dx += ∂L/∂x`, `dg += ∂L/∂g`, `db += ∂L/∂b` given the
/// upstream gradient `dy` and the forward cache.
#[allow(clippy::too_many_arguments)]
fn ln_bwd(
    x: &[f32],
    g: &[f32],
    cache: &LnCache,
    dy: &[f32],
    rows: usize,
    d: usize,
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xr = &x[r * d..][..d];
        let dyr = &dy[r * d..][..d];
        let (m, rs) = (cache.mean[r], cache.rstd[r]);
        let mut s1 = 0.0f32; // Σ dxhat
        let mut s2 = 0.0f32; // Σ dxhat·xhat
        for j in 0..d {
            let xhat = (xr[j] - m) * rs;
            let dxhat = dyr[j] * g[j];
            dg[j] += dyr[j] * xhat;
            db[j] += dyr[j];
            s1 += dxhat;
            s2 += dxhat * xhat;
        }
        s1 *= inv_d;
        s2 *= inv_d;
        let dxr = &mut dx[r * d..][..d];
        for j in 0..d {
            let xhat = (xr[j] - m) * rs;
            let dxhat = dyr[j] * g[j];
            dxr[j] += rs * (dxhat - s1 - xhat * s2);
        }
    }
}

// --- multi-head reshapes --------------------------------------------------------

/// Token-major `(B·L, H·hd)` → head-major `(B·H, L, hd)`.
fn split_heads(x: &[f32], bsz: usize, l: usize, n_head: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    split_heads_into(x, bsz, l, n_head, hd, &mut out);
    out
}

/// [`split_heads`] into a caller-held buffer (fully overwritten).
// deny_alloc
// bounds: (b, h, t) index arithmetic is a permutation of 0..x.len(), which
// the entry debug_assert pins to out.len()
fn split_heads_into(x: &[f32], bsz: usize, l: usize, n_head: usize, hd: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), x.len());
    let d = n_head * hd;
    for b in 0..bsz {
        for h in 0..n_head {
            for t in 0..l {
                let src = &x[((b * l + t) * d + h * hd)..][..hd];
                out[((b * n_head + h) * l + t) * hd..][..hd].copy_from_slice(src);
            }
        }
    }
}

/// Head-major `(B·H, L, hd)` → token-major `(B·L, H·hd)` (inverse of
/// [`split_heads`]).
fn merge_heads(xh: &[f32], bsz: usize, l: usize, n_head: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; xh.len()];
    merge_heads_into(xh, bsz, l, n_head, hd, &mut out);
    out
}

/// [`merge_heads`] into a caller-held buffer (fully overwritten).
// deny_alloc
// bounds: inverse permutation of split_heads_into — same entry debug_assert
fn merge_heads_into(xh: &[f32], bsz: usize, l: usize, n_head: usize, hd: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), xh.len());
    let d = n_head * hd;
    for b in 0..bsz {
        for h in 0..n_head {
            for t in 0..l {
                let src = &xh[(((b * n_head + h) * l + t) * hd)..][..hd];
                out[(b * l + t) * d + h * hd..][..hd].copy_from_slice(src);
            }
        }
    }
}

// --- forward ----------------------------------------------------------------

pub(crate) fn attn_gamma(kind: AttnKind) -> f32 {
    match kind {
        AttnKind::Gated => GATED_DECAY,
        _ => 1.0,
    }
}

/// Per-variant tensors the attention backward needs (all head-major).
enum AttnCache {
    Softmax {
        qh: Vec<f32>,
        kh: Vec<f32>,
        vh: Vec<f32>,
    },
    Linear {
        /// pre-feature projections (for the elu′ chain)
        qh: Vec<f32>,
        kh: Vec<f32>,
        /// φ(q), φ(k), extended v, raw scan output u
        fq: Vec<f32>,
        fk: Vec<f32>,
        vext: Vec<f32>,
        u: Vec<f32>,
    },
}

/// Everything one block's backward pass needs from its forward pass.
struct BlockCache {
    /// block input (rows × d)
    h_in: Vec<f32>,
    ln1: Option<LnCache>,
    /// attention sub-block input: LN₁(h_in), or h_in itself when !layernorm
    x1: Vec<f32>,
    att: AttnCache,
    /// merged attention output (rows × d), pre-`wo`
    a: Vec<f32>,
    /// after the attention residual
    h_mid: Vec<f32>,
    ln2: Option<LnCache>,
    /// MLP sub-block input (when `d_ff > 0`)
    x2: Option<Vec<f32>>,
    /// pre-GELU hidden (rows × d_ff)
    m1: Option<Vec<f32>>,
    /// post-GELU hidden
    gact: Option<Vec<f32>>,
}

/// Full forward cache.
struct Cache {
    blocks: Vec<BlockCache>,
    /// residual stream after the last block
    h_last: Vec<f32>,
    lnf: Option<LnCache>,
    /// unembedding input: LN_f(h_last), or h_last when !layernorm
    xf: Vec<f32>,
}

/// One block: pre-norm attention + residual, then pre-norm MLP + residual.
/// Consumes the incoming residual stream and returns (h_out, cache).
fn block_forward(
    cfg: &LmConfig,
    p: &P,
    bi: &BlockIdx,
    h_in: Vec<f32>,
    pool: &ThreadPool,
) -> (Vec<f32>, BlockCache) {
    let (bsz, l, d) = (cfg.batch, cfg.n_ctx, cfg.d_model);
    let (nh, hd) = (cfg.n_head, cfg.head_dim());
    let rows = bsz * l;

    let (x1, ln1) = match bi.ln1 {
        Some(i) => {
            let (y, c) = ln_fwd(&h_in, p.at(i), p.at(i + 1), rows, d);
            (y, Some(c))
        }
        None => (h_in.clone(), None),
    };

    let mut qp = vec![0.0f32; rows * d];
    let mut kp = vec![0.0f32; rows * d];
    let mut vp = vec![0.0f32; rows * d];
    matmul(pool, &x1, p.at(bi.wq), rows, d, d, &mut qp);
    matmul(pool, &x1, p.at(bi.wq + 1), rows, d, d, &mut kp);
    matmul(pool, &x1, p.at(bi.wq + 2), rows, d, d, &mut vp);

    let qh = split_heads(&qp, bsz, l, nh, hd);
    let kh = split_heads(&kp, bsz, l, nh, hd);
    let vh = split_heads(&vp, bsz, l, nh, hd);
    drop((qp, kp, vp));

    let (ah, att) = match cfg.attn {
        AttnKind::Softmax => {
            let sh = LayerShape::cube(bsz * nh, l, hd);
            let scale = 1.0 / (hd as f32).sqrt();
            let ah = softmax_fwd(pool, &qh, &kh, &vh, sh, scale);
            (ah, AttnCache::Softmax { qh, kh, vh })
        }
        kind => {
            let gamma = attn_gamma(kind);
            let hrows = bsz * nh * l;
            let fq: Vec<f32> = qh.iter().map(|&x| elu1(x)).collect();
            let fk: Vec<f32> = kh.iter().map(|&x| elu1(x)).collect();
            let mut vext = vec![0.0f32; hrows * (hd + 1)];
            for r in 0..hrows {
                vext[r * (hd + 1)..][..hd].copy_from_slice(&vh[r * hd..][..hd]);
                vext[r * (hd + 1) + hd] = 1.0;
            }
            let sh = LayerShape { bh: bsz * nh, n: l, dk: hd, dv: hd + 1 };
            let u = la_scan_fwd(pool, &fq, &fk, &vext, sh, gamma);
            let mut ah = vec![0.0f32; hrows * hd];
            for r in 0..hrows {
                let ur = &u[r * (hd + 1)..][..hd + 1];
                let z = ur[hd] + EPS;
                let ar = &mut ah[r * hd..][..hd];
                for (ax, ux) in ar.iter_mut().zip(ur) {
                    *ax = ux / z;
                }
            }
            (ah, AttnCache::Linear { qh, kh, fq, fk, vext, u })
        }
    };
    let a = merge_heads(&ah, bsz, l, nh, hd);

    let mut h_mid = h_in.clone();
    matmul(pool, &a, p.at(bi.wq + 3), rows, d, d, &mut h_mid);

    let (h_out, ln2, x2, m1, gact) = match bi.mlp {
        Some(mi) => {
            let f = cfg.d_ff;
            let (x2, ln2) = match bi.ln2 {
                Some(i) => {
                    let (y, c) = ln_fwd(&h_mid, p.at(i), p.at(i + 1), rows, d);
                    (y, Some(c))
                }
                None => (h_mid.clone(), None),
            };
            let b1 = p.at(mi + 1);
            let mut m1 = vec![0.0f32; rows * f];
            for r in 0..rows {
                m1[r * f..][..f].copy_from_slice(b1);
            }
            matmul(pool, &x2, p.at(mi), rows, d, f, &mut m1);
            let gact: Vec<f32> = m1.iter().map(|&x| gelu(x)).collect();
            let b2 = p.at(mi + 3);
            let mut h_out = h_mid.clone();
            for r in 0..rows {
                let hr = &mut h_out[r * d..][..d];
                for (hx, bx) in hr.iter_mut().zip(b2) {
                    *hx += bx;
                }
            }
            matmul(pool, &gact, p.at(mi + 2), rows, f, d, &mut h_out);
            (h_out, ln2, Some(x2), Some(m1), Some(gact))
        }
        None => (h_mid.clone(), None, None, None, None),
    };

    (
        h_out,
        BlockCache { h_in, ln1, x1, att, a, h_mid, ln2, x2, m1, gact },
    )
}

/// Forward pass over `x` (batch × n_ctx token ids) → (logits, cache).
/// `keep_cache = false` (eval / logits paths, no backward) drops each
/// block's activation cache as soon as the block completes, so peak memory
/// stays one block deep instead of `n_layer` deep.
fn forward(
    cfg: &LmConfig,
    p: &P,
    x: &[i32],
    pool: &ThreadPool,
    keep_cache: bool,
) -> Result<(Vec<f32>, Cache)> {
    let (bsz, l, d, v) = (cfg.batch, cfg.n_ctx, cfg.d_model, cfg.vocab);
    let rows = bsz * l;
    if x.len() != rows {
        bail!("expected {} tokens, got {}", rows, x.len());
    }
    let wte = p.at(p.idx.wte);
    let wpe = p.at(p.idx.wpe);
    let mut h = vec![0.0f32; rows * d];
    for (r, &tok) in x.iter().enumerate() {
        if tok < 0 || tok as usize >= v {
            bail!("token id {tok} out of range [0, {v})");
        }
        let te = &wte[tok as usize * d..][..d];
        let pe = &wpe[(r % l) * d..][..d];
        let hr = &mut h[r * d..][..d];
        for ((hx, a), b) in hr.iter_mut().zip(te).zip(pe) {
            *hx = a + b;
        }
    }

    let mut blocks = Vec::with_capacity(if keep_cache { cfg.n_layer } else { 0 });
    for bi in &p.idx.blocks {
        let (h_next, bc) = block_forward(cfg, p, bi, h, pool);
        h = h_next;
        if keep_cache {
            blocks.push(bc);
        }
    }
    let h_last = h;

    let (xf, lnf) = match p.idx.lnf {
        Some(i) => {
            let (y, c) = ln_fwd(&h_last, p.at(i), p.at(i + 1), rows, d);
            (y, Some(c))
        }
        None => (h_last.clone(), None),
    };

    let bu = p.at(p.idx.bu);
    let mut logits = vec![0.0f32; rows * v];
    for r in 0..rows {
        logits[r * v..][..v].copy_from_slice(bu);
    }
    matmul(pool, &xf, p.at(p.idx.wu), rows, d, v, &mut logits);
    Ok((logits, Cache { blocks, h_last, lnf, xf }))
}

/// Mean cross-entropy of `logits` against `y`; optionally fills `dlogits`
/// with the loss gradient (softmax − onehot, scaled by 1/rows).
fn cross_entropy(
    logits: &[f32],
    y: &[i32],
    vocab: usize,
    mut dlogits: Option<&mut [f32]>,
) -> Result<f32> {
    let rows = y.len();
    let inv_rows = 1.0 / rows as f32;
    let mut loss = 0.0f64;
    for (r, &target) in y.iter().enumerate() {
        if target < 0 || target as usize >= vocab {
            bail!("target id {target} out of range [0, {vocab})");
        }
        let lr = &logits[r * vocab..][..vocab];
        let m = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &x in lr {
            z += (x - m).exp();
        }
        loss += (m as f64) + (z as f64).ln() - lr[target as usize] as f64;
        if let Some(dl) = dlogits.as_deref_mut() {
            let dr = &mut dl[r * vocab..][..vocab];
            let inv_z = 1.0 / z;
            for (dx, &x) in dr.iter_mut().zip(lr) {
                *dx = (x - m).exp() * inv_z * inv_rows;
            }
            dr[target as usize] -= inv_rows;
        }
    }
    Ok((loss / rows as f64) as f32)
}

/// Forward + loss, no gradients (the `lm_*_eval` artifact body).
pub fn eval_loss(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &Tensor,
    pool: &ThreadPool,
) -> Result<f32> {
    let p = P::bind(cfg, params)?;
    let (x, y) = split_xy(cfg, tokens)?;
    let (logits, _cache) = forward(cfg, &p, &x, pool, false)?;
    cross_entropy(&logits, &y, cfg.vocab, None)
}

/// Forward only, over full-context token rows (the `lm_*_logits` artifact).
pub fn logits(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &Tensor,
    pool: &ThreadPool,
) -> Result<Tensor> {
    let p = P::bind(cfg, params)?;
    let x = tokens.as_i32()?;
    if tokens.shape() != [cfg.batch, cfg.n_ctx].as_slice() {
        bail!(
            "logits artifact wants tokens ({}, {}), got {:?}",
            cfg.batch,
            cfg.n_ctx,
            tokens.shape()
        );
    }
    let (lg, _cache) = forward(cfg, &p, x, pool, false)?;
    Tensor::f32(vec![cfg.batch, cfg.n_ctx, cfg.vocab], lg)
}

// --- incremental (decode-time) forward ----------------------------------------

/// One-token incremental forward over `n_seq` concurrent sequences: consumes
/// one token id per sequence, updates the per-layer [`DecodeState`] (the
/// O(hd²) recurrent matrix for `ours`/`gated`, the appended KV cache for
/// `softmax`), and returns the `n_seq × vocab` next-token logits.
///
/// The arithmetic mirrors the full-context [`forward`] step-for-step — same
/// GEMM microkernels for the projections/MLP/unembedding, same per-token
/// state-scan update order as [`la_scan_fwd`]'s inner loop, same streaming
/// row softmax as [`softmax_fwd`] — so feeding a sequence token-by-token
/// reproduces the full-context logits (the decode-parity tests pin this for
/// every `AttnKind`). Cost per token is O(1) in the consumed prefix for the
/// linear variants and O(pos) for softmax; the prefix is never re-scanned.
pub fn logits_step(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &[i32],
    st: &mut DecodeState,
    pool: &ThreadPool,
) -> Result<Vec<f32>> {
    DecodeModel::bind(cfg, params)?.logits_step(tokens, st, pool)
}

/// [`logits_step`] without the final LayerNorm + unembedding GEMM — the
/// prompt-prefill fast path: every prompt token but the last only needs to
/// advance the decode state, and the `ns × d × vocab` unembedding is the
/// single largest matmul of a step.
pub fn prefill_step(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &[i32],
    st: &mut DecodeState,
    pool: &ThreadPool,
) -> Result<()> {
    DecodeModel::bind(cfg, params)?.prefill_step(tokens, st, pool)
}

/// Caller-held per-token work buffers for the incremental decode step.
///
/// Every intermediate `block_step`/`step` once allocated fresh per token
/// now lives here and is resized once, then reused: after the first token
/// of a session the steady-state decode performs **zero** allocations on
/// the stepping thread for every attention variant (the softmax variant
/// stores its K/V rows into per-sequence cache lanes that [`AttnState`]
/// allocates up-front to the full `n_ctx` window). `tests/alloc_gate.rs`
/// pins this with the counting global allocator; the budget there is the
/// contract.
///
/// Buffers are plain `Vec<f32>`s sized by [`DecodeScratch::ensure`] at the
/// top of each step, so one scratch can serve configs of different sizes
/// (it grows to the largest seen). All contents are dead between steps —
/// only capacity is carried.
#[derive(Default)]
pub struct DecodeScratch {
    /// Residual stream (`ns × d`); taken out of the struct during a step so
    /// `block_step` can borrow it mutably alongside the other buffers.
    h: Vec<f32>,
    x1: Vec<f32>,
    qp: Vec<f32>,
    kp: Vec<f32>,
    vp: Vec<f32>,
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    fq: Vec<f32>,
    fk: Vec<f32>,
    vext: Vec<f32>,
    /// Per-(seq, head) `Sᵀ·φ(q)` accumulators, one `hd+1` window per task.
    u: Vec<f32>,
    ah: Vec<f32>,
    a: Vec<f32>,
    x2: Vec<f32>,
    m1: Vec<f32>,
    gact: Vec<f32>,
    /// Softmax-variant attention scores, one `n_ctx` window per (seq, head).
    scores: Vec<f32>,
    /// f32 staging for quantized linear-attention state: one `hd·(hd+1)`
    /// window per (seq, head) task, dequantized in, requantized out.
    sdeq: Vec<f32>,
    /// Per-sequence position cursors snapshotted from the [`DecodeState`]
    /// at the top of a step (sequences in a continuous batch sit at
    /// different depths); taken out of the struct alongside `h` during the
    /// step so `block_step` can read it while borrowing the rest mutably.
    spos: Vec<usize>,
    xf: Vec<f32>,
    logits: Vec<f32>,
}

impl DecodeScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to the sizes this `(cfg, n_seq)` step needs.
    /// `Vec::resize` only reallocates when the target exceeds capacity, so
    /// in steady state this is a handful of length stores.
    fn ensure(&mut self, cfg: &LmConfig, ns: usize) {
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_head, cfg.head_dim());
        let n_sh = ns * nh;
        let f = cfg.d_ff;
        self.h.resize(ns * d, 0.0);
        self.x1.resize(ns * d, 0.0);
        self.qp.resize(ns * d, 0.0);
        self.kp.resize(ns * d, 0.0);
        self.vp.resize(ns * d, 0.0);
        self.qh.resize(ns * d, 0.0);
        self.kh.resize(ns * d, 0.0);
        self.vh.resize(ns * d, 0.0);
        self.fq.resize(ns * d, 0.0);
        self.fk.resize(ns * d, 0.0);
        self.vext.resize(n_sh * (hd + 1), 0.0);
        self.u.resize(n_sh * (hd + 1), 0.0);
        self.ah.resize(n_sh * hd, 0.0);
        self.a.resize(ns * d, 0.0);
        self.x2.resize(ns * d, 0.0);
        self.m1.resize(ns * f, 0.0);
        self.gact.resize(ns * f, 0.0);
        self.scores.resize(n_sh * cfg.n_ctx, 0.0);
        self.sdeq.resize(n_sh * hd * (hd + 1), 0.0);
        self.spos.resize(ns, 0);
        self.xf.resize(ns * d, 0.0);
        self.logits.resize(ns * cfg.vocab, 0.0);
    }
}

/// Caller-held work buffers for the chunked prompt prefill — the whole-window
/// sibling of [`DecodeScratch`]: every buffer spans all `ns · l` prompt rows
/// of one layer pass instead of one token. Sized once by `ensure` at the top
/// of [`DecodeModel::prefill_chunked`] and reused, so a warm prefill's
/// allocation count is bounded by the number of chunks the kernels tile the
/// window into (the chunkwise states + per-tile score buffers), never by the
/// prompt length. `tests/alloc_gate.rs` pins that budget.
#[derive(Default)]
pub struct PrefillScratch {
    /// Residual stream (`ns·l × d`), seq-major (row `r = s·l + t`); taken
    /// out of the struct during a pass so `block_prefill` can borrow it
    /// mutably alongside the other buffers.
    h: Vec<f32>,
    x1: Vec<f32>,
    qp: Vec<f32>,
    kp: Vec<f32>,
    vp: Vec<f32>,
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    fq: Vec<f32>,
    fk: Vec<f32>,
    vext: Vec<f32>,
    /// Chunkwise-kernel output: one `hd+1` row (`Sᵀ·φ(q)` ++ normalizer)
    /// per (seq, head, token).
    u: Vec<f32>,
    ah: Vec<f32>,
    a: Vec<f32>,
    x2: Vec<f32>,
    m1: Vec<f32>,
    gact: Vec<f32>,
    /// f32 staging for one layer's whole recurrent state (`n_sh` blocks of
    /// `hd·(hd+1)`): dequantized in, scanned by the carry kernel, then
    /// requantized back in one [`QuantBuf::store_f32`] pass.
    s0: Vec<f32>,
    /// Staging for the softmax KV cache: the head-major projections
    /// transposed into each sequence lane's `(token, head)` row order so
    /// the whole window stores in one `store_rows` call per sequence.
    kstage: Vec<f32>,
    vstage: Vec<f32>,
    /// Softmax score rows, one `n_ctx` window per in-flight (query, head)
    /// task — bounded by the chunk length, not the prompt length.
    scores: Vec<f32>,
}

impl PrefillScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to the sizes a `(cfg, ns, l)` prefill pass needs.
    fn ensure(&mut self, cfg: &LmConfig, ns: usize, l: usize, chunk: usize) {
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_head, cfg.head_dim());
        let n_sh = ns * nh;
        let rows = ns * l;
        let f = cfg.d_ff;
        self.h.resize(rows * d, 0.0);
        self.x1.resize(rows * d, 0.0);
        self.qp.resize(rows * d, 0.0);
        self.kp.resize(rows * d, 0.0);
        self.vp.resize(rows * d, 0.0);
        self.qh.resize(rows * d, 0.0);
        self.kh.resize(rows * d, 0.0);
        self.vh.resize(rows * d, 0.0);
        self.a.resize(rows * d, 0.0);
        self.x2.resize(rows * d, 0.0);
        self.m1.resize(rows * f, 0.0);
        self.gact.resize(rows * f, 0.0);
        if cfg.attn == AttnKind::Softmax {
            self.kstage.resize(rows * d, 0.0);
            self.vstage.resize(rows * d, 0.0);
            self.scores.resize(n_sh * chunk.min(l) * cfg.n_ctx, 0.0);
        } else {
            self.fq.resize(rows * d, 0.0);
            self.fk.resize(rows * d, 0.0);
            self.vext.resize(n_sh * l * (hd + 1), 0.0);
            self.u.resize(n_sh * l * (hd + 1), 0.0);
            self.s0.resize(n_sh * hd * (hd + 1), 0.0);
        }
        self.ah.resize(n_sh * l * hd, 0.0);
    }
}

/// Parameter views bound and shape-checked **once** for a decode session.
/// The free [`logits_step`]/[`prefill_step`] functions rebind per call —
/// fine for tests and one-shot use, but a generation loop issues one call
/// per token, and re-walking the parameter layout (name `String`s, shape
/// validation) every token is pure overhead. Bind once, step many times.
pub struct DecodeModel<'a> {
    cfg: LmConfig,
    p: DecodeP<'a>,
}

impl<'a> DecodeModel<'a> {
    /// Bind full-precision tensors. The slice of refs itself may be a
    /// temporary — the model borrows the tensors, not the slice — so a
    /// session can bind from a freshly-collected `Vec<&Tensor>`.
    pub fn bind(cfg: &LmConfig, params: &[&'a Tensor]) -> Result<Self> {
        Ok(Self { cfg: *cfg, p: DecodeP::from_f32(cfg, params)? })
    }

    /// The architecture this model was bound for (including the storage
    /// precision its [`DecodeState`]s must match).
    pub fn cfg(&self) -> &LmConfig {
        &self.cfg
    }

    /// Bind a quantized parameter set. The session config comes from the
    /// [`QuantModel`] itself so `cfg.precision` always matches the weights
    /// (and the [`DecodeState`]s built from it).
    pub fn bind_quantized(qm: &'a QuantModel) -> Result<Self> {
        Ok(Self { cfg: qm.cfg, p: DecodeP::from_quant(&qm.cfg, qm)? })
    }

    /// One incremental step producing next-token logits (`n_seq × vocab`).
    ///
    /// Convenience form that pays one fresh [`DecodeScratch`] + `to_vec`
    /// per call; generation loops should hold a scratch and use
    /// [`logits_step_scratch`](Self::logits_step_scratch).
    pub fn logits_step(
        &self,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
    ) -> Result<Vec<f32>> {
        let mut sc = DecodeScratch::new();
        Ok(self.logits_step_scratch(tokens, st, pool, &mut sc)?.to_vec())
    }

    /// One incremental step that only advances the state (no unembedding).
    pub fn prefill_step(
        &self,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
    ) -> Result<()> {
        let mut sc = DecodeScratch::new();
        self.prefill_step_scratch(tokens, st, pool, &mut sc)
    }

    /// [`logits_step`](Self::logits_step) writing into caller-held scratch.
    /// The returned logits view (`ns × vocab`) borrows the scratch and is
    /// valid until the next step reuses it.
    // no_panic
    pub fn logits_step_scratch<'s>(
        &self,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
        sc: &'s mut DecodeScratch,
    ) -> Result<&'s [f32]> {
        self.step_with(tokens, st, pool, sc, true, None)?
            .ok_or_else(|| anyhow::anyhow!("internal: step_with(want_logits) returned no logits"))
    }

    /// One masked incremental step for the continuous-batching engine: rows
    /// with `active[r] == false` are carried through the batched arithmetic
    /// as zero lanes — their per-layer state is not written, their position
    /// cursor does not advance, and their logits rows are meaningless
    /// (callers must not sample them; their token ids are ignored). Active
    /// rows produce logits bit-identical to a lockstep step over only those
    /// rows, because every decode op is row-independent — the engine's
    /// batch-parity tests pin this per `AttnKind`.
    // no_panic
    pub fn decode_step_masked<'s>(
        &self,
        tokens: &[i32],
        active: &[bool],
        st: &mut DecodeState,
        pool: &ThreadPool,
        sc: &'s mut DecodeScratch,
    ) -> Result<&'s [f32]> {
        self.step_with(tokens, st, pool, sc, true, Some(active))?
            .ok_or_else(|| anyhow::anyhow!("internal: step_with(want_logits) returned no logits"))
    }

    /// [`prefill_step`](Self::prefill_step) with caller-held scratch.
    pub fn prefill_step_scratch(
        &self,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
        sc: &mut DecodeScratch,
    ) -> Result<()> {
        self.step_with(tokens, st, pool, sc, false, None).map(|_| ())
    }

    /// Chunked prompt prefill: consume `l` tokens per sequence (`tokens` is
    /// seq-major, `ns · l` ids) in **one pass per layer** through the
    /// parallel chunkwise kernels instead of `l` sequential
    /// [`prefill_step`](Self::prefill_step) calls — the projections, MLP and
    /// reshapes run batched over all `ns · l` rows, the linear variants scan
    /// via [`la_chunk_fwd_carry`] (inter/intra GEMM tiles with the decode
    /// state as the carry), and softmax fills its KV cache in one bulk
    /// append plus a blocked pass of the streaming quadratic kernel. The
    /// [`DecodeState`] afterwards is the same state the token-by-token route
    /// produces (bit-exact for softmax/f32, reassociation-tolerance for the
    /// linear kinds, one requantization per layer instead of per token for
    /// bf16/int8 — `tests/infer.rs` pins all of it), so decoding continues
    /// seamlessly. No logits are computed; follow with
    /// [`logits_step_scratch`](Self::logits_step_scratch) on the last prompt
    /// token.
    ///
    /// Chunk length comes from `RUST_PALLAS_CHUNK` (default 128) — use
    /// [`prefill_chunked_with`](Self::prefill_chunked_with) to pin it.
    pub fn prefill_chunked(
        &self,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
        sc: &mut PrefillScratch,
    ) -> Result<()> {
        self.prefill_chunked_with(super::ours_chunk(), tokens, st, pool, sc)
    }

    /// [`prefill_chunked`](Self::prefill_chunked) with an explicit chunk
    /// length (the chunk-invariance tests sweep this directly instead of
    /// mutating the process environment).
    pub fn prefill_chunked_with(
        &self,
        chunk: usize,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
        sc: &mut PrefillScratch,
    ) -> Result<()> {
        let (cfg, p) = (&self.cfg, &self.p);
        st.check(cfg)?;
        let ns = st.n_seq();
        if tokens.is_empty() || tokens.len() % ns != 0 {
            bail!(
                "prefill_chunked wants a non-empty seq-major window of {} sequences \
                 (l ids each), got {} token ids",
                ns,
                tokens.len()
            );
        }
        let l = tokens.len() / ns;
        let pos = st.pos();
        if st.seq_positions().iter().any(|&p| p != pos) {
            bail!(
                "prefill_chunked wants lockstep sequences (all at one position), \
                 got cursors {:?} — prefill each sequence separately (the batch \
                 engine stages prompts through a one-sequence state)",
                st.seq_positions()
            );
        }
        let (d, v) = (cfg.d_model, cfg.vocab);
        if pos + l > cfg.n_ctx {
            bail!(
                "context window exhausted: positions [{pos}, {}) exceed n_ctx {} — \
                 reset the DecodeState",
                pos + l,
                cfg.n_ctx
            );
        }
        let chunk = chunk.max(1);
        sc.ensure(cfg, ns, l, chunk);

        // h[s·l + t] = wte[tok] + wpe[pos + t], all prompt rows at once
        let mut h = std::mem::take(&mut sc.h);
        let wte = p.at(p.idx.wte);
        let wpe = p.at(p.idx.wpe);
        for (r, &tok) in tokens.iter().enumerate() {
            if tok < 0 || tok as usize >= v {
                sc.h = h;
                bail!("token id {tok} out of range [0, {v})");
            }
            let te = &wte[tok as usize * d..][..d];
            let pe = &wpe[(pos + r % l) * d..][..d];
            let hr = &mut h[r * d..][..d];
            for ((hx, a), b) in hr.iter_mut().zip(te).zip(pe) {
                *hx = a + b;
            }
        }

        for (li, bi) in p.idx.blocks.iter().enumerate() {
            block_prefill(cfg, p, bi, &mut h, st.layer_mut(li), ns, l, pos, chunk, pool, sc);
        }
        st.advance_by(l);
        sc.h = h;
        Ok(())
    }

    /// Shared one-token step: embed, run every block through the decode
    /// state, then (optionally) unembed. All intermediates live in `sc`.
    /// With an `active` mask, sequences are stepped at their own position
    /// cursors (a continuous batch is not lockstep) and masked rows are
    /// zeroed through the row-independent arithmetic without touching
    /// their state.
    // no_panic
    // bounds: token ids are vocab-checked at entry; the mask length is
    // checked against ns at entry; row/feature spans follow the scratch
    // shapes sized by DecodeScratch::new
    fn step_with<'s>(
        &self,
        tokens: &[i32],
        st: &mut DecodeState,
        pool: &ThreadPool,
        sc: &'s mut DecodeScratch,
        compute_logits: bool,
        active: Option<&[bool]>,
    ) -> Result<Option<&'s [f32]>> {
        let (cfg, p) = (&self.cfg, &self.p);
        st.check(cfg)?;
        let ns = st.n_seq();
        if tokens.len() != ns {
            bail!("logits_step wants {} token ids (one per sequence), got {}", ns, tokens.len());
        }
        if let Some(a) = active {
            if a.len() != ns {
                bail!("active mask wants {} flags (one per sequence), got {}", ns, a.len());
            }
            if !a.iter().any(|&x| x) {
                bail!("active mask selects no sequences");
            }
        }
        let (d, v) = (cfg.d_model, cfg.vocab);
        sc.ensure(cfg, ns);
        sc.spos.copy_from_slice(st.seq_positions());
        for (r, &pos) in sc.spos.iter().enumerate() {
            if active.map_or(true, |a| a[r]) && pos >= cfg.n_ctx {
                bail!(
                    "context window exhausted: sequence {r} at position {pos} ≥ n_ctx {} — \
                     reset (or clear) the DecodeState",
                    cfg.n_ctx
                );
            }
        }

        // h = wte[tok] + wpe[spos[r]]. The residual and position buffers are
        // moved out of the scratch for the duration of the step so
        // `block_step` can use them alongside the other scratch fields
        // (put back before returning).
        let mut h = std::mem::take(&mut sc.h);
        let spos = std::mem::take(&mut sc.spos);
        let wte = p.at(p.idx.wte);
        let wpe = p.at(p.idx.wpe);
        for (r, &tok) in tokens.iter().enumerate() {
            let hr = &mut h[r * d..][..d];
            if !active.map_or(true, |a| a[r]) {
                // masked lane: zero input keeps every downstream row finite
                // (LN has an epsilon) without touching this row's state
                hr.fill(0.0);
                continue;
            }
            if tok < 0 || tok as usize >= v {
                sc.h = h;
                sc.spos = spos;
                bail!("token id {tok} out of range [0, {v})");
            }
            let te = &wte[tok as usize * d..][..d];
            let pe = &wpe[spos[r] * d..][..d];
            for ((hx, a), b) in hr.iter_mut().zip(te).zip(pe) {
                *hx = a + b;
            }
        }

        for (li, bi) in p.idx.blocks.iter().enumerate() {
            block_step(cfg, p, bi, &mut h, st.layer_mut(li), ns, &spos, active, pool, sc);
        }
        match active {
            None => st.advance(),
            Some(a) => st.advance_masked(a),
        }
        sc.spos = spos;

        if !compute_logits {
            sc.h = h;
            return Ok(None);
        }
        match p.idx.lnf {
            Some(i) => ln_fwd_into(&h, p.at(i), p.at(i + 1), ns, d, &mut sc.xf),
            None => sc.xf.copy_from_slice(&h),
        }
        sc.h = h;
        let bu = p.at(p.idx.bu);
        for r in 0..ns {
            sc.logits[r * v..][..v].copy_from_slice(bu);
        }
        matmul_q(pool, &sc.xf, p.w(p.idx.wu), ns, d, v, &mut sc.logits);
        Ok(Some(&sc.logits))
    }
}

/// One block of the incremental forward: pre-norm attention step (through
/// the layer's [`AttnState`]) + residual, then the pre-norm MLP + residual.
/// `spos[s]` is sequence `s`'s position cursor (a continuous batch is not
/// lockstep); rows whose `active` flag is false flow through the batched
/// GEMM/LN arithmetic as zero lanes but never read or write their state.
///
/// Allocation-free on the stepping thread: every intermediate lives in the
/// caller's [`DecodeScratch`] (the softmax K/V rows are stored into
/// per-sequence cache lanes [`AttnState`] allocates up-front).
/// `tests/alloc_gate.rs` gates this; keep new temporaries in the scratch.
// deny_alloc
// no_panic
// bounds: per-head and per-row spans follow the scratch shapes sized by
// DecodeScratch::new against the checkpoint config; spos/active are
// ns-length by step_with's entry checks
#[allow(clippy::too_many_arguments)]
fn block_step(
    cfg: &LmConfig,
    p: &DecodeP,
    bi: &BlockIdx,
    h: &mut [f32],
    ls: &mut AttnState,
    ns: usize,
    spos: &[usize],
    active: Option<&[bool]>,
    pool: &ThreadPool,
    sc: &mut DecodeScratch,
) {
    let d = cfg.d_model;
    let (nh, hd) = (cfg.n_head, cfg.head_dim());
    let n_sh = ns * nh;
    let act = move |s: usize| active.map_or(true, |a| a[s]);

    match bi.ln1 {
        Some(i) => ln_fwd_into(h, p.at(i), p.at(i + 1), ns, d, &mut sc.x1),
        None => sc.x1.copy_from_slice(h),
    }
    // matmul accumulates into its output: clear the projection buffers
    sc.qp.fill(0.0);
    sc.kp.fill(0.0);
    sc.vp.fill(0.0);
    matmul_q(pool, &sc.x1, p.w(bi.wq), ns, d, d, &mut sc.qp);
    matmul_q(pool, &sc.x1, p.w(bi.wq + 1), ns, d, d, &mut sc.kp);
    matmul_q(pool, &sc.x1, p.w(bi.wq + 2), ns, d, d, &mut sc.vp);
    split_heads_into(&sc.qp, ns, 1, nh, hd, &mut sc.qh);
    split_heads_into(&sc.kp, ns, 1, nh, hd, &mut sc.kh);
    split_heads_into(&sc.vp, ns, 1, nh, hd, &mut sc.vh);

    sc.ah.fill(0.0);
    match ls {
        AttnState::Linear { s, gamma } => {
            // φ(q), φ(k), [v, 1] for every (seq, head) row of this token
            for (o, &x) in sc.fq.iter_mut().zip(sc.qh.iter()) {
                *o = elu1(x);
            }
            for (o, &x) in sc.fk.iter_mut().zip(sc.kh.iter()) {
                *o = elu1(x);
            }
            for r in 0..n_sh {
                sc.vext[r * (hd + 1)..][..hd].copy_from_slice(&sc.vh[r * hd..][..hd]);
                sc.vext[r * (hd + 1) + hd] = 1.0;
            }
            sc.u.fill(0.0);
            let (fq, fk, vext) = (&sc.fq[..], &sc.fk[..], &sc.vext[..]);
            let gamma = *gamma;
            let sd = hd * (hd + 1);
            let ap = super::pool::SliceParts::new(&mut sc.ah);
            let up = super::pool::SliceParts::new(&mut sc.u);
            // one (seq, head) state block per pool task — disjoint windows.
            // The f32 arm runs the scan on the stored state directly
            // (statement-identical to the pre-quantization path); the
            // bf16/int8 arms dequantize the block into its `sdeq` window,
            // run the same f32 scan, then requantize in place.
            match s {
                QuantBuf::F32(data) => {
                    let sp = super::pool::SliceParts::new(data);
                    pool.run(n_sh, |i| {
                        if !act(i / nh) {
                            return; // masked lane: state untouched, ah row stays zero
                        }
                        // SAFETY: task `i` touches windows `i` of
                        // `s`/`ah`/`u` only.
                        let (sw, aw, uw) = unsafe {
                            (
                                sp.window(i * sd, sd),
                                ap.window(i * hd, hd),
                                up.window(i * (hd + 1), hd + 1),
                            )
                        };
                        linear_state_task(
                            sw,
                            &fq[i * hd..][..hd],
                            &fk[i * hd..][..hd],
                            &vext[i * (hd + 1)..][..hd + 1],
                            aw,
                            uw,
                            gamma,
                            hd,
                        );
                    });
                }
                QuantBuf::Bf16(data) => {
                    let sp = super::pool::SliceParts::new(data);
                    let dp = super::pool::SliceParts::new(&mut sc.sdeq);
                    pool.run(n_sh, |i| {
                        if !act(i / nh) {
                            return; // masked lane: state untouched, ah row stays zero
                        }
                        // SAFETY: task `i` touches windows `i` of
                        // `s`/`sdeq`/`ah`/`u` only.
                        let (sw, dw, aw, uw) = unsafe {
                            (
                                sp.window(i * sd, sd),
                                dp.window(i * sd, sd),
                                ap.window(i * hd, hd),
                                up.window(i * (hd + 1), hd + 1),
                            )
                        };
                        for (o, &b) in dw.iter_mut().zip(sw.iter()) {
                            *o = quant::bf16_to_f32(b);
                        }
                        linear_state_task(
                            dw,
                            &fq[i * hd..][..hd],
                            &fk[i * hd..][..hd],
                            &vext[i * (hd + 1)..][..hd + 1],
                            aw,
                            uw,
                            gamma,
                            hd,
                        );
                        for (o, &x) in sw.iter_mut().zip(dw.iter()) {
                            *o = quant::f32_to_bf16(x);
                        }
                    });
                }
                QuantBuf::Int8 { q, scales, .. } => {
                    let sp = super::pool::SliceParts::new(q);
                    let scl = super::pool::SliceParts::new(scales);
                    let dp = super::pool::SliceParts::new(&mut sc.sdeq);
                    pool.run(n_sh, |i| {
                        if !act(i / nh) {
                            return; // masked lane: state untouched, ah row stays zero
                        }
                        // SAFETY: task `i` touches windows `i` of
                        // `s`/`scales`/`sdeq`/`ah`/`u` only.
                        let (sw, scw, dw, aw, uw) = unsafe {
                            (
                                sp.window(i * sd, sd),
                                scl.window(i * hd, hd),
                                dp.window(i * sd, sd),
                                ap.window(i * hd, hd),
                                up.window(i * (hd + 1), hd + 1),
                            )
                        };
                        for (r, (qrow, drow)) in sw
                            .chunks_exact(hd + 1)
                            .zip(dw.chunks_exact_mut(hd + 1))
                            .enumerate()
                        {
                            quant::dequantize_row_i8(qrow, scw[r], drow);
                        }
                        linear_state_task(
                            dw,
                            &fq[i * hd..][..hd],
                            &fk[i * hd..][..hd],
                            &vext[i * (hd + 1)..][..hd + 1],
                            aw,
                            uw,
                            gamma,
                            hd,
                        );
                        for (r, (qrow, drow)) in sw
                            .chunks_exact_mut(hd + 1)
                            .zip(dw.chunks_exact(hd + 1))
                            .enumerate()
                        {
                            scw[r] = quant::quantize_row_i8(drow, qrow);
                        }
                    });
                }
            }
        }
        AttnState::Softmax { k, v } => {
            // store this token's K/V head rows into each active sequence's
            // cache lane (row `(s·n_ctx + spos[s])·nh + h`); store_rows
            // quantizes per row exactly like the legacy bulk append did
            let nctx = cfg.n_ctx;
            for s in 0..ns {
                if !act(s) {
                    continue;
                }
                let base = (s * nctx + spos[s]) * nh;
                k.store_rows(base, hd, &sc.kh[s * nh * hd..][..nh * hd]);
                v.store_rows(base, hd, &sc.vh[s * nh * hd..][..nh * hd]);
            }
            let (kc, vc) = (&*k, &*v);
            let scale = 1.0 / (hd as f32).sqrt();
            let qh = &sc.qh[..];
            let scp = super::pool::SliceParts::new(&mut sc.scores);
            // streaming causal softmax over the cached lane prefix, one
            // (seq, head) row per pool task — identical accumulation order
            // to softmax_fwd's row `spos[s]`. Cache rows are read through
            // [`QuantBuf::row_dot`]/[`QuantBuf::row_axpy`], whose f32 arms
            // are the same `gemm::dot`/`gemm::axpy` calls as before.
            pool.run_chunks(&mut sc.ah, hd, |sh, out| {
                let (s, hh) = (sh / nh, sh % nh);
                if !act(s) {
                    return; // masked lane: ah row stays zero
                }
                let pos = spos[s];
                let qr = &qh[sh * hd..][..hd];
                // SAFETY: task `sh` touches scores window `sh` only (rows
                // are `nctx` apart; `pos + 1 ≤ nctx`).
                let scores = unsafe { scp.window(sh * nctx, pos + 1) };
                let mut m = f32::NEG_INFINITY;
                for (t, sc) in scores.iter_mut().enumerate() {
                    let a = kc.row_dot((s * nctx + t) * nh + hh, hd, qr) * scale;
                    *sc = a;
                    m = m.max(a);
                }
                let mut z = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - m).exp();
                    z += *sc;
                }
                let inv = 1.0 / z;
                for (t, sc) in scores.iter().enumerate() {
                    vc.row_axpy((s * nctx + t) * nh + hh, hd, sc * inv, out);
                }
            });
        }
    }
    merge_heads_into(&sc.ah, ns, 1, nh, hd, &mut sc.a);
    matmul_q(pool, &sc.a, p.w(bi.wq + 3), ns, d, d, h);

    if let Some(mi) = bi.mlp {
        let f = cfg.d_ff;
        match bi.ln2 {
            Some(i) => ln_fwd_into(h, p.at(i), p.at(i + 1), ns, d, &mut sc.x2),
            None => sc.x2.copy_from_slice(h),
        }
        let b1 = p.at(mi + 1);
        for r in 0..ns {
            sc.m1[r * f..][..f].copy_from_slice(b1);
        }
        matmul_q(pool, &sc.x2, p.w(mi), ns, d, f, &mut sc.m1);
        for (o, &x) in sc.gact.iter_mut().zip(sc.m1.iter()) {
            *o = gelu(x);
        }
        let b2 = p.at(mi + 3);
        for r in 0..ns {
            let hr = &mut h[r * d..][..d];
            for (hx, bx) in hr.iter_mut().zip(b2) {
                *hx += bx;
            }
        }
        matmul_q(pool, &sc.gact, p.w(mi + 2), ns, f, d, h);
    }
}

/// The per-(seq, head) linear-attention scan on one f32 state block:
/// `S ← γ·S + φ(k)·[v, 1]ᵀ`, then `u = Sᵀ·φ(q)` and the normalizer divide.
/// Extracted from `block_step` verbatim so every storage precision runs the
/// exact same arithmetic — the f32 state path stays bit-identical to the
/// pre-quantization code, and the bf16/int8 paths run it on their
/// dequantized staging windows.
// deny_alloc
// no_panic
// bounds: sw/krow/vrow windows are carved by the caller to hd/hd+1 exactly
#[inline]
#[allow(clippy::too_many_arguments)]
fn linear_state_task(
    sw: &mut [f32],
    fqr: &[f32],
    fkr: &[f32],
    vr: &[f32],
    aw: &mut [f32],
    uw: &mut [f32],
    gamma: f32,
    hd: usize,
) {
    // S ← γ·S + φ(k)·[v, 1]ᵀ   (same order as the training scan)
    if gamma != 1.0 {
        for x in sw.iter_mut() {
            *x *= gamma;
        }
    }
    for (row, srow) in sw.chunks_exact_mut(hd + 1).enumerate() {
        gemm::axpy(fkr[row], vr, srow);
    }
    // u = Sᵀ·φ(q), then divide by the normalizer channel
    for (row, srow) in sw.chunks_exact(hd + 1).enumerate() {
        gemm::axpy(fqr[row], srow, uw);
    }
    let z = uw[hd] + EPS;
    for (ax, ux) in aw.iter_mut().zip(&uw[..hd]) {
        *ax = ux / z;
    }
}

/// One block of the chunked prefill: the whole-window sibling of
/// [`block_step`]. Same pre-norm attention + residual, pre-norm MLP +
/// residual structure, but batched over all `ns · l` prompt rows so the
/// projections/MLP are real GEMMs and the attention mixer runs through the
/// parallel chunkwise kernels:
///
/// - **Linear** (`ours`/`gated`): the layer's recurrent state is
///   dequantized once into `sc.s0`, [`la_chunk_fwd_carry`] advances it over
///   the window (per-chunk inter/intra GEMM tiles, prefix-state carry — the
///   training-scan decomposition), and the result is requantized back in
///   one [`QuantBuf::store_f32`] pass (vs per token in `block_step`).
/// - **Softmax**: the head-major K/V projections are transposed into each
///   sequence's cache-lane row order, stored in one bulk call per sequence,
///   then the queries run the identical streaming two-pass softmax as
///   `block_step`, blocked `chunk` rows at a time so the score scratch
///   stays bounded by the chunk length.
// deny_alloc
#[allow(clippy::too_many_arguments)]
fn block_prefill(
    cfg: &LmConfig,
    p: &DecodeP,
    bi: &BlockIdx,
    h: &mut [f32],
    ls: &mut AttnState,
    ns: usize,
    l: usize,
    pos: usize,
    chunk: usize,
    pool: &ThreadPool,
    sc: &mut PrefillScratch,
) {
    let d = cfg.d_model;
    let (nh, hd) = (cfg.n_head, cfg.head_dim());
    let n_sh = ns * nh;
    let rows = ns * l;

    match bi.ln1 {
        Some(i) => ln_fwd_into(h, p.at(i), p.at(i + 1), rows, d, &mut sc.x1),
        None => sc.x1.copy_from_slice(h),
    }
    // matmul accumulates into its output: clear the projection buffers
    sc.qp.fill(0.0);
    sc.kp.fill(0.0);
    sc.vp.fill(0.0);
    matmul_q(pool, &sc.x1, p.w(bi.wq), rows, d, d, &mut sc.qp);
    matmul_q(pool, &sc.x1, p.w(bi.wq + 1), rows, d, d, &mut sc.kp);
    matmul_q(pool, &sc.x1, p.w(bi.wq + 2), rows, d, d, &mut sc.vp);
    split_heads_into(&sc.qp, ns, l, nh, hd, &mut sc.qh);
    split_heads_into(&sc.kp, ns, l, nh, hd, &mut sc.kh);
    split_heads_into(&sc.vp, ns, l, nh, hd, &mut sc.vh);

    sc.ah.fill(0.0);
    match ls {
        AttnState::Linear { s, gamma } => {
            // φ(q), φ(k), [v, 1] for every (seq, head, token) row
            for (o, &x) in sc.fq.iter_mut().zip(sc.qh.iter()) {
                *o = elu1(x);
            }
            for (o, &x) in sc.fk.iter_mut().zip(sc.kh.iter()) {
                *o = elu1(x);
            }
            for r in 0..n_sh * l {
                sc.vext[r * (hd + 1)..][..hd].copy_from_slice(&sc.vh[r * hd..][..hd]);
                sc.vext[r * (hd + 1) + hd] = 1.0;
            }
            // whole-layer state staged in f32, scanned by the carry kernel,
            // requantized back once (vs per token in block_step)
            s.dequantize_into(&mut sc.s0);
            let shp = LayerShape { bh: n_sh, n: l, dk: hd, dv: hd + 1 };
            la_chunk_fwd_carry(
                pool,
                &sc.fq,
                &sc.fk,
                &sc.vext,
                shp,
                chunk,
                *gamma,
                &mut sc.s0,
                &mut sc.u,
            );
            s.store_f32(&sc.s0);
            normalize_linear_rows(&sc.u, hd, &mut sc.ah);
        }
        AttnState::Softmax { k, v } => {
            // head-major [(s,h)][t][hd] → the cache lane's [t][h][hd] row
            // order per sequence, then one bulk (quantizing) store per
            // sequence at its lane offset `(s·n_ctx + pos)·nh`
            for shi in 0..n_sh {
                let (s, hh) = (shi / nh, shi % nh);
                for t in 0..l {
                    let kk = &sc.kh[(shi * l + t) * hd..][..hd];
                    sc.kstage[((s * l + t) * nh + hh) * hd..][..hd].copy_from_slice(kk);
                    let vv = &sc.vh[(shi * l + t) * hd..][..hd];
                    sc.vstage[((s * l + t) * nh + hh) * hd..][..hd].copy_from_slice(vv);
                }
            }
            let nctx = cfg.n_ctx;
            for s in 0..ns {
                let base = (s * nctx + pos) * nh;
                k.store_rows(base, hd, &sc.kstage[s * l * d..][..l * d]);
                v.store_rows(base, hd, &sc.vstage[s * l * d..][..l * d]);
            }
            let (kc, vc) = (&*k, &*v);
            let scale = 1.0 / (hd as f32).sqrt();
            let qh = &sc.qh[..];
            // identical per-query streaming softmax as block_step (same
            // accumulation order ⇒ same bits), blocked `chunk` query rows
            // at a time so the score scratch is chunk-bounded
            let qblock = chunk.min(l);
            let scp = super::pool::SliceParts::new(&mut sc.scores);
            let ap = super::pool::SliceParts::new(&mut sc.ah);
            let mut q0 = 0;
            while q0 < l {
                let tb = qblock.min(l - q0);
                let base = q0;
                pool.run(tb * n_sh, |task| {
                    let (ti, sh) = (task / n_sh, task % n_sh);
                    let (s, hh) = (sh / nh, sh % nh);
                    let t = base + ti;
                    let g = pos + t; // global position of this query row
                    let qr = &qh[(sh * l + t) * hd..][..hd];
                    // SAFETY: task `task` touches scores window `task` and
                    // ah window `(sh·l + t)` only — each (t, sh) pair occurs
                    // in exactly one task across the query blocks.
                    let (scores, out) = unsafe {
                        (scp.window(task * nctx, g + 1), ap.window((sh * l + t) * hd, hd))
                    };
                    let mut m = f32::NEG_INFINITY;
                    for (tt, sx) in scores.iter_mut().enumerate() {
                        let a = kc.row_dot((s * nctx + tt) * nh + hh, hd, qr) * scale;
                        *sx = a;
                        m = m.max(a);
                    }
                    let mut z = 0.0f32;
                    for sx in scores.iter_mut() {
                        *sx = (*sx - m).exp();
                        z += *sx;
                    }
                    let inv = 1.0 / z;
                    for (tt, sx) in scores.iter().enumerate() {
                        vc.row_axpy((s * nctx + tt) * nh + hh, hd, sx * inv, out);
                    }
                });
                q0 += tb;
            }
        }
    }
    merge_heads_into(&sc.ah, ns, l, nh, hd, &mut sc.a);
    matmul_q(pool, &sc.a, p.w(bi.wq + 3), rows, d, d, h);

    if let Some(mi) = bi.mlp {
        let f = cfg.d_ff;
        match bi.ln2 {
            Some(i) => ln_fwd_into(h, p.at(i), p.at(i + 1), rows, d, &mut sc.x2),
            None => sc.x2.copy_from_slice(h),
        }
        let b1 = p.at(mi + 1);
        for r in 0..rows {
            sc.m1[r * f..][..f].copy_from_slice(b1);
        }
        matmul_q(pool, &sc.x2, p.w(mi), rows, d, f, &mut sc.m1);
        for (o, &x) in sc.gact.iter_mut().zip(sc.m1.iter()) {
            *o = gelu(x);
        }
        let b2 = p.at(mi + 3);
        for r in 0..rows {
            let hr = &mut h[r * d..][..d];
            for (hx, bx) in hr.iter_mut().zip(b2) {
                *hx += bx;
            }
        }
        matmul_q(pool, &sc.gact, p.w(mi + 2), rows, f, d, h);
    }
}

/// The linear variants' normalizer divide over whole-window kernel output:
/// each `hd+1` row of `u` is `Sᵀ·φ(q)` ++ ones-channel; `ah` gets the first
/// `hd` entries divided by the (floored) normalizer — the batched form of
/// [`linear_state_task`]'s tail.
// deny_alloc
fn normalize_linear_rows(u: &[f32], hd: usize, ah: &mut [f32]) {
    for (ur, ar) in u.chunks_exact(hd + 1).zip(ah.chunks_exact_mut(hd)) {
        let z = ur[hd] + EPS;
        for (a, &x) in ar.iter_mut().zip(&ur[..hd]) {
            *a = x / z;
        }
    }
}

/// Split a `(batch, n_ctx+1)` token tensor into model inputs and next-token
/// targets.
fn split_xy(cfg: &LmConfig, tokens: &Tensor) -> Result<(Vec<i32>, Vec<i32>)> {
    if tokens.shape() != [cfg.batch, cfg.n_ctx + 1].as_slice() {
        bail!(
            "train/eval artifact wants tokens ({}, {}), got {:?}",
            cfg.batch,
            cfg.n_ctx + 1,
            tokens.shape()
        );
    }
    let data = tokens.as_i32()?;
    let row = cfg.n_ctx + 1;
    let mut x = Vec::with_capacity(cfg.batch * cfg.n_ctx);
    let mut y = Vec::with_capacity(cfg.batch * cfg.n_ctx);
    for b in 0..cfg.batch {
        let r = &data[b * row..][..row];
        x.extend_from_slice(&r[..cfg.n_ctx]);
        y.extend_from_slice(&r[1..]);
    }
    Ok((x, y))
}

// --- backward -----------------------------------------------------------------

/// Attention-mixer backward for one block: upstream head-major gradient
/// `dah` → head-major `(dqh, dkh, dvh)`.
fn attn_backward(
    cfg: &LmConfig,
    att: &AttnCache,
    dah: &[f32],
    pool: &ThreadPool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (bsz, l) = (cfg.batch, cfg.n_ctx);
    let (nh, hd) = (cfg.n_head, cfg.head_dim());
    match att {
        AttnCache::Softmax { qh, kh, vh } => {
            let sh = LayerShape::cube(bsz * nh, l, hd);
            let scale = 1.0 / (hd as f32).sqrt();
            softmax_bwd(pool, qh, kh, vh, dah, sh, scale)
        }
        AttnCache::Linear { qh, kh, fq, fk, vext, u } => {
            let gamma = attn_gamma(cfg.attn);
            let hrows = bsz * nh * l;
            // a = u[..hd] / z  with z = u[hd] + EPS
            let mut du = vec![0.0f32; hrows * (hd + 1)];
            for r in 0..hrows {
                let ur = &u[r * (hd + 1)..][..hd + 1];
                let z = ur[hd] + EPS;
                let dar = &dah[r * hd..][..hd];
                let dur = &mut du[r * (hd + 1)..][..hd + 1];
                let mut dot = 0.0f32;
                for j in 0..hd {
                    dur[j] = dar[j] / z;
                    dot += dar[j] * ur[j];
                }
                dur[hd] = -dot / (z * z);
            }
            let sh = LayerShape { bh: bsz * nh, n: l, dk: hd, dv: hd + 1 };
            let (dfq, dfk, dvext) = la_scan_bwd(pool, fq, fk, vext, &du, sh, gamma);
            let mut dqh = vec![0.0f32; hrows * hd];
            let mut dkh = vec![0.0f32; hrows * hd];
            let mut dvh = vec![0.0f32; hrows * hd];
            for i in 0..hrows * hd {
                dqh[i] = dfq[i] * elu1_grad(qh[i]);
                dkh[i] = dfk[i] * elu1_grad(kh[i]);
            }
            for r in 0..hrows {
                dvh[r * hd..][..hd].copy_from_slice(&dvext[r * (hd + 1)..][..hd]);
            }
            (dqh, dkh, dvh)
        }
    }
}

/// One block's backward: `dh` holds ∂L/∂h_out on entry and ∂L/∂h_in on
/// exit; parameter gradients accumulate into `grads` (state order).
#[allow(clippy::too_many_arguments)]
fn block_backward(
    cfg: &LmConfig,
    p: &P,
    bi: &BlockIdx,
    bc: &BlockCache,
    dh: &mut [f32],
    grads: &mut [Vec<f32>],
    pool: &ThreadPool,
) {
    let (bsz, l, d) = (cfg.batch, cfg.n_ctx, cfg.d_model);
    let (nh, hd) = (cfg.n_head, cfg.head_dim());
    let rows = bsz * l;

    // MLP sub-block: h_out = h_mid + GELU(x2·w1 + b1)·w2 + b2
    if let Some(mi) = bi.mlp {
        let f = cfg.d_ff;
        let (x2, m1, gact) = (
            bc.x2.as_ref().expect("mlp cache"),
            bc.m1.as_ref().expect("mlp cache"),
            bc.gact.as_ref().expect("mlp cache"),
        );
        for r in 0..rows {
            let dr = &dh[r * d..][..d];
            for (db, g) in grads[mi + 3].iter_mut().zip(dr) {
                *db += g;
            }
        }
        matmul_dw(pool, gact, dh, rows, f, d, &mut grads[mi + 2]);
        let mut dm1 = vec![0.0f32; rows * f];
        matmul_dx(pool, dh, p.at(mi + 2), rows, f, d, &mut dm1);
        for (dx, &m) in dm1.iter_mut().zip(m1.iter()) {
            *dx *= gelu_grad(m);
        }
        for r in 0..rows {
            let dr = &dm1[r * f..][..f];
            for (db, g) in grads[mi + 1].iter_mut().zip(dr) {
                *db += g;
            }
        }
        matmul_dw(pool, x2, &dm1, rows, d, f, &mut grads[mi]);
        match bi.ln2 {
            Some(i) => {
                let mut dx2 = vec![0.0f32; rows * d];
                matmul_dx(pool, &dm1, p.at(mi), rows, d, f, &mut dx2);
                let (dg, db) = grads_pair(grads, i);
                ln_bwd(
                    &bc.h_mid,
                    p.at(i),
                    bc.ln2.as_ref().expect("ln2 cache"),
                    &dx2,
                    rows,
                    d,
                    dh,
                    dg,
                    db,
                );
            }
            None => matmul_dx(pool, &dm1, p.at(mi), rows, d, f, dh),
        }
    }

    // attention sub-block: h_mid = h_in + MHA(x1)·wo
    let mut da = vec![0.0f32; rows * d];
    matmul_dw(pool, &bc.a, dh, rows, d, d, &mut grads[bi.wq + 3]);
    matmul_dx(pool, dh, p.at(bi.wq + 3), rows, d, d, &mut da);
    let dah = split_heads(&da, bsz, l, nh, hd);
    let (dqh, dkh, dvh) = attn_backward(cfg, &bc.att, &dah, pool);
    let dqp = merge_heads(&dqh, bsz, l, nh, hd);
    let dkp = merge_heads(&dkh, bsz, l, nh, hd);
    let dvp = merge_heads(&dvh, bsz, l, nh, hd);

    matmul_dw(pool, &bc.x1, &dqp, rows, d, d, &mut grads[bi.wq]);
    matmul_dw(pool, &bc.x1, &dkp, rows, d, d, &mut grads[bi.wq + 1]);
    matmul_dw(pool, &bc.x1, &dvp, rows, d, d, &mut grads[bi.wq + 2]);
    match bi.ln1 {
        Some(i) => {
            let mut dx1 = vec![0.0f32; rows * d];
            matmul_dx(pool, &dqp, p.at(bi.wq), rows, d, d, &mut dx1);
            matmul_dx(pool, &dkp, p.at(bi.wq + 1), rows, d, d, &mut dx1);
            matmul_dx(pool, &dvp, p.at(bi.wq + 2), rows, d, d, &mut dx1);
            let (dg, db) = grads_pair(grads, i);
            ln_bwd(
                &bc.h_in,
                p.at(i),
                bc.ln1.as_ref().expect("ln1 cache"),
                &dx1,
                rows,
                d,
                dh,
                dg,
                db,
            );
        }
        None => {
            // accumulate straight into dh — matches the pre-refactor
            // single-buffer ordering bit-for-bit on the legacy preset
            matmul_dx(pool, &dqp, p.at(bi.wq), rows, d, d, dh);
            matmul_dx(pool, &dkp, p.at(bi.wq + 1), rows, d, d, dh);
            matmul_dx(pool, &dvp, p.at(bi.wq + 2), rows, d, d, dh);
        }
    }
}

/// Two adjacent mutable gradient arrays (a LayerNorm's gain and shift).
fn grads_pair(grads: &mut [Vec<f32>], i: usize) -> (&mut [f32], &mut [f32]) {
    let (a, b) = grads[i..].split_at_mut(1);
    (a[0].as_mut_slice(), b[0].as_mut_slice())
}

/// Loss + gradients for every parameter array (state order) — public so the
/// finite-difference tests can check the analytic backward directly.
pub fn loss_and_grads(
    cfg: &LmConfig,
    params: &[&Tensor],
    tokens: &Tensor,
    pool: &ThreadPool,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let p = P::bind(cfg, params)?;
    let (x, y) = split_xy(cfg, tokens)?;
    loss_and_grads_inner(cfg, &p, &x, &y, pool)
}

fn loss_and_grads_inner(
    cfg: &LmConfig,
    p: &P,
    x: &[i32],
    y: &[i32],
    pool: &ThreadPool,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let (bsz, l, d, v) = (cfg.batch, cfg.n_ctx, cfg.d_model, cfg.vocab);
    let rows = bsz * l;
    let (logits, cache) = forward(cfg, p, x, pool, true)?;
    let mut dlogits = vec![0.0f32; rows * v];
    let loss = cross_entropy(&logits, y, v, Some(&mut dlogits))?;

    let shapes = cfg.param_shapes();
    let mut grads: Vec<Vec<f32>> = shapes
        .iter()
        .map(|(_, s)| vec![0.0f32; s.iter().product()])
        .collect();
    let idx = p.idx.clone();

    // logits = xf·wu + bu
    for r in 0..rows {
        let dr = &dlogits[r * v..][..v];
        for (db, g) in grads[idx.bu].iter_mut().zip(dr) {
            *db += g;
        }
    }
    matmul_dw(pool, &cache.xf, &dlogits, rows, d, v, &mut grads[idx.wu]);
    let mut dxf = vec![0.0f32; rows * d];
    matmul_dx(pool, &dlogits, p.at(idx.wu), rows, d, v, &mut dxf);

    // final LayerNorm (or pass-through)
    let mut dh = match idx.lnf {
        Some(i) => {
            let mut dhl = vec![0.0f32; rows * d];
            let (dg, db) = grads_pair(&mut grads, i);
            ln_bwd(
                &cache.h_last,
                p.at(i),
                cache.lnf.as_ref().expect("lnf cache"),
                &dxf,
                rows,
                d,
                &mut dhl,
                dg,
                db,
            );
            dhl
        }
        None => dxf,
    };

    for (bi, bc) in idx.blocks.iter().zip(&cache.blocks).rev() {
        block_backward(cfg, p, bi, bc, &mut dh, &mut grads, pool);
    }

    // h = wte[x] + wpe
    let (d_wte, d_wpe) = {
        let (a, b) = grads.split_at_mut(idx.wpe);
        (&mut a[idx.wte], &mut b[0])
    };
    for (r, &tok) in x.iter().enumerate() {
        let g = &dh[r * d..][..d];
        let te = &mut d_wte[tok as usize * d..][..d];
        for (dx, gx) in te.iter_mut().zip(g) {
            *dx += gx;
        }
        let pe = &mut d_wpe[(r % l) * d..][..d];
        for (dx, gx) in pe.iter_mut().zip(g) {
            *dx += gx;
        }
    }

    Ok((loss, grads))
}

// --- AdamW --------------------------------------------------------------------

/// AdamW hyper-parameters resolved for one 0-based step.
#[derive(Debug, Clone, Copy)]
struct AdamHp {
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    /// Bias corrections `1 − βᵗ`.
    bc1: f32,
    bc2: f32,
    wd: f32,
    clip: f32,
}

impl LmConfig {
    fn adam_hp(&self, step: usize) -> AdamHp {
        let (b1, b2) = (0.9f32, 0.999f32);
        let t1 = (step + 1) as i32;
        AdamHp {
            lr: self.lr_at(step),
            b1,
            b2,
            eps: 1e-8,
            bc1: 1.0 - b1.powi(t1),
            bc2: 1.0 - b2.powi(t1),
            wd: self.weight_decay as f32,
            clip: self.clip_norm as f32,
        }
    }
}

/// Global L2 norm over all gradient arrays. Deterministic regardless of the
/// pool size: per-array sums accumulate in f64 in state order.
pub fn grad_global_norm(grads: &[Vec<f32>]) -> f32 {
    let mut total = 0.0f64;
    for g in grads {
        total += g.iter().map(|&x| x as f64 * x as f64).sum::<f64>();
    }
    total.sqrt() as f32
}

/// Gradient rescale factor for global-norm clipping (1.0 when disabled or
/// under the threshold).
fn clip_scale(hp: &AdamHp, norm: f32) -> f32 {
    if hp.clip > 0.0 && norm > hp.clip {
        hp.clip / norm
    } else {
        1.0
    }
}

/// One element of the AdamW update: `(p, m, v) × g → (p', m', v')`. The
/// single source of the arithmetic — the in-place and rebuild routes both
/// inline this, which is what makes their outputs bit-exact against each
/// other (and, at `wd = 0`, value-identical to the pre-AdamW Adam step).
#[inline(always)]
fn adamw_elem(p: f32, m: f32, v: f32, g: f32, hp: &AdamHp, wd: f32) -> (f32, f32, f32) {
    let m_new = hp.b1 * m + (1.0 - hp.b1) * g;
    let v_new = hp.b2 * v + (1.0 - hp.b2) * g * g;
    let mh = m_new / hp.bc1;
    let vh = v_new / hp.bc2;
    // decoupled decay: pulls on the parameter directly, never through m/v
    let p_new = p - hp.lr * mh / (vh.sqrt() + hp.eps) - hp.lr * wd * p;
    (p_new, m_new, v_new)
}

/// Whether weight decay applies to parameter array `i` (matrices and
/// embeddings decay; biases and LayerNorm affines do not).
fn decays(shape: &[usize]) -> bool {
    shape.len() >= 2
}

/// Raw per-array `(param, m, v)` views of one training state, so the pool
/// can update disjoint arrays concurrently. Same contract as
/// [`super::pool::SliceParts`]: task `i` touches exactly triple `i`.
/// Borrows the scratch's pointer list; the lifetime ties it to the
/// `state` borrow the pointers were derived from.
struct StateViews<'a> {
    arrs: &'a [(*mut f32, *mut f32, *mut f32, usize)],
}

// SAFETY: each (p, m, v, len) triple aliases a distinct set of tensors, and
// the parallel update hands triple `i` to task `i` only, while the borrow of
// the state slice is held by the caller for the whole update.
unsafe impl Send for StateViews<'_> {}
unsafe impl Sync for StateViews<'_> {}

/// Reusable buffers for [`adamw_update_mut_scratch`]: the per-array decay
/// flags (computed once from the config's shapes — the only call-site of
/// the allocating `param_shapes()`) and the pointer-triple list the pool
/// tasks index. After the first update with a given config, the update is
/// **strictly allocation-free** — `tests/alloc_gate.rs` asserts zero
/// allocation events on the stepping thread with a 1-thread pool.
///
/// A scratch is per-config: it caches decay flags by array count, so reuse
/// it across steps of one run, not across models.
#[derive(Default)]
pub struct AdamwScratch {
    decay: Vec<bool>,
    arrs: Vec<(*mut f32, *mut f32, *mut f32, usize)>,
}

impl AdamwScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Fused in-place AdamW update over `state = params ++ m ++ v`: clips by
/// global norm, then updates moments and parameters buffer-by-buffer with no
/// allocation, one parameter array per pool task. Returns the **pre-clip**
/// gradient norm (the logged metric).
///
/// Convenience form paying one fresh [`AdamwScratch`] (two small `Vec`s +
/// the `param_shapes()` walk) per call; training loops should hold a
/// scratch and use [`adamw_update_mut_scratch`].
pub fn adamw_update_mut(
    cfg: &LmConfig,
    state: &mut [Tensor],
    grads: &[Vec<f32>],
    step: usize,
    pool: &ThreadPool,
) -> Result<f32> {
    let mut sc = AdamwScratch::new();
    adamw_update_mut_scratch(cfg, state, grads, step, pool, &mut sc)
}

/// [`adamw_update_mut`] with caller-held scratch: zero allocations per step
/// once the scratch is warm (see [`AdamwScratch`]).
pub fn adamw_update_mut_scratch(
    cfg: &LmConfig,
    state: &mut [Tensor],
    grads: &[Vec<f32>],
    step: usize,
    pool: &ThreadPool,
    sc: &mut AdamwScratch,
) -> Result<f32> {
    let np = cfg.n_param_arrays();
    if state.len() != 3 * np {
        bail!("adamw_update_mut wants {} state arrays (params ++ m ++ v), got {}", 3 * np, state.len());
    }
    if grads.len() != np {
        bail!("adamw_update_mut wants {np} gradient arrays, got {}", grads.len());
    }
    if sc.decay.len() != np {
        // one-time (per config) — the only allocating path in this update
        sc.decay.clear();
        sc.decay.extend(cfg.param_shapes().iter().map(|(_, s)| decays(s)));
        sc.arrs.reserve(np);
    }
    let hp = cfg.adam_hp(step);
    let norm = grad_global_norm(grads);
    let scale = clip_scale(&hp, norm);

    let (ps, rest) = state.split_at_mut(np);
    let (ms, vs) = rest.split_at_mut(np);
    sc.arrs.clear();
    for i in 0..np {
        let pw = ps[i].as_f32_mut()?;
        let n = pw.len();
        let pw = pw.as_mut_ptr();
        let mw = ms[i].as_f32_mut()?;
        let vw = vs[i].as_f32_mut()?;
        if n != grads[i].len() || mw.len() != n || vw.len() != n {
            bail!("state array {i} has inconsistent length");
        }
        sc.arrs.push((pw, mw.as_mut_ptr(), vw.as_mut_ptr(), n));
    }
    let decay = &sc.decay[..];
    let views = StateViews { arrs: &sc.arrs };
    let views = &views;
    pool.run(np, |i| {
        let (pp, mp, vp, n) = views.arrs[i];
        // SAFETY: triple `i` is visited by task `i` only; the pointers stay
        // valid for the duration of `run` (state is mutably borrowed above).
        let (pw, mw, vw) = unsafe {
            (
                std::slice::from_raw_parts_mut(pp, n),
                std::slice::from_raw_parts_mut(mp, n),
                std::slice::from_raw_parts_mut(vp, n),
            )
        };
        let g = &grads[i];
        let wd = if decay[i] { hp.wd } else { 0.0 };
        for j in 0..n {
            let (p2, m2, v2) = adamw_elem(pw[j], mw[j], vw[j], g[j] * scale, &hp, wd);
            pw[j] = p2;
            mw[j] = m2;
            vw[j] = v2;
        }
    });
    Ok(norm)
}

/// The preserved rebuild AdamW step: same arithmetic as
/// [`adamw_update_mut`], but every output array is a freshly-allocated
/// `Vec`+`Tensor` (the pre-optimization allocation pattern). Kept as the
/// bit-exact parity oracle and the `bench-native` in-place speedup baseline.
/// Returns `(pre-clip grad norm, new state)`.
pub fn adamw_update_rebuild(
    cfg: &LmConfig,
    state: &[&Tensor],
    grads: &[Vec<f32>],
    step: usize,
) -> Result<(f32, Vec<Tensor>)> {
    let np = cfg.n_param_arrays();
    if state.len() < 3 * np {
        bail!("adamw_update_rebuild wants {} state arrays, got {}", 3 * np, state.len());
    }
    let shapes = cfg.param_shapes();
    let hp = cfg.adam_hp(step);
    let norm = grad_global_norm(grads);
    let scale = clip_scale(&hp, norm);

    let mut new_params = Vec::with_capacity(np);
    let mut new_m = Vec::with_capacity(np);
    let mut new_v = Vec::with_capacity(np);
    for i in 0..np {
        let pw = state[i].as_f32()?;
        let mw = state[np + i].as_f32()?;
        let vw = state[2 * np + i].as_f32()?;
        let g = &grads[i];
        if pw.len() != g.len() || mw.len() != g.len() || vw.len() != g.len() {
            bail!("state array {} has inconsistent length", shapes[i].0);
        }
        let wd = if decays(&shapes[i].1) { hp.wd } else { 0.0 };
        let mut p2 = Vec::with_capacity(g.len());
        let mut m2 = Vec::with_capacity(g.len());
        let mut v2 = Vec::with_capacity(g.len());
        for j in 0..g.len() {
            let (pj, mj, vj) = adamw_elem(pw[j], mw[j], vw[j], g[j] * scale, &hp, wd);
            p2.push(pj);
            m2.push(mj);
            v2.push(vj);
        }
        new_params.push(Tensor::f32(shapes[i].1.clone(), p2)?);
        new_m.push(Tensor::f32(shapes[i].1.clone(), m2)?);
        new_v.push(Tensor::f32(shapes[i].1.clone(), v2)?);
    }
    let mut out = Vec::with_capacity(3 * np);
    out.extend(new_params);
    out.extend(new_m);
    out.extend(new_v);
    Ok((norm, out))
}

/// One AdamW step over the full state via the **rebuild** route (the
/// borrowed-input `lm_*_train_step` artifact body). `state` is
/// params ++ m ++ v; returns `[loss, grad_norm] ++ new state`.
pub fn train_step(
    cfg: &LmConfig,
    state: &[&Tensor],
    tokens: &Tensor,
    step: i64,
    pool: &ThreadPool,
) -> Result<Vec<Tensor>> {
    let np = cfg.n_param_arrays();
    if state.len() != 3 * np {
        bail!("train_step wants {} state arrays (params ++ m ++ v), got {}", 3 * np, state.len());
    }
    let p = P::bind(cfg, &state[..np])?;
    let (x, y) = split_xy(cfg, tokens)?;
    let (loss, grads) = loss_and_grads_inner(cfg, &p, &x, &y, pool)?;
    let (norm, new_state) = adamw_update_rebuild(cfg, state, &grads, step.max(0) as usize)?;

    let mut out = Vec::with_capacity(2 + 3 * np);
    out.push(Tensor::scalar_f32(loss));
    out.push(Tensor::scalar_f32(norm));
    out.extend(new_state);
    Ok(out)
}

/// One AdamW step that mutates `state` (params ++ m ++ v) **in place** —
/// the steady-state training loop allocates no state tensors at all.
/// Returns `(loss, pre-clip grad norm)`.
pub fn train_step_mut(
    cfg: &LmConfig,
    state: &mut [Tensor],
    tokens: &Tensor,
    step: i64,
    pool: &ThreadPool,
) -> Result<(f32, f32)> {
    let np = cfg.n_param_arrays();
    if state.len() != 3 * np {
        bail!("train_step_mut wants {} state arrays (params ++ m ++ v), got {}", 3 * np, state.len());
    }
    let (x, y) = split_xy(cfg, tokens)?;
    let (loss, grads) = {
        let refs: Vec<&Tensor> = state[..np].iter().collect();
        let p = P::bind(cfg, &refs)?;
        loss_and_grads_inner(cfg, &p, &x, &y, pool)?
    };
    let norm = adamw_update_mut(cfg, state, &grads, step.max(0) as usize, pool)?;
    Ok((loss, norm))
}

/// Scalar from a rank-0/rank-1 tensor (seeds, step counters).
pub fn scalar_i64(t: &Tensor) -> Result<i64> {
    match t {
        Tensor::I32 { data, .. } => {
            data.first().map(|&x| x as i64).ok_or_else(|| anyhow!("empty scalar tensor"))
        }
        Tensor::F32 { data, .. } => {
            data.first().map(|&x| x as i64).ok_or_else(|| anyhow!("empty scalar tensor"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn refs(state: &[Tensor]) -> Vec<&Tensor> {
        state.iter().collect()
    }

    fn pool() -> ThreadPool {
        ThreadPool::new(2)
    }

    fn tiny_tokens(cfg: &LmConfig, seed: u64) -> Tensor {
        let mut rng = crate::data::rng::SplitMix64::new(seed);
        let n = cfg.batch * (cfg.n_ctx + 1);
        Tensor::i32(
            vec![cfg.batch, cfg.n_ctx + 1],
            (0..n).map(|_| rng.below(cfg.vocab) as i32).collect(),
        )
        .unwrap()
    }

    #[test]
    fn param_layout_is_consistent() {
        for cfg in [
            LmConfig::tiny(AttnKind::Ours),
            LmConfig::small(AttnKind::Softmax),
            LmConfig::medium(AttnKind::Ours),
            LmConfig::legacy_tiny(AttnKind::Gated),
        ] {
            cfg.validate().unwrap();
            let shapes = cfg.param_shapes();
            let idx = cfg.param_idx();
            assert_eq!(shapes.len(), idx.count);
            assert_eq!(cfg.n_param_arrays(), shapes.len());
            assert_eq!(shapes[idx.wte].0, "wte");
            assert_eq!(shapes[idx.wpe].0, "wpe");
            assert_eq!(shapes[idx.wu].0, "wu");
            assert_eq!(shapes[idx.bu].0, "bu");
            for (b, bi) in idx.blocks.iter().enumerate() {
                assert_eq!(shapes[bi.wq].0, format!("h{b}.wq"));
                assert_eq!(shapes[bi.wq + 3].0, format!("h{b}.wo"));
                if let Some(i) = bi.ln1 {
                    assert_eq!(shapes[i].0, format!("h{b}.ln1_g"));
                    assert_eq!(shapes[i + 1].0, format!("h{b}.ln1_b"));
                }
                if let Some(mi) = bi.mlp {
                    assert_eq!(shapes[mi].0, format!("h{b}.w1"));
                    assert_eq!(shapes[mi + 3].0, format!("h{b}.b2"));
                }
            }
            if let Some(i) = idx.lnf {
                assert_eq!(shapes[i].0, "lnf_g");
            }
            // scalar count matches the sum of array sizes
            let total: u64 =
                shapes.iter().map(|(_, s)| s.iter().product::<usize>() as u64).sum();
            assert_eq!(cfg.n_params(), total);
        }
    }

    #[test]
    fn legacy_layout_matches_pre_refactor_state_order() {
        let cfg = LmConfig::legacy_tiny(AttnKind::Ours);
        let names: Vec<String> =
            cfg.param_shapes().into_iter().map(|(n, _)| n).collect();
        assert_eq!(
            names,
            ["wte", "wpe", "h0.wq", "h0.wk", "h0.wv", "h0.wo", "wu", "bu"]
        );
        assert_eq!(cfg.n_param_arrays(), 8);
    }

    #[test]
    fn init_state_shapes_and_determinism() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let np = cfg.n_param_arrays();
        let a = cfg.init_state(7);
        let b = cfg.init_state(7);
        assert_eq!(a.len(), 3 * np);
        assert_eq!(a, b);
        let c = cfg.init_state(8);
        assert_ne!(a, c);
        for ((name, shape), t) in cfg.param_shapes().iter().zip(&a) {
            assert_eq!(t.shape(), shape.as_slice(), "{name}");
        }
        // LayerNorm gains start at one, shifts and biases at zero
        let idx = cfg.param_idx();
        let ln1 = idx.blocks[0].ln1.unwrap();
        assert!(a[ln1].as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(a[ln1 + 1].as_f32().unwrap().iter().all(|&x| x == 0.0));
        let mi = idx.blocks[0].mlp.unwrap();
        assert!(a[mi + 1].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(a[idx.bu].as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fresh_model_loss_is_near_uniform() {
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::tiny(attn);
            let state = cfg.init_state(0);
            let toks = tiny_tokens(&cfg, 1);
            let s = refs(&state);
            let loss = eval_loss(&cfg, &s[..cfg.n_param_arrays()], &toks, &pool()).unwrap();
            let uniform = (cfg.vocab as f32).ln();
            assert!(
                (loss - uniform).abs() < 0.3,
                "{attn:?}: fresh loss {loss} vs ln(V) {uniform}"
            );
        }
    }

    #[test]
    fn train_step_reduces_loss_on_repeated_batch() {
        // overfit a single highly-structured batch (a short token cycle —
        // next-token is a deterministic function of the current token):
        // a few Adam steps must cut the loss clearly
        for attn in [AttnKind::Ours, AttnKind::Gated, AttnKind::Softmax] {
            let cfg = LmConfig::tiny(attn);
            let mut state = cfg.init_state(3);
            let n = cfg.batch * (cfg.n_ctx + 1);
            let toks = Tensor::i32(
                vec![cfg.batch, cfg.n_ctx + 1],
                (0..n).map(|i| (i % 17) as i32).collect(),
            )
            .unwrap();
            let mut first = f32::NAN;
            let mut last = f32::NAN;
            for step in 0..20 {
                let s = refs(&state);
                let out = train_step(&cfg, &s, &toks, step, &pool()).unwrap();
                let loss = out[0].scalar().unwrap();
                assert!(loss.is_finite(), "{attn:?} step {step}");
                assert!(out[1].scalar().unwrap().is_finite(), "{attn:?} grad norm, step {step}");
                if step == 0 {
                    first = loss;
                }
                last = loss;
                state = out[2..].to_vec();
            }
            assert!(
                last < first - 0.3,
                "{attn:?}: loss did not drop ({first} → {last})"
            );
        }
    }

    #[test]
    fn logits_shape_matches_artifact_contract() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let state = cfg.init_state(0);
        let s = refs(&state);
        let toks = Tensor::i32(
            vec![cfg.batch, cfg.n_ctx],
            vec![5; cfg.batch * cfg.n_ctx],
        )
        .unwrap();
        let lg = logits(&cfg, &s[..cfg.n_param_arrays()], &toks, &pool()).unwrap();
        assert_eq!(lg.shape(), &[cfg.batch, cfg.n_ctx, cfg.vocab]);
        assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn split_merge_heads_roundtrip() {
        let (bsz, l, nh, hd) = (2, 3, 4, 5);
        let n = bsz * l * nh * hd;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let h = split_heads(&x, bsz, l, nh, hd);
        let back = merge_heads(&h, bsz, l, nh, hd);
        assert_eq!(back, x);
        // H = 1 is the identity layout (the legacy preset's path)
        let h1 = split_heads(&x, bsz, l, 1, nh * hd);
        assert_eq!(h1, x);
    }

    #[test]
    fn layernorm_normalizes_rows() {
        let (rows, d) = (4, 16);
        let x: Vec<f32> = (0..rows * d).map(|i| (i as f32 * 0.37).sin() * 3.0 + 1.0).collect();
        let g = vec![1.0f32; d];
        let b = vec![0.0f32; d];
        let (y, _c) = ln_fwd(&x, &g, &b, rows, d);
        for r in 0..rows {
            let yr = &y[r * d..][..d];
            let m: f32 = yr.iter().sum::<f32>() / d as f32;
            let var: f32 = yr.iter().map(|&v| (v - m) * (v - m)).sum::<f32>() / d as f32;
            assert!(m.abs() < 1e-4, "row {r} mean {m}");
            assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
        }
    }

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        assert!(cfg.lr_at(0) < cfg.lr_at(cfg.warmup_steps - 1) + 1e-9);
        let peak = cfg.lr_at(cfg.warmup_steps);
        assert!((peak - cfg.lr_max as f32).abs() < 1e-6);
        assert!(cfg.lr_at(cfg.total_steps) <= cfg.lr_min as f32 + 1e-6);
    }

    #[test]
    fn rejects_out_of_range_tokens() {
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let state = cfg.init_state(0);
        let s = refs(&state);
        let mut data = vec![0i32; cfg.batch * (cfg.n_ctx + 1)];
        data[3] = cfg.vocab as i32; // one past the end
        let toks = Tensor::i32(vec![cfg.batch, cfg.n_ctx + 1], data).unwrap();
        assert!(eval_loss(&cfg, &s[..cfg.n_param_arrays()], &toks, &pool()).is_err());
    }

    #[test]
    fn medium_preset_is_deep_and_scales_corpus() {
        let cfg = LmConfig::medium(AttnKind::Ours);
        cfg.validate().unwrap();
        assert!(cfg.n_layer >= 8 && cfg.n_head >= 8 && cfg.d_model >= 256);
        assert!(cfg.n_params() > 2_000_000, "n_params {}", cfg.n_params());
        assert!(
            cfg.corpus_bytes_hint() > LmConfig::small(AttnKind::Ours).corpus_bytes_hint(),
            "medium must train on a larger corpus"
        );
        assert!(cfg.weight_decay > 0.0 && cfg.clip_norm > 0.0);
        // legacy stays plain Adam so its pinned trajectory is untouched
        let legacy = LmConfig::legacy_tiny(AttnKind::Ours);
        assert_eq!(legacy.weight_decay, 0.0);
        assert_eq!(legacy.clip_norm, 0.0);
    }

    #[test]
    fn rejects_indivisible_head_count() {
        let mut cfg = LmConfig::tiny(AttnKind::Ours);
        cfg.n_head = 3;
        assert!(cfg.validate().is_err());
        assert!(LmConfig::by_preset("huge", AttnKind::Ours).is_err());
    }
}
