//! Cache-blocked f32 matmul microkernels for the native backend.
//!
//! All operands are row-major flat slices; every routine **accumulates** into
//! `out` (`+=`), matching how the kernels and the LM backward compose
//! partial products. Three orientations cover every product in the tree:
//!
//! - [`gemm_nn`] — `out[m×n] += a[m×k] · b[k×n]` (chunkwise inter term,
//!   masked-score × V, LM forward layers);
//! - [`gemm_nt`] — `out[m×n] += a[m×k] · b[n×k]ᵀ` (Q·Kᵀ score tiles,
//!   GO·Vᵀ tiles, LM `dx` backward);
//! - [`gemm_tn`] — `out[m×n] += a[k×m]ᵀ · b[k×n]` (Kᵀ·V state updates,
//!   Qᵀ·GO reverse states, LM `dw` backward).
//!
//! The hot path is a fixed `MR×NR = 8×8` register tile: `NR = 8` output
//! columns form one AVX2 lane (or one `f32x8` under the `simd` feature), and
//! the eight per-row accumulators live in registers across the full `k` loop.
//! At the shapes this crate runs (`k ≤ 512`), the `MR×k` A-panel and `k×NR`
//! B-panel both sit in L1, so no copy-packing pass is needed — the i/j tile
//! loops are the cache blocking. Edge tiles (`m % 8`, `n % 8`) fall back to a
//! runtime-sized variant of the same kernel.
//!
//! `par_gemm_*` split the *output rows* into contiguous stripes across the
//! [`ThreadPool`] — output-disjoint, so no reduction step — and fall back to
//! single-thread below [`PAR_MIN_FLOPS`].
//!
//! With `--features simd` (nightly), the full tiles and [`dot`] use
//! `core::simd::f32x8` with fused multiply-add; the stable default relies on
//! the same loop shapes autovectorizing.

use super::pool::ThreadPool;
use super::quant;

/// Microkernel tile height (output rows held in flight).
pub const MR: usize = 8;
/// Microkernel tile width (output columns per SIMD lane).
pub const NR: usize = 8;

/// Below this many multiply-adds a parallel launch costs more than it saves.
pub const PAR_MIN_FLOPS: usize = 1 << 17;

// --- dot / axpy primitives --------------------------------------------------

/// Dot product with eight parallel accumulators (one vector lane).
// deny_alloc
#[cfg(not(feature = "simd"))]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 8];
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let xs = &x[c * 8..][..8];
        let ys = &y[c * 8..][..8];
        for l in 0..8 {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Dot product, `f32x8` + FMA.
// deny_alloc
#[cfg(feature = "simd")]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    use std::simd::f32x8;
    use std::simd::num::SimdFloat;
    use std::simd::StdFloat;
    debug_assert_eq!(x.len(), y.len());
    let mut acc = f32x8::splat(0.0);
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let xv = f32x8::from_slice(&x[c * 8..]);
        let yv = f32x8::from_slice(&y[c * 8..]);
        acc = xv.mul_add(yv, acc);
    }
    let mut s = acc.reduce_sum();
    for i in chunks * 8..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// `y += alpha · x`.
// deny_alloc
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

// --- quantized dot / axpy (bf16 + int8 inputs, f32 accumulation) -------------
//
// The decode KV cache stores K/V rows in bf16 or int8; these widen each
// element to f32 on load and accumulate in f32, so only the bytes at rest
// shrink. Same eight-accumulator shape as `dot` so the stable build
// autovectorizes identically.

/// Dot of an f32 query row against a bf16-coded row.
// deny_alloc
pub fn dot_bf16(x: &[f32], y: &[u16]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 8];
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let xs = &x[c * 8..][..8];
        let ys = &y[c * 8..][..8];
        for l in 0..8 {
            acc[l] += xs[l] * quant::bf16_to_f32(ys[l]);
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..x.len() {
        s += x[i] * quant::bf16_to_f32(y[i]);
    }
    s
}

/// Dot of an f32 row against raw int8 codes — the caller multiplies the
/// result by the row's scale once, outside the loop.
// deny_alloc
pub fn dot_i8(x: &[f32], y: &[i8]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 8];
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let xs = &x[c * 8..][..8];
        let ys = &y[c * 8..][..8];
        for l in 0..8 {
            acc[l] += xs[l] * ys[l] as f32;
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..x.len() {
        s += x[i] * y[i] as f32;
    }
    s
}

/// `y += alpha · bf16(x)`.
// deny_alloc
pub fn axpy_bf16(alpha: f32, x: &[u16], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * quant::bf16_to_f32(xv);
    }
}

/// `y += alpha · x` for int8 codes (`alpha` carries the row scale).
// deny_alloc
pub fn axpy_i8(alpha: f32, x: &[i8], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv as f32;
    }
}

// --- gemm_nn ----------------------------------------------------------------

/// `out[m×n] += a[m×k] · b[k×n]`, row-major, accumulating.
// deny_alloc
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    let mut i0 = 0;
    while i0 < m {
        let mh = (m - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let nh = (n - j0).min(NR);
            if mh == MR && nh == NR {
                tile_nn_full(a, b, k, n, i0, j0, out);
            } else {
                tile_nn_edge(a, b, k, n, i0, j0, mh, nh, out);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// Full `MR×NR` tile of `gemm_nn`: broadcast `a[i][p]`, stream `b[p][j0..]`.
#[cfg(not(feature = "simd"))]
#[inline]
#[allow(clippy::needless_range_loop)]
// bounds: full-tile fast path — caller dispatches it only when mh == MR && nh == NR, and the enclosing gemm's entry debug_assert covers every a/b/out span
fn tile_nn_full(a: &[f32], b: &[f32], k: usize, n: usize, i0: usize, j0: usize, out: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..NR];
        for ii in 0..MR {
            let av = a[(i0 + ii) * k + p];
            for jj in 0..NR {
                acc[ii][jj] += av * brow[jj];
            }
        }
    }
    for ii in 0..MR {
        let orow = &mut out[(i0 + ii) * n + j0..][..NR];
        for jj in 0..NR {
            orow[jj] += acc[ii][jj];
        }
    }
}

#[cfg(feature = "simd")]
#[inline]
#[allow(clippy::needless_range_loop)]
// bounds: full-tile fast path — caller dispatches it only when mh == MR && nh == NR, and the enclosing gemm's entry debug_assert covers every a/b/out span
fn tile_nn_full(a: &[f32], b: &[f32], k: usize, n: usize, i0: usize, j0: usize, out: &mut [f32]) {
    use std::simd::f32x8;
    use std::simd::StdFloat;
    let mut acc = [f32x8::splat(0.0); MR];
    for p in 0..k {
        let bv = f32x8::from_slice(&b[p * n + j0..]);
        for ii in 0..MR {
            let av = f32x8::splat(a[(i0 + ii) * k + p]);
            acc[ii] = av.mul_add(bv, acc[ii]);
        }
    }
    for ii in 0..MR {
        let orow = &mut out[(i0 + ii) * n + j0..][..NR];
        let cur = f32x8::from_slice(orow) + acc[ii];
        cur.copy_to_slice(orow);
    }
}

/// Edge tile of `gemm_nn` (`mh ≤ MR`, `nh ≤ NR` at runtime).
#[inline]
// bounds: mh/nh clamp the tile to the matrix edge; all spans sit inside the enclosing gemm's entry debug_assert
fn tile_nn_edge(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    mh: usize,
    nh: usize,
    out: &mut [f32],
) {
    let mut acc = [0.0f32; MR * NR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..nh];
        for ii in 0..mh {
            let av = a[(i0 + ii) * k + p];
            let arow = &mut acc[ii * NR..][..nh];
            for (c, &bv) in arow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    }
    for ii in 0..mh {
        let orow = &mut out[(i0 + ii) * n + j0..][..nh];
        for (o, c) in orow.iter_mut().zip(&acc[ii * NR..][..nh]) {
            *o += c;
        }
    }
}

// --- gemm_nt ----------------------------------------------------------------

/// `out[m×n] += a[m×k] · b[n×k]ᵀ` — row-row dot products; each `a` row stays
/// hot in L1 across all `n` columns.
// deny_alloc
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(a.len() >= m * k && b.len() >= n * k && out.len() >= m * n);
    for i in 0..m {
        let ar = &a[i * k..][..k];
        let orow = &mut out[i * n..][..n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o += dot(ar, &b[j * k..][..k]);
        }
    }
}

// --- gemm_tn ----------------------------------------------------------------

/// `out[m×n] += a[k×m]ᵀ · b[k×n]` — rank-1 accumulation over the shared `k`
/// rows; both tile loads are contiguous.
// deny_alloc
pub fn gemm_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    gemm_tn_rows(a, b, m, k, n, 0, m, out);
}

/// Rows `[r0, r1)` of the `gemm_tn` output, written into `out_rows` (a slab
/// holding exactly those rows) — the unit the parallel wrapper stripes over.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_tn_rows(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    r0: usize,
    r1: usize,
    out_rows: &mut [f32],
) {
    debug_assert!(r0 <= r1 && r1 <= m);
    debug_assert!(a.len() >= k * m && b.len() >= k * n && out_rows.len() >= (r1 - r0) * n);
    let mut i0 = r0;
    while i0 < r1 {
        let mh = (r1 - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let nh = (n - j0).min(NR);
            let mut acc = [0.0f32; MR * NR];
            for p in 0..k {
                let arow = &a[p * m + i0..][..mh];
                let brow = &b[p * n + j0..][..nh];
                for (ii, &av) in arow.iter().enumerate() {
                    let accrow = &mut acc[ii * NR..][..nh];
                    for (c, &bv) in accrow.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
            for ii in 0..mh {
                let orow = &mut out_rows[(i0 - r0 + ii) * n + j0..][..nh];
                for (o, c) in orow.iter_mut().zip(&acc[ii * NR..][..nh]) {
                    *o += c;
                }
            }
            j0 += NR;
        }
        i0 += mh;
    }
}

// --- parallel wrappers --------------------------------------------------------

/// [`gemm_nn`] with output rows striped across the pool.
// bounds: stripe offsets mirror run_stripes' disjoint partition of out[..m*n]; a rows covered by the serial gemm's entry debug_assert
pub fn par_gemm_nn(
    pool: &ThreadPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    if pool.threads() <= 1 || m * k * n < PAR_MIN_FLOPS {
        return gemm_nn(a, b, m, k, n, out);
    }
    pool.run_stripes(&mut out[..m * n], n, |r0, slab| {
        let rows = slab.len() / n;
        gemm_nn(&a[r0 * k..][..rows * k], b, rows, k, n, slab);
    });
}

/// [`gemm_nt`] with output rows striped across the pool.
pub fn par_gemm_nt(
    pool: &ThreadPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    if pool.threads() <= 1 || m * k * n < PAR_MIN_FLOPS {
        return gemm_nt(a, b, m, k, n, out);
    }
    pool.run_stripes(&mut out[..m * n], n, |r0, slab| {
        let rows = slab.len() / n;
        gemm_nt(&a[r0 * k..][..rows * k], b, rows, k, n, slab);
    });
}

/// [`gemm_tn`] with output rows striped across the pool (every stripe reads
/// all `k` rows of `a` and `b`; writes stay disjoint).
pub fn par_gemm_tn(
    pool: &ThreadPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    if pool.threads() <= 1 || m * k * n < PAR_MIN_FLOPS {
        return gemm_tn(a, b, m, k, n, out);
    }
    pool.run_stripes(&mut out[..m * n], n, |r0, slab| {
        let rows = slab.len() / n;
        gemm_tn_rows(a, b, m, k, n, r0, r0 + rows, slab);
    });
}

// --- quantized gemm_nn (bf16 / int8 B operand, f32 accumulation) -------------
//
// The decode hot path (`logits_step`) is `x[rows×k] · W[k×n]` with tiny
// `rows` (one token per sequence) and all the traffic in `W` — exactly the
// operand these variants store in bf16 or per-row-scaled int8. The tile
// structure is the same 8×8 register kernel as `gemm_nn`: per `p` the B-row
// slice is widened once into an f32 lane, then broadcast-FMA'd into the f32
// accumulators; for int8 the per-row scale folds into the broadcast side
// (`a[i][p] · scale[p]`), so the inner loop stays a pure widen-multiply-add.
// `gemm_nn_bf16_ref` / `gemm_nn_i8_ref` are the scalar parity oracles.

/// `out[m×n] += a[m×k] · bf16(b)[k×n]`, accumulating in f32.
// deny_alloc
pub fn gemm_nn_bf16(a: &[f32], b: &[u16], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    let mut i0 = 0;
    while i0 < m {
        let mh = (m - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let nh = (n - j0).min(NR);
            if mh == MR && nh == NR {
                tile_nn_bf16_full(a, b, k, n, i0, j0, out);
            } else {
                tile_nn_bf16_edge(a, b, k, n, i0, j0, mh, nh, out);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// Full `MR×NR` tile: widen one bf16 B-row slice to an f32 lane per `p`.
#[cfg(not(feature = "simd"))]
#[inline]
#[allow(clippy::needless_range_loop)]
// bounds: full-tile fast path — caller dispatches it only when mh == MR && nh == NR, and the enclosing gemm's entry debug_assert covers every a/b/out span
fn tile_nn_bf16_full(a: &[f32], b: &[u16], k: usize, n: usize, i0: usize, j0: usize, out: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..NR];
        let mut bw = [0.0f32; NR];
        for jj in 0..NR {
            bw[jj] = quant::bf16_to_f32(brow[jj]);
        }
        for ii in 0..MR {
            let av = a[(i0 + ii) * k + p];
            for jj in 0..NR {
                acc[ii][jj] += av * bw[jj];
            }
        }
    }
    for ii in 0..MR {
        let orow = &mut out[(i0 + ii) * n + j0..][..NR];
        for jj in 0..NR {
            orow[jj] += acc[ii][jj];
        }
    }
}

#[cfg(feature = "simd")]
#[inline]
#[allow(clippy::needless_range_loop)]
// bounds: full-tile fast path — caller dispatches it only when mh == MR && nh == NR, and the enclosing gemm's entry debug_assert covers every a/b/out span
fn tile_nn_bf16_full(a: &[f32], b: &[u16], k: usize, n: usize, i0: usize, j0: usize, out: &mut [f32]) {
    use std::simd::f32x8;
    use std::simd::StdFloat;
    let mut acc = [f32x8::splat(0.0); MR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..NR];
        let mut bw = [0.0f32; NR];
        for jj in 0..NR {
            bw[jj] = quant::bf16_to_f32(brow[jj]);
        }
        let bv = f32x8::from_array(bw);
        for ii in 0..MR {
            let av = f32x8::splat(a[(i0 + ii) * k + p]);
            acc[ii] = av.mul_add(bv, acc[ii]);
        }
    }
    for ii in 0..MR {
        let orow = &mut out[(i0 + ii) * n + j0..][..NR];
        let cur = f32x8::from_slice(orow) + acc[ii];
        cur.copy_to_slice(orow);
    }
}

/// Edge tile of [`gemm_nn_bf16`] (`mh ≤ MR`, `nh ≤ NR` at runtime).
#[inline]
#[allow(clippy::too_many_arguments)]
// bounds: mh/nh clamp the tile to the matrix edge; all spans sit inside the enclosing gemm's entry debug_assert
fn tile_nn_bf16_edge(
    a: &[f32],
    b: &[u16],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    mh: usize,
    nh: usize,
    out: &mut [f32],
) {
    let mut acc = [0.0f32; MR * NR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..nh];
        for ii in 0..mh {
            let av = a[(i0 + ii) * k + p];
            let arow = &mut acc[ii * NR..][..nh];
            for (c, &bv) in arow.iter_mut().zip(brow) {
                *c += av * quant::bf16_to_f32(bv);
            }
        }
    }
    for ii in 0..mh {
        let orow = &mut out[(i0 + ii) * n + j0..][..nh];
        for (o, c) in orow.iter_mut().zip(&acc[ii * NR..][..nh]) {
            *o += c;
        }
    }
}

/// `out[m×n] += a[m×k] · (i8(b) ⊙ scales)[k×n]`: `b` holds int8 codes row-
/// scaled by `scales[p]` (one f32 per B row, `scales.len() ≥ k`), accumulated
/// in f32. The scale folds into the broadcast `a` element, so the inner loop
/// is a pure widen-multiply-add.
// deny_alloc
pub fn gemm_nn_i8(
    a: &[f32],
    b: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    debug_assert!(scales.len() >= k);
    let mut i0 = 0;
    while i0 < m {
        let mh = (m - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let nh = (n - j0).min(NR);
            if mh == MR && nh == NR {
                tile_nn_i8_full(a, b, scales, k, n, i0, j0, out);
            } else {
                tile_nn_i8_edge(a, b, scales, k, n, i0, j0, mh, nh, out);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// Full `MR×NR` tile: per `p`, scale-folded broadcast × widened i8 B-row.
#[cfg(not(feature = "simd"))]
#[inline]
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// bounds: full-tile fast path — caller dispatches it only when mh == MR && nh == NR, and the enclosing gemm's entry debug_assert covers every a/b/out span
fn tile_nn_i8_full(
    a: &[f32],
    b: &[i8],
    scales: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    out: &mut [f32],
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..NR];
        let mut bw = [0.0f32; NR];
        for jj in 0..NR {
            bw[jj] = brow[jj] as f32;
        }
        let s = scales[p];
        for ii in 0..MR {
            let av = a[(i0 + ii) * k + p] * s;
            for jj in 0..NR {
                acc[ii][jj] += av * bw[jj];
            }
        }
    }
    for ii in 0..MR {
        let orow = &mut out[(i0 + ii) * n + j0..][..NR];
        for jj in 0..NR {
            orow[jj] += acc[ii][jj];
        }
    }
}

#[cfg(feature = "simd")]
#[inline]
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// bounds: full-tile fast path — caller dispatches it only when mh == MR && nh == NR, and the enclosing gemm's entry debug_assert covers every a/b/out span
fn tile_nn_i8_full(
    a: &[f32],
    b: &[i8],
    scales: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    out: &mut [f32],
) {
    use std::simd::f32x8;
    use std::simd::StdFloat;
    let mut acc = [f32x8::splat(0.0); MR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..NR];
        let mut bw = [0.0f32; NR];
        for jj in 0..NR {
            bw[jj] = brow[jj] as f32;
        }
        let bv = f32x8::from_array(bw);
        let s = scales[p];
        for ii in 0..MR {
            let av = f32x8::splat(a[(i0 + ii) * k + p] * s);
            acc[ii] = av.mul_add(bv, acc[ii]);
        }
    }
    for ii in 0..MR {
        let orow = &mut out[(i0 + ii) * n + j0..][..NR];
        let cur = f32x8::from_slice(orow) + acc[ii];
        cur.copy_to_slice(orow);
    }
}

/// Edge tile of [`gemm_nn_i8`] (`mh ≤ MR`, `nh ≤ NR` at runtime).
#[inline]
#[allow(clippy::too_many_arguments)]
// bounds: mh/nh clamp the tile to the matrix edge; all spans sit inside the enclosing gemm's entry debug_assert
fn tile_nn_i8_edge(
    a: &[f32],
    b: &[i8],
    scales: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    mh: usize,
    nh: usize,
    out: &mut [f32],
) {
    let mut acc = [0.0f32; MR * NR];
    for p in 0..k {
        let brow = &b[p * n + j0..][..nh];
        let s = scales[p];
        for ii in 0..mh {
            let av = a[(i0 + ii) * k + p] * s;
            let arow = &mut acc[ii * NR..][..nh];
            for (c, &bv) in arow.iter_mut().zip(brow) {
                *c += av * bv as f32;
            }
        }
    }
    for ii in 0..mh {
        let orow = &mut out[(i0 + ii) * n + j0..][..nh];
        for (o, c) in orow.iter_mut().zip(&acc[ii * NR..][..nh]) {
            *o += c;
        }
    }
}

/// Scalar reference twin of [`gemm_nn_bf16`] — the naive triple loop, kept
/// (non-test) as the parity oracle the tiled kernel is tested against.
pub fn gemm_nn_bf16_ref(a: &[f32], b: &[u16], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                out[i * n + j] += av * quant::bf16_to_f32(b[p * n + j]);
            }
        }
    }
}

/// Scalar reference twin of [`gemm_nn_i8`].
pub fn gemm_nn_i8_ref(
    a: &[f32],
    b: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    debug_assert!(scales.len() >= k);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p] * scales[p];
            for j in 0..n {
                out[i * n + j] += av * b[p * n + j] as f32;
            }
        }
    }
}

/// [`gemm_nn_bf16`] with output rows striped across the pool.
// bounds: stripe offsets mirror run_stripes' disjoint partition of out[..m*n]; a rows covered by the serial gemm's entry debug_assert
pub fn par_gemm_nn_bf16(
    pool: &ThreadPool,
    a: &[f32],
    b: &[u16],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    if pool.threads() <= 1 || m * k * n < PAR_MIN_FLOPS {
        return gemm_nn_bf16(a, b, m, k, n, out);
    }
    pool.run_stripes(&mut out[..m * n], n, |r0, slab| {
        let rows = slab.len() / n;
        gemm_nn_bf16(&a[r0 * k..][..rows * k], b, rows, k, n, slab);
    });
}

/// [`gemm_nn_i8`] with output rows striped across the pool.
// bounds: stripe offsets mirror run_stripes' disjoint partition of out[..m*n]; a rows covered by the serial gemm's entry debug_assert
pub fn par_gemm_nn_i8(
    pool: &ThreadPool,
    a: &[f32],
    b: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    if pool.threads() <= 1 || m * k * n < PAR_MIN_FLOPS {
        return gemm_nn_i8(a, b, scales, m, k, n, out);
    }
    pool.run_stripes(&mut out[..m * n], n, |r0, slab| {
        let rows = slab.len() / n;
        gemm_nn_i8(&a[r0 * k..][..rows * k], b, scales, rows, k, n, slab);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        match Tensor::randn(vec![n], seed) {
            Tensor::F32 { data, .. } => data,
            _ => unreachable!(),
        }
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    out[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        out
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    /// Transpose a row-major `r×c` matrix into `c×r`.
    fn transpose(a: &[f32], r: usize, c: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                t[j * r + i] = a[i * c + j];
            }
        }
        t
    }

    #[test]
    fn nn_matches_naive_incl_edges() {
        // deliberately non-multiples of the 8×8 tile
        for (m, k, n) in [(1, 1, 1), (8, 8, 8), (13, 7, 9), (33, 20, 17), (16, 64, 24)] {
            let a = randn(m * k, 1);
            let b = randn(k * n, 2);
            let mut out = randn(m * n, 3); // accumulate onto non-zero init
            let mut want = out.clone();
            for (w, x) in want.iter_mut().zip(naive_nn(&a, &b, m, k, n)) {
                *w += x;
            }
            gemm_nn(&a, &b, m, k, n, &mut out);
            assert!(max_abs_diff(&out, &want) < 1e-4, "nn {m}x{k}x{n}");
        }
    }

    #[test]
    fn nt_and_tn_match_naive_via_transpose() {
        for (m, k, n) in [(5, 12, 7), (16, 8, 16), (9, 30, 11)] {
            let a = randn(m * k, 4);
            let bt = randn(n * k, 5); // b stored n×k for NT
            let mut out = vec![0.0f32; m * n];
            gemm_nt(&a, &bt, m, k, n, &mut out);
            let want = naive_nn(&a, &transpose(&bt, n, k), m, k, n);
            assert!(max_abs_diff(&out, &want) < 1e-4, "nt {m}x{k}x{n}");

            let at = randn(k * m, 6); // a stored k×m for TN
            let b = randn(k * n, 7);
            let mut out = vec![0.0f32; m * n];
            gemm_tn(&at, &b, m, k, n, &mut out);
            let want = naive_nn(&transpose(&at, k, m), &b, m, k, n);
            assert!(max_abs_diff(&out, &want) < 1e-4, "tn {m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_wrappers_match_single_thread() {
        let (m, k, n) = (65, 48, 33);
        let a = randn(m * k, 8);
        let b = randn(k * n, 9);
        let at = transpose(&a, m, k);
        let bt = transpose(&b, k, n);
        let pool = ThreadPool::new(4);
        for which in 0..3 {
            let mut seq = vec![0.0f32; m * n];
            let mut par = vec![0.0f32; m * n];
            match which {
                0 => {
                    gemm_nn(&a, &b, m, k, n, &mut seq);
                    // force the parallel path regardless of PAR_MIN_FLOPS by
                    // calling run_stripes the way par_gemm_nn does
                    pool.run_stripes(&mut par, n, |r0, slab| {
                        let rows = slab.len() / n;
                        gemm_nn(&a[r0 * k..][..rows * k], &b, rows, k, n, slab);
                    });
                }
                1 => {
                    gemm_nt(&a, &bt, m, k, n, &mut seq);
                    pool.run_stripes(&mut par, n, |r0, slab| {
                        let rows = slab.len() / n;
                        gemm_nt(&a[r0 * k..][..rows * k], &bt, rows, k, n, slab);
                    });
                }
                _ => {
                    gemm_tn(&at, &b, m, k, n, &mut seq);
                    pool.run_stripes(&mut par, n, |r0, slab| {
                        let rows = slab.len() / n;
                        gemm_tn_rows(&at, &b, m, k, n, r0, r0 + rows, slab);
                    });
                }
            }
            // tolerance, not bitwise: stripe boundaries move rows between the
            // full-tile and edge-tile paths, which differ by one FMA rounding
            // under `--features simd`
            assert!(
                max_abs_diff(&seq, &par) < 1e-5,
                "orientation {which}: {}",
                max_abs_diff(&seq, &par)
            );
        }
    }

    #[test]
    fn dot_and_axpy() {
        let x = randn(37, 10);
        let y = randn(37, 11);
        let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - want).abs() < 1e-4 * (1.0 + want.abs()));
        let mut z = y.clone();
        axpy(2.5, &x, &mut z);
        for i in 0..z.len() {
            assert!((z[i] - (y[i] + 2.5 * x[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn bf16_nn_matches_scalar_reference_incl_edges() {
        for (m, k, n) in [(1, 1, 1), (8, 8, 8), (13, 7, 9), (33, 20, 17), (16, 64, 24)] {
            let a = randn(m * k, 21);
            let bq: Vec<u16> =
                randn(k * n, 22).iter().map(|&x| quant::f32_to_bf16(x)).collect();
            let init = randn(m * n, 23); // accumulate onto non-zero init
            let mut out = init.clone();
            let mut want = init.clone();
            gemm_nn_bf16(&a, &bq, m, k, n, &mut out);
            gemm_nn_bf16_ref(&a, &bq, m, k, n, &mut want);
            assert!(max_abs_diff(&out, &want) < 1e-4, "bf16 nn {m}x{k}x{n}");
        }
    }

    #[test]
    fn i8_nn_matches_scalar_reference_incl_edges() {
        for (m, k, n) in [(1, 1, 1), (8, 8, 8), (13, 7, 9), (33, 20, 17), (16, 64, 24)] {
            let a = randn(m * k, 24);
            let bf = randn(k * n, 25);
            let mut bq = vec![0i8; k * n];
            let mut scales = vec![0.0f32; k];
            for p in 0..k {
                scales[p] = quant::quantize_row_i8(&bf[p * n..][..n], &mut bq[p * n..][..n]);
            }
            let init = randn(m * n, 26);
            let mut out = init.clone();
            let mut want = init.clone();
            gemm_nn_i8(&a, &bq, &scales, m, k, n, &mut out);
            gemm_nn_i8_ref(&a, &bq, &scales, m, k, n, &mut want);
            assert!(max_abs_diff(&out, &want) < 1e-4, "i8 nn {m}x{k}x{n}");
        }
    }

    #[test]
    fn quantized_nn_tracks_the_f32_product_within_format_error() {
        // not a bit-parity check (that is vs the _ref twins) — a sanity bound
        // that the stored formats stay close to the f32 product
        let (m, k, n) = (5, 40, 24);
        let a = randn(m * k, 27);
        let bf = randn(k * n, 28);
        let mut f32_out = vec![0.0f32; m * n];
        gemm_nn(&a, &bf, m, k, n, &mut f32_out);

        let bq16: Vec<u16> = bf.iter().map(|&x| quant::f32_to_bf16(x)).collect();
        let mut b16_out = vec![0.0f32; m * n];
        gemm_nn_bf16(&a, &bq16, m, k, n, &mut b16_out);
        // bf16 keeps 8 mantissa bits: ~0.4% relative per element
        assert!(max_abs_diff(&f32_out, &b16_out) < 0.05 * k as f32 / 8.0);

        let mut bq8 = vec![0i8; k * n];
        let mut scales = vec![0.0f32; k];
        for p in 0..k {
            scales[p] = quant::quantize_row_i8(&bf[p * n..][..n], &mut bq8[p * n..][..n]);
        }
        let mut b8_out = vec![0.0f32; m * n];
        gemm_nn_i8(&a, &bq8, &scales, m, k, n, &mut b8_out);
        let max_scale = scales.iter().fold(0.0f32, |mx, &s| if s > mx { s } else { mx });
        // per-element error ≤ scale/2, |a| is O(1) randn: bound by k·scale
        assert!(max_abs_diff(&f32_out, &b8_out) < k as f32 * max_scale);
    }

    #[test]
    fn quantized_parallel_wrappers_match_single_thread() {
        let (m, k, n) = (65, 48, 33);
        let a = randn(m * k, 29);
        let bf = randn(k * n, 30);
        let bq16: Vec<u16> = bf.iter().map(|&x| quant::f32_to_bf16(x)).collect();
        let mut bq8 = vec![0i8; k * n];
        let mut scales = vec![0.0f32; k];
        for p in 0..k {
            scales[p] = quant::quantize_row_i8(&bf[p * n..][..n], &mut bq8[p * n..][..n]);
        }
        let pool = ThreadPool::new(4);
        let mut seq = vec![0.0f32; m * n];
        let mut par = vec![0.0f32; m * n];
        gemm_nn_bf16(&a, &bq16, m, k, n, &mut seq);
        pool.run_stripes(&mut par, n, |r0, slab| {
            let rows = slab.len() / n;
            gemm_nn_bf16(&a[r0 * k..][..rows * k], &bq16, rows, k, n, slab);
        });
        assert!(max_abs_diff(&seq, &par) < 1e-5, "bf16 par");

        let mut seq = vec![0.0f32; m * n];
        let mut par = vec![0.0f32; m * n];
        gemm_nn_i8(&a, &bq8, &scales, m, k, n, &mut seq);
        pool.run_stripes(&mut par, n, |r0, slab| {
            let rows = slab.len() / n;
            gemm_nn_i8(&a[r0 * k..][..rows * k], &bq8, &scales, rows, k, n, slab);
        });
        assert!(max_abs_diff(&seq, &par) < 1e-5, "i8 par");
    }

    #[test]
    fn quantized_dot_and_axpy_match_widened_f32() {
        let x = randn(37, 31);
        let y = randn(37, 32);
        let y16: Vec<u16> = y.iter().map(|&v| quant::f32_to_bf16(v)).collect();
        let y_wide: Vec<f32> = y16.iter().map(|&b| quant::bf16_to_f32(b)).collect();
        let want: f32 = x.iter().zip(&y_wide).map(|(a, b)| a * b).sum();
        assert!((dot_bf16(&x, &y16) - want).abs() < 1e-4 * (1.0 + want.abs()));
        let mut z = vec![0.0f32; 37];
        axpy_bf16(1.5, &y16, &mut z);
        for i in 0..z.len() {
            assert!((z[i] - 1.5 * y_wide[i]).abs() < 1e-6);
        }

        let mut q = vec![0i8; 37];
        let scale = quant::quantize_row_i8(&y, &mut q);
        let q_wide: Vec<f32> = q.iter().map(|&v| v as f32).collect();
        let want: f32 = x.iter().zip(&q_wide).map(|(a, b)| a * b).sum();
        assert!((dot_i8(&x, &q) - want).abs() < 1e-3 * (1.0 + want.abs()));
        let mut z = vec![0.0f32; 37];
        axpy_i8(scale, &q, &mut z);
        for i in 0..z.len() {
            assert!((z[i] - scale * q_wide[i]).abs() < 1e-6);
        }
    }
}
