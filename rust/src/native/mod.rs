//! The native CPU backend: pure-Rust implementations of every artifact the
//! runtime names, with zero external dependencies — no Python build step, no
//! HLO artifacts, no FFI. This is the default backend and the reference
//! implementation every accelerated path is diffed against.
//!
//! - [`kernels`] — the paper's causal linear-attention forward/backward
//!   (state scan + chunkwise variants) and the quadratic baselines, parallel
//!   across B·H (and `(bh, chunk)` tiles) with the scalar originals kept in
//!   [`kernels::reference`];
//! - [`pool`] — the dependency-free persistent worker pool
//!   (`RUST_PALLAS_THREADS`) every executor dispatches on;
//! - [`gemm`] — the cache-blocked f32 matmul microkernels shared by the
//!   chunkwise/quadratic kernels and the LM's linear layers;
//! - [`model`] — the block-structured Transformer LM (train step / eval /
//!   logits / init; `tiny`, `small` and `medium` presets) with a
//!   hand-derived backward pass and in-tree AdamW (in-place mutable-state
//!   route plus the preserved rebuild baseline);
//! - [`NativeBackend`] — the [`Backend`] impl: a code-built [`Manifest`]
//!   mirroring the AOT artifact naming scheme (`layer_<impl>_<kind>_n<N>_d<D>`,
//!   `lm_<preset>_<attn>_<op>`, `quickstart_la_*`) and per-artifact executors.
//!   The chunkwise sweep chunk length is `RUST_PALLAS_CHUNK` (default 128),
//!   recorded in each artifact's manifest metadata.

pub mod gemm;
pub mod kernels;
pub mod model;
pub mod pool;
pub mod quant;

use anyhow::{anyhow, bail, Result};

use crate::runtime::backend::{Backend, Executor};
use crate::runtime::{ArtifactMeta, IoSpec, Manifest, Tensor};
use crate::util::json::Json;

use kernels::LayerShape;
use model::{AttnKind, LmConfig};
use pool::ThreadPool;

/// Batch×heads used by every registered layer artifact.
const LAYER_BH: usize = 4;
/// Head dimension of the registered layer sweep.
const LAYER_D: usize = 128;
/// Default chunk length of the chunkwise `ours` artifacts.
const OURS_CHUNK: usize = 128;

/// Chunk length of the chunkwise sweep artifacts: `RUST_PALLAS_CHUNK`
/// (positive integer) or the built-in default of 128. Read at manifest build
/// time so the sweep metadata records the value each run actually used —
/// chunk-size sensitivity is benchmarked by re-running under different
/// settings of the variable.
pub fn ours_chunk() -> usize {
    std::env::var("RUST_PALLAS_CHUNK")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(OURS_CHUNK)
}

/// The dependency-free CPU backend, carrying the worker pool every executor
/// dispatches on.
pub struct NativeBackend {
    pool: ThreadPool,
    /// Run the scalar single-thread reference kernels instead of the
    /// parallel/tiled paths (the `bench-native` speedup baseline).
    reference: bool,
}

impl NativeBackend {
    /// Pool sized from `RUST_PALLAS_THREADS` (0/unset = all cores).
    pub fn new() -> Self {
        Self { pool: ThreadPool::from_env(), reference: false }
    }

    /// Backend over an explicit pool (tests, thread-count sweeps).
    pub fn with_pool(pool: ThreadPool) -> Self {
        Self { pool, reference: false }
    }

    /// The pre-optimization scalar kernels on one thread — the baseline the
    /// `BENCH_native.json` speedup column is measured against. Serves the
    /// `layer_*` artifact kinds only (loading an `lm_*` artifact errors: the
    /// LM has no preserved scalar path).
    pub fn scalar_reference() -> Self {
        Self { pool: ThreadPool::new(1), reference: true }
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "cpu".to_string()
    }

    fn manifest(&self) -> Result<Manifest> {
        Ok(build_manifest())
    }

    fn load(&self, name: &str, meta: &ArtifactMeta) -> Result<Box<dyn Executor>> {
        match meta.kind.as_str() {
            "layer_fwd" | "layer_fwdbwd" => {
                let imp = match meta.implementation() {
                    Some("ours") => LayerImpl::Chunk(meta.chunk.unwrap_or_else(ours_chunk)),
                    Some("ours_scan") => LayerImpl::Scan,
                    Some("quadratic") => LayerImpl::Quadratic,
                    Some("softmax") => LayerImpl::Softmax,
                    other => bail!("no native kernel for impl {other:?} ({name})"),
                };
                let sh = LayerShape::cube(
                    meta.bh.ok_or_else(|| anyhow!("{name}: missing bh"))?,
                    meta.n.ok_or_else(|| anyhow!("{name}: missing n"))?,
                    meta.d.ok_or_else(|| anyhow!("{name}: missing d"))?,
                );
                Ok(Box::new(LayerExec {
                    imp,
                    grad: meta.kind == "layer_fwdbwd",
                    sh,
                    pool: self.pool.clone(),
                    reference: self.reference,
                }))
            }
            "lm_train_step" | "lm_eval" | "lm_init" | "lm_logits" => {
                if self.reference {
                    bail!(
                        "the scalar-reference backend serves layer kernels only; \
                         no scalar LM path is preserved ({name})"
                    );
                }
                let attn = AttnKind::from_name(
                    meta.attn.as_deref().ok_or_else(|| anyhow!("{name}: missing attn"))?,
                )?;
                let preset =
                    meta.preset.as_deref().ok_or_else(|| anyhow!("{name}: missing preset"))?;
                let cfg = LmConfig::by_preset(preset, attn)?;
                let op = match meta.kind.as_str() {
                    "lm_train_step" => LmOp::TrainStep,
                    "lm_eval" => LmOp::Eval,
                    "lm_init" => LmOp::Init,
                    _ => LmOp::Logits,
                };
                Ok(Box::new(LmExec { cfg, op, pool: self.pool.clone() }))
            }
            other => bail!("native backend cannot execute artifact kind {other:?} ({name})"),
        }
    }
}

// --- layer executors --------------------------------------------------------

#[derive(Clone, Copy)]
enum LayerImpl {
    /// Chunkwise linear attention (the paper's kernel layout).
    Chunk(usize),
    /// Sequential state scan (same math, pure recurrence).
    Scan,
    /// Softmax-free quadratic reference (masked (QKᵀ)V).
    Quadratic,
    /// Standard causal softmax attention.
    Softmax,
}

struct LayerExec {
    imp: LayerImpl,
    grad: bool,
    sh: LayerShape,
    pool: ThreadPool,
    reference: bool,
}

impl Executor for LayerExec {
    fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let want = if self.grad { 4 } else { 3 };
        if inputs.len() != want {
            bail!("layer kernel wants {want} inputs (q, k, v{}), got {}",
                  if self.grad { ", grad_o" } else { "" }, inputs.len());
        }
        let sh = self.sh;
        let numel = sh.bh * sh.n * sh.dk;
        let mut bufs = Vec::with_capacity(want);
        for (i, t) in inputs.iter().enumerate() {
            let data = t.as_f32()?;
            if data.len() != numel {
                bail!("layer input #{i}: expected {numel} elements, got {}", data.len());
            }
            bufs.push(data);
        }
        let (q, k, v) = (bufs[0], bufs[1], bufs[2]);
        let cube = vec![sh.bh, sh.n, sh.dk];
        let scale = 1.0 / (sh.dk as f32).sqrt();
        let pool = &self.pool;
        if !self.grad {
            let o = if self.reference {
                match self.imp {
                    LayerImpl::Chunk(c) => kernels::reference::la_chunk_fwd(q, k, v, sh, c),
                    LayerImpl::Scan => kernels::reference::la_scan_fwd(q, k, v, sh, 1.0),
                    LayerImpl::Quadratic => kernels::reference::la_quadratic_fwd(q, k, v, sh),
                    LayerImpl::Softmax => kernels::reference::softmax_fwd(q, k, v, sh, scale),
                }
            } else {
                match self.imp {
                    LayerImpl::Chunk(c) => kernels::la_chunk_fwd(pool, q, k, v, sh, c),
                    LayerImpl::Scan => kernels::la_scan_fwd(pool, q, k, v, sh, 1.0),
                    LayerImpl::Quadratic => kernels::la_quadratic_fwd(pool, q, k, v, sh),
                    LayerImpl::Softmax => kernels::softmax_fwd(pool, q, k, v, sh, scale),
                }
            };
            Ok(vec![Tensor::f32(cube, o)?])
        } else {
            let go = bufs[3];
            let (dq, dk, dv) = if self.reference {
                match self.imp {
                    LayerImpl::Chunk(c) => kernels::reference::la_chunk_bwd(q, k, v, go, sh, c),
                    LayerImpl::Scan => kernels::reference::la_scan_bwd(q, k, v, go, sh, 1.0),
                    LayerImpl::Quadratic => kernels::reference::la_quadratic_bwd(q, k, v, go, sh),
                    LayerImpl::Softmax => kernels::reference::softmax_bwd(q, k, v, go, sh, scale),
                }
            } else {
                match self.imp {
                    LayerImpl::Chunk(c) => kernels::la_chunk_bwd(pool, q, k, v, go, sh, c),
                    LayerImpl::Scan => kernels::la_scan_bwd(pool, q, k, v, go, sh, 1.0),
                    LayerImpl::Quadratic => kernels::la_quadratic_bwd(pool, q, k, v, go, sh),
                    LayerImpl::Softmax => kernels::softmax_bwd(pool, q, k, v, go, sh, scale),
                }
            };
            Ok(vec![
                Tensor::f32(cube.clone(), dq)?,
                Tensor::f32(cube.clone(), dk)?,
                Tensor::f32(cube, dv)?,
            ])
        }
    }
}

// --- LM executors -----------------------------------------------------------

#[derive(Clone, Copy)]
enum LmOp {
    Init,
    TrainStep,
    Eval,
    Logits,
}

struct LmExec {
    cfg: LmConfig,
    op: LmOp,
    pool: ThreadPool,
}

impl Executor for LmExec {
    /// The owned-state hot path: `lm_train_step` runs the fused in-place
    /// AdamW step, mutating the `params ++ m ++ v` buffers directly instead
    /// of reallocating `3·np` tensors per call. Other LM ops carry no
    /// mutable state and reject the route.
    fn execute_mut(&self, state: &mut [Tensor], inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let np = self.cfg.n_param_arrays();
        match self.op {
            LmOp::TrainStep => {
                if state.len() != 3 * np || inputs.len() != 2 {
                    bail!(
                        "lm_train_step (owned) wants {} state arrays + 2 inputs \
                         (tokens, step), got {} + {}",
                        3 * np,
                        state.len(),
                        inputs.len()
                    );
                }
                let step = model::scalar_i64(inputs[1])?;
                let (loss, grad_norm) =
                    model::train_step_mut(&self.cfg, state, inputs[0], step, &self.pool)?;
                Ok(vec![Tensor::scalar_f32(loss), Tensor::scalar_f32(grad_norm)])
            }
            _ => bail!("execute_mut is only supported for lm_train_step artifacts"),
        }
    }

    fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let np = self.cfg.n_param_arrays();
        match self.op {
            LmOp::Init => {
                if inputs.len() != 1 {
                    bail!("lm_init wants 1 input (seed), got {}", inputs.len());
                }
                let seed = model::scalar_i64(inputs[0])?;
                Ok(self.cfg.init_state(seed as u64))
            }
            LmOp::TrainStep => {
                if inputs.len() != 3 * np + 2 {
                    bail!(
                        "lm_train_step wants {} inputs (state ++ tokens ++ step), got {}",
                        3 * np + 2,
                        inputs.len()
                    );
                }
                let state = &inputs[..3 * np];
                let tokens = inputs[3 * np];
                let step = model::scalar_i64(inputs[3 * np + 1])?;
                model::train_step(&self.cfg, state, tokens, step, &self.pool)
            }
            LmOp::Eval => {
                if inputs.len() != np + 1 {
                    bail!("lm_eval wants {} inputs (params ++ tokens), got {}", np + 1, inputs.len());
                }
                let loss = model::eval_loss(&self.cfg, &inputs[..np], inputs[np], &self.pool)?;
                Ok(vec![Tensor::scalar_f32(loss)])
            }
            LmOp::Logits => {
                if inputs.len() != np + 1 {
                    bail!("lm_logits wants {} inputs (params ++ tokens), got {}", np + 1, inputs.len());
                }
                Ok(vec![model::logits(&self.cfg, &inputs[..np], inputs[np], &self.pool)?])
            }
        }
    }
}

// --- manifest construction --------------------------------------------------

fn f32_spec(index: usize, shape: &[usize]) -> IoSpec {
    IoSpec { index, dtype: "f32".to_string(), shape: shape.to_vec() }
}

fn i32_spec(index: usize, shape: &[usize]) -> IoSpec {
    IoSpec { index, dtype: "i32".to_string(), shape: shape.to_vec() }
}

fn layer_meta(kind: &str, imp: &str, bh: usize, n: usize, d: usize, chunk: usize) -> ArtifactMeta {
    let cube = [bh, n, d];
    let grad = kind == "layer_fwdbwd";
    let n_in = if grad { 4 } else { 3 };
    let n_out = if grad { 3 } else { 1 };
    ArtifactMeta {
        file: format!("native://layer/{imp}/{kind}/n{n}_d{d}"),
        hash: "native".to_string(),
        kind: kind.to_string(),
        impl_name: Some(imp.to_string()),
        bh: Some(bh),
        n: Some(n),
        d: Some(d),
        chunk: if chunk > 0 { Some(chunk) } else { None },
        preset: None,
        attn: None,
        batch: None,
        n_params: None,
        n_param_arrays: None,
        param_names: None,
        model: None,
        train: None,
        inputs: (0..n_in).map(|i| f32_spec(i, &cube)).collect(),
        outputs: (0..n_out).map(|i| f32_spec(i, &cube)).collect(),
    }
}

fn lm_meta(cfg: &LmConfig, preset: &str, attn_name: &str, kind: &str) -> ArtifactMeta {
    let shapes = cfg.param_shapes();
    let np = shapes.len();
    let state_shapes: Vec<Vec<usize>> = shapes
        .iter()
        .map(|(_, s)| s.clone())
        .chain(shapes.iter().map(|(_, s)| s.clone()))
        .chain(shapes.iter().map(|(_, s)| s.clone()))
        .collect();
    let train_tokens = [cfg.batch, cfg.n_ctx + 1];
    let ctx_tokens = [cfg.batch, cfg.n_ctx];
    let (inputs, outputs) = match kind {
        "lm_train_step" => {
            let mut ins: Vec<IoSpec> =
                state_shapes.iter().enumerate().map(|(i, s)| f32_spec(i, s)).collect();
            ins.push(i32_spec(3 * np, &train_tokens));
            ins.push(i32_spec(3 * np + 1, &[]));
            // outputs: loss, pre-clip grad norm, then the refreshed state
            let mut outs = vec![f32_spec(0, &[]), f32_spec(1, &[])];
            outs.extend(state_shapes.iter().enumerate().map(|(i, s)| f32_spec(i + 2, s)));
            (ins, outs)
        }
        "lm_eval" => {
            let mut ins: Vec<IoSpec> = shapes
                .iter()
                .enumerate()
                .map(|(i, (_, s))| f32_spec(i, s))
                .collect();
            ins.push(i32_spec(np, &train_tokens));
            (ins, vec![f32_spec(0, &[])])
        }
        "lm_init" => {
            let outs: Vec<IoSpec> =
                state_shapes.iter().enumerate().map(|(i, s)| f32_spec(i, s)).collect();
            (vec![i32_spec(0, &[])], outs)
        }
        _ => {
            // lm_logits
            let mut ins: Vec<IoSpec> = shapes
                .iter()
                .enumerate()
                .map(|(i, (_, s))| f32_spec(i, s))
                .collect();
            ins.push(i32_spec(np, &ctx_tokens));
            (ins, vec![f32_spec(0, &[cfg.batch, cfg.n_ctx, cfg.vocab])])
        }
    };
    ArtifactMeta {
        file: format!("native://lm/{preset}/{attn_name}/{kind}"),
        hash: "native".to_string(),
        kind: kind.to_string(),
        impl_name: None,
        bh: None,
        n: None,
        d: None,
        chunk: None,
        preset: Some(preset.to_string()),
        attn: Some(attn_name.to_string()),
        batch: Some(cfg.batch),
        n_params: Some(cfg.n_params()),
        n_param_arrays: Some(np),
        param_names: Some(shapes.iter().map(|(n, _)| n.clone()).collect()),
        model: Some(Json::obj(vec![
            ("n_ctx", Json::num(cfg.n_ctx as f64)),
            ("vocab_size", Json::num(cfg.vocab as f64)),
            ("d_model", Json::num(cfg.d_model as f64)),
            ("n_layer", Json::num(cfg.n_layer as f64)),
            ("n_head", Json::num(cfg.n_head as f64)),
            ("d_ff", Json::num(cfg.d_ff as f64)),
            ("attn", Json::str(attn_name)),
        ])),
        train: Some(Json::obj(vec![
            ("lr_max", Json::num(cfg.lr_max)),
            ("lr_min", Json::num(cfg.lr_min)),
            ("warmup_steps", Json::num(cfg.warmup_steps as f64)),
            ("total_steps", Json::num(cfg.total_steps as f64)),
            ("weight_decay", Json::num(cfg.weight_decay)),
            ("clip_norm", Json::num(cfg.clip_norm)),
            ("corpus_bytes", Json::num(cfg.corpus_bytes_hint() as f64)),
        ])),
        inputs,
        outputs,
    }
}

/// The artifact registry of the native backend, mirroring the naming scheme
/// of the AOT path so every caller works unmodified against either backend.
pub fn build_manifest() -> Manifest {
    let mut artifacts = std::collections::BTreeMap::new();

    // quickstart trio: fixed BH=4, N=256, D=64
    artifacts.insert(
        "quickstart_la_fwd".to_string(),
        layer_meta("layer_fwd", "ours", 4, 256, 64, 64),
    );
    artifacts.insert(
        "quickstart_la_bwd".to_string(),
        layer_meta("layer_fwdbwd", "ours", 4, 256, 64, 64),
    );
    artifacts.insert(
        "quickstart_la_ref".to_string(),
        layer_meta("layer_fwd", "quadratic", 4, 256, 64, 0),
    );

    // layer sweep: (impl, chunk, fwd Ns, fwdbwd Ns). N starts at 1024 (below
    // that the analytic model's fixed launch overhead dominates and the
    // linear-scaling series is meaningless); quadratic-time baselines stop
    // earlier so a full sweep stays tractable on one core.
    let chunk = ours_chunk();
    let sweeps: &[(&str, usize, &[usize], &[usize])] = &[
        ("ours", chunk, &[1024, 2048, 4096, 8192], &[1024, 2048, 4096]),
        ("ours_scan", 0, &[1024, 2048, 4096, 8192], &[1024, 2048, 4096]),
        ("quadratic", 0, &[1024, 2048], &[1024, 2048]),
        ("softmax", 0, &[1024, 2048, 4096], &[1024, 2048]),
    ];
    for &(imp, chunk, fwd_ns, bwd_ns) in sweeps {
        for &n in fwd_ns {
            artifacts.insert(
                format!("layer_{imp}_fwd_n{n}_d{LAYER_D}"),
                layer_meta("layer_fwd", imp, LAYER_BH, n, LAYER_D, chunk),
            );
        }
        for &n in bwd_ns {
            artifacts.insert(
                format!("layer_{imp}_fwdbwd_n{n}_d{LAYER_D}"),
                layer_meta("layer_fwdbwd", imp, LAYER_BH, n, LAYER_D, chunk),
            );
        }
    }

    // the LM presets, all three attention variants
    for preset in LmConfig::preset_names() {
        for attn in ["ours", "gated", "softmax"] {
            let cfg = LmConfig::by_preset(preset, AttnKind::from_name(attn).expect("static"))
                .expect("static preset name");
            for kind in ["lm_train_step", "lm_eval", "lm_init", "lm_logits"] {
                artifacts.insert(
                    format!("lm_{preset}_{attn}_{kind}"),
                    lm_meta(&cfg, preset, attn, kind),
                );
            }
        }
    }

    Manifest {
        version: 2,
        jax: String::new(),
        preset: "native".to_string(),
        artifacts,
        dir: std::path::PathBuf::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_has_core_artifact_families() {
        let m = build_manifest();
        for name in [
            "quickstart_la_fwd",
            "quickstart_la_bwd",
            "quickstart_la_ref",
            "layer_ours_fwd_n1024_d128",
            "layer_quadratic_fwd_n1024_d128",
            "layer_softmax_fwd_n4096_d128",
            "lm_tiny_ours_train_step",
            "lm_tiny_gated_eval",
            "lm_tiny_softmax_init",
            "lm_tiny_ours_logits",
            "lm_small_ours_train_step",
            "lm_small_gated_eval",
            "lm_small_softmax_init",
            "lm_small_ours_logits",
            "lm_medium_ours_train_step",
            "lm_medium_gated_eval",
            "lm_medium_softmax_init",
            "lm_medium_ours_logits",
        ] {
            assert!(m.get(name).is_ok(), "missing {name}");
        }
        assert!(!m.by_kind("layer_fwd").is_empty());
        assert!(!m.by_kind("lm_train_step").is_empty());
        // sweep series exclude quickstart_* and are (N, D)-sorted
        let ours = m.layer_sweep("layer_fwd", "ours");
        assert!(ours.len() >= 4);
        assert!(ours.windows(2).all(|w| w[0].1.n <= w[1].1.n));
        assert!(ours.iter().all(|(name, _)| !name.starts_with("quickstart")));
    }

    #[test]
    fn sweep_manifest_records_chunk_length() {
        // no env override in the test process → the built-in default; the
        // env-driven path shares the same parse (`ours_chunk`)
        let m = build_manifest();
        let ours = m.get("layer_ours_fwd_n1024_d128").unwrap();
        assert_eq!(ours.chunk, Some(ours_chunk()));
        let scan = m.get("layer_ours_scan_fwd_n1024_d128").unwrap();
        assert_eq!(scan.chunk, None);
    }

    #[test]
    fn lm_meta_matches_trainer_contract() {
        let m = build_manifest();
        let cfg = LmConfig::tiny(AttnKind::Ours);
        let step = m.get("lm_tiny_ours_train_step").unwrap();
        let np = step.n_param_arrays.unwrap();
        assert_eq!(np, cfg.n_param_arrays());
        assert_eq!(step.n_params, Some(cfg.n_params()));
        assert_eq!(step.batch, Some(8));
        assert_eq!(step.model_field_usize("n_ctx"), Some(64));
        assert_eq!(step.model_field_usize("vocab_size"), Some(256));
        assert_eq!(step.model_field_usize("n_layer"), Some(2));
        assert_eq!(step.model_field_usize("n_head"), Some(2));
        assert!(step.train_field_f64("lr_max").unwrap() > 0.0);
        assert_eq!(step.train_field_f64("weight_decay"), Some(cfg.weight_decay));
        assert_eq!(step.train_field_f64("clip_norm"), Some(cfg.clip_norm));
        assert_eq!(
            step.train_field_f64("corpus_bytes"),
            Some(cfg.corpus_bytes_hint() as f64)
        );
        assert_eq!(step.inputs.len(), 3 * np + 2);
        // outputs: loss + grad_norm + refreshed state
        assert_eq!(step.outputs.len(), 3 * np + 2);
        let init = m.get("lm_tiny_ours_init").unwrap();
        assert_eq!(init.inputs.len(), 1);
        assert_eq!(init.outputs.len(), 3 * np);
    }

    #[test]
    fn lm_medium_is_registered_with_larger_corpus() {
        let m = build_manifest();
        let cfg = LmConfig::medium(AttnKind::Ours);
        let step = m.get("lm_medium_ours_train_step").unwrap();
        assert_eq!(step.n_params, Some(cfg.n_params()));
        assert_eq!(step.model_field_usize("n_layer"), Some(8));
        assert_eq!(step.model_field_usize("n_head"), Some(8));
        assert_eq!(step.model_field_usize("d_model"), Some(256));
        let small = m.get("lm_small_ours_train_step").unwrap();
        assert!(
            step.train_field_f64("corpus_bytes").unwrap()
                > small.train_field_f64("corpus_bytes").unwrap()
        );
    }

    #[test]
    fn lm_small_is_deep_and_multi_head() {
        let m = build_manifest();
        let cfg = LmConfig::small(AttnKind::Ours);
        assert!(cfg.n_layer >= 4 && cfg.n_head >= 4);
        assert!(cfg.vocab > 256, "small preset must exercise the BPE vocab");
        let step = m.get("lm_small_ours_train_step").unwrap();
        assert_eq!(step.n_param_arrays, Some(cfg.n_param_arrays()));
        assert_eq!(step.n_params, Some(cfg.n_params()));
        assert_eq!(step.model_field_usize("n_layer"), Some(cfg.n_layer));
        assert_eq!(step.model_field_usize("n_head"), Some(cfg.n_head));
        assert_eq!(step.model_field_usize("d_ff"), Some(cfg.d_ff));
        // the deep model is ~1M params — an order of magnitude over tiny
        assert!(cfg.n_params() > 500_000, "n_params {}", cfg.n_params());
    }
}
