//! Pure-Rust CPU kernels for causal attention layers, on flat `f32` slices —
//! parallel across the folded batch×heads dimension and tiled through the
//! [`gemm`](super::gemm) microkernels.
//!
//! All kernels operate on row-major `(BH, N, D)` buffers (`BH` = batch ×
//! heads folded) and take a [`ThreadPool`] handle threaded down from the
//! executor. Three algorithmic families, matching the paper's §4/§5
//! evaluation set:
//!
//! - **state scan** (`la_scan_*`) — the O(N·D²) two-pass recurrence: a
//!   forward scan over the running `D×D` state `S_t = γ·S_{t-1} + k_t vᵗ_t`
//!   for the forward/`dq` pass, and a mirrored *reverse* scan
//!   `R_t = q_t goᵗ_t + γ·R_{t+1}` for `dk`/`dv` — gradients are computed
//!   analytically, never by taping the forward (the O(N·D²)-residency trap
//!   the paper §4 eliminates). `γ = 1` is plain linear attention; `γ < 1`
//!   is the gated/decayed variant. The scan is sequential in `t`, so it
//!   parallelizes over `BH` only.
//! - **chunkwise** (`la_chunk_*`) — the inter/intra decomposition (Yang et
//!   al. 2023), restructured into the two-phase form GPU kernels tile: phase
//!   one materializes the per-chunk prefix states `S_i = Σ_{j<i} K_jᵀV_j`
//!   (and, for the backward, the suffix states `R_i = Σ_{j>i} Q_jᵀGO_j`)
//!   sequentially per `bh`; phase two computes every `(bh, chunk)` output
//!   tile *independently* — one `Q·S` inter GEMM plus masked local `C×C`
//!   intra GEMMs — so parallelism scales with `BH · N/C`, not just `BH`.
//! - **quadratic baselines** — `la_quadratic_*` materializes the masked
//!   `(QKᵀ)V` product of the same softmax-free attention as blocked score
//!   tiles (the eager-baseline access pattern), and `softmax_*` is standard
//!   causal softmax attention with a streaming row softmax.
//!
//! The pre-optimization scalar single-thread kernels are preserved verbatim
//! in [`reference`]: they are the parity oracle for the parallel paths *and*
//! the baseline the `bench-native` speedup column is measured against.
//!
//! Gradients of the softmax-free forms, for `o_t = Σ_{s≤t} γ^{t-s}(q_t·k_s)
//! v_s`:
//!   `dq_t = S_t·go_t`, `dk_s = R_s·v_s`, `dv_s = Rᵗ_s·k_s`.

use super::gemm;
use super::pool::{SliceParts, ThreadPool};

/// Row-block edge for the blocked quadratic baselines.
const QUAD_BLOCK: usize = 64;

/// Cap on the total f32 count materialized as per-chunk states (256 MB).
/// Above it (tiny `RUST_PALLAS_CHUNK`, huge N·BH) the chunkwise kernels fall
/// back to a running-state sweep — same tiled GEMM math, parallel over `bh`
/// only, O(dk·dv) state per worker. Intra-chunk score tiles are blocked at
/// `QUAD_BLOCK²` regardless of chunk length, so no `RUST_PALLAS_CHUNK`
/// setting (small or huge) can exhaust host memory.
const CHUNK_STATE_FLOATS_BUDGET: usize = 64 << 20;

/// Shape of one layer call; `dk`/`dv` may differ (the LM appends a
/// normalizer channel to `v`).
#[derive(Debug, Clone, Copy)]
pub struct LayerShape {
    pub bh: usize,
    pub n: usize,
    pub dk: usize,
    pub dv: usize,
}

impl LayerShape {
    pub fn cube(bh: usize, n: usize, d: usize) -> Self {
        Self { bh, n, dk: d, dv: d }
    }
}

/// Zero the strictly-upper triangle (`col > row`) of a `rows×cols` tile —
/// the causal mask applied to dense score tiles.
fn zero_strict_upper(a: &mut [f32], rows: usize, cols: usize) {
    for t in 0..rows.min(cols) {
        for x in &mut a[t * cols + t + 1..(t + 1) * cols] {
            *x = 0.0;
        }
    }
}

// --- state scan --------------------------------------------------------------

/// Causal linear attention, sequential state scan (decay `gamma`; 1.0 = none).
pub fn la_scan_fwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: LayerShape,
    gamma: f32,
) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    pool.run_chunks(&mut o, n * dv, |b, ob| {
        scan_fwd_one(
            &q[b * n * dk..][..n * dk],
            &k[b * n * dk..][..n * dk],
            &v[b * n * dv..][..n * dv],
            n,
            dk,
            dv,
            gamma,
            ob,
        );
    });
    o
}

#[allow(clippy::too_many_arguments)]
fn scan_fwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    gamma: f32,
    o: &mut [f32],
) {
    let mut s = vec![0.0f32; dk * dv];
    for t in 0..n {
        let qr = &q[t * dk..][..dk];
        let kr = &k[t * dk..][..dk];
        let vr = &v[t * dv..][..dv];
        if gamma != 1.0 {
            for x in s.iter_mut() {
                *x *= gamma;
            }
        }
        for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
            gemm::axpy(kr[i], vr, srow);
        }
        let orow = &mut o[t * dv..][..dv];
        for (i, srow) in s.chunks_exact(dv).enumerate() {
            gemm::axpy(qr[i], srow, orow);
        }
    }
}

/// Backward of [`la_scan_fwd`]: analytical gradients via one forward state
/// scan (for `dq`) and one reverse scan (for `dk`, `dv`).
pub fn la_scan_bwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
    gamma: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    pool.run_chunks3(&mut dq, n * dk, &mut dkk, n * dk, &mut dvv, n * dv, |b, dqb, dkb, dvb| {
        scan_bwd_one(
            &q[b * n * dk..][..n * dk],
            &k[b * n * dk..][..n * dk],
            &v[b * n * dv..][..n * dv],
            &go[b * n * dv..][..n * dv],
            n,
            dk,
            dv,
            gamma,
            dqb,
            dkb,
            dvb,
        );
    });
    (dq, dkk, dvv)
}

#[allow(clippy::too_many_arguments)]
fn scan_bwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    gamma: f32,
    dq: &mut [f32],
    dkk: &mut [f32],
    dvv: &mut [f32],
) {
    let mut s = vec![0.0f32; dk * dv];
    let mut r = vec![0.0f32; dk * dv];
    // pass 1 (forward): S_t, dq_t = S_t · go_t
    for t in 0..n {
        let kr = &k[t * dk..][..dk];
        let vr = &v[t * dv..][..dv];
        let gr = &go[t * dv..][..dv];
        if gamma != 1.0 {
            for x in s.iter_mut() {
                *x *= gamma;
            }
        }
        for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
            gemm::axpy(kr[i], vr, srow);
        }
        let dqr = &mut dq[t * dk..][..dk];
        for (i, srow) in s.chunks_exact(dv).enumerate() {
            dqr[i] = gemm::dot(srow, gr);
        }
    }
    // pass 2 (reverse): R_t, dk_t = R_t · v_t, dv_t = Rᵗ_t · k_t
    for t in (0..n).rev() {
        let qr = &q[t * dk..][..dk];
        let kr = &k[t * dk..][..dk];
        let vr = &v[t * dv..][..dv];
        let gr = &go[t * dv..][..dv];
        if gamma != 1.0 {
            for x in r.iter_mut() {
                *x *= gamma;
            }
        }
        for (i, rrow) in r.chunks_exact_mut(dv).enumerate() {
            gemm::axpy(qr[i], gr, rrow);
        }
        let dkr = &mut dkk[t * dk..][..dk];
        let dvr = &mut dvv[t * dv..][..dv];
        for (i, rrow) in r.chunks_exact(dv).enumerate() {
            dkr[i] = gemm::dot(rrow, vr);
            gemm::axpy(kr[i], rrow, dvr);
        }
    }
}

// --- chunkwise ---------------------------------------------------------------

/// Prefix chunk states: `st[i] = Σ_{j<i} K_jᵀ·V_j` for `i` in `0..nc`
/// (`st[0] = 0`); each state is a `dk×dv` block of `st`.
#[allow(clippy::too_many_arguments)]
fn chunk_states_prefix(
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    c: usize,
    nc: usize,
    st: &mut [f32],
) {
    let sd = dk * dv;
    for i in 1..nc {
        let (head, tail) = st.split_at_mut(i * sd);
        let prev = &head[(i - 1) * sd..];
        let cur = &mut tail[..sd];
        cur.copy_from_slice(prev);
        let c0 = (i - 1) * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        gemm::gemm_tn(&k[c0 * dk..][..rows * dk], &v[c0 * dv..][..rows * dv], dk, rows, dv, cur);
    }
}

/// Suffix chunk states: `st[i] = Σ_{j>i} Q_jᵀ·GO_j` (`st[nc-1] = 0`).
#[allow(clippy::too_many_arguments)]
fn chunk_states_suffix(
    q: &[f32],
    go: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    c: usize,
    nc: usize,
    st: &mut [f32],
) {
    let sd = dk * dv;
    for i in (0..nc.saturating_sub(1)).rev() {
        let (head, tail) = st.split_at_mut((i + 1) * sd);
        let cur = &mut head[i * sd..];
        let next = &tail[..sd];
        cur.copy_from_slice(next);
        let c0 = (i + 1) * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        gemm::gemm_tn(&q[c0 * dk..][..rows * dk], &go[c0 * dv..][..rows * dv], dk, rows, dv, cur);
    }
}

/// One score tile of the masked `(QKᵀ)V` product: `ob += mask(Q·Kᵀ)·V`,
/// where `masked` zeroes `key > query` pairs (the causal diagonal block).
/// `att` is caller-provided scratch of at least `rows·cols` floats.
#[allow(clippy::too_many_arguments)]
fn quad_tile(
    qb: &[f32],
    kb: &[f32],
    vb: &[f32],
    rows: usize,
    cols: usize,
    dk: usize,
    dv: usize,
    masked: bool,
    att: &mut [f32],
    ob: &mut [f32],
) {
    let at = &mut att[..rows * cols];
    at.fill(0.0);
    gemm::gemm_nt(qb, kb, rows, dk, cols, at);
    if masked {
        zero_strict_upper(at, rows, cols);
    }
    gemm::gemm_nn(at, vb, rows, cols, dv, ob);
}

/// Masked causal `(QKᵀ)V` over one contiguous window, blocked at
/// [`QUAD_BLOCK`] so the score tile stays O(`QUAD_BLOCK`²) for any window
/// length — the shared intra-chunk forward body of the chunkwise kernels.
fn quad_fwd_one(q: &[f32], k: &[f32], v: &[f32], n: usize, dk: usize, dv: usize, o: &mut [f32]) {
    let nb = n.div_ceil(QUAD_BLOCK);
    let mut att = vec![0.0f32; QUAD_BLOCK * QUAD_BLOCK];
    for ti in 0..nb {
        let t0 = ti * QUAD_BLOCK;
        let te = (t0 + QUAD_BLOCK).min(n);
        let rows = te - t0;
        let qb = &q[t0 * dk..][..rows * dk];
        let ob = &mut o[t0 * dv..][..rows * dv];
        for si in 0..=ti {
            let s0 = si * QUAD_BLOCK;
            let se = (s0 + QUAD_BLOCK).min(n);
            let cols = se - s0;
            let kb = &k[s0 * dk..][..cols * dk];
            let vb = &v[s0 * dv..][..cols * dv];
            quad_tile(qb, kb, vb, rows, cols, dk, dv, si == ti, &mut att, ob);
        }
    }
}

/// One `bh` slice of the chunkwise forward with a single running state —
/// the bounded-memory fallback (and the shape of the original algorithm,
/// but with every product as a tiled GEMM).
#[allow(clippy::too_many_arguments)]
fn chunk_fwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    c: usize,
    o: &mut [f32],
) {
    let mut s = vec![0.0f32; dk * dv];
    let mut c0 = 0;
    while c0 < n {
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[c0 * dk..][..rows * dk];
        let kb = &k[c0 * dk..][..rows * dk];
        let vb = &v[c0 * dv..][..rows * dv];
        let ob = &mut o[c0 * dv..][..rows * dv];
        gemm::gemm_nn(qb, &s, rows, dk, dv, ob);
        quad_fwd_one(qb, kb, vb, rows, dk, dv, ob);
        gemm::gemm_tn(kb, vb, dk, rows, dv, &mut s);
        c0 = ce;
    }
}

/// One `bh` slice of the chunkwise backward with running prefix/suffix
/// states — the bounded-memory fallback of [`la_chunk_bwd`].
#[allow(clippy::too_many_arguments)]
fn chunk_bwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    c: usize,
    dq: &mut [f32],
    dkk: &mut [f32],
    dvv: &mut [f32],
) {
    let sd = dk * dv;
    // forward over chunks: running S drives dq (inter), plus masked intra
    let mut s = vec![0.0f32; sd];
    let mut c0 = 0;
    while c0 < n {
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[c0 * dk..][..rows * dk];
        let kb = &k[c0 * dk..][..rows * dk];
        let vb = &v[c0 * dv..][..rows * dv];
        let gob = &go[c0 * dv..][..rows * dv];
        let dqb = &mut dq[c0 * dk..][..rows * dk];
        gemm::gemm_nt(gob, &s, rows, dv, dk, dqb);
        // all three intra terms are the blocked quadratic vjp over the window
        let dkb = &mut dkk[c0 * dk..][..rows * dk];
        let dvb = &mut dvv[c0 * dv..][..rows * dv];
        quad_bwd_one(qb, kb, vb, gob, rows, dk, dv, dqb, dkb, dvb);
        gemm::gemm_tn(kb, vb, dk, rows, dv, &mut s);
        c0 = ce;
    }
    // reverse over chunks: running R drives the dk/dv inter terms
    let mut r = vec![0.0f32; sd];
    let nc = n.div_ceil(c);
    for ci in (0..nc).rev() {
        let c0 = ci * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[c0 * dk..][..rows * dk];
        let kb = &k[c0 * dk..][..rows * dk];
        let vb = &v[c0 * dv..][..rows * dv];
        let gob = &go[c0 * dv..][..rows * dv];
        let dkb = &mut dkk[c0 * dk..][..rows * dk];
        let dvb = &mut dvv[c0 * dv..][..rows * dv];
        gemm::gemm_nt(vb, &r, rows, dv, dk, dkb);
        gemm::gemm_nn(kb, &r, rows, dk, dv, dvb);
        // R gains this chunk only after it is processed (R = Σ over j > ci)
        gemm::gemm_tn(qb, gob, dk, rows, dv, &mut r);
    }
}

/// Chunkwise causal linear attention (inter/intra decomposition, no decay):
/// per-chunk states first, then every `(bh, chunk)` output tile in parallel.
pub fn la_chunk_fwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: LayerShape,
    chunk: usize,
) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    if bh == 0 || n == 0 {
        return o;
    }
    let c = chunk.max(1);
    let nc = n.div_ceil(c);
    let sd = dk * dv;
    if bh.saturating_mul(nc).saturating_mul(sd) > CHUNK_STATE_FLOATS_BUDGET {
        pool.run_chunks(&mut o, n * dv, |b, ob| {
            let qb = &q[b * n * dk..][..n * dk];
            let kb = &k[b * n * dk..][..n * dk];
            let vb = &v[b * n * dv..][..n * dv];
            chunk_fwd_one(qb, kb, vb, n, dk, dv, c, ob);
        });
        return o;
    }
    // phase 1: prefix states, sequential in chunk index, parallel over bh
    let mut states = vec![0.0f32; bh * nc * sd];
    pool.run_chunks(&mut states, nc * sd, |b, st| {
        let (kb, vb) = (&k[b * n * dk..][..n * dk], &v[b * n * dv..][..n * dv]);
        chunk_states_prefix(kb, vb, n, dk, dv, c, nc, st);
    });
    // phase 2: independent (bh, chunk) output tiles
    let parts = SliceParts::new(&mut o);
    pool.run(bh * nc, |task| {
        let (b, ci) = (task / nc, task % nc);
        let c0 = ci * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[(b * n + c0) * dk..][..rows * dk];
        let kb = &k[(b * n + c0) * dk..][..rows * dk];
        let vb = &v[(b * n + c0) * dv..][..rows * dv];
        let st = &states[(b * nc + ci) * sd..][..sd];
        // SAFETY: tile (b, ci) owns rows [c0, ce) of batch b exclusively.
        let ob = unsafe { parts.window((b * n + c0) * dv, rows * dv) };
        // inter-chunk: O += Q · S
        gemm::gemm_nn(qb, st, rows, dk, dv, ob);
        // intra-chunk: masked local quadratic, O += tril(Q·Kᵀ) · V,
        // blocked at QUAD_BLOCK² regardless of chunk length
        quad_fwd_one(qb, kb, vb, rows, dk, dv, ob);
    });
    o
}

/// Chunkwise causal linear attention **with state carry** — the prefill
/// form of [`la_chunk_fwd`]: the scan starts from a caller-provided
/// per-`bh` state `s` (`bh` blocks of `dk·dv` — the recurrent decode state
/// after the tokens already consumed) and the end-of-window state is
/// written back into `s`, so a decode loop can continue exactly where the
/// chunked pass left off. `o` is fully overwritten.
///
/// `gamma < 1` is the gated variant; the decay folds into the chunk
/// decomposition in closed form:
/// - inter: local row `t` of a chunk sees the chunk-entry state through
///   `γ^{t+1}` (the state decays once per token, including its own);
/// - intra: pair `(t, i)` (key `i ≤` query `t`) keeps weight `γ^{t-i}`;
/// - state recurrence: `S ← γ^{rows}·S + Σ_i γ^{rows-1-i}·k_i·v_iᵀ` — the
///   closed form of `rows` steps of `S ← γ·S + k·vᵀ`.
///
/// Matches the sequential scan up to f32 reassociation (GEMM-reordered
/// sums); `gamma = 1` with a zero carry is exactly [`la_chunk_fwd`]'s math.
#[allow(clippy::too_many_arguments)]
pub fn la_chunk_fwd_carry(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: LayerShape,
    chunk: usize,
    gamma: f32,
    s: &mut [f32],
    o: &mut [f32],
) {
    let LayerShape { bh, n, dk, dv } = sh;
    let sd = dk * dv;
    debug_assert!(s.len() >= bh * sd && o.len() >= bh * n * dv);
    o[..bh * n * dv].fill(0.0);
    if bh == 0 || n == 0 {
        return;
    }
    let c = chunk.max(1);
    let nc = n.div_ceil(c);
    if bh.saturating_mul(nc).saturating_mul(sd) > CHUNK_STATE_FLOATS_BUDGET {
        // bounded-memory fallback: one running state per bh, tiled GEMMs
        let sp = SliceParts::new(s);
        pool.run_chunks(&mut o[..bh * n * dv], n * dv, |b, ob| {
            // SAFETY: task `b` touches carry block `b` only.
            let sb = unsafe { sp.window(b * sd, sd) };
            chunk_fwd_carry_one(
                &q[b * n * dk..][..n * dk],
                &k[b * n * dk..][..n * dk],
                &v[b * n * dv..][..n * dv],
                n,
                dk,
                dv,
                c,
                gamma,
                sb,
                ob,
            );
        });
        return;
    }
    // phase 1: chunk-entry states seeded from the carry (and the final
    // state back into `s`) — sequential per bh, parallel across bh
    let mut states = vec![0.0f32; bh * nc * sd];
    {
        let sp = SliceParts::new(s);
        pool.run_chunks(&mut states, nc * sd, |b, stw| {
            // SAFETY: task `b` touches carry block `b` only.
            let sb = unsafe { sp.window(b * sd, sd) };
            chunk_states_prefix_carry(
                &k[b * n * dk..][..n * dk],
                &v[b * n * dv..][..n * dv],
                n,
                dk,
                dv,
                c,
                nc,
                gamma,
                sb,
                stw,
            );
        });
    }
    // phase 2: independent (bh, chunk) output tiles
    let parts = SliceParts::new(o);
    pool.run(bh * nc, |task| {
        let (b, ci) = (task / nc, task % nc);
        let c0 = ci * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[(b * n + c0) * dk..][..rows * dk];
        let kb = &k[(b * n + c0) * dk..][..rows * dk];
        let vb = &v[(b * n + c0) * dv..][..rows * dv];
        let st = &states[(b * nc + ci) * sd..][..sd];
        // SAFETY: tile (b, ci) owns rows [c0, ce) of batch b exclusively.
        let ob = unsafe { parts.window((b * n + c0) * dv, rows * dv) };
        // inter-chunk: O += Q · S_entry, row t decayed by γ^{t+1}
        gemm::gemm_nn(qb, st, rows, dk, dv, ob);
        if gamma != 1.0 {
            scale_rows_geometric(ob, rows, dv, gamma);
        }
        // intra-chunk: masked (and decayed) local quadratic
        if gamma == 1.0 {
            quad_fwd_one(qb, kb, vb, rows, dk, dv, ob);
        } else {
            quad_fwd_decayed_one(qb, kb, vb, rows, dk, dv, gamma, ob);
        }
    });
}

/// One `bh` slice of the carry forward with a single running state — the
/// bounded-memory fallback of [`la_chunk_fwd_carry`].
#[allow(clippy::too_many_arguments)]
fn chunk_fwd_carry_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    c: usize,
    gamma: f32,
    s: &mut [f32],
    o: &mut [f32],
) {
    let mut kdec = vec![0.0f32; if gamma != 1.0 { c * dk } else { 0 }];
    let mut c0 = 0;
    while c0 < n {
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[c0 * dk..][..rows * dk];
        let kb = &k[c0 * dk..][..rows * dk];
        let vb = &v[c0 * dv..][..rows * dv];
        let ob = &mut o[c0 * dv..][..rows * dv];
        gemm::gemm_nn(qb, s, rows, dk, dv, ob);
        if gamma != 1.0 {
            scale_rows_geometric(ob, rows, dv, gamma);
        }
        if gamma == 1.0 {
            quad_fwd_one(qb, kb, vb, rows, dk, dv, ob);
            gemm::gemm_tn(kb, vb, dk, rows, dv, s);
        } else {
            quad_fwd_decayed_one(qb, kb, vb, rows, dk, dv, gamma, ob);
            chunk_state_decay_step(kb, vb, rows, dk, dv, gamma, &mut kdec, s);
        }
        c0 = ce;
    }
}

/// Per-chunk entry states seeded from the carry: `st[0] = s`, then each
/// chunk advances the recurrence; the same step once more (over the last
/// chunk) writes the end-of-window state back into `s`.
#[allow(clippy::too_many_arguments)]
fn chunk_states_prefix_carry(
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    c: usize,
    nc: usize,
    gamma: f32,
    s: &mut [f32],
    st: &mut [f32],
) {
    let sd = dk * dv;
    let mut kdec = vec![0.0f32; if gamma != 1.0 { c * dk } else { 0 }];
    st[..sd].copy_from_slice(&s[..sd]);
    for i in 1..=nc {
        let c0 = (i - 1) * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let kb = &k[c0 * dk..][..rows * dk];
        let vb = &v[c0 * dv..][..rows * dv];
        if i < nc {
            let (head, tail) = st.split_at_mut(i * sd);
            let prev = &head[(i - 1) * sd..];
            let cur = &mut tail[..sd];
            chunk_state_advance(prev, kb, vb, rows, dk, dv, gamma, &mut kdec, cur);
        } else {
            // the final chunk advances the last entry state into the carry
            let prev = &st[(nc - 1) * sd..][..sd];
            chunk_state_advance(prev, kb, vb, rows, dk, dv, gamma, &mut kdec, s);
        }
    }
}

/// `cur = γ^{rows}·prev + Σ_i γ^{rows-1-i}·k_iᵀ·v_i` over one chunk
/// (`γ = 1` degenerates to copy + plain `KᵀV`).
// deny_alloc
#[allow(clippy::too_many_arguments)]
fn chunk_state_advance(
    prev: &[f32],
    kb: &[f32],
    vb: &[f32],
    rows: usize,
    dk: usize,
    dv: usize,
    gamma: f32,
    kdec: &mut [f32],
    cur: &mut [f32],
) {
    if gamma == 1.0 {
        cur.copy_from_slice(prev);
        gemm::gemm_tn(kb, vb, dk, rows, dv, cur);
    } else {
        let g = gamma.powi(rows as i32);
        for (o, &p) in cur.iter_mut().zip(prev) {
            *o = g * p;
        }
        decay_rows_into(kb, rows, dk, gamma, kdec);
        gemm::gemm_tn(&kdec[..rows * dk], vb, dk, rows, dv, cur);
    }
}

/// In-place chunk-state step for the running-state fallback:
/// `s ← γ^{rows}·s + Σ_i γ^{rows-1-i}·k_iᵀ·v_i`.
// deny_alloc
#[allow(clippy::too_many_arguments)]
fn chunk_state_decay_step(
    kb: &[f32],
    vb: &[f32],
    rows: usize,
    dk: usize,
    dv: usize,
    gamma: f32,
    kdec: &mut [f32],
    s: &mut [f32],
) {
    let g = gamma.powi(rows as i32);
    for x in s.iter_mut() {
        *x *= g;
    }
    decay_rows_into(kb, rows, dk, gamma, kdec);
    gemm::gemm_tn(&kdec[..rows * dk], vb, dk, rows, dv, s);
}

/// Scale row `t` of a `rows×cols` tile by `γ^{t+1}` — the inter-chunk decay
/// of the carried state as seen from local position `t`.
// deny_alloc
fn scale_rows_geometric(o: &mut [f32], rows: usize, cols: usize, gamma: f32) {
    let mut g = gamma;
    for r in 0..rows {
        for x in &mut o[r * cols..][..cols] {
            *x *= g;
        }
        g *= gamma;
    }
}

/// `out` row `i` = `γ^{rows-1-i}·k_i` — the per-token decay weights one
/// chunk's keys contribute to the chunk-state sum.
// deny_alloc
fn decay_rows_into(k: &[f32], rows: usize, dk: usize, gamma: f32, out: &mut [f32]) {
    let mut g = 1.0f32;
    for i in (0..rows).rev() {
        let kr = &k[i * dk..][..dk];
        let orow = &mut out[i * dk..][..dk];
        for (o, &x) in orow.iter_mut().zip(kr) {
            *o = g * x;
        }
        g *= gamma;
    }
}

/// Causal decay mask on a score tile whose rows are queries `t0..t0+rows`
/// and columns keys `s0..s0+cols`: pair `(t, s)` keeps weight `γ^{t-s}` for
/// `s ≤ t` and is zeroed otherwise.
// deny_alloc
fn apply_causal_decay(att: &mut [f32], rows: usize, cols: usize, t0: usize, s0: usize, gamma: f32) {
    for t in 0..rows {
        let tq = t0 + t;
        let arow = &mut att[t * cols..][..cols];
        for (i, x) in arow.iter_mut().enumerate() {
            let sk = s0 + i;
            if sk > tq {
                *x = 0.0;
            } else {
                *x *= gamma.powi((tq - sk) as i32);
            }
        }
    }
}

/// [`quad_fwd_one`] with the pairwise decay `γ^{t-s}` folded into every
/// score tile — the intra-chunk term of the gated chunkwise forward.
#[allow(clippy::too_many_arguments)]
fn quad_fwd_decayed_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    gamma: f32,
    o: &mut [f32],
) {
    let nb = n.div_ceil(QUAD_BLOCK);
    let mut att = vec![0.0f32; QUAD_BLOCK * QUAD_BLOCK];
    for ti in 0..nb {
        let t0 = ti * QUAD_BLOCK;
        let te = (t0 + QUAD_BLOCK).min(n);
        let rows = te - t0;
        let qb = &q[t0 * dk..][..rows * dk];
        let ob = &mut o[t0 * dv..][..rows * dv];
        for si in 0..=ti {
            let s0 = si * QUAD_BLOCK;
            let se = (s0 + QUAD_BLOCK).min(n);
            let cols = se - s0;
            let kb = &k[s0 * dk..][..cols * dk];
            let vb = &v[s0 * dv..][..cols * dv];
            let at = &mut att[..rows * cols];
            at.fill(0.0);
            gemm::gemm_nt(qb, kb, rows, dk, cols, at);
            apply_causal_decay(at, rows, cols, t0, s0, gamma);
            gemm::gemm_nn(at, vb, rows, cols, dv, ob);
        }
    }
}

/// Backward of [`la_chunk_fwd`]: same inter/intra split; prefix states drive
/// `dq`, suffix states drive `dk`/`dv`, and every `(bh, chunk)` gradient
/// tile is independent once both state sets exist.
pub fn la_chunk_bwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
    chunk: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    if bh == 0 || n == 0 {
        return (dq, dkk, dvv);
    }
    let c = chunk.max(1);
    let nc = n.div_ceil(c);
    let sd = dk * dv;
    if bh.saturating_mul(2 * nc).saturating_mul(sd) > CHUNK_STATE_FLOATS_BUDGET {
        pool.run_chunks3(
            &mut dq,
            n * dk,
            &mut dkk,
            n * dk,
            &mut dvv,
            n * dv,
            |b, dqb, dkb, dvb| {
                let qb = &q[b * n * dk..][..n * dk];
                let kb = &k[b * n * dk..][..n * dk];
                let vb = &v[b * n * dv..][..n * dv];
                let gob = &go[b * n * dv..][..n * dv];
                chunk_bwd_one(qb, kb, vb, gob, n, dk, dv, c, dqb, dkb, dvb);
            },
        );
        return (dq, dkk, dvv);
    }
    let mut s_states = vec![0.0f32; bh * nc * sd];
    pool.run_chunks(&mut s_states, nc * sd, |b, st| {
        let (kb, vb) = (&k[b * n * dk..][..n * dk], &v[b * n * dv..][..n * dv]);
        chunk_states_prefix(kb, vb, n, dk, dv, c, nc, st);
    });
    let mut r_states = vec![0.0f32; bh * nc * sd];
    pool.run_chunks(&mut r_states, nc * sd, |b, st| {
        let (qb, gob) = (&q[b * n * dk..][..n * dk], &go[b * n * dv..][..n * dv]);
        chunk_states_suffix(qb, gob, n, dk, dv, c, nc, st);
    });
    let dq_parts = SliceParts::new(&mut dq);
    let dk_parts = SliceParts::new(&mut dkk);
    let dv_parts = SliceParts::new(&mut dvv);
    pool.run(bh * nc, |task| {
        let (b, ci) = (task / nc, task % nc);
        let c0 = ci * c;
        let ce = (c0 + c).min(n);
        let rows = ce - c0;
        let qb = &q[(b * n + c0) * dk..][..rows * dk];
        let kb = &k[(b * n + c0) * dk..][..rows * dk];
        let vb = &v[(b * n + c0) * dv..][..rows * dv];
        let gob = &go[(b * n + c0) * dv..][..rows * dv];
        let s = &s_states[(b * nc + ci) * sd..][..sd];
        let r = &r_states[(b * nc + ci) * sd..][..sd];
        // SAFETY: tile (b, ci) owns rows [c0, ce) of batch b in all three
        // gradient buffers exclusively.
        let dqb = unsafe { dq_parts.window((b * n + c0) * dk, rows * dk) };
        let dkb = unsafe { dk_parts.window((b * n + c0) * dk, rows * dk) };
        let dvb = unsafe { dv_parts.window((b * n + c0) * dv, rows * dv) };
        // inter terms: dQ += GO·Sᵀ ; dK += V·Rᵀ ; dV += K·R
        gemm::gemm_nt(gob, s, rows, dv, dk, dqb);
        gemm::gemm_nt(vb, r, rows, dv, dk, dkb);
        gemm::gemm_nn(kb, r, rows, dk, dv, dvb);
        // intra terms: the blocked quadratic vjp over the chunk window
        // (tril-masked G = GO·Vᵀ and A = Q·Kᵀ tiles, QUAD_BLOCK² memory)
        quad_bwd_one(qb, kb, vb, gob, rows, dk, dv, dqb, dkb, dvb);
    });
    (dq, dkk, dvv)
}

// --- quadratic baselines ------------------------------------------------------

/// Quadratic-time reference of the same softmax-free attention: the masked
/// `(QKᵀ)V` product as blocked score tiles (the eager-baseline access
/// pattern). Output is comparable to the scan/chunk forms up to f32
/// reassociation. Row blocks are independent, so it parallelizes over
/// `(bh, row block)`.
pub fn la_quadratic_fwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: LayerShape,
) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    if bh == 0 || n == 0 {
        return o;
    }
    let nb = n.div_ceil(QUAD_BLOCK);
    let parts = SliceParts::new(&mut o);
    pool.run(bh * nb, |task| {
        let (b, ti) = (task / nb, task % nb);
        let t0 = ti * QUAD_BLOCK;
        let te = (t0 + QUAD_BLOCK).min(n);
        let rows = te - t0;
        let qb = &q[(b * n + t0) * dk..][..rows * dk];
        // SAFETY: tile (b, ti) owns rows [t0, te) of batch b exclusively.
        let ob = unsafe { parts.window((b * n + t0) * dv, rows * dv) };
        let mut att = vec![0.0f32; rows * QUAD_BLOCK];
        for si in 0..=ti {
            let s0 = si * QUAD_BLOCK;
            let se = (s0 + QUAD_BLOCK).min(n);
            let cols = se - s0;
            let kb = &k[(b * n + s0) * dk..][..cols * dk];
            let vb = &v[(b * n + s0) * dv..][..cols * dv];
            quad_tile(qb, kb, vb, rows, cols, dk, dv, si == ti, &mut att, ob);
        }
    });
    o
}

/// Backward of [`la_quadratic_fwd`], blocked pairwise. `dk`/`dv` tiles are
/// revisited by every later row block, so parallelism is over `bh` only.
pub fn la_quadratic_bwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    pool.run_chunks3(&mut dq, n * dk, &mut dkk, n * dk, &mut dvv, n * dv, |b, dqb, dkb, dvb| {
        quad_bwd_one(
            &q[b * n * dk..][..n * dk],
            &k[b * n * dk..][..n * dk],
            &v[b * n * dv..][..n * dv],
            &go[b * n * dv..][..n * dv],
            n,
            dk,
            dv,
            dqb,
            dkb,
            dvb,
        );
    });
    (dq, dkk, dvv)
}

#[allow(clippy::too_many_arguments)]
fn quad_bwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    dq: &mut [f32],
    dkk: &mut [f32],
    dvv: &mut [f32],
) {
    let nb = n.div_ceil(QUAD_BLOCK);
    let mut att = vec![0.0f32; QUAD_BLOCK * QUAD_BLOCK];
    let mut g = vec![0.0f32; QUAD_BLOCK * QUAD_BLOCK];
    for ti in 0..nb {
        let t0 = ti * QUAD_BLOCK;
        let te = (t0 + QUAD_BLOCK).min(n);
        let rows = te - t0;
        let qb = &q[t0 * dk..][..rows * dk];
        let gob = &go[t0 * dv..][..rows * dv];
        for si in 0..=ti {
            let s0 = si * QUAD_BLOCK;
            let se = (s0 + QUAD_BLOCK).min(n);
            let cols = se - s0;
            let kb = &k[s0 * dk..][..cols * dk];
            let vb = &v[s0 * dv..][..cols * dv];
            let at = &mut att[..rows * cols];
            at.fill(0.0);
            gemm::gemm_nt(qb, kb, rows, dk, cols, at);
            let gt = &mut g[..rows * cols];
            gt.fill(0.0);
            gemm::gemm_nt(gob, vb, rows, dv, cols, gt);
            if si == ti {
                zero_strict_upper(at, rows, cols);
                zero_strict_upper(gt, rows, cols);
            }
            gemm::gemm_nn(gt, kb, rows, cols, dk, &mut dq[t0 * dk..][..rows * dk]);
            gemm::gemm_tn(gt, qb, cols, rows, dk, &mut dkk[s0 * dk..][..cols * dk]);
            gemm::gemm_tn(at, gob, cols, rows, dv, &mut dvv[s0 * dv..][..cols * dv]);
        }
    }
}

// --- softmax baseline ---------------------------------------------------------

/// Standard causal softmax attention with a streaming row softmax
/// (scores scaled by `scale`, typically `1/sqrt(dk)`); parallel over `bh`.
pub fn softmax_fwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: LayerShape,
    scale: f32,
) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    pool.run_chunks(&mut o, n * dv, |b, ob| {
        softmax_fwd_one(
            &q[b * n * dk..][..n * dk],
            &k[b * n * dk..][..n * dk],
            &v[b * n * dv..][..n * dv],
            n,
            dk,
            dv,
            scale,
            ob,
        );
    });
    o
}

#[allow(clippy::too_many_arguments)]
fn softmax_fwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    scale: f32,
    o: &mut [f32],
) {
    let mut scores = vec![0.0f32; n];
    for t in 0..n {
        let qr = &q[t * dk..][..dk];
        let mut m = f32::NEG_INFINITY;
        for sidx in 0..=t {
            let a = gemm::dot(qr, &k[sidx * dk..][..dk]) * scale;
            scores[sidx] = a;
            m = m.max(a);
        }
        let mut z = 0.0f32;
        for sc in scores[..=t].iter_mut() {
            *sc = (*sc - m).exp();
            z += *sc;
        }
        let inv = 1.0 / z;
        let orow = &mut o[t * dv..][..dv];
        for sidx in 0..=t {
            gemm::axpy(scores[sidx] * inv, &v[sidx * dv..][..dv], orow);
        }
    }
}

/// Backward of [`softmax_fwd`]: recomputes each probability row, then applies
/// the standard softmax-attention vjp; parallel over `bh`.
pub fn softmax_bwd(
    pool: &ThreadPool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
    scale: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    pool.run_chunks3(&mut dq, n * dk, &mut dkk, n * dk, &mut dvv, n * dv, |b, dqb, dkb, dvb| {
        softmax_bwd_one(
            &q[b * n * dk..][..n * dk],
            &k[b * n * dk..][..n * dk],
            &v[b * n * dv..][..n * dv],
            &go[b * n * dv..][..n * dv],
            n,
            dk,
            dv,
            scale,
            dqb,
            dkb,
            dvb,
        );
    });
    (dq, dkk, dvv)
}

#[allow(clippy::too_many_arguments)]
fn softmax_bwd_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    scale: f32,
    dq: &mut [f32],
    dkk: &mut [f32],
    dvv: &mut [f32],
) {
    let mut p = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    for t in 0..n {
        let qr = &q[t * dk..][..dk];
        let gr = &go[t * dv..][..dv];
        // recompute the probability row
        let mut m = f32::NEG_INFINITY;
        for sidx in 0..=t {
            let a = gemm::dot(qr, &k[sidx * dk..][..dk]) * scale;
            p[sidx] = a;
            m = m.max(a);
        }
        let mut z = 0.0f32;
        for sc in p[..=t].iter_mut() {
            *sc = (*sc - m).exp();
            z += *sc;
        }
        let inv = 1.0 / z;
        // g_s = go_t · v_s ; c = Σ p_s g_s
        let mut csum = 0.0f32;
        for sidx in 0..=t {
            p[sidx] *= inv;
            let gv = gemm::dot(gr, &v[sidx * dv..][..dv]);
            g[sidx] = gv;
            csum += p[sidx] * gv;
        }
        // dv_s += p_s go_t ; dscore_s = p_s (g_s − c)
        for sidx in 0..=t {
            let ds = p[sidx] * (g[sidx] - csum) * scale;
            gemm::axpy(p[sidx], gr, &mut dvv[sidx * dv..][..dv]);
            let kr = &k[sidx * dk..][..dk];
            gemm::axpy(ds, kr, &mut dq[t * dk..][..dk]);
            gemm::axpy(ds, qr, &mut dkk[sidx * dk..][..dk]);
        }
    }
}

// --- scalar reference ---------------------------------------------------------

/// The pre-optimization kernels: scalar, single-threaded, loop-nest form —
/// kept verbatim as the parity oracle for the parallel/tiled paths and as
/// the `bench-native` speedup baseline. Do not optimize these.
pub mod reference {
    use super::LayerShape;

    /// Causal linear attention, sequential state scan (decay `gamma`).
    pub fn la_scan_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape, gamma: f32) -> Vec<f32> {
        let LayerShape { bh, n, dk, dv } = sh;
        let mut o = vec![0.0f32; bh * n * dv];
        let mut s = vec![0.0f32; dk * dv];
        for b in 0..bh {
            s.fill(0.0);
            for t in 0..n {
                let qr = &q[(b * n + t) * dk..][..dk];
                let kr = &k[(b * n + t) * dk..][..dk];
                let vr = &v[(b * n + t) * dv..][..dv];
                if gamma != 1.0 {
                    for x in s.iter_mut() {
                        *x *= gamma;
                    }
                }
                for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                    let ki = kr[i];
                    for (sx, vx) in srow.iter_mut().zip(vr) {
                        *sx += ki * vx;
                    }
                }
                let orow = &mut o[(b * n + t) * dv..][..dv];
                for (i, srow) in s.chunks_exact(dv).enumerate() {
                    let qi = qr[i];
                    for (ox, sx) in orow.iter_mut().zip(srow) {
                        *ox += qi * sx;
                    }
                }
            }
        }
        o
    }

    /// Backward of [`la_scan_fwd`].
    pub fn la_scan_bwd(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        go: &[f32],
        sh: LayerShape,
        gamma: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let LayerShape { bh, n, dk, dv } = sh;
        let mut dq = vec![0.0f32; bh * n * dk];
        let mut dkk = vec![0.0f32; bh * n * dk];
        let mut dvv = vec![0.0f32; bh * n * dv];
        let mut s = vec![0.0f32; dk * dv];
        let mut r = vec![0.0f32; dk * dv];
        for b in 0..bh {
            s.fill(0.0);
            for t in 0..n {
                let kr = &k[(b * n + t) * dk..][..dk];
                let vr = &v[(b * n + t) * dv..][..dv];
                let gr = &go[(b * n + t) * dv..][..dv];
                if gamma != 1.0 {
                    for x in s.iter_mut() {
                        *x *= gamma;
                    }
                }
                for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                    let ki = kr[i];
                    for (sx, vx) in srow.iter_mut().zip(vr) {
                        *sx += ki * vx;
                    }
                }
                let dqr = &mut dq[(b * n + t) * dk..][..dk];
                for (i, srow) in s.chunks_exact(dv).enumerate() {
                    let mut acc = 0.0f32;
                    for (sx, gx) in srow.iter().zip(gr) {
                        acc += sx * gx;
                    }
                    dqr[i] = acc;
                }
            }
            r.fill(0.0);
            for t in (0..n).rev() {
                let qr = &q[(b * n + t) * dk..][..dk];
                let kr = &k[(b * n + t) * dk..][..dk];
                let vr = &v[(b * n + t) * dv..][..dv];
                let gr = &go[(b * n + t) * dv..][..dv];
                if gamma != 1.0 {
                    for x in r.iter_mut() {
                        *x *= gamma;
                    }
                }
                for (i, rrow) in r.chunks_exact_mut(dv).enumerate() {
                    let qi = qr[i];
                    for (rx, gx) in rrow.iter_mut().zip(gr) {
                        *rx += qi * gx;
                    }
                }
                let dkr = &mut dkk[(b * n + t) * dk..][..dk];
                let dvr = &mut dvv[(b * n + t) * dv..][..dv];
                for (i, rrow) in r.chunks_exact(dv).enumerate() {
                    let mut acc = 0.0f32;
                    for (rx, vx) in rrow.iter().zip(vr.iter()) {
                        acc += rx * vx;
                    }
                    dkr[i] = acc;
                    let ki = kr[i];
                    for (dx, rx) in dvr.iter_mut().zip(rrow) {
                        *dx += ki * rx;
                    }
                }
            }
        }
        (dq, dkk, dvv)
    }

    /// Chunkwise causal linear attention (single running state, scalar).
    pub fn la_chunk_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape, chunk: usize) -> Vec<f32> {
        let LayerShape { bh, n, dk, dv } = sh;
        let c = chunk.max(1);
        let mut o = vec![0.0f32; bh * n * dv];
        let mut s = vec![0.0f32; dk * dv];
        for b in 0..bh {
            s.fill(0.0);
            let mut c0 = 0;
            while c0 < n {
                let ce = (c0 + c).min(n);
                for t in c0..ce {
                    let qr = &q[(b * n + t) * dk..][..dk];
                    let orow = &mut o[(b * n + t) * dv..][..dv];
                    for (i, srow) in s.chunks_exact(dv).enumerate() {
                        let qi = qr[i];
                        for (ox, sx) in orow.iter_mut().zip(srow) {
                            *ox += qi * sx;
                        }
                    }
                    for sidx in c0..=t {
                        let kr = &k[(b * n + sidx) * dk..][..dk];
                        let vr = &v[(b * n + sidx) * dv..][..dv];
                        let mut a = 0.0f32;
                        for (qx, kx) in qr.iter().zip(kr) {
                            a += qx * kx;
                        }
                        for (ox, vx) in orow.iter_mut().zip(vr) {
                            *ox += a * vx;
                        }
                    }
                }
                for t in c0..ce {
                    let kr = &k[(b * n + t) * dk..][..dk];
                    let vr = &v[(b * n + t) * dv..][..dv];
                    for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                        let ki = kr[i];
                        for (sx, vx) in srow.iter_mut().zip(vr) {
                            *sx += ki * vx;
                        }
                    }
                }
                c0 = ce;
            }
        }
        o
    }

    /// Backward of [`la_chunk_fwd`].
    pub fn la_chunk_bwd(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        go: &[f32],
        sh: LayerShape,
        chunk: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let LayerShape { bh, n, dk, dv } = sh;
        let c = chunk.max(1);
        let mut dq = vec![0.0f32; bh * n * dk];
        let mut dkk = vec![0.0f32; bh * n * dk];
        let mut dvv = vec![0.0f32; bh * n * dv];
        let mut s = vec![0.0f32; dk * dv];
        let mut r = vec![0.0f32; dk * dv];
        for b in 0..bh {
            s.fill(0.0);
            let mut c0 = 0;
            while c0 < n {
                let ce = (c0 + c).min(n);
                for t in c0..ce {
                    let gr = &go[(b * n + t) * dv..][..dv];
                    let dqr = &mut dq[(b * n + t) * dk..][..dk];
                    for (i, srow) in s.chunks_exact(dv).enumerate() {
                        let mut acc = 0.0f32;
                        for (sx, gx) in srow.iter().zip(gr) {
                            acc += sx * gx;
                        }
                        dqr[i] = acc;
                    }
                    for sidx in c0..=t {
                        let kr = &k[(b * n + sidx) * dk..][..dk];
                        let vr = &v[(b * n + sidx) * dv..][..dv];
                        let mut gv = 0.0f32;
                        for (gx, vx) in gr.iter().zip(vr) {
                            gv += gx * vx;
                        }
                        for (dx, kx) in dqr.iter_mut().zip(kr) {
                            *dx += gv * kx;
                        }
                    }
                }
                for t in c0..ce {
                    let kr = &k[(b * n + t) * dk..][..dk];
                    let vr = &v[(b * n + t) * dv..][..dv];
                    for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                        let ki = kr[i];
                        for (sx, vx) in srow.iter_mut().zip(vr) {
                            *sx += ki * vx;
                        }
                    }
                }
                c0 = ce;
            }
            r.fill(0.0);
            let n_chunks = n.div_ceil(c);
            for ci in (0..n_chunks).rev() {
                let c0 = ci * c;
                let ce = (c0 + c).min(n);
                for t in c0..ce {
                    let kr = &k[(b * n + t) * dk..][..dk];
                    let vr = &v[(b * n + t) * dv..][..dv];
                    let dkr = &mut dkk[(b * n + t) * dk..][..dk];
                    let dvr = &mut dvv[(b * n + t) * dv..][..dv];
                    for (i, rrow) in r.chunks_exact(dv).enumerate() {
                        let mut acc = 0.0f32;
                        for (rx, vx) in rrow.iter().zip(vr.iter()) {
                            acc += rx * vx;
                        }
                        dkr[i] = acc;
                        let ki = kr[i];
                        for (dx, rx) in dvr.iter_mut().zip(rrow) {
                            *dx += ki * rx;
                        }
                    }
                    for sidx in t..ce {
                        let qr = &q[(b * n + sidx) * dk..][..dk];
                        let gr = &go[(b * n + sidx) * dv..][..dv];
                        let mut gv = 0.0f32;
                        for (gx, vx) in gr.iter().zip(vr.iter()) {
                            gv += gx * vx;
                        }
                        let mut a = 0.0f32;
                        for (qx, kx) in qr.iter().zip(kr.iter()) {
                            a += qx * kx;
                        }
                        for (dx, qx) in dkr.iter_mut().zip(qr) {
                            *dx += gv * qx;
                        }
                        for (dx, gx) in dvr.iter_mut().zip(gr) {
                            *dx += a * gx;
                        }
                    }
                }
                for t in c0..ce {
                    let qr = &q[(b * n + t) * dk..][..dk];
                    let gr = &go[(b * n + t) * dv..][..dv];
                    for (i, rrow) in r.chunks_exact_mut(dv).enumerate() {
                        let qi = qr[i];
                        for (rx, gx) in rrow.iter_mut().zip(gr) {
                            *rx += qi * gx;
                        }
                    }
                }
            }
        }
        (dq, dkk, dvv)
    }

    /// Pairwise masked `(QKᵀ)V` reference.
    pub fn la_quadratic_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape) -> Vec<f32> {
        let LayerShape { bh, n, dk, dv } = sh;
        let mut o = vec![0.0f32; bh * n * dv];
        for b in 0..bh {
            for t in 0..n {
                let qr = &q[(b * n + t) * dk..][..dk];
                let orow = &mut o[(b * n + t) * dv..][..dv];
                for sidx in 0..=t {
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    let vr = &v[(b * n + sidx) * dv..][..dv];
                    let mut a = 0.0f32;
                    for (qx, kx) in qr.iter().zip(kr) {
                        a += qx * kx;
                    }
                    for (ox, vx) in orow.iter_mut().zip(vr) {
                        *ox += a * vx;
                    }
                }
            }
        }
        o
    }

    /// Backward of [`la_quadratic_fwd`], pairwise.
    pub fn la_quadratic_bwd(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        go: &[f32],
        sh: LayerShape,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let LayerShape { bh, n, dk, dv } = sh;
        let mut dq = vec![0.0f32; bh * n * dk];
        let mut dkk = vec![0.0f32; bh * n * dk];
        let mut dvv = vec![0.0f32; bh * n * dv];
        for b in 0..bh {
            for t in 0..n {
                let qr = &q[(b * n + t) * dk..][..dk];
                let gr = &go[(b * n + t) * dv..][..dv];
                for sidx in 0..=t {
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    let vr = &v[(b * n + sidx) * dv..][..dv];
                    let mut gv = 0.0f32;
                    for (gx, vx) in gr.iter().zip(vr) {
                        gv += gx * vx;
                    }
                    let mut a = 0.0f32;
                    for (qx, kx) in qr.iter().zip(kr) {
                        a += qx * kx;
                    }
                    {
                        let dqr = &mut dq[(b * n + t) * dk..][..dk];
                        for (dx, kx) in dqr.iter_mut().zip(kr) {
                            *dx += gv * kx;
                        }
                    }
                    {
                        let dkr = &mut dkk[(b * n + sidx) * dk..][..dk];
                        for (dx, qx) in dkr.iter_mut().zip(qr) {
                            *dx += gv * qx;
                        }
                    }
                    {
                        let dvr = &mut dvv[(b * n + sidx) * dv..][..dv];
                        for (dx, gx) in dvr.iter_mut().zip(gr) {
                            *dx += a * gx;
                        }
                    }
                }
            }
        }
        (dq, dkk, dvv)
    }

    /// Streaming causal softmax attention.
    pub fn softmax_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape, scale: f32) -> Vec<f32> {
        let LayerShape { bh, n, dk, dv } = sh;
        let mut o = vec![0.0f32; bh * n * dv];
        let mut scores = vec![0.0f32; n];
        for b in 0..bh {
            for t in 0..n {
                let qr = &q[(b * n + t) * dk..][..dk];
                let mut m = f32::NEG_INFINITY;
                for sidx in 0..=t {
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    let mut a = 0.0f32;
                    for (qx, kx) in qr.iter().zip(kr) {
                        a += qx * kx;
                    }
                    let a = a * scale;
                    scores[sidx] = a;
                    m = m.max(a);
                }
                let mut z = 0.0f32;
                for sc in scores[..=t].iter_mut() {
                    *sc = (*sc - m).exp();
                    z += *sc;
                }
                let inv = 1.0 / z;
                let orow = &mut o[(b * n + t) * dv..][..dv];
                for sidx in 0..=t {
                    let w = scores[sidx] * inv;
                    let vr = &v[(b * n + sidx) * dv..][..dv];
                    for (ox, vx) in orow.iter_mut().zip(vr) {
                        *ox += w * vx;
                    }
                }
            }
        }
        o
    }

    /// Backward of [`softmax_fwd`].
    pub fn softmax_bwd(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        go: &[f32],
        sh: LayerShape,
        scale: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let LayerShape { bh, n, dk, dv } = sh;
        let mut dq = vec![0.0f32; bh * n * dk];
        let mut dkk = vec![0.0f32; bh * n * dk];
        let mut dvv = vec![0.0f32; bh * n * dv];
        let mut p = vec![0.0f32; n];
        let mut g = vec![0.0f32; n];
        for b in 0..bh {
            for t in 0..n {
                let qr = &q[(b * n + t) * dk..][..dk];
                let gr = &go[(b * n + t) * dv..][..dv];
                let mut m = f32::NEG_INFINITY;
                for sidx in 0..=t {
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    let mut a = 0.0f32;
                    for (qx, kx) in qr.iter().zip(kr) {
                        a += qx * kx;
                    }
                    let a = a * scale;
                    p[sidx] = a;
                    m = m.max(a);
                }
                let mut z = 0.0f32;
                for sc in p[..=t].iter_mut() {
                    *sc = (*sc - m).exp();
                    z += *sc;
                }
                let inv = 1.0 / z;
                let mut csum = 0.0f32;
                for sidx in 0..=t {
                    p[sidx] *= inv;
                    let vr = &v[(b * n + sidx) * dv..][..dv];
                    let mut gv = 0.0f32;
                    for (gx, vx) in gr.iter().zip(vr) {
                        gv += gx * vx;
                    }
                    g[sidx] = gv;
                    csum += p[sidx] * gv;
                }
                let dqr_start = (b * n + t) * dk;
                for sidx in 0..=t {
                    let ds = p[sidx] * (g[sidx] - csum) * scale;
                    {
                        let dvr = &mut dvv[(b * n + sidx) * dv..][..dv];
                        let w = p[sidx];
                        for (dx, gx) in dvr.iter_mut().zip(gr) {
                            *dx += w * gx;
                        }
                    }
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    {
                        let dqr = &mut dq[dqr_start..][..dk];
                        for (dx, kx) in dqr.iter_mut().zip(kr) {
                            *dx += ds * kx;
                        }
                    }
                    {
                        let dkr = &mut dkk[(b * n + sidx) * dk..][..dk];
                        for (dx, qx) in dkr.iter_mut().zip(qr) {
                            *dx += ds * qx;
                        }
                    }
                }
            }
        }
        (dq, dkk, dvv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    fn pool() -> ThreadPool {
        ThreadPool::new(3)
    }

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        match Tensor::randn(vec![n], seed) {
            Tensor::F32 { data, .. } => data,
            _ => unreachable!(),
        }
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn scan_chunk_quadratic_agree_on_forward() {
        let sh = LayerShape::cube(2, 33, 8);
        let q = randn(sh.bh * sh.n * sh.dk, 1);
        let k = randn(sh.bh * sh.n * sh.dk, 2);
        let v = randn(sh.bh * sh.n * sh.dv, 3);
        let a = la_scan_fwd(&pool(), &q, &k, &v, sh, 1.0);
        let b = la_chunk_fwd(&pool(), &q, &k, &v, sh, 7);
        let c = la_quadratic_fwd(&pool(), &q, &k, &v, sh);
        assert!(max_abs_diff(&a, &c) < 1e-3, "scan vs quadratic {}", max_abs_diff(&a, &c));
        assert!(max_abs_diff(&b, &c) < 1e-3, "chunk vs quadratic {}", max_abs_diff(&b, &c));
    }

    #[test]
    fn scan_chunk_quadratic_agree_on_backward() {
        let sh = LayerShape::cube(2, 21, 6);
        let q = randn(sh.bh * sh.n * sh.dk, 4);
        let k = randn(sh.bh * sh.n * sh.dk, 5);
        let v = randn(sh.bh * sh.n * sh.dv, 6);
        let go = randn(sh.bh * sh.n * sh.dv, 7);
        let (aq, ak, av) = la_scan_bwd(&pool(), &q, &k, &v, &go, sh, 1.0);
        let (bq, bk, bv) = la_chunk_bwd(&pool(), &q, &k, &v, &go, sh, 5);
        let (cq, ck, cv) = la_quadratic_bwd(&pool(), &q, &k, &v, &go, sh);
        for (x, y) in [(&aq, &cq), (&ak, &ck), (&av, &cv), (&bq, &cq), (&bk, &ck), (&bv, &cv)] {
            assert!(max_abs_diff(x, y) < 1e-3, "bwd mismatch {}", max_abs_diff(x, y));
        }
    }

    #[test]
    fn parallel_kernels_match_scalar_reference() {
        // quick in-module guard at an awkward shape (ragged chunks and
        // blocks); the full-size parity suite lives in tests/native_parallel.rs
        let sh = LayerShape::cube(3, 70, 10);
        let q = randn(sh.bh * sh.n * sh.dk, 40);
        let k = randn(sh.bh * sh.n * sh.dk, 41);
        let v = randn(sh.bh * sh.n * sh.dv, 42);
        let go = randn(sh.bh * sh.n * sh.dv, 43);
        let p = pool();
        assert!(
            max_abs_diff(
                &la_chunk_fwd(&p, &q, &k, &v, sh, 16),
                &reference::la_chunk_fwd(&q, &k, &v, sh, 16)
            ) < 1e-3
        );
        let (pq, pk, pv) = la_chunk_bwd(&p, &q, &k, &v, &go, sh, 16);
        let (rq, rk, rv) = reference::la_chunk_bwd(&q, &k, &v, &go, sh, 16);
        for (x, y) in [(&pq, &rq), (&pk, &rk), (&pv, &rv)] {
            assert!(max_abs_diff(x, y) < 1e-3, "chunk bwd vs reference {}", max_abs_diff(x, y));
        }
        assert!(
            max_abs_diff(
                &la_quadratic_fwd(&p, &q, &k, &v, sh),
                &reference::la_quadratic_fwd(&q, &k, &v, sh)
            ) < 1e-3
        );
    }

    #[test]
    fn chunk_running_state_fallback_matches_reference() {
        // the bounded-memory path (chunk_fwd_one / chunk_bwd_one) is only
        // reachable through the public API past the 256 MB state budget, so
        // pin it directly against the scalar reference here
        let sh = LayerShape::cube(1, 53, 9);
        let q = randn(sh.n * sh.dk, 60);
        let k = randn(sh.n * sh.dk, 61);
        let v = randn(sh.n * sh.dv, 62);
        let go = randn(sh.n * sh.dv, 63);
        for c in [1usize, 8, 64] {
            let mut o = vec![0.0f32; sh.n * sh.dv];
            chunk_fwd_one(&q, &k, &v, sh.n, sh.dk, sh.dv, c, &mut o);
            let o_ref = reference::la_chunk_fwd(&q, &k, &v, sh, c);
            assert!(max_abs_diff(&o, &o_ref) < 1e-3, "fwd C={c}: {}", max_abs_diff(&o, &o_ref));

            let mut dq = vec![0.0f32; sh.n * sh.dk];
            let mut dkk = vec![0.0f32; sh.n * sh.dk];
            let mut dvv = vec![0.0f32; sh.n * sh.dv];
            chunk_bwd_one(&q, &k, &v, &go, sh.n, sh.dk, sh.dv, c, &mut dq, &mut dkk, &mut dvv);
            let (rq, rk, rv) = reference::la_chunk_bwd(&q, &k, &v, &go, sh, c);
            for (name, x, y) in [("dq", &dq, &rq), ("dk", &dkk, &rk), ("dv", &dvv, &rv)] {
                assert!(max_abs_diff(x, y) < 1e-3, "{name} C={c}: {}", max_abs_diff(x, y));
            }
        }
    }

    #[test]
    fn degenerate_shapes_return_empty() {
        let p = pool();
        let sh = LayerShape { bh: 2, n: 0, dk: 8, dv: 8 };
        assert!(la_scan_fwd(&p, &[], &[], &[], sh, 1.0).is_empty());
        assert!(la_chunk_fwd(&p, &[], &[], &[], sh, 16).is_empty());
        assert!(la_quadratic_fwd(&p, &[], &[], &[], sh).is_empty());
        assert!(softmax_fwd(&p, &[], &[], &[], sh, 1.0).is_empty());
        let (dq, dk, dv) = la_scan_bwd(&p, &[], &[], &[], &[], sh, 1.0);
        assert!(dq.is_empty() && dk.is_empty() && dv.is_empty());
        let (dq, dk, dv) = la_chunk_bwd(&p, &[], &[], &[], &[], sh, 16);
        assert!(dq.is_empty() && dk.is_empty() && dv.is_empty());
    }

    #[test]
    fn scan_gradients_match_finite_differences() {
        // tiny shape so central differences are cheap and well-conditioned
        let sh = LayerShape::cube(1, 5, 3);
        let q = randn(sh.bh * sh.n * sh.dk, 10);
        let k = randn(sh.bh * sh.n * sh.dk, 11);
        let v = randn(sh.bh * sh.n * sh.dv, 12);
        let go = randn(sh.bh * sh.n * sh.dv, 13);
        let gamma = 0.9f32;
        let p = pool();
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            la_scan_fwd(&p, q, k, v, sh, gamma)
                .iter()
                .zip(&go)
                .map(|(o, g)| (*o as f64) * (*g as f64))
                .sum()
        };
        let (dq, dk, dv) = la_scan_bwd(&p, &q, &k, &v, &go, sh, gamma);
        let eps = 1e-3f32;
        for idx in [0usize, 4, 7, 13] {
            for (buf, grad, which) in [
                (q.clone(), &dq, 0),
                (k.clone(), &dk, 1),
                (v.clone(), &dv, 2),
            ] {
                let mut plus = buf.clone();
                let mut minus = buf.clone();
                plus[idx] += eps;
                minus[idx] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grad[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "which={which} idx={idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        let sh = LayerShape::cube(1, 16, 4);
        let q = randn(sh.bh * sh.n * sh.dk, 20);
        let k = randn(sh.bh * sh.n * sh.dk, 21);
        // v constant 1 → every output row must be exactly 1 (weights sum to 1)
        let v = vec![1.0f32; sh.bh * sh.n * sh.dv];
        let o = softmax_fwd(&pool(), &q, &k, &v, sh, 0.5);
        for x in &o {
            assert!((x - 1.0).abs() < 1e-5, "row weight sum drifted: {x}");
        }
    }

    #[test]
    fn softmax_gradients_match_finite_differences() {
        let sh = LayerShape::cube(1, 4, 3);
        let q = randn(sh.bh * sh.n * sh.dk, 30);
        let k = randn(sh.bh * sh.n * sh.dk, 31);
        let v = randn(sh.bh * sh.n * sh.dv, 32);
        let go = randn(sh.bh * sh.n * sh.dv, 33);
        let scale = 0.7f32;
        let p = pool();
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            softmax_fwd(&p, q, k, v, sh, scale)
                .iter()
                .zip(&go)
                .map(|(o, g)| (*o as f64) * (*g as f64))
                .sum()
        };
        let (dq, dk, dv) = softmax_bwd(&p, &q, &k, &v, &go, sh, scale);
        let eps = 1e-3f32;
        for idx in [0usize, 3, 8, 11] {
            for which in 0..3 {
                let (buf, grad) = match which {
                    0 => (&q, &dq),
                    1 => (&k, &dk),
                    _ => (&v, &dv),
                };
                let mut plus = buf.clone();
                let mut minus = buf.clone();
                plus[idx] += eps;
                minus[idx] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grad[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "which={which} idx={idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn gated_scan_decays_old_context() {
        // with strong decay, o_t is dominated by the most recent (k,v)
        let sh = LayerShape::cube(1, 3, 2);
        let q = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let k = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let v = vec![1.0, 1.0, 2.0, 2.0, 4.0, 4.0];
        let o = la_scan_fwd(&pool(), &q, &k, &v, sh, 0.5);
        // t=2: 0.25·1 + 0.5·2 + 4 = 5.25
        assert!((o[4] - 5.25).abs() < 1e-6, "o[4] {}", o[4]);
        let o_plain = la_scan_fwd(&pool(), &q, &k, &v, sh, 1.0);
        assert!((o_plain[4] - 7.0).abs() < 1e-6);
    }
}
