//! Pure-Rust CPU kernels for causal attention layers, on flat `f32` slices.
//!
//! All kernels operate on row-major `(BH, N, D)` buffers (`BH` = batch ×
//! heads folded). Three algorithmic families, matching the paper's §4/§5
//! evaluation set:
//!
//! - **state scan** (`la_scan_*`) — the O(N·D²) two-pass recurrence: a
//!   forward scan over the running `D×D` state `S_t = γ·S_{t-1} + k_t vᵗ_t`
//!   for the forward/`dq` pass, and a mirrored *reverse* scan
//!   `R_t = q_t goᵗ_t + γ·R_{t+1}` for `dk`/`dv` — gradients are computed
//!   analytically, never by taping the forward (the O(N·D²)-residency trap
//!   the paper §4 eliminates). `γ = 1` is plain linear attention; `γ < 1`
//!   is the gated/decayed variant.
//! - **chunkwise** (`la_chunk_*`) — the inter/intra decomposition (Yang et
//!   al. 2023): per chunk of length `C`, one `q_t·S` inter-chunk term plus a
//!   local `C×C` causal quadratic intra-chunk term, then one state update.
//!   Identical math to the scan, but the hot loops touch `O(C·D)` data —
//!   the cache-friendly layout the GPU kernel tiles the same way.
//! - **quadratic baselines** — `la_quadratic_*` materializes the masked
//!   `(QKᵀ)V` product of the same softmax-free attention (the eager-baseline
//!   reference the sweep compares against), and `softmax_*` is standard
//!   causal softmax attention with a streaming row softmax.
//!
//! Gradients of the softmax-free forms, for `o_t = Σ_{s≤t} γ^{t-s}(q_t·k_s)
//! v_s`:
//!   `dq_t = S_t·go_t`, `dk_s = R_s·v_s`, `dv_s = Rᵗ_s·k_s`.

/// Shape of one layer call; `dk`/`dv` may differ (the LM appends a
/// normalizer channel to `v`).
#[derive(Debug, Clone, Copy)]
pub struct LayerShape {
    pub bh: usize,
    pub n: usize,
    pub dk: usize,
    pub dv: usize,
}

impl LayerShape {
    pub fn cube(bh: usize, n: usize, d: usize) -> Self {
        Self { bh, n, dk: d, dv: d }
    }
}

/// Causal linear attention, sequential state scan (decay `gamma`; 1.0 = none).
pub fn la_scan_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape, gamma: f32) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    let mut s = vec![0.0f32; dk * dv];
    for b in 0..bh {
        s.fill(0.0);
        for t in 0..n {
            let qr = &q[(b * n + t) * dk..][..dk];
            let kr = &k[(b * n + t) * dk..][..dk];
            let vr = &v[(b * n + t) * dv..][..dv];
            if gamma != 1.0 {
                for x in s.iter_mut() {
                    *x *= gamma;
                }
            }
            for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                let ki = kr[i];
                for (sx, vx) in srow.iter_mut().zip(vr) {
                    *sx += ki * vx;
                }
            }
            let orow = &mut o[(b * n + t) * dv..][..dv];
            for (i, srow) in s.chunks_exact(dv).enumerate() {
                let qi = qr[i];
                for (ox, sx) in orow.iter_mut().zip(srow) {
                    *ox += qi * sx;
                }
            }
        }
    }
    o
}

/// Backward of [`la_scan_fwd`]: analytical gradients via one forward state
/// scan (for `dq`) and one reverse scan (for `dk`, `dv`).
pub fn la_scan_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
    gamma: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    let mut s = vec![0.0f32; dk * dv];
    let mut r = vec![0.0f32; dk * dv];
    for b in 0..bh {
        // pass 1 (forward): S_t, dq_t = S_t · go_t
        s.fill(0.0);
        for t in 0..n {
            let kr = &k[(b * n + t) * dk..][..dk];
            let vr = &v[(b * n + t) * dv..][..dv];
            let gr = &go[(b * n + t) * dv..][..dv];
            if gamma != 1.0 {
                for x in s.iter_mut() {
                    *x *= gamma;
                }
            }
            for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                let ki = kr[i];
                for (sx, vx) in srow.iter_mut().zip(vr) {
                    *sx += ki * vx;
                }
            }
            let dqr = &mut dq[(b * n + t) * dk..][..dk];
            for (i, srow) in s.chunks_exact(dv).enumerate() {
                let mut acc = 0.0f32;
                for (sx, gx) in srow.iter().zip(gr) {
                    acc += sx * gx;
                }
                dqr[i] = acc;
            }
        }
        // pass 2 (reverse): R_t, dk_t = R_t · v_t, dv_t = Rᵗ_t · k_t
        r.fill(0.0);
        for t in (0..n).rev() {
            let qr = &q[(b * n + t) * dk..][..dk];
            let kr = &k[(b * n + t) * dk..][..dk];
            let vr = &v[(b * n + t) * dv..][..dv];
            let gr = &go[(b * n + t) * dv..][..dv];
            if gamma != 1.0 {
                for x in r.iter_mut() {
                    *x *= gamma;
                }
            }
            for (i, rrow) in r.chunks_exact_mut(dv).enumerate() {
                let qi = qr[i];
                for (rx, gx) in rrow.iter_mut().zip(gr) {
                    *rx += qi * gx;
                }
            }
            let dkr = &mut dkk[(b * n + t) * dk..][..dk];
            let dvr = &mut dvv[(b * n + t) * dv..][..dv];
            for (i, rrow) in r.chunks_exact(dv).enumerate() {
                let mut acc = 0.0f32;
                for (rx, vx) in rrow.iter().zip(vr.iter()) {
                    acc += rx * vx;
                }
                dkr[i] = acc;
                let ki = kr[i];
                for (dx, rx) in dvr.iter_mut().zip(rrow) {
                    *dx += ki * rx;
                }
            }
        }
    }
    (dq, dkk, dvv)
}

/// Chunkwise causal linear attention (inter/intra decomposition, no decay).
pub fn la_chunk_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape, chunk: usize) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let c = chunk.max(1);
    let mut o = vec![0.0f32; bh * n * dv];
    let mut s = vec![0.0f32; dk * dv];
    for b in 0..bh {
        s.fill(0.0);
        let mut c0 = 0;
        while c0 < n {
            let ce = (c0 + c).min(n);
            for t in c0..ce {
                let qr = &q[(b * n + t) * dk..][..dk];
                let orow = &mut o[(b * n + t) * dv..][..dv];
                // inter-chunk: q_t · S (state of all previous chunks)
                for (i, srow) in s.chunks_exact(dv).enumerate() {
                    let qi = qr[i];
                    for (ox, sx) in orow.iter_mut().zip(srow) {
                        *ox += qi * sx;
                    }
                }
                // intra-chunk: local causal quadratic
                for sidx in c0..=t {
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    let vr = &v[(b * n + sidx) * dv..][..dv];
                    let mut a = 0.0f32;
                    for (qx, kx) in qr.iter().zip(kr) {
                        a += qx * kx;
                    }
                    for (ox, vx) in orow.iter_mut().zip(vr) {
                        *ox += a * vx;
                    }
                }
            }
            // state update: S += Σ_chunk k_t ⊗ v_t
            for t in c0..ce {
                let kr = &k[(b * n + t) * dk..][..dk];
                let vr = &v[(b * n + t) * dv..][..dv];
                for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                    let ki = kr[i];
                    for (sx, vx) in srow.iter_mut().zip(vr) {
                        *sx += ki * vx;
                    }
                }
            }
            c0 = ce;
        }
    }
    o
}

/// Backward of [`la_chunk_fwd`]: same inter/intra split, forward pass over
/// chunks for `dq`, reverse pass for `dk`/`dv`.
pub fn la_chunk_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
    chunk: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let c = chunk.max(1);
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    let mut s = vec![0.0f32; dk * dv];
    let mut r = vec![0.0f32; dk * dv];
    for b in 0..bh {
        // forward over chunks: dq_t = S_pre·go_t + Σ_{s≤t, same chunk} (go_t·v_s) k_s
        s.fill(0.0);
        let mut c0 = 0;
        while c0 < n {
            let ce = (c0 + c).min(n);
            for t in c0..ce {
                let gr = &go[(b * n + t) * dv..][..dv];
                let dqr = &mut dq[(b * n + t) * dk..][..dk];
                for (i, srow) in s.chunks_exact(dv).enumerate() {
                    let mut acc = 0.0f32;
                    for (sx, gx) in srow.iter().zip(gr) {
                        acc += sx * gx;
                    }
                    dqr[i] = acc;
                }
                for sidx in c0..=t {
                    let kr = &k[(b * n + sidx) * dk..][..dk];
                    let vr = &v[(b * n + sidx) * dv..][..dv];
                    let mut gv = 0.0f32;
                    for (gx, vx) in gr.iter().zip(vr) {
                        gv += gx * vx;
                    }
                    for (dx, kx) in dqr.iter_mut().zip(kr) {
                        *dx += gv * kx;
                    }
                }
            }
            for t in c0..ce {
                let kr = &k[(b * n + t) * dk..][..dk];
                let vr = &v[(b * n + t) * dv..][..dv];
                for (i, srow) in s.chunks_exact_mut(dv).enumerate() {
                    let ki = kr[i];
                    for (sx, vx) in srow.iter_mut().zip(vr) {
                        *sx += ki * vx;
                    }
                }
            }
            c0 = ce;
        }
        // reverse over chunks: dk/dv from R_post + intra terms
        r.fill(0.0);
        let n_chunks = (n + c - 1) / c;
        for ci in (0..n_chunks).rev() {
            let c0 = ci * c;
            let ce = (c0 + c).min(n);
            for t in c0..ce {
                let kr = &k[(b * n + t) * dk..][..dk];
                let vr = &v[(b * n + t) * dv..][..dv];
                let dkr = &mut dkk[(b * n + t) * dk..][..dk];
                let dvr = &mut dvv[(b * n + t) * dv..][..dv];
                // inter: later chunks, via R_post
                for (i, rrow) in r.chunks_exact(dv).enumerate() {
                    let mut acc = 0.0f32;
                    for (rx, vx) in rrow.iter().zip(vr.iter()) {
                        acc += rx * vx;
                    }
                    dkr[i] = acc;
                    let ki = kr[i];
                    for (dx, rx) in dvr.iter_mut().zip(rrow) {
                        *dx += ki * rx;
                    }
                }
                // intra: s ≥ t within this chunk
                for sidx in t..ce {
                    let qr = &q[(b * n + sidx) * dk..][..dk];
                    let gr = &go[(b * n + sidx) * dv..][..dv];
                    let mut gv = 0.0f32;
                    for (gx, vx) in gr.iter().zip(vr.iter()) {
                        gv += gx * vx;
                    }
                    let mut a = 0.0f32;
                    for (qx, kx) in qr.iter().zip(kr.iter()) {
                        a += qx * kx;
                    }
                    for (dx, qx) in dkr.iter_mut().zip(qr) {
                        *dx += gv * qx;
                    }
                    for (dx, gx) in dvr.iter_mut().zip(gr) {
                        *dx += a * gx;
                    }
                }
            }
            for t in c0..ce {
                let qr = &q[(b * n + t) * dk..][..dk];
                let gr = &go[(b * n + t) * dv..][..dv];
                for (i, rrow) in r.chunks_exact_mut(dv).enumerate() {
                    let qi = qr[i];
                    for (rx, gx) in rrow.iter_mut().zip(gr) {
                        *rx += qi * gx;
                    }
                }
            }
        }
    }
    (dq, dkk, dvv)
}

/// Quadratic-time reference of the same softmax-free attention: the masked
/// `(QKᵀ)V` product, materialized pairwise (the eager-baseline access
/// pattern). Output is bit-comparable to the scan/chunk forms up to f32
/// reassociation.
pub fn la_quadratic_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    for b in 0..bh {
        for t in 0..n {
            let qr = &q[(b * n + t) * dk..][..dk];
            let orow = &mut o[(b * n + t) * dv..][..dv];
            for sidx in 0..=t {
                let kr = &k[(b * n + sidx) * dk..][..dk];
                let vr = &v[(b * n + sidx) * dv..][..dv];
                let mut a = 0.0f32;
                for (qx, kx) in qr.iter().zip(kr) {
                    a += qx * kx;
                }
                for (ox, vx) in orow.iter_mut().zip(vr) {
                    *ox += a * vx;
                }
            }
        }
    }
    o
}

/// Backward of [`la_quadratic_fwd`], pairwise.
pub fn la_quadratic_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    for b in 0..bh {
        for t in 0..n {
            let qr = &q[(b * n + t) * dk..][..dk];
            let gr = &go[(b * n + t) * dv..][..dv];
            for sidx in 0..=t {
                let kr = &k[(b * n + sidx) * dk..][..dk];
                let vr = &v[(b * n + sidx) * dv..][..dv];
                let mut gv = 0.0f32;
                for (gx, vx) in gr.iter().zip(vr) {
                    gv += gx * vx;
                }
                let mut a = 0.0f32;
                for (qx, kx) in qr.iter().zip(kr) {
                    a += qx * kx;
                }
                {
                    let dqr = &mut dq[(b * n + t) * dk..][..dk];
                    for (dx, kx) in dqr.iter_mut().zip(kr) {
                        *dx += gv * kx;
                    }
                }
                {
                    let dkr = &mut dkk[(b * n + sidx) * dk..][..dk];
                    for (dx, qx) in dkr.iter_mut().zip(qr) {
                        *dx += gv * qx;
                    }
                }
                {
                    let dvr = &mut dvv[(b * n + sidx) * dv..][..dv];
                    for (dx, gx) in dvr.iter_mut().zip(gr) {
                        *dx += a * gx;
                    }
                }
            }
        }
    }
    (dq, dkk, dvv)
}

/// Standard causal softmax attention with a streaming row softmax
/// (scores scaled by `scale`, typically `1/sqrt(dk)`).
pub fn softmax_fwd(q: &[f32], k: &[f32], v: &[f32], sh: LayerShape, scale: f32) -> Vec<f32> {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut o = vec![0.0f32; bh * n * dv];
    let mut scores = vec![0.0f32; n];
    for b in 0..bh {
        for t in 0..n {
            let qr = &q[(b * n + t) * dk..][..dk];
            let mut m = f32::NEG_INFINITY;
            for sidx in 0..=t {
                let kr = &k[(b * n + sidx) * dk..][..dk];
                let mut a = 0.0f32;
                for (qx, kx) in qr.iter().zip(kr) {
                    a += qx * kx;
                }
                let a = a * scale;
                scores[sidx] = a;
                m = m.max(a);
            }
            let mut z = 0.0f32;
            for sc in scores[..=t].iter_mut() {
                *sc = (*sc - m).exp();
                z += *sc;
            }
            let inv = 1.0 / z;
            let orow = &mut o[(b * n + t) * dv..][..dv];
            for sidx in 0..=t {
                let w = scores[sidx] * inv;
                let vr = &v[(b * n + sidx) * dv..][..dv];
                for (ox, vx) in orow.iter_mut().zip(vr) {
                    *ox += w * vx;
                }
            }
        }
    }
    o
}

/// Backward of [`softmax_fwd`]: recomputes each probability row, then applies
/// the standard softmax-attention vjp.
pub fn softmax_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
    sh: LayerShape,
    scale: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let LayerShape { bh, n, dk, dv } = sh;
    let mut dq = vec![0.0f32; bh * n * dk];
    let mut dkk = vec![0.0f32; bh * n * dk];
    let mut dvv = vec![0.0f32; bh * n * dv];
    let mut p = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    for b in 0..bh {
        for t in 0..n {
            let qr = &q[(b * n + t) * dk..][..dk];
            let gr = &go[(b * n + t) * dv..][..dv];
            // recompute the probability row
            let mut m = f32::NEG_INFINITY;
            for sidx in 0..=t {
                let kr = &k[(b * n + sidx) * dk..][..dk];
                let mut a = 0.0f32;
                for (qx, kx) in qr.iter().zip(kr) {
                    a += qx * kx;
                }
                let a = a * scale;
                p[sidx] = a;
                m = m.max(a);
            }
            let mut z = 0.0f32;
            for sc in p[..=t].iter_mut() {
                *sc = (*sc - m).exp();
                z += *sc;
            }
            let inv = 1.0 / z;
            // g_s = go_t · v_s ; c = Σ p_s g_s
            let mut csum = 0.0f32;
            for sidx in 0..=t {
                p[sidx] *= inv;
                let vr = &v[(b * n + sidx) * dv..][..dv];
                let mut gv = 0.0f32;
                for (gx, vx) in gr.iter().zip(vr) {
                    gv += gx * vx;
                }
                g[sidx] = gv;
                csum += p[sidx] * gv;
            }
            // dv_s += p_s go_t ; dscore_s = p_s (g_s − c)
            let dqr_start = (b * n + t) * dk;
            for sidx in 0..=t {
                let ds = p[sidx] * (g[sidx] - csum) * scale;
                {
                    let dvr = &mut dvv[(b * n + sidx) * dv..][..dv];
                    let w = p[sidx];
                    for (dx, gx) in dvr.iter_mut().zip(gr) {
                        *dx += w * gx;
                    }
                }
                let kr = &k[(b * n + sidx) * dk..][..dk];
                {
                    let dqr = &mut dq[dqr_start..][..dk];
                    for (dx, kx) in dqr.iter_mut().zip(kr) {
                        *dx += ds * kx;
                    }
                }
                {
                    let dkr = &mut dkk[(b * n + sidx) * dk..][..dk];
                    for (dx, qx) in dkr.iter_mut().zip(qr) {
                        *dx += ds * qx;
                    }
                }
            }
        }
    }
    (dq, dkk, dvv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        match Tensor::randn(vec![n], seed) {
            Tensor::F32 { data, .. } => data,
            _ => unreachable!(),
        }
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn scan_chunk_quadratic_agree_on_forward() {
        let sh = LayerShape::cube(2, 33, 8);
        let q = randn(sh.bh * sh.n * sh.dk, 1);
        let k = randn(sh.bh * sh.n * sh.dk, 2);
        let v = randn(sh.bh * sh.n * sh.dv, 3);
        let a = la_scan_fwd(&q, &k, &v, sh, 1.0);
        let b = la_chunk_fwd(&q, &k, &v, sh, 7);
        let c = la_quadratic_fwd(&q, &k, &v, sh);
        assert!(max_abs_diff(&a, &c) < 1e-3, "scan vs quadratic {}", max_abs_diff(&a, &c));
        assert!(max_abs_diff(&b, &c) < 1e-3, "chunk vs quadratic {}", max_abs_diff(&b, &c));
    }

    #[test]
    fn scan_chunk_quadratic_agree_on_backward() {
        let sh = LayerShape::cube(2, 21, 6);
        let q = randn(sh.bh * sh.n * sh.dk, 4);
        let k = randn(sh.bh * sh.n * sh.dk, 5);
        let v = randn(sh.bh * sh.n * sh.dv, 6);
        let go = randn(sh.bh * sh.n * sh.dv, 7);
        let (aq, ak, av) = la_scan_bwd(&q, &k, &v, &go, sh, 1.0);
        let (bq, bk, bv) = la_chunk_bwd(&q, &k, &v, &go, sh, 5);
        let (cq, ck, cv) = la_quadratic_bwd(&q, &k, &v, &go, sh);
        for (x, y) in [(&aq, &cq), (&ak, &ck), (&av, &cv), (&bq, &cq), (&bk, &ck), (&bv, &cv)] {
            assert!(max_abs_diff(x, y) < 1e-3, "bwd mismatch {}", max_abs_diff(x, y));
        }
    }

    #[test]
    fn scan_gradients_match_finite_differences() {
        // tiny shape so central differences are cheap and well-conditioned
        let sh = LayerShape::cube(1, 5, 3);
        let q = randn(sh.bh * sh.n * sh.dk, 10);
        let k = randn(sh.bh * sh.n * sh.dk, 11);
        let v = randn(sh.bh * sh.n * sh.dv, 12);
        let go = randn(sh.bh * sh.n * sh.dv, 13);
        let gamma = 0.9f32;
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            la_scan_fwd(q, k, v, sh, gamma)
                .iter()
                .zip(&go)
                .map(|(o, g)| (*o as f64) * (*g as f64))
                .sum()
        };
        let (dq, dk, dv) = la_scan_bwd(&q, &k, &v, &go, sh, gamma);
        let eps = 1e-3f32;
        for idx in [0usize, 4, 7, 13] {
            for (buf, grad, which) in [
                (q.clone(), &dq, 0),
                (k.clone(), &dk, 1),
                (v.clone(), &dv, 2),
            ] {
                let mut plus = buf.clone();
                let mut minus = buf.clone();
                plus[idx] += eps;
                minus[idx] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grad[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "which={which} idx={idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        let sh = LayerShape::cube(1, 16, 4);
        let q = randn(sh.bh * sh.n * sh.dk, 20);
        let k = randn(sh.bh * sh.n * sh.dk, 21);
        // v constant 1 → every output row must be exactly 1 (weights sum to 1)
        let v = vec![1.0f32; sh.bh * sh.n * sh.dv];
        let o = softmax_fwd(&q, &k, &v, sh, 0.5);
        for x in &o {
            assert!((x - 1.0).abs() < 1e-5, "row weight sum drifted: {x}");
        }
    }

    #[test]
    fn softmax_gradients_match_finite_differences() {
        let sh = LayerShape::cube(1, 4, 3);
        let q = randn(sh.bh * sh.n * sh.dk, 30);
        let k = randn(sh.bh * sh.n * sh.dk, 31);
        let v = randn(sh.bh * sh.n * sh.dv, 32);
        let go = randn(sh.bh * sh.n * sh.dv, 33);
        let scale = 0.7f32;
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            softmax_fwd(q, k, v, sh, scale)
                .iter()
                .zip(&go)
                .map(|(o, g)| (*o as f64) * (*g as f64))
                .sum()
        };
        let (dq, dk, dv) = softmax_bwd(&q, &k, &v, &go, sh, scale);
        let eps = 1e-3f32;
        for idx in [0usize, 3, 8, 11] {
            for which in 0..3 {
                let (buf, grad) = match which {
                    0 => (&q, &dq),
                    1 => (&k, &dk),
                    _ => (&v, &dv),
                };
                let mut plus = buf.clone();
                let mut minus = buf.clone();
                plus[idx] += eps;
                minus[idx] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grad[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "which={which} idx={idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn gated_scan_decays_old_context() {
        // with strong decay, o_t is dominated by the most recent (k,v)
        let sh = LayerShape::cube(1, 3, 2);
        let q = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let k = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let v = vec![1.0, 1.0, 2.0, 2.0, 4.0, 4.0];
        let o = la_scan_fwd(&q, &k, &v, sh, 0.5);
        // t=2: 0.25·1 + 0.5·2 + 4 = 5.25
        assert!((o[4] - 5.25).abs() < 1e-6, "o[4] {}", o[4]);
        let o_plain = la_scan_fwd(&q, &k, &v, sh, 1.0);
        assert!((o_plain[4] - 7.0).abs() < 1e-6);
    }
}
