//! A tiny scoped thread pool — `std::thread` only, no rayon.
//!
//! Every native kernel is embarrassingly parallel across the folded
//! batch×heads (`BH`) dimension (and, for the chunkwise form, across
//! `(bh, chunk)` tiles once the per-chunk states are materialized). The pool
//! turns that structure into wall-clock speedup with three primitives:
//!
//! - [`ThreadPool::run`] — indexed tasks drained from a shared atomic counter;
//! - [`ThreadPool::run_chunks`] / [`ThreadPool::run_chunks3`] — safe
//!   fixed-stride windows of one (or three) output buffers, distributed as
//!   contiguous stripes;
//! - [`ThreadPool::run_stripes`] — contiguous row-block partition for the
//!   dense GEMM wrappers.
//!
//! Task decomposition is *independent of the worker count*: task `i` always
//! performs the same arithmetic, so kernel results do not depend on
//! `RUST_PALLAS_THREADS` — bitwise on the default build; within last-bit FMA
//! rounding under `--features simd`, where stripe boundaries move rows
//! between the fused and scalar tile paths (the invariance test pins 1e-5).
//! Workers are spawned per call via [`std::thread::scope`]; at kernel
//! granularity (≥ 100 µs of work per call) the ~10 µs spawn cost is noise,
//! and scoped spawning keeps the pool free of `unsafe` lifetime erasure.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Worker-count handle. Cheap to copy; holds no threads between calls.
#[derive(Debug, Clone, Copy)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// Pool with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Pool sized from `RUST_PALLAS_THREADS`; `0`, unset, or unparseable
    /// means auto-detect ([`std::thread::available_parallelism`]).
    pub fn from_env() -> Self {
        let n = std::env::var("RUST_PALLAS_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or(0);
        if n == 0 {
            Self::new(Self::available())
        } else {
            Self::new(n)
        }
    }

    /// Host parallelism (1 if undetectable).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// The process-wide pool, sized once from the environment.
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(ThreadPool::from_env)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0) … f(tasks-1)`, drained from a shared counter across the
    /// pool. Tasks must touch disjoint data (or only `&` data).
    pub fn run<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let workers = self.threads.min(tasks);
        if workers <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(|| drain(&next, tasks, &f));
            }
            drain(&next, tasks, &f);
        });
    }

    /// Split `buf` into `buf.len() / chunk` consecutive windows of `chunk`
    /// elements and run `f(window_index, window)` for each, in parallel.
    /// `buf.len()` must be a multiple of `chunk`.
    pub fn run_chunks<F>(&self, buf: &mut [f32], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        if buf.is_empty() {
            return;
        }
        debug_assert!(chunk > 0 && buf.len() % chunk == 0);
        let tasks = buf.len() / chunk;
        let workers = self.threads.min(tasks);
        if workers <= 1 {
            for (i, w) in buf.chunks_mut(chunk).enumerate() {
                f(i, w);
            }
            return;
        }
        let per = tasks.div_ceil(workers);
        std::thread::scope(|s| {
            for (slab_i, slab) in buf.chunks_mut(per * chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, w) in slab.chunks_mut(chunk).enumerate() {
                        f(slab_i * per + j, w);
                    }
                });
            }
        });
    }

    /// Three-buffer variant of [`run_chunks`](Self::run_chunks): window `i`
    /// of each buffer is handed to the same task (the kernel backward passes
    /// write `dq`/`dk`/`dv` for one `bh` slice together).
    #[allow(clippy::too_many_arguments)]
    pub fn run_chunks3<F>(
        &self,
        a: &mut [f32],
        ca: usize,
        b: &mut [f32],
        cb: usize,
        c: &mut [f32],
        cc: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        if a.is_empty() && b.is_empty() && c.is_empty() {
            return;
        }
        // hard asserts: a silent length mismatch would skip trailing windows
        assert!(ca > 0 && cb > 0 && cc > 0, "run_chunks3: zero stride");
        let tasks = a.len() / ca;
        assert!(
            a.len() == tasks * ca && b.len() == tasks * cb && c.len() == tasks * cc,
            "run_chunks3: buffers disagree on task count ({} / {} / {} windows)",
            a.len() / ca,
            b.len() / cb,
            c.len() / cc,
        );
        let workers = self.threads.min(tasks);
        if workers <= 1 {
            for i in 0..tasks {
                f(i, &mut a[i * ca..][..ca], &mut b[i * cb..][..cb], &mut c[i * cc..][..cc]);
            }
            return;
        }
        let per = tasks.div_ceil(workers);
        std::thread::scope(|s| {
            let mut ia = a.chunks_mut(per * ca);
            let mut ib = b.chunks_mut(per * cb);
            let mut ic = c.chunks_mut(per * cc);
            let mut base = 0usize;
            while let (Some(sa), Some(sb), Some(sc)) = (ia.next(), ib.next(), ic.next()) {
                let f = &f;
                s.spawn(move || {
                    for (j, ((wa, wb), wc)) in sa
                        .chunks_mut(ca)
                        .zip(sb.chunks_mut(cb))
                        .zip(sc.chunks_mut(cc))
                        .enumerate()
                    {
                        f(base + j, wa, wb, wc);
                    }
                });
                base += per;
            }
        });
    }

    /// Partition `buf` (rows of `row` elements) into at most `threads`
    /// contiguous row stripes and run `f(first_row, stripe)` per stripe —
    /// the row-parallel GEMM entry point.
    pub fn run_stripes<F>(&self, buf: &mut [f32], row: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        if buf.is_empty() {
            return;
        }
        debug_assert!(row > 0 && buf.len() % row == 0);
        let rows = buf.len() / row;
        let workers = self.threads.min(rows);
        if workers <= 1 {
            if !buf.is_empty() {
                f(0, buf);
            }
            return;
        }
        let per = rows.div_ceil(workers);
        std::thread::scope(|s| {
            for (i, stripe) in buf.chunks_mut(per * row).enumerate() {
                let f = &f;
                s.spawn(move || f(i * per, stripe));
            }
        });
    }
}

fn drain<F: Fn(usize) + Sync>(next: &AtomicUsize, tasks: usize, f: &F) {
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= tasks {
            return;
        }
        f(i);
    }
}

/// Shared view over one mutable buffer for tasks that write disjoint windows
/// at non-uniform offsets (the per-`(bh, chunk)` output tiles, whose last
/// chunk may be ragged). The [`run_chunks`](ThreadPool::run_chunks) family
/// covers the uniform-stride cases safely; this is the escape hatch.
pub struct SliceParts<'a> {
    ptr: *mut f32,
    len: usize,
    _life: PhantomData<&'a mut [f32]>,
}

// SAFETY: windows handed out by `window` are required (by its contract) to be
// disjoint across concurrent tasks, so sharing the base pointer is sound.
unsafe impl Send for SliceParts<'_> {}
unsafe impl Sync for SliceParts<'_> {}

impl<'a> SliceParts<'a> {
    pub fn new(buf: &'a mut [f32]) -> Self {
        Self { ptr: buf.as_mut_ptr(), len: buf.len(), _life: PhantomData }
    }

    /// Window `[offset, offset + len)` of the underlying buffer.
    ///
    /// # Safety
    /// Concurrent callers must take non-overlapping windows. Bounds are
    /// checked; disjointness is the caller's contract (one window per task
    /// index, as in the kernel tilings).
    pub unsafe fn window(&self, offset: usize, len: usize) -> &mut [f32] {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= self.len),
            "SliceParts window [{offset}, {offset}+{len}) out of bounds (len {})",
            self.len
        );
        std::slice::from_raw_parts_mut(self.ptr.add(offset), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_visits_every_task_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU32> = (0..37).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn run_chunks_covers_buffer_with_correct_indices() {
        for threads in [1, 2, 5] {
            let pool = ThreadPool::new(threads);
            let mut buf = vec![0.0f32; 6 * 4];
            pool.run_chunks(&mut buf, 4, |i, w| {
                for x in w.iter_mut() {
                    *x = i as f32 + 1.0;
                }
            });
            for (i, x) in buf.iter().enumerate() {
                assert_eq!(*x, (i / 4) as f32 + 1.0, "elem {i} (threads {threads})");
            }
        }
    }

    #[test]
    fn run_chunks3_zips_windows_of_different_strides() {
        let pool = ThreadPool::new(3);
        let (ca, cb, cc) = (2, 3, 5);
        let tasks = 7;
        let mut a = vec![0.0f32; tasks * ca];
        let mut b = vec![0.0f32; tasks * cb];
        let mut c = vec![0.0f32; tasks * cc];
        pool.run_chunks3(&mut a, ca, &mut b, cb, &mut c, cc, |i, wa, wb, wc| {
            assert_eq!((wa.len(), wb.len(), wc.len()), (ca, cb, cc));
            wa.fill(i as f32);
            wb.fill(i as f32 + 0.25);
            wc.fill(i as f32 + 0.5);
        });
        for i in 0..tasks {
            assert!(a[i * ca..][..ca].iter().all(|&x| x == i as f32));
            assert!(b[i * cb..][..cb].iter().all(|&x| x == i as f32 + 0.25));
            assert!(c[i * cc..][..cc].iter().all(|&x| x == i as f32 + 0.5));
        }
    }

    #[test]
    fn run_stripes_partitions_rows() {
        let pool = ThreadPool::new(3);
        let mut buf = vec![0.0f32; 10 * 2];
        pool.run_stripes(&mut buf, 2, |first_row, stripe| {
            for (j, row) in stripe.chunks_mut(2).enumerate() {
                row.fill((first_row + j) as f32);
            }
        });
        for (r, row) in buf.chunks(2).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn slice_parts_disjoint_windows() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0.0f32; 23];
        // ragged windows: 6, 6, 6, 5
        let bounds = [(0usize, 6usize), (6, 6), (12, 6), (18, 5)];
        let parts = SliceParts::new(&mut buf);
        pool.run(bounds.len(), |i| {
            let (off, len) = bounds[i];
            let w = unsafe { parts.window(off, len) };
            w.fill(i as f32 + 1.0);
        });
        assert!(buf[..6].iter().all(|&x| x == 1.0));
        assert!(buf[18..].iter().all(|&x| x == 4.0));
    }

    #[test]
    fn env_zero_means_auto() {
        // Constructors only — reading the real env var here would race other
        // tests; from_env parsing of "0"/garbage is covered by the clamp.
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert!(ThreadPool::available() >= 1);
        assert!(ThreadPool::global().threads() >= 1);
    }
}
